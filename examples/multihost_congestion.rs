//! Congestion study (paper §1: "each CXL switch can cause congestion
//! when multiple hosts use the switch at the same time"): scale the
//! number of hosts sharing one switch and watch the congestion delay
//! per host grow super-linearly.
//!
//!     cargo run --release --offline --example multihost_congestion

use cxlmemsim::coordinator::SimConfig;
use cxlmemsim::multihost;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::util::cli::Args;
use cxlmemsim::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = SimConfig::default();
    cfg.scale = args.f64("scale", 0.005);
    cfg.cache_scale = args.u64("cache-scale", 32);
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = AnalyzerBackend::parse(&b).expect("--backend pjrt|native");
    }
    let topo = Topology::resolve(&args.str("topo", "wide"))?;
    let wl_name = args.str("workload", "stream");

    println!(
        "congestion study: `{}` on `{}` (every host behind the same switch)\n",
        wl_name, topo.name
    );
    let mut rows = Vec::new();
    for hosts in [1usize, 2, 4, 6, 8] {
        let workloads: Vec<_> = (0..hosts)
            .map(|i| workload::by_name(&wl_name, cfg.scale, cfg.seed + i as u64).unwrap())
            .collect();
        let rep = multihost::run_shared(&topo, &cfg, workloads)?;
        let per_epoch_cong = rep.cong_delay_ns / rep.epochs.max(1) as f64;
        let per_epoch_bw = rep.bwd_delay_ns / rep.epochs.max(1) as f64;
        rows.push(vec![
            hosts.to_string(),
            rep.epochs.to_string(),
            format!("{:.3}", per_epoch_cong / 1e3),
            format!("{:.3}", per_epoch_bw / 1e3),
            format!("{:.3}x", rep.mean_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Hosts", "Epochs", "Cong/epoch (µs)", "BW/epoch (µs)", "Mean slowdown"],
            &rows
        )
    );
    println!("\nexpected shape: congestion/epoch grows super-linearly with hosts;");
    println!("the paper's Figure-1 discussion predicts exactly this switch-sharing penalty.");
    Ok(())
}

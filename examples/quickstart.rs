//! Quickstart: simulate one workload on the paper's Figure-1 topology
//! and print the per-pool / per-delay-class breakdown.
//!
//!     cargo run --release --offline --example quickstart
//!
//! Flags: --workload W --topo T --scale F --backend pjrt|native

use cxlmemsim::prelude::*;
use cxlmemsim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let topo = Topology::resolve(&args.str("topo", "fig1"))?;
    println!("{}", topo.describe());

    let mut cfg = SimConfig::default();
    cfg.scale = args.f64("scale", 0.05);
    cfg.cache_scale = args.u64("cache-scale", 8);
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = AnalyzerBackend::parse(&b).expect("--backend pjrt|native");
    }

    let wl = args.str("workload", "mcf_like");
    let mut sim = Coordinator::new(topo, cfg)?;
    let report = sim.run_workload(&wl)?;
    print!("{}", report.summary());

    println!("\ndelay breakdown:");
    println!(
        "  latency    {:>10.3} ms  (paper: #ops x (pool latency - local latency))",
        report.lat_delay_ns / 1e6
    );
    println!(
        "  congestion {:>10.3} ms  (events within a switch STT window)",
        report.cong_delay_ns / 1e6
    );
    println!(
        "  bandwidth  {:>10.3} ms  (observed bandwidth above switch capacity)",
        report.bwd_delay_ns / 1e6
    );
    Ok(())
}

//! Procurement study (paper §1 / §5: "allows data-center operators to
//! evaluate potential topologies before procurement"): sweep the
//! builtin topologies with three representative workloads and rank
//! them by simulated slowdown.
//!
//!     cargo run --release --offline --example topology_sweep

use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = SimConfig::default();
    cfg.scale = args.f64("scale", 0.01);
    cfg.cache_scale = args.u64("cache-scale", 16);
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = AnalyzerBackend::parse(&b).expect("--backend pjrt|native");
    }

    let workloads = ["stream", "mcf_like", "zipfian"];
    let topos = ["direct", "fig1", "fig2", "deep", "wide", "pooled"];

    let mut rows = Vec::new();
    let mut ranking: Vec<(String, f64)> = Vec::new();
    for topo_name in topos {
        let topo = Topology::resolve(topo_name)?;
        let mut slowdowns = Vec::new();
        for wl in workloads {
            let mut sim = Coordinator::new(topo.clone(), cfg.clone())?;
            let rep = sim.run_workload(wl)?;
            slowdowns.push(rep.sim_slowdown());
            rows.push(vec![
                topo_name.to_string(),
                wl.to_string(),
                format!("{:.3}", rep.native_ns / 1e6),
                format!("{:.3}", rep.simulated_ns / 1e6),
                format!("{:.3}x", rep.sim_slowdown()),
                format!("{:.1}%", rep.cong_delay_ns / rep.delay_ns.max(1e-9) * 100.0),
                format!("{:.1}%", rep.bwd_delay_ns / rep.delay_ns.max(1e-9) * 100.0),
            ]);
        }
        let geo = (slowdowns.iter().map(|s| s.ln()).sum::<f64>() / slowdowns.len() as f64).exp();
        ranking.push((topo_name.to_string(), geo));
    }
    println!(
        "{}",
        markdown_table(
            &["Topology", "Workload", "Native(ms)", "Sim(ms)", "Slowdown", "Cong%", "BW%"],
            &rows
        )
    );
    ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nprocurement ranking (geomean slowdown, lower is better):");
    for (i, (name, geo)) in ranking.iter().enumerate() {
        println!("  {}. {name:8} {geo:.3}x", i + 1);
    }
    Ok(())
}

//! End-to-end driver: reproduce the paper's Table 1 on this machine.
//!
//! Runs all seven benchmarks (five allocation microbenchmarks plus the
//! mcf/wrf twins) three ways — native, detailed (gem5-like), CXLMemSim —
//! through the full stack (workload engine → cache hierarchy → alloc
//! tracker → epoch binning → AOT timing analyzer via PJRT) and prints
//! the same rows the paper reports, plus the slowdown factors.
//!
//!     cargo run --release --offline --example table1 -- --scale 0.02
//!
//! `--backend native` swaps the analyzer to the pure-rust mirror;
//! `--skip-detailed` drops the slow baseline column.

use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::gem5like::DetailedSim;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::{markdown_table, time_once};
use cxlmemsim::util::cli::Args;
use cxlmemsim::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = SimConfig::default();
    cfg.scale = args.f64("scale", 0.02);
    cfg.cache_scale = args.u64("cache-scale", 1);
    cfg.sample_period = args.u64("sample-period", 1) as u32;
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = AnalyzerBackend::parse(&b).expect("--backend pjrt|native");
    } else {
        cfg.backend = AnalyzerBackend::Pjrt; // the shipped path
    }
    let topo = Topology::resolve(&args.str("topo", "fig2"))?;
    let skip_detailed = args.bool("skip-detailed");

    println!(
        "# Table 1 (paper §4): topology `{}`, scale {}, backend {:?}\n",
        topo.name, cfg.scale, cfg.backend
    );

    let mut rows = Vec::new();
    let mut geo_sim = 0.0f64;
    let mut geo_det = 0.0f64;
    let mut n_det = 0u32;

    for wl_name in TABLE1_WORKLOADS {
        eprintln!("[table1] {wl_name} ...");
        // --- native: generate the program's events, nothing else ----
        let mut wl = workload::by_name(wl_name, cfg.scale, cfg.seed).unwrap();
        let (_, native_wall) = time_once(|| while wl.next_event().is_some() {});

        // --- detailed event-driven baseline (gem5 substitute) -------
        let det_wall = if skip_detailed {
            None
        } else {
            let mut det = DetailedSim::new(topo.clone(), cfg.cache_scale, cfg.policy.clone());
            let mut wl = workload::by_name(wl_name, cfg.scale, cfg.seed).unwrap();
            Some(det.run(wl.as_mut()).wall_s)
        };

        // --- CXLMemSim through the full three-layer stack ------------
        let mut sim = Coordinator::new(topo.clone(), cfg.clone())?;
        let rep = sim.run_workload(wl_name)?;

        let sim_over = rep.wall_s / native_wall;
        geo_sim += sim_over.ln();
        if let Some(d) = det_wall {
            geo_det += (d / native_wall).ln();
            n_det += 1;
        }
        rows.push(vec![
            wl_name.to_string(),
            format!("{native_wall:.4}"),
            det_wall.map(|d| format!("{d:.3}")).unwrap_or("-".into()),
            format!("{:.3}", rep.wall_s),
            det_wall
                .map(|d| format!("{:.1}x", d / native_wall))
                .unwrap_or("-".into()),
            format!("{sim_over:.1}x"),
            format!("{:.3}x", rep.sim_slowdown()),
        ]);
    }

    println!(
        "{}",
        markdown_table(
            &[
                "Benchmark",
                "Native (s)",
                "Detailed (s)",
                "CXLMemSim (s)",
                "Detailed/Nat",
                "CXLMemSim/Nat",
                "SimSlowdown"
            ],
            &rows
        )
    );
    println!(
        "\ngeomean tool overhead: CXLMemSim {:.1}x native{}",
        (geo_sim / TABLE1_WORKLOADS.len() as f64).exp(),
        if n_det > 0 {
            format!(
                ", detailed {:.1}x native (CXLMemSim is {:.1}x faster than detailed)",
                (geo_det / n_det as f64).exp(),
                ((geo_det / n_det as f64) - (geo_sim / TABLE1_WORKLOADS.len() as f64)).exp()
            )
        } else {
            String::new()
        }
    );
    println!("(paper: CXLMemSim 41.06x native across all rows, ~73x faster than gem5)");
    Ok(())
}

//! Policy research demo (paper §1: "comparison of software and
//! hardware memory prefetching and migration ... cache-line and page
//! memory management"): compare placement policies and the hotness
//! migration policy on a skewed workload.
//!
//!     cargo run --release --offline --example policy_compare

use cxlmemsim::alloctrack::PolicyKind;
use cxlmemsim::policy::PolicySpec;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let base = {
        let mut cfg = SimConfig::default();
        cfg.scale = args.f64("scale", 0.01);
        cfg.cache_scale = args.u64("cache-scale", 16);
        if let Some(b) = args.opt_str("backend") {
            cfg.backend = AnalyzerBackend::parse(&b).expect("--backend pjrt|native");
        }
        cfg
    };
    let topo = Topology::resolve(&args.str("topo", "fig2"))?;
    let wl = args.str("workload", "zipfian");

    let policies: Vec<(&str, PolicyKind)> = vec![
        ("local-only", PolicyKind::LocalOnly),
        ("cxl-only", PolicyKind::CxlOnly),
        ("localfirst-1MB", PolicyKind::LocalFirst { local_cap_bytes: 1 << 20 }),
        ("interleave-4K", PolicyKind::Interleave { page_bytes: 4096 }),
        ("interleave-2M", PolicyKind::Interleave { page_bytes: 2 << 20 }),
        ("sizeclass-2MB", PolicyKind::SizeClass { threshold_bytes: 2 << 20 }),
        ("leastloaded", PolicyKind::LeastLoaded),
    ];

    println!("placement policies on `{}` running {}:\n", topo.name, wl);
    let mut rows = Vec::new();
    for (name, policy) in policies {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let mut sim = Coordinator::new(topo.clone(), cfg)?;
        let rep = sim.run_workload(&wl)?;
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", rep.simulated_ns / 1e6),
            format!("{:.3}x", rep.sim_slowdown()),
            format!("{:.3}", rep.lat_delay_ns / 1e6),
            format!("{:.3}", rep.cong_delay_ns / 1e6),
            format!("{:.3}", rep.bwd_delay_ns / 1e6),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Policy", "Sim(ms)", "Slowdown", "Lat(ms)", "Cong(ms)", "BW(ms)"],
            &rows
        )
    );

    // two-phase policy stacks: cxl-only placement + epoch policies
    // with cost-modeled migration (copy traffic + per-byte stall)
    println!("\nepoch-policy stacks on cxl-only placement (migration is cost-modeled):");
    let mut rows = Vec::new();
    for (label, spec) in [
        ("off", None),
        ("hotness:2", Some("hotness:2")),
        ("hotness:8", Some("hotness:8")),
        ("prefetch:0.5", Some("prefetch:0.5")),
        ("hotness:2+prefetch", Some("hotness:2,prefetch:0.5")),
        ("full stack", Some("hotness:2,prefetch:0.5,rebalance")),
    ] {
        let mut cfg = base.clone();
        cfg.policy = PolicyKind::CxlOnly;
        if let Some(s) = spec {
            cfg.epoch_policy = Some(PolicySpec::parse(s)?);
        }
        let mut sim = Coordinator::new(topo.clone(), cfg)?;
        let rep = sim.run_workload(&wl)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", rep.simulated_ns / 1e6),
            format!("{:.3}x", rep.sim_slowdown()),
            format!("{}", rep.migrations),
            format!("{:.1}", rep.migrated_bytes as f64 / 1024.0),
            format!("{:.3}", rep.mig_delay_ns / 1e6),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Stack", "Sim(ms)", "Slowdown", "Migrations", "Moved(KB)", "MigStall(ms)"],
            &rows
        )
    );

    // hardware vs software prefetch (paper §1's promised comparison —
    // hw is a cache-level prefetcher model, sw is a phase-1 bin shaper)
    println!("\nhardware vs software prefetch on a streaming workload:");
    let mut rows = Vec::new();
    for (label, pf, sw) in [
        ("none", None, None),
        ("hw-nextline", Some("nextline"), None),
        ("hw-stride", Some("stride"), None),
        ("sw-prefetch:0.5", None, Some("prefetch:0.5")),
        ("sw-prefetch:1.0", None, Some("prefetch:1.0")),
    ] {
        let mut cfg = base.clone();
        cfg.policy = PolicyKind::CxlOnly;
        cfg.prefetcher = pf.map(|s: &str| s.to_string());
        if let Some(s) = sw {
            cfg.epoch_policy = Some(PolicySpec::parse(s)?);
        }
        let mut sim = Coordinator::new(topo.clone(), cfg)?;
        let rep = sim.run_workload("stream")?;
        rows.push(vec![
            label.to_string(),
            format!("{}", rep.total_misses),
            format!("{}", rep.prefetches),
            format!("{:.3}x", rep.sim_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["Prefetch", "Demand misses", "Prefetch fills", "Slowdown"], &rows)
    );
    Ok(())
}

#!/usr/bin/env python3
"""CLI-doc drift gate: docs/CLI.md must match rust/src/main.rs.

Extracts every flag the binary reads (``args.str("x", ..)``,
``.opt_str("x")``, ``.f64/.u64/.usize/.bool``) from main.rs and every
documented flag (a ``| `--x ...`` table row) from docs/CLI.md, then
fails (exit 1) listing the drift in BOTH directions:

  * a flag the binary reads but CLI.md does not document, or
  * a flag CLI.md documents but the binary no longer reads.

Run from the repo root (CI does):  python3 tools/check_cli_docs.py
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# accessor calls may be split across lines by rustfmt, so match the
# method name through whitespace: `.str(\n  "workloads", ...`
ACCESSOR = re.compile(
    r'\.\s*(?:str|opt_str|f64|u64|usize|bool)\(\s*"([a-z0-9-]+)"', re.S
)
DOC_ROW = re.compile(r"^\|\s*`--([a-z0-9-]+)[ =`]", re.M)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--main", default="rust/src/main.rs")
    ap.add_argument("--doc", default="docs/CLI.md")
    args = ap.parse_args()

    src = Path(args.main).read_text()
    doc = Path(args.doc).read_text()

    in_binary = set(ACCESSOR.findall(src))
    in_doc = set(DOC_ROW.findall(doc))

    undocumented = sorted(in_binary - in_doc)
    stale = sorted(in_doc - in_binary)

    ok = True
    if undocumented:
        ok = False
        print(f"{args.doc}: missing rows for flags read by {args.main}:")
        for f in undocumented:
            print(f"  --{f}")
    if stale:
        ok = False
        print(f"{args.doc}: documents flags {args.main} does not read:")
        for f in stale:
            print(f"  --{f}")
    if ok:
        print(
            f"check_cli_docs: OK — {len(in_binary)} flags in {args.main}, "
            f"all documented in {args.doc}"
        )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Accuracy gate over sweep artifacts: relative orderings must hold.

The simulator cannot pin absolute nanoseconds, so the accuracy
regression suite pins relative orderings (deeper topology != faster,
more hosts != less congestion, ...). ``cxlmemsim sweep`` evaluates the
spec's ``[[invariant]]`` blocks into the artifact; this gate re-checks
the artifact so CI fails loudly even if the artifact was produced with
a driver that ignored exit codes.

For each artifact given:

  * every cell must carry a report (no ``error`` cells),
  * every invariant verdict must be ``holds: true`` — violations are
    printed with the offending cell pair and values,
  * ``--cells N`` (optional) pins the expected grid size,
  * an artifact with zero invariants fails unless ``--allow-empty``:
    an accuracy gate that checks nothing must be an explicit decision.

Usage:  python3 tools/accuracy_gate.py SWEEP_table1.json [more...]
"""

from __future__ import annotations

import argparse
import json
import sys


def check(path: str, expected_cells: int | None, allow_empty: bool) -> bool:
    with open(path) as f:
        art = json.load(f)
    ok = True
    name = art.get("spec_name", path)

    cells = art.get("cells", [])
    if expected_cells is not None and len(cells) != expected_cells:
        print(f"{name}: expected {expected_cells} cells, artifact has {len(cells)}")
        ok = False
    failed = [c for c in cells if "error" in c]
    for c in failed:
        print(f"{name}: cell `{c.get('id')}` failed: {c.get('error')}")
        ok = False
    if not cells:
        print(f"{name}: artifact has no cells")
        ok = False

    invariants = art.get("invariants", [])
    if not invariants and not allow_empty:
        print(f"{name}: no invariants in artifact (use --allow-empty to accept)")
        ok = False
    for inv in invariants:
        what = (
            f"{inv.get('metric')} along {inv.get('axis')} "
            f"in order {inv.get('order')}"
        )
        if inv.get("holds"):
            print(
                f"{name}: OK  {what} "
                f"({inv.get('checked', 0)} pairs, {inv.get('missing', 0)} missing)"
            )
            continue
        ok = False
        print(f"{name}: FAIL {what}")
        for v in inv.get("violations", []):
            print(
                f"  at {v.get('at') or '(unpinned)'}: "
                f"{v.get('from')} = {v.get('from_value')} -> "
                f"{v.get('to')} = {v.get('to_value')}"
            )
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifacts", nargs="+", help="sweep artifact JSON files")
    ap.add_argument("--cells", type=int, default=None, help="expected cell count")
    ap.add_argument(
        "--allow-empty",
        action="store_true",
        help="accept artifacts whose spec declared no invariants",
    )
    args = ap.parse_args()

    ok = True
    for path in args.artifacts:
        ok = check(path, args.cells, args.allow_empty) and ok
    if ok:
        print(f"accuracy_gate: OK — {len(args.artifacts)} artifact(s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

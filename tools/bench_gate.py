#!/usr/bin/env python3
"""Bench-regression gate for the hotpath perf trajectory.

Diffs a fresh smoke-mode ``BENCH_hotpath.json`` (written by
``cargo bench --bench hotpath`` with ``HOTPATH_SMOKE=1``) against the
committed ``rust/BENCH_baseline.json`` and fails (exit 1) when any
gated metric regressed by more than ``--tolerance`` (default 25%).

Gated entries / metrics (the hot paths named in ROADMAP):

  bins_record      bulk_recs_per_s            higher is better
  batch_analyze    fused_epochs_per_s         higher is better
  batch_analyze    blocked_epochs_per_s       higher is better
  scan_kernel      blocked_calls_per_s        higher is better
  replay_group     group256_epochs_per_s      higher is better
  replay_stream    events_per_s               higher is better
  fault_epoch      faultfree_epochs_per_s     higher is better
  fault_soak       armed_epochs_per_s         higher is better
  multihost_epoch  pooled_epochs_per_s        higher is better
  policy_epoch     empty_stack_ns_per_epoch   lower is better
  policy_epoch     full_stack_ns_per_epoch    lower is better
  pipeline_overlap pipelined_epochs_per_s     higher is better
  sweep            cells_per_s                higher is better

A missing gated entry or metric in either file is a hard failure:
schema drift must be an explicit decision (refresh the baseline with
``--update``), never a silently skipped gate.

Refreshing the baseline from a CI run:

  HOTPATH_SMOKE=1 cargo bench --bench hotpath       # in rust/
  python3 ../tools/bench_gate.py --baseline BENCH_baseline.json \
      --fresh BENCH_hotpath.json --update

and commit the rewritten ``rust/BENCH_baseline.json``.
"""

import argparse
import json
import shutil
import sys

# entry name -> [(metric, direction)]
GATES = {
    "bins_record": [("bulk_recs_per_s", "higher")],
    "batch_analyze": [
        ("fused_epochs_per_s", "higher"),
        ("blocked_epochs_per_s", "higher"),
    ],
    "scan_kernel": [("blocked_calls_per_s", "higher")],
    "replay_group": [("group256_epochs_per_s", "higher")],
    "replay_stream": [("events_per_s", "higher")],
    "fault_epoch": [("faultfree_epochs_per_s", "higher")],
    "fault_soak": [("armed_epochs_per_s", "higher")],
    "multihost_epoch": [("pooled_epochs_per_s", "higher")],
    "policy_epoch": [
        ("empty_stack_ns_per_epoch", "lower"),
        ("full_stack_ns_per_epoch", "lower"),
    ],
    "pipeline_overlap": [("pipelined_epochs_per_s", "higher")],
    "sweep": [("cells_per_s", "higher")],
}


def load_entries(path):
    with open(path) as f:
        doc = json.load(f)
    entries = {}
    for item in doc.get("results", []):
        name = item.get("name")
        if name not in entries:  # first occurrence wins (names are unique today)
            entries[name] = item.get("data", {})
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed BENCH_baseline.json")
    ap.add_argument("--fresh", required=True, help="freshly produced BENCH_hotpath.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed fractional slowdown before failing (default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the fresh file over the baseline instead of gating",
    )
    args = ap.parse_args()

    if args.update:
        # never blind-copy: a fresh file missing a gated entry (bench
        # renamed, run truncated, wrong file) would silently disarm
        # that gate for every future run
        try:
            fresh = load_entries(args.fresh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"refusing to update baseline: {args.fresh}: {e}", file=sys.stderr)
            return 1
        bad = []
        for name, metrics in GATES.items():
            for metric, _direction in metrics:
                if name not in fresh or metric not in fresh[name]:
                    bad.append(f"{name}.{metric}: missing from fresh results")
                    continue
                try:
                    value = float(fresh[name][metric])
                except (TypeError, ValueError):
                    bad.append(f"{name}.{metric}: not a number ({fresh[name][metric]!r})")
                    continue
                if value <= 0:
                    bad.append(f"{name}.{metric}: non-positive value ({value})")
        if bad:
            print("refusing to update baseline: fresh file fails gate schema:", file=sys.stderr)
            for msg in bad:
                print(f"  - {msg}", file=sys.stderr)
            return 1
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline updated: {args.fresh} -> {args.baseline}")
        return 0

    base = load_entries(args.baseline)
    fresh = load_entries(args.fresh)
    failures = []
    rows = []
    for name, metrics in GATES.items():
        for metric, direction in metrics:
            if name not in base or metric not in base[name]:
                failures.append(f"{name}.{metric}: missing from baseline")
                continue
            if name not in fresh or metric not in fresh[name]:
                failures.append(f"{name}.{metric}: missing from fresh results")
                continue
            b, f = float(base[name][metric]), float(fresh[name][metric])
            if b <= 0 or f <= 0:
                failures.append(f"{name}.{metric}: non-positive value (base={b}, fresh={f})")
                continue
            # slowdown > 1.0 means the fresh run is worse than baseline
            slowdown = (b / f) if direction == "higher" else (f / b)
            ok = slowdown <= 1.0 + args.tolerance
            rows.append((name, metric, direction, b, f, slowdown, ok))
            if not ok:
                failures.append(
                    f"{name}.{metric}: {slowdown:.2f}x slowdown "
                    f"(baseline {b:.4g}, fresh {f:.4g}, direction {direction})"
                )

    width = max((len(f"{n}.{m}") for n, m, *_ in rows), default=20)
    print(f"bench gate (tolerance: {args.tolerance:.0%} slowdown)")
    for name, metric, direction, b, f, slowdown, ok in rows:
        verdict = "ok  " if ok else "FAIL"
        print(
            f"  {verdict} {f'{name}.{metric}':<{width}}  "
            f"baseline {b:>12.4g}  fresh {f:>12.4g}  slowdown {slowdown:5.2f}x ({direction})"
        )
    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""AOT path tests: the HLO text artifacts must lower, parse as HLO, and
carry the expected entry signature; golden vectors must be reproducible."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels.ref import timing_analyzer_ref


def test_lower_single_produces_hlo_text():
    text = aot.lower_single(4, 4, 32)
    assert text.startswith("HloModule")
    assert "f32[4,32]" in text
    # pallas (interpret) must lower to plain HLO: no Mosaic custom-calls
    assert "mosaic" not in text.lower()


def test_lowering_preserves_structure():
    """§Perf L2 contract: the topology contraction stays a single dot
    (MXU-shaped) and the queueing scans lower to while loops — no
    unrolled 256x code blow-up."""
    text = aot.lower_single(model.NUM_POOLS, model.NUM_SWITCHES, model.NUM_BINS)
    assert "dot(" in text, "desc_mask contraction must lower to a dot"
    assert "while(" in text or "while." in text, "scan must lower to a while loop"
    # unrolling 256 bins would emit hundreds of dynamic-update-slices
    assert text.count("dynamic-update-slice") < 64


def test_lower_batch_produces_hlo_text():
    text = aot.lower_batch(2, 4, 4, 32)
    assert text.startswith("HloModule")
    assert "f32[2,4,32]" in text


def test_entry_layout_matches_manifest_contract():
    text = aot.lower_single(model.NUM_POOLS, model.NUM_SWITCHES, model.NUM_BINS)
    header = text.splitlines()[0]
    # 9 inputs: reads, writes, extra_rd, extra_wr, desc_mask, stt, bw, 2 scalars
    assert header.count("f32[") >= 9
    assert f"f32[{model.NUM_POOLS},{model.NUM_BINS}]" in header
    assert f"f32[{model.NUM_SWITCHES},{model.NUM_POOLS}]" in header


def test_golden_inputs_are_deterministic():
    a = aot.golden_inputs(8, 8, 64)
    b = aot.golden_inputs(8, 8, 64)
    for k in a:
        assert_allclose(np.asarray(a[k]), np.asarray(b[k]), err_msg=k)


def test_write_golden_roundtrip(tmp_path):
    path = tmp_path / "golden.json"
    out = aot.write_golden(str(path), 8, 8, 64)
    blob = json.loads(path.read_text())
    assert blob["shapes"] == {"pools": 8, "switches": 8, "nbins": 64}
    assert_allclose(blob["outputs"]["total"], float(out["total"]), rtol=1e-6)
    assert len(blob["outputs"]["lat"]) == 8
    assert len(blob["outputs"]["cong_backlog"]) == 8 * 64
    # outputs recompute identically from the stored inputs
    gin = aot.golden_inputs(8, 8, 64)
    re = timing_analyzer_ref(**gin)
    assert_allclose(float(re["total"]), blob["outputs"]["total"], rtol=1e-6)


def test_shipped_artifacts_match_source(tmp_path):
    """If artifacts/ exists, its manifest must match model.py constants."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    m = json.load(open(manifest_path))
    assert m["pools"] == model.NUM_POOLS
    assert m["switches"] == model.NUM_SWITCHES
    assert m["nbins"] == model.NUM_BINS
    assert os.path.exists(os.path.join(art, m["single"]))
    assert os.path.exists(os.path.join(art, m["batch_module"]))

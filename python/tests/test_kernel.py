"""L1 correctness: Pallas queue_scan vs the pure-jnp and numpy oracles.

This is the core correctness signal for the kernel, including a
hypothesis sweep over shapes and value regimes.
"""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile.kernels.queue_scan import queue_scan
from compile.kernels.ref import queue_scan_np, queue_scan_ref


def _run_all(demand, capacity):
    backlog_k, qsum_k = queue_scan(demand, capacity)
    backlog_r, qsum_r = queue_scan_ref(demand, capacity)
    backlog_n, qsum_n = queue_scan_np(demand, capacity)
    return (
        np.asarray(backlog_k), np.asarray(qsum_k),
        np.asarray(backlog_r), np.asarray(qsum_r),
        backlog_n, qsum_n,
    )


def test_zero_demand_is_zero_backlog():
    d = np.zeros((4, 32), np.float32)
    c = np.ones((4, 32), np.float32)
    bk, qk, *_ = _run_all(d, c)
    assert_allclose(bk, 0.0)
    assert_allclose(qk, 0.0)


def test_demand_below_capacity_never_queues():
    rng = np.random.default_rng(1)
    c = rng.uniform(1.0, 2.0, (3, 64)).astype(np.float32)
    d = c * 0.9
    bk, qk, *_ = _run_all(d, c)
    assert_allclose(bk, 0.0)
    assert_allclose(qk, 0.0)


def test_constant_overload_grows_linearly():
    # demand 2, capacity 1 -> backlog 1, 2, 3, ... per bin.
    nbins = 16
    d = np.full((1, nbins), 2.0, np.float32)
    c = np.ones((1, nbins), np.float32)
    bk, qk, br, qr, bn, qn = _run_all(d, c)
    expect = np.arange(1, nbins + 1, dtype=np.float32)[None, :]
    assert_allclose(bk, expect, rtol=1e-6)
    assert_allclose(qk, expect.sum(axis=1), rtol=1e-6)
    assert_allclose(br, expect, rtol=1e-6)
    assert_allclose(bn, expect, rtol=1e-6)


def test_burst_drains():
    # one big burst then idle: backlog decays by capacity per bin.
    d = np.zeros((1, 10), np.float32)
    d[0, 0] = 5.0
    c = np.ones((1, 10), np.float32)
    bk, qk, *_ = _run_all(d, c)
    assert_allclose(bk[0, :5], [4.0, 3.0, 2.0, 1.0, 0.0], rtol=1e-6)
    assert_allclose(bk[0, 5:], 0.0)


def test_rows_are_independent():
    rng = np.random.default_rng(2)
    d = rng.uniform(0, 4, (6, 40)).astype(np.float32)
    c = rng.uniform(0.5, 3, (6, 40)).astype(np.float32)
    bk_full, _, *_ = _run_all(d, c)
    for r in range(6):
        bk_row, _ = queue_scan(d[r : r + 1], c[r : r + 1])
        assert_allclose(np.asarray(bk_row)[0], bk_full[r], rtol=1e-6)


def test_kernel_matches_ref_random():
    rng = np.random.default_rng(3)
    d = rng.exponential(2.0, (8, 256)).astype(np.float32)
    c = rng.uniform(0.5, 4.0, (8, 256)).astype(np.float32)
    bk, qk, br, qr, bn, qn = _run_all(d, c)
    assert_allclose(bk, br, rtol=1e-5, atol=1e-4)
    assert_allclose(qk, qr, rtol=1e-5, atol=1e-3)
    assert_allclose(bk, bn, rtol=1e-4, atol=1e-2)


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        queue_scan(np.zeros((2, 8), np.float32), np.zeros((2, 9), np.float32))


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 12),
    nbins=st.sampled_from([1, 2, 7, 32, 256]),
    scale=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_kernel_vs_ref(rows, nbins, scale, seed):
    rng = np.random.default_rng(seed)
    d = (rng.exponential(1.0, (rows, nbins)) * scale).astype(np.float32)
    c = (rng.uniform(0.2, 2.0, (rows, nbins)) * scale).astype(np.float32)
    bk, qk = queue_scan(d, c)
    bn, qn = queue_scan_np(d, c)
    assert_allclose(np.asarray(bk), bn, rtol=1e-4, atol=scale * 1e-3)
    assert_allclose(np.asarray(qk), qn, rtol=1e-4, atol=scale * nbins * 1e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbins=st.sampled_from([8, 64, 256]))
def test_hypothesis_backlog_invariants(seed, nbins):
    """Invariants: backlog >= 0; backlog lipschitz wrt demand ordering."""
    rng = np.random.default_rng(seed)
    d = rng.exponential(2.0, (4, nbins)).astype(np.float32)
    c = rng.uniform(0.5, 2.0, (4, nbins)).astype(np.float32)
    bk, qk = queue_scan(d, c)
    bk = np.asarray(bk)
    assert (bk >= 0).all()
    # adding demand can never reduce backlog anywhere (monotonicity)
    bk2, _ = queue_scan(d + 0.5, c)
    assert (np.asarray(bk2) - bk >= -1e-4).all()
    # adding capacity can never increase backlog
    bk3, _ = queue_scan(d, c + 0.5)
    assert (np.asarray(bk3) - bk <= 1e-4).all()


def test_float64_inputs_are_accepted():
    d = np.ones((2, 4), np.float64)
    c = np.ones((2, 4), np.float64) * 2
    bk, qk = queue_scan(d, c)
    assert np.asarray(bk).dtype == np.float32
    assert_allclose(np.asarray(bk), 0.0)

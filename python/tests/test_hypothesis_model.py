"""Hypothesis sweeps over the full timing analyzer: shapes, dtypes,
value regimes, and model-level metamorphic properties."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import timing_analyzer_ref


def mk(seed, pools, switches, nbins, rate):
    rng = np.random.default_rng(seed)
    return dict(
        reads=rng.poisson(rate, (pools, nbins)).astype(np.float32),
        writes=rng.poisson(rate / 2, (pools, nbins)).astype(np.float32),
        extra_read_lat=rng.uniform(0, 300, pools).astype(np.float32),
        extra_write_lat=rng.uniform(0, 300, pools).astype(np.float32),
        desc_mask=(rng.uniform(0, 1, (switches, pools)) < 0.4).astype(np.float32),
        stt=rng.uniform(0, 40, switches).astype(np.float32),
        bw=rng.uniform(1, 64, switches).astype(np.float32),
        bin_width=np.float32(rng.uniform(100, 10_000)),
        bytes_per_ev=np.float32(64.0),
    )


def run_model(gin):
    out = model.timing_analyzer(*[np.asarray(v) for v in gin.values()])
    return [np.asarray(x) for x in out]


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    pools=st.integers(1, 8),
    switches=st.integers(1, 8),
    nbins=st.sampled_from([4, 32, 256]),
    rate=st.floats(0.1, 50.0),
)
def test_model_matches_ref_across_shapes(seed, pools, switches, nbins, rate):
    gin = mk(seed, pools, switches, nbins, rate)
    total, lat, cong, bwd, backlog = run_model(gin)
    exp = timing_analyzer_ref(**gin)
    scale = max(float(exp["total"]), 1.0)
    assert_allclose(total, exp["total"], rtol=1e-4, atol=scale * 1e-5)
    assert_allclose(lat, exp["lat"], rtol=1e-4, atol=1e-1)
    assert_allclose(cong, exp["cong"], rtol=1e-3, atol=scale * 1e-4)
    assert_allclose(bwd, exp["bwd"], rtol=1e-3, atol=scale * 1e-4)
    assert_allclose(backlog, exp["cong_backlog"], rtol=1e-3, atol=1.0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_outputs_are_finite_and_nonnegative(seed):
    gin = mk(seed, 8, 8, 64, 20.0)
    total, lat, cong, bwd, backlog = run_model(gin)
    for name, arr in [("total", total), ("lat", lat), ("cong", cong),
                      ("bwd", bwd), ("backlog", backlog)]:
        assert np.isfinite(arr).all(), name
        assert (arr >= 0).all(), name


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.floats(1.1, 4.0))
def test_delay_monotone_under_traffic_scaling(seed, k):
    gin = mk(seed, 6, 4, 32, 10.0)
    base = run_model(gin)[0]
    gin2 = dict(gin)
    gin2["reads"] = gin["reads"] * np.float32(k)
    gin2["writes"] = gin["writes"] * np.float32(k)
    more = run_model(gin2)[0]
    assert more >= base * 0.999, f"scaling traffic by {k} reduced delay"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_permuting_pools_permutes_latency(seed):
    """Metamorphic: relabeling pools permutes lat[] identically."""
    gin = mk(seed, 6, 4, 32, 5.0)
    rng = np.random.default_rng(seed ^ 1)
    perm = rng.permutation(6)
    gin2 = dict(gin)
    gin2["reads"] = gin["reads"][perm]
    gin2["writes"] = gin["writes"][perm]
    gin2["extra_read_lat"] = gin["extra_read_lat"][perm]
    gin2["extra_write_lat"] = gin["extra_write_lat"][perm]
    gin2["desc_mask"] = gin["desc_mask"][:, perm]
    lat1 = run_model(gin)[1]
    lat2 = run_model(gin2)[1]
    assert_allclose(lat2, lat1[perm], rtol=1e-5, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_infinite_bandwidth_means_no_bw_delay(seed):
    gin = mk(seed, 4, 4, 32, 20.0)
    gin["bw"] = np.full(4, 1e9, np.float32)
    bwd = run_model(gin)[3]
    assert_allclose(bwd, 0.0, atol=1e-3)

"""L2 correctness: timing_analyzer (Pallas path) vs the pure-jnp oracle,
plus semantic tests of the timing model itself."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.aot import golden_inputs
from compile.kernels.ref import timing_analyzer_ref

P, S, B = 4, 3, 64


def mk_inputs(seed=0, pools=P, switches=S, nbins=B, rate=2.0):
    rng = np.random.default_rng(seed)
    gin = dict(
        reads=rng.poisson(rate, (pools, nbins)).astype(np.float32),
        writes=rng.poisson(rate / 2, (pools, nbins)).astype(np.float32),
        extra_read_lat=rng.uniform(50, 200, pools).astype(np.float32),
        extra_write_lat=rng.uniform(50, 200, pools).astype(np.float32),
        desc_mask=(rng.uniform(0, 1, (switches, pools)) < 0.5).astype(np.float32),
        stt=rng.uniform(1, 30, switches).astype(np.float32),
        bw=rng.uniform(8, 64, switches).astype(np.float32),
        bin_width=np.float32(1000.0),
        bytes_per_ev=np.float32(64.0),
    )
    return gin


def run_model(gin):
    total, lat, cong, bwd, backlog = model.timing_analyzer(
        *[np.asarray(v) for v in gin.values()]
    )
    return dict(
        total=np.asarray(total), lat=np.asarray(lat), cong=np.asarray(cong),
        bwd=np.asarray(bwd), cong_backlog=np.asarray(backlog),
    )


def test_model_matches_ref():
    gin = mk_inputs(7)
    got = run_model(gin)
    exp = timing_analyzer_ref(**gin)
    for k in ("total", "lat", "cong", "bwd", "cong_backlog"):
        assert_allclose(got[k], exp[k], rtol=1e-5, atol=1e-2, err_msg=k)


def test_golden_matches_ref():
    """The golden vectors rust consumes are self-consistent with the model."""
    gin = golden_inputs(model.NUM_POOLS, model.NUM_SWITCHES, model.NUM_BINS)
    got = run_model(gin)
    exp = timing_analyzer_ref(**gin)
    assert_allclose(got["total"], exp["total"], rtol=1e-5)
    assert_allclose(got["lat"], exp["lat"], rtol=1e-5)


def test_zero_traffic_zero_delay():
    gin = mk_inputs(1)
    gin["reads"][:] = 0
    gin["writes"][:] = 0
    got = run_model(gin)
    assert got["total"] == 0.0
    assert_allclose(got["lat"], 0.0)
    assert_allclose(got["cong"], 0.0)
    assert_allclose(got["bwd"], 0.0)


def test_latency_delay_is_count_times_extra():
    """Paper rule: latency delay = #ops x (pool latency - local latency)."""
    gin = mk_inputs(2)
    gin["desc_mask"][:] = 0  # no switches -> only latency delay
    got = run_model(gin)
    expect = (
        gin["reads"].sum(1) * gin["extra_read_lat"]
        + gin["writes"].sum(1) * gin["extra_write_lat"]
    )
    assert_allclose(got["lat"], expect, rtol=1e-5)
    assert_allclose(got["total"], expect.sum(), rtol=1e-5)


def test_local_pool_contributes_nothing():
    gin = mk_inputs(3)
    gin["extra_read_lat"][0] = 0.0
    gin["extra_write_lat"][0] = 0.0
    gin["desc_mask"][:, 0] = 0.0
    base = run_model(gin)
    gin2 = {k: np.copy(v) if hasattr(v, "copy") else v for k, v in gin.items()}
    gin2["reads"][0] += 1000  # hammer the local pool
    got = run_model(gin2)
    assert_allclose(got["total"], base["total"], rtol=1e-5)


def test_congestion_monotone_in_stt():
    gin = mk_inputs(4, rate=8.0)
    gin["bw"][:] = 1e9  # disable bandwidth effects
    lo = run_model(gin)
    gin["stt"] = gin["stt"] * 4
    hi = run_model(gin)
    assert hi["cong"].sum() >= lo["cong"].sum() - 1e-3


def test_bandwidth_monotone_in_bw():
    gin = mk_inputs(5, rate=20.0)
    gin["stt"][:] = 0.01  # negligible congestion
    lo_bw = dict(gin)
    lo_bw["bw"] = gin["bw"] * 0.1
    slow = run_model(lo_bw)
    fast = run_model(gin)
    assert slow["bwd"].sum() >= fast["bwd"].sum() - 1e-3


def test_padding_rows_are_inert():
    """Zero desc_mask rows + zero stt/bw must contribute exactly nothing."""
    gin = mk_inputs(6, switches=S)
    gin["desc_mask"][-1, :] = 0
    gin["stt"][-1] = 0.0
    gin["bw"][-1] = 0.0
    got = run_model(gin)
    assert got["cong"][-1] == 0.0
    assert got["bwd"][-1] == 0.0
    assert np.isfinite(got["total"])


def test_batch_matches_singles():
    e = 3
    gins = [mk_inputs(seed) for seed in range(e)]
    shared = gins[0]
    reads = np.stack([g["reads"] for g in gins])
    writes = np.stack([g["writes"] for g in gins])
    total, lat, cong, bwd = [
        np.asarray(x)
        for x in model.timing_analyzer_batch(
            reads, writes, shared["extra_read_lat"], shared["extra_write_lat"],
            shared["desc_mask"], shared["stt"], shared["bw"],
            shared["bin_width"], shared["bytes_per_ev"],
        )
    ]
    for i in range(e):
        single = model.timing_analyzer(
            reads[i], writes[i], shared["extra_read_lat"],
            shared["extra_write_lat"], shared["desc_mask"], shared["stt"],
            shared["bw"], shared["bin_width"], shared["bytes_per_ev"],
        )
        assert_allclose(total[i], np.asarray(single[0]), rtol=1e-4, atol=1e-2)
        assert_allclose(lat[i], np.asarray(single[1]), rtol=1e-4, atol=1e-2)
        assert_allclose(cong[i], np.asarray(single[2]), rtol=1e-4, atol=1e-2)
        assert_allclose(bwd[i], np.asarray(single[3]), rtol=1e-4, atol=1e-2)


def test_more_traffic_more_delay():
    gin = mk_inputs(8, rate=4.0)
    base = run_model(gin)
    gin["reads"] = gin["reads"] * 3
    got = run_model(gin)
    assert got["total"] >= base["total"]

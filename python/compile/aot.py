"""AOT compile path: lower the timing analyzer to HLO *text* artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Emits:
  artifacts/timing_p{P}s{S}b{B}.hlo.txt          per-epoch analyzer
  artifacts/timing_batch{E}_p{P}s{S}b{B}.hlo.txt  batched replay variant
  artifacts/manifest.json                         shapes + input order
  artifacts/golden.json                           cross-language test vectors

HLO text (NOT jax.export / .serialize()): the published ``xla`` crate
links xla_extension 0.5.1, which rejects jax>=0.5's 64-bit-instruction-id
protos; the HLO text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_single(pools, switches, nbins) -> str:
    fn = lambda *a: model.timing_analyzer(*a, interpret=True)
    return to_hlo_text(jax.jit(fn).lower(*model.example_args(pools, switches, nbins)))


def lower_batch(batch, pools, switches, nbins) -> str:
    fn = lambda *a: model.timing_analyzer_batch(*a, interpret=True)
    return to_hlo_text(
        jax.jit(fn).lower(*model.example_args_batch(batch, pools, switches, nbins))
    )


def golden_inputs(pools, switches, nbins, seed=0x5EED):
    """Deterministic pseudo-random inputs for the golden vectors."""
    rng = np.random.default_rng(seed)
    reads = rng.poisson(3.0, size=(pools, nbins)).astype(np.float32)
    writes = rng.poisson(1.5, size=(pools, nbins)).astype(np.float32)
    # pools 0..2 are CXL, pool 3 local (zero extra), rest padding.
    extra_rd = np.zeros(pools, np.float32)
    extra_wr = np.zeros(pools, np.float32)
    extra_rd[:3] = [85.0, 95.0, 170.0]
    extra_wr[:3] = [90.0, 100.0, 180.0]
    reads[4:] = 0
    writes[4:] = 0
    desc = np.zeros((switches, pools), np.float32)
    desc[0, :3] = 1.0          # root complex sees all CXL pools
    desc[1, :2] = 1.0          # switch 1: pools 0,1
    desc[2, 2] = 1.0           # switch 2: pool 2
    stt = np.zeros(switches, np.float32)
    stt[:3] = [2.0, 25.0, 25.0]
    bw = np.zeros(switches, np.float32)
    bw[:3] = [64.0, 32.0, 32.0]  # bytes/ns
    bin_width = np.float32(3906.25)  # 1 ms epoch / 256 bins
    bytes_per_ev = np.float32(64.0)
    return dict(
        reads=reads, writes=writes, extra_read_lat=extra_rd,
        extra_write_lat=extra_wr, desc_mask=desc, stt=stt, bw=bw,
        bin_width=bin_width, bytes_per_ev=bytes_per_ev,
    )


def write_golden(path, pools, switches, nbins):
    gin = golden_inputs(pools, switches, nbins)
    out = ref.timing_analyzer_ref(**gin)
    blob = {
        "shapes": {"pools": pools, "switches": switches, "nbins": nbins},
        "inputs": {k: np.asarray(v).ravel().tolist() for k, v in gin.items()},
        "outputs": {
            "total": float(out["total"]),
            "lat": out["lat"].ravel().tolist(),
            "cong": out["cong"].ravel().tolist(),
            "bwd": out["bwd"].ravel().tolist(),
            "cong_backlog": out["cong_backlog"].ravel().tolist(),
        },
    }
    with open(path, "w") as f:
        json.dump(blob, f)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--pools", type=int, default=model.NUM_POOLS)
    ap.add_argument("--switches", type=int, default=model.NUM_SWITCHES)
    ap.add_argument("--nbins", type=int, default=model.NUM_BINS)
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    p, s, b, e = args.pools, args.switches, args.nbins, args.batch

    single_name = f"timing_p{p}s{s}b{b}.hlo.txt"
    batch_name = f"timing_batch{e}_p{p}s{s}b{b}.hlo.txt"

    text = lower_single(p, s, b)
    with open(os.path.join(args.out, single_name), "w") as f:
        f.write(text)
    print(f"wrote {single_name}: {len(text)} chars")

    btext = lower_batch(e, p, s, b)
    with open(os.path.join(args.out, batch_name), "w") as f:
        f.write(btext)
    print(f"wrote {batch_name}: {len(btext)} chars")

    manifest = {
        "pools": p,
        "switches": s,
        "nbins": b,
        "batch": e,
        "single": single_name,
        "batch_module": batch_name,
        "input_order": [
            "reads[P,B]", "writes[P,B]", "extra_read_lat[P]",
            "extra_write_lat[P]", "desc_mask[S,P]", "stt[S]", "bw[S]",
            "bin_width[]", "bytes_per_ev[]",
        ],
        "output_order_single": ["total[]", "lat[P]", "cong[S]", "bwd[S]",
                                "cong_backlog[S,B]"],
        "output_order_batch": ["total[E]", "lat[E,P]", "cong[E,S]", "bwd[E,S]"],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")

    write_golden(os.path.join(args.out, "golden.json"), p, s, b)
    print("wrote golden.json")


if __name__ == "__main__":
    main()

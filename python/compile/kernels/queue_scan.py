"""Layer-1 Pallas kernel: per-row queueing scan.

This is the timing analyzer's hot spot. Each row models one CXL switch
(or the root complex) over one epoch that has been discretized into B
time bins. ``demand[r, b]`` is the service time (or bytes) of the work
arriving at switch ``r`` during bin ``b``; ``capacity[r, b]`` is how much
service the switch can perform during that bin.  The scan carries the
unserved *backlog* forward:

    q_b = max(0, q_{b-1} + demand_b - capacity_b)

and returns both the full backlog profile (used by migration policies and
the bandwidth pass) and the per-row backlog integral ``sum_b q_b`` (which
layer 2 converts into waiting time via Little's law).

Rows are independent, so the Pallas grid is one program per row and each
program walks its [1, B] block sequentially with a ``fori_loop``.  On a
real TPU the block (B=256 f32 = 1 KiB) trivially fits VMEM; on this CPU
testbed the kernel must run with ``interpret=True`` because the CPU PJRT
plugin cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _queue_scan_kernel(demand_ref, cap_ref, backlog_ref, qsum_ref):
    """One grid program == one switch row.

    demand_ref, cap_ref, backlog_ref: [1, B] blocks in VMEM.
    qsum_ref: [1, 1] per-row backlog integral.
    """
    nbins = demand_ref.shape[1]

    def body(b, carry):
        q, total = carry
        d = demand_ref[0, b]
        c = cap_ref[0, b]
        q = jnp.maximum(q + d - c, 0.0)
        backlog_ref[0, b] = q
        return (q, total + q)

    _, total = jax.lax.fori_loop(0, nbins, body, (jnp.float32(0.0), jnp.float32(0.0)))
    qsum_ref[0, 0] = total


@functools.partial(jax.jit, static_argnames=("interpret",))
def queue_scan(demand: jax.Array, capacity: jax.Array, *, interpret: bool = True):
    """Run the queueing scan over every row.

    Args:
      demand:   f32[R, B] work arriving per row per bin.
      capacity: f32[R, B] service available per row per bin.
      interpret: lower the Pallas kernel in interpret mode (required for
        CPU PJRT; compile-only on real TPUs may set False).

    Returns:
      (backlog, qsum): f32[R, B] backlog after each bin and f32[R] the
      per-row backlog integral  sum_b backlog[r, b].
    """
    if demand.shape != capacity.shape:
        raise ValueError(f"shape mismatch {demand.shape} vs {capacity.shape}")
    rows, nbins = demand.shape
    backlog, qsum = pl.pallas_call(
        _queue_scan_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, nbins), lambda r: (r, 0)),
            pl.BlockSpec((1, nbins), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nbins), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, nbins), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(demand.astype(jnp.float32), capacity.astype(jnp.float32))
    return backlog, qsum[:, 0]

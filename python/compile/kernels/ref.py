"""Pure-jnp correctness oracles for the Pallas kernel and the full model.

These are the ground truth the pytest suite checks against, and the
source of the golden vectors (`artifacts/golden.json`) the rust side uses
for cross-language differential testing.  Everything here is deliberately
written in the most obvious way (lax.scan / plain loops), with zero
Pallas and zero cleverness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def queue_scan_ref(demand, capacity):
    """Oracle for kernels.queue_scan: a plain lax.scan per row.

    Returns (backlog f32[R, B], qsum f32[R]).
    """
    demand = jnp.asarray(demand, jnp.float32)
    capacity = jnp.asarray(capacity, jnp.float32)

    def row(carry, dc):
        d, c = dc
        q = jnp.maximum(carry + d - c, 0.0)
        return q, q

    def one_row(d_row, c_row):
        _, qs = jax.lax.scan(row, jnp.float32(0.0), (d_row, c_row))
        return qs

    backlog = jax.vmap(one_row)(demand, capacity)
    return backlog, backlog.sum(axis=1)


def queue_scan_np(demand, capacity):
    """Second, numpy-only oracle (no jax at all) for triangulation."""
    demand = np.asarray(demand, np.float64)
    capacity = np.asarray(capacity, np.float64)
    rows, nbins = demand.shape
    backlog = np.zeros((rows, nbins), np.float64)
    for r in range(rows):
        q = 0.0
        for b in range(nbins):
            q = max(0.0, q + demand[r, b] - capacity[r, b])
            backlog[r, b] = q
    return backlog, backlog.sum(axis=1)


def timing_analyzer_ref(
    reads,
    writes,
    extra_read_lat,
    extra_write_lat,
    desc_mask,
    stt,
    bw,
    bin_width,
    bytes_per_ev,
):
    """Oracle for model.timing_analyzer (see model.py for the math).

    All arrays are numpy/jnp convertible; returns a dict of numpy arrays.
    """
    reads = jnp.asarray(reads, jnp.float32)
    writes = jnp.asarray(writes, jnp.float32)
    extra_read_lat = jnp.asarray(extra_read_lat, jnp.float32)
    extra_write_lat = jnp.asarray(extra_write_lat, jnp.float32)
    desc_mask = jnp.asarray(desc_mask, jnp.float32)
    stt = jnp.asarray(stt, jnp.float32)
    bw = jnp.asarray(bw, jnp.float32)
    bin_width = jnp.float32(bin_width)
    bytes_per_ev = jnp.float32(bytes_per_ev)

    # 1. latency delay per pool.
    lat = reads.sum(axis=1) * extra_read_lat + writes.sum(axis=1) * extra_write_lat

    # 2. per-switch event stream.
    ev = desc_mask @ (reads + writes)  # [S, B]

    # 3. congestion: serialize events through each switch at one per STT.
    # delay = drain time of end-of-epoch backlog + transient waiting
    # capped at one epoch (see model.py / DESIGN.md §5).
    nbins = reads.shape[1]
    epoch_len = bin_width * nbins
    safe_stt = jnp.where(stt > 0, stt, 1.0)
    d_cong = ev * stt[:, None]
    cap = jnp.broadcast_to(bin_width, d_cong.shape)
    cong_backlog, cong_qsum = queue_scan_ref(d_cong, cap)
    cong_wait = jnp.minimum(cong_qsum * (bin_width / safe_stt), epoch_len)
    cong = jnp.where(stt > 0, cong_backlog[:, -1] + cong_wait, 0.0)

    # 4. bandwidth applies to the congestion-shifted (served) stream.
    prev = jnp.concatenate(
        [jnp.zeros((cong_backlog.shape[0], 1), jnp.float32), cong_backlog[:, :-1]],
        axis=1,
    )
    served_work = d_cong + prev - cong_backlog  # ns actually transiting per bin
    served_events = jnp.where(stt[:, None] > 0, served_work / safe_stt[:, None], ev)
    d_bw = served_events * bytes_per_ev
    cap_bw = jnp.broadcast_to(bw[:, None] * bin_width, d_bw.shape)
    bw_backlog, bw_qsum = queue_scan_ref(d_bw, cap_bw)
    safe_bw = jnp.where(bw > 0, bw, 1.0)
    bw_wait = jnp.minimum(bw_qsum * (bin_width / bytes_per_ev), epoch_len)
    bwd = jnp.where(bw > 0, bw_backlog[:, -1] / safe_bw + bw_wait, 0.0)

    total = lat.sum() + cong.sum() + bwd.sum()
    return {
        "total": np.asarray(total),
        "lat": np.asarray(lat),
        "cong": np.asarray(cong),
        "bwd": np.asarray(bwd),
        "cong_backlog": np.asarray(cong_backlog),
    }

"""Layer-2: the CXLMemSim timing analyzer as a JAX computation graph.

This is the paper's §3 "Timing Analyzer" re-expressed as a dense tensor
program so it AOT-lowers to a single HLO module that the rust coordinator
executes per epoch through PJRT (python is never on the simulation path).

Inputs (fixed AOT shapes; rust zero-pads unused pools/switches):

  reads, writes     f32[P, B]   LLC-miss events per pool per time bin
  extra_read_lat    f32[P]      pool path read latency - local DRAM (ns)
  extra_write_lat   f32[P]      pool path write latency - local DRAM (ns)
  desc_mask         f32[S, P]   1.0 iff pool p routes through switch s
  stt               f32[S]      serial transmission time per event (ns)
  bw                f32[S]      switch bandwidth (bytes/ns)
  bin_width         f32[]       epoch_length / B (ns)
  bytes_per_ev      f32[]       cacheline size per event (bytes)

Outputs (5-tuple):

  total             f32[]       total delay to inject this epoch (ns)
  lat               f32[P]      latency delay per pool
  cong              f32[S]      congestion delay per switch
  bwd               f32[S]      bandwidth delay per switch
  cong_backlog      f32[S, B]   congestion backlog profile (policy input)

Timing model (DESIGN.md §5):

  * latency delay: count x (path latency - local latency), the paper's
    rule verbatim.
  * congestion: events traversing switch s during bin b demand
    ev*STT ns of serial service against bin_width ns of capacity; the
    queue_scan Pallas kernel carries the backlog.  Little's law converts
    the backlog integral into waiting time: at the end of bin b there are
    backlog/STT queued events, each waiting one bin (bin_width ns), so
    cong[s] = qsum[s] * bin_width / stt[s].
  * bandwidth: applied to the *served* (congestion-shifted) stream, per
    the paper's "after the latency and congestion delays are added";
    demand is bytes, capacity bw*bin_width, wait = qsum*bin_width/bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.queue_scan import queue_scan

# Default AOT shapes. Keep in sync with rust/src/runtime/shapes.rs and
# artifacts/manifest.json (written by aot.py).
NUM_POOLS = 8
NUM_SWITCHES = 8
NUM_BINS = 256
BATCH = 16


def timing_analyzer(
    reads,
    writes,
    extra_read_lat,
    extra_write_lat,
    desc_mask,
    stt,
    bw,
    bin_width,
    bytes_per_ev,
    *,
    interpret: bool = True,
):
    """One epoch of the CXLMemSim timing analyzer. Shapes per module doc."""
    reads = reads.astype(jnp.float32)
    writes = writes.astype(jnp.float32)

    # --- 1. latency delay (paper: count x latency difference) -----------
    lat = reads.sum(axis=1) * extra_read_lat + writes.sum(axis=1) * extra_write_lat

    # --- 2. route events through the topology: MXU-shaped matmul --------
    ev = desc_mask @ (reads + writes)  # [S, B]

    # --- 3. congestion scan (Pallas kernel) -----------------------------
    # Delay = drain time of the work still queued at epoch end (the
    # throughput effect: a saturated switch stretches the epoch by
    # exactly its unserved serial work) + the transient waiting of
    # drained bursts (Little's law), capped at one epoch length so the
    # open-loop model stays physical past saturation (DESIGN.md §5).
    nbins = reads.shape[1]
    epoch_len = bin_width * nbins
    safe_stt = jnp.where(stt > 0, stt, 1.0)
    d_cong = ev * stt[:, None]
    cap = jnp.broadcast_to(bin_width, d_cong.shape)
    cong_backlog, cong_qsum = queue_scan(d_cong, cap, interpret=interpret)
    cong_wait = jnp.minimum(cong_qsum * (bin_width / safe_stt), epoch_len)
    cong = jnp.where(stt > 0, cong_backlog[:, -1] + cong_wait, 0.0)

    # --- 4. bandwidth scan on the served stream (Pallas kernel) ---------
    prev = jnp.concatenate(
        [jnp.zeros((cong_backlog.shape[0], 1), jnp.float32), cong_backlog[:, :-1]],
        axis=1,
    )
    served_work = d_cong + prev - cong_backlog
    served_events = jnp.where(stt[:, None] > 0, served_work / safe_stt[:, None], ev)
    d_bw = served_events * bytes_per_ev
    cap_bw = jnp.broadcast_to(bw[:, None] * bin_width, d_bw.shape)
    bw_backlog, bw_qsum = queue_scan(d_bw, cap_bw, interpret=interpret)
    safe_bw = jnp.where(bw > 0, bw, 1.0)
    bw_wait = jnp.minimum(bw_qsum * (bin_width / bytes_per_ev), epoch_len)
    bwd = jnp.where(bw > 0, bw_backlog[:, -1] / safe_bw + bw_wait, 0.0)

    total = lat.sum() + cong.sum() + bwd.sum()
    return total, lat, cong, bwd, cong_backlog


def timing_analyzer_batch(
    reads,
    writes,
    extra_read_lat,
    extra_write_lat,
    desc_mask,
    stt,
    bw,
    bin_width,
    bytes_per_ev,
    *,
    interpret: bool = True,
):
    """Batched variant for offline replay: reads/writes are f32[E, P, B].

    Topology tensors are shared across the batch.  Implemented by folding
    the batch into the queue_scan row dimension (rows stay independent),
    not vmap, so a single Pallas grid covers all E*S rows.
    """
    e = reads.shape[0]
    reads = reads.astype(jnp.float32)
    writes = writes.astype(jnp.float32)

    lat = (
        reads.sum(axis=2) * extra_read_lat[None, :]
        + writes.sum(axis=2) * extra_write_lat[None, :]
    )  # [E, P]

    ev = jnp.einsum("sp,epb->esb", desc_mask, reads + writes)  # [E, S, B]

    s, b = ev.shape[1], ev.shape[2]
    epoch_len = bin_width * b
    safe_stt = jnp.where(stt > 0, stt, 1.0)
    d_cong = (ev * stt[None, :, None]).reshape(e * s, b)
    cap = jnp.broadcast_to(bin_width, d_cong.shape)
    cong_backlog, cong_qsum = queue_scan(d_cong, cap, interpret=interpret)
    cong_qsum = cong_qsum.reshape(e, s)
    cong_end = cong_backlog[:, -1].reshape(e, s)
    cong_wait = jnp.minimum(cong_qsum * (bin_width / safe_stt[None, :]), epoch_len)
    cong = jnp.where(stt[None, :] > 0, cong_end + cong_wait, 0.0)

    prev = jnp.concatenate(
        [jnp.zeros((e * s, 1), jnp.float32), cong_backlog[:, :-1]], axis=1
    )
    served_work = d_cong + prev - cong_backlog
    stt_rows = jnp.tile(stt, e)[:, None]
    served_events = jnp.where(
        stt_rows > 0, served_work / jnp.where(stt_rows > 0, stt_rows, 1.0),
        ev.reshape(e * s, b),
    )
    d_bw = served_events * bytes_per_ev
    cap_bw = jnp.broadcast_to(jnp.tile(bw, e)[:, None] * bin_width, d_bw.shape)
    bw_backlog, bw_qsum = queue_scan(d_bw, cap_bw, interpret=interpret)
    bw_qsum = bw_qsum.reshape(e, s)
    bw_end = bw_backlog[:, -1].reshape(e, s)
    safe_bw = jnp.where(bw > 0, bw, 1.0)
    bw_wait = jnp.minimum(bw_qsum * (bin_width / bytes_per_ev), epoch_len)
    bwd = jnp.where(bw[None, :] > 0, bw_end / safe_bw[None, :] + bw_wait, 0.0)

    total = lat.sum(axis=1) + cong.sum(axis=1) + bwd.sum(axis=1)  # [E]
    return total, lat, cong, bwd


def example_args(pools: int = NUM_POOLS, switches: int = NUM_SWITCHES,
                 nbins: int = NUM_BINS):
    """ShapeDtypeStructs for AOT lowering of timing_analyzer."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((pools, nbins), f32),      # reads
        jax.ShapeDtypeStruct((pools, nbins), f32),      # writes
        jax.ShapeDtypeStruct((pools,), f32),            # extra_read_lat
        jax.ShapeDtypeStruct((pools,), f32),            # extra_write_lat
        jax.ShapeDtypeStruct((switches, pools), f32),   # desc_mask
        jax.ShapeDtypeStruct((switches,), f32),         # stt
        jax.ShapeDtypeStruct((switches,), f32),         # bw
        jax.ShapeDtypeStruct((), f32),                  # bin_width
        jax.ShapeDtypeStruct((), f32),                  # bytes_per_ev
    )


def example_args_batch(batch: int = BATCH, pools: int = NUM_POOLS,
                       switches: int = NUM_SWITCHES, nbins: int = NUM_BINS):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((batch, pools, nbins), f32),
        jax.ShapeDtypeStruct((batch, pools, nbins), f32),
        jax.ShapeDtypeStruct((pools,), f32),
        jax.ShapeDtypeStruct((pools,), f32),
        jax.ShapeDtypeStruct((switches, pools), f32),
        jax.ShapeDtypeStruct((switches,), f32),
        jax.ShapeDtypeStruct((switches,), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
    )

//! End-to-end integration: full pipeline (workload → cache → tracker →
//! binning → AOT timing analyzer via PJRT → report) on real builtin
//! topologies, plus trace record/replay and CLI-level consistency.

use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::gem5like::DetailedSim;
use cxlmemsim::multihost;
use cxlmemsim::prelude::*;
use cxlmemsim::alloctrack::PolicyKind;
use cxlmemsim::trace::io as trace_io;
use cxlmemsim::workload::{self, TraceReplay};

fn fast_cfg() -> SimConfig {
    SimConfig {
        scale: 0.002,
        cache_scale: 64,
        epoch_ms: 0.1,
        ..SimConfig::default()
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_full_pipeline_mmap_read() {
    let mut cfg = fast_cfg();
    cfg.backend = AnalyzerBackend::Pjrt;
    let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
    let rep = sim.run_workload("mmap_read").unwrap();
    assert!(rep.total_misses > 0);
    assert!(rep.simulated_ns > rep.native_ns);
    assert_eq!(rep.backend, "pjrt");
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_agree_end_to_end() {
    // identical seeds + workload => identical binned inputs => the two
    // backends must produce near-identical *simulated* time.
    let run = |backend| {
        let mut cfg = fast_cfg();
        cfg.backend = backend;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        sim.run_workload("zipfian").unwrap()
    };
    let p = run(AnalyzerBackend::Pjrt);
    let n = run(AnalyzerBackend::Native);
    assert_eq!(p.total_misses, n.total_misses, "substrate must be deterministic");
    let rel = (p.simulated_ns - n.simulated_ns).abs() / n.simulated_ns;
    assert!(rel < 1e-3, "pjrt {} vs native {} (rel {rel})", p.simulated_ns, n.simulated_ns);
}

#[test]
fn all_table1_workloads_run_e2e() {
    for wl in TABLE1_WORKLOADS {
        let mut sim = Coordinator::new(builtin::fig2(), fast_cfg()).unwrap();
        let rep = sim.run_workload(wl).unwrap();
        assert!(rep.total_accesses > 0, "{wl}");
        assert!(rep.epochs_run > 0, "{wl}");
        assert!(rep.simulated_ns >= rep.native_ns, "{wl}");
    }
}

#[test]
fn record_replay_matches_direct_run() {
    // record the trace, replay it: must see the same misses and delay.
    let mut wl = workload::by_name("stream", 0.002, 9).unwrap();
    let mut events = Vec::new();
    while let Some(ev) = wl.next_event() {
        events.push(ev);
    }
    // roundtrip through the binary format
    let mut buf = Vec::new();
    trace_io::write_binary(&mut buf, &events).unwrap();
    let back = trace_io::read_binary(&buf).unwrap();
    assert_eq!(back.len(), events.len());

    let mut cfg = fast_cfg();
    cfg.seed = 9;
    let mut direct = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let direct_rep = direct.run_workload("stream").unwrap();

    let mut replayed = Coordinator::new(builtin::fig2(), cfg).unwrap();
    let mut replay = TraceReplay::new("replay", back);
    let replay_rep = replayed.run(&mut replay).unwrap();

    assert_eq!(direct_rep.total_misses, replay_rep.total_misses);
    let rel = (direct_rep.delay_ns - replay_rep.delay_ns).abs() / direct_rep.delay_ns.max(1.0);
    assert!(rel < 1e-6, "replay drifted: {rel}");
}

#[test]
fn v2_streamed_replay_matches_direct_run() {
    // the streaming flavor of record_replay_matches_direct_run: record
    // to a chunked v2 file on disk, replay it through the auto-detect
    // front door (TraceWorkload → TraceStream), compare to direct run.
    let mut cfg = fast_cfg();
    cfg.seed = 9;
    let mut wl = workload::by_name("stream", cfg.scale, cfg.seed).unwrap();
    let path = std::env::temp_dir().join(format!("cxlms-e2e-v2-{}.bin", std::process::id()));
    let f = std::fs::File::create(&path).unwrap();
    let mut w = trace_io::V2Writer::with_chunk_events(f, 1024).unwrap();
    let mut buf = Vec::new();
    while wl.next_batch(&mut buf, 4096) {
        w.push_slice(&buf).unwrap();
        buf.clear();
    }
    w.push_slice(&buf).unwrap();
    let summary = w.finish().unwrap();
    assert!(summary.chunks > 1, "want a multi-chunk archive");

    let mut direct = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let direct_rep = direct.run_workload("stream").unwrap();

    let mut replay = TraceWorkload::open(path.to_str().unwrap()).unwrap();
    assert!(replay.stream().is_some(), "v2 file must stream, not load");
    let mut replayed = Coordinator::new(builtin::fig2(), cfg).unwrap();
    let replay_rep = replayed.run(&mut replay).unwrap();
    assert!(replay.take_error().is_none());
    std::fs::remove_file(&path).ok();

    assert_eq!(direct_rep.total_misses, replay_rep.total_misses);
    assert_eq!(direct_rep.total_accesses, replay_rep.total_accesses);
    let rel = (direct_rep.delay_ns - replay_rep.delay_ns).abs() / direct_rep.delay_ns.max(1.0);
    assert!(rel < 1e-6, "streamed replay drifted: {rel}");
}

#[test]
fn detailed_and_epoch_models_rank_topologies_identically() {
    // accuracy shape check: both models must agree that deep > fig2 >
    // direct in simulated slowdown for a CXL-heavy streaming workload.
    let mut sims = Vec::new();
    for topo in [builtin::direct(), builtin::fig2(), builtin::deep()] {
        let mut sim = Coordinator::new(topo.clone(), fast_cfg()).unwrap();
        let rep = sim.run_workload("mmap_write").unwrap();
        let mut det = DetailedSim::new(topo, 64, PolicyKind::CxlOnly);
        let mut wl = workload::by_name("mmap_write", 0.002, fast_cfg().seed).unwrap();
        let det_rep = det.run(wl.as_mut());
        sims.push((rep.simulated_ns, det_rep.simulated_ns));
    }
    assert!(sims[0].0 < sims[2].0, "epoch model: direct must beat deep");
    assert!(sims[0].1 < sims[2].1, "detailed model: direct must beat deep");
}

#[test]
fn multihost_shares_one_analyzer() {
    let cfg = fast_cfg();
    let hosts: Vec<_> = (0..3)
        .map(|i| workload::by_name("uniform", 0.002, i).unwrap())
        .collect();
    let rep = multihost::run_shared(&builtin::wide(), &cfg, hosts).unwrap();
    assert_eq!(rep.hosts.len(), 3);
    assert!(rep.epochs > 0);
    assert!(rep.hosts.iter().all(|h| h.misses > 0));
}

#[test]
fn policy_changes_outcome() {
    // local-only vs cxl-only must bracket localfirst
    let run = |policy| {
        let mut cfg = fast_cfg();
        cfg.policy = policy;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        sim.run_workload("mmap_write").unwrap().delay_ns
    };
    let local = run(PolicyKind::LocalOnly);
    let cxl = run(PolicyKind::CxlOnly);
    assert_eq!(local, 0.0);
    assert!(cxl > 0.0);
    let lf = run(PolicyKind::LocalFirst { local_cap_bytes: u64::MAX });
    assert_eq!(lf, 0.0, "everything fits locally under localfirst");
}

#[cfg(feature = "pjrt")]
#[test]
fn batched_replay_matches_sequential_coordinator() {
    // the batch-16 artifact must produce the same totals as the
    // sequential epoch loop (delays don't feed back into the stream)
    let mut cfg = fast_cfg();
    cfg.backend = AnalyzerBackend::Pjrt;
    cfg.scale = 0.004;
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("zipfian").unwrap();

    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let bat_rep =
        cxlmemsim::coordinator::run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();

    assert_eq!(seq_rep.epochs_run, bat_rep.epochs_run);
    assert_eq!(seq_rep.total_misses, bat_rep.total_misses);
    let rel = (seq_rep.delay_ns - bat_rep.delay_ns).abs() / seq_rep.delay_ns.max(1.0);
    assert!(
        rel < 1e-3,
        "batched {} vs sequential {} (rel {rel})",
        bat_rep.delay_ns,
        seq_rep.delay_ns
    );
}

#[test]
fn epoch_migration_policy_reduces_delay() {
    use cxlmemsim::policy::{HotnessMigration, PolicyStack};
    let run = |migrate: bool| {
        let mut cfg = fast_cfg();
        cfg.scale = 0.004;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        if migrate {
            // zero per-byte stall isolates the placement benefit; the
            // injected copy traffic is still paid (cost-modeled)
            let stack =
                PolicyStack::new(0.0).with(Box::new(HotnessMigration::new(2, u64::MAX)));
            sim.set_policy_stack(stack);
        }
        sim.run_workload("zipfian").unwrap()
    };
    let without = run(false);
    let with = run(true);
    assert!(with.migrations > 0, "stack must act");
    assert!(
        with.delay_ns < without.delay_ns,
        "migration should help a zipfian workload even paying its copy \
         traffic: {} !< {}",
        with.delay_ns,
        without.delay_ns
    );
}

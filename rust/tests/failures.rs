//! Failure injection: every user-reachable error path must fail with a
//! clear error, never a panic, and never corrupt subsequent runs.

use cxlmemsim::coordinator::{Coordinator, SimConfig};
#[cfg(feature = "pjrt")]
use cxlmemsim::runtime::pjrt::PjrtAnalyzer;
#[cfg(feature = "pjrt")]
use cxlmemsim::runtime::shapes;
#[cfg(feature = "pjrt")]
use cxlmemsim::topology::TopoTensors;
use cxlmemsim::topology::{builtin, Topology};
use cxlmemsim::trace::io as trace_io;
use cxlmemsim::util::json::Json;
use cxlmemsim::util::toml::TomlDoc;

fn fast_cfg() -> SimConfig {
    SimConfig { scale: 0.002, cache_scale: 64, epoch_ms: 0.1, ..SimConfig::default() }
}

/// `unwrap_err` without requiring `T: Debug` on the success side.
fn err_of<T>(r: anyhow::Result<T>) -> String {
    match r {
        Ok(_) => panic!("expected an error"),
        Err(e) => e.to_string(),
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn missing_artifacts_dir_is_clean_error() {
    let mut cfg = fast_cfg();
    cfg.backend = cxlmemsim::runtime::AnalyzerBackend::Pjrt;
    cfg.artifacts_dir = "/does/not/exist".into();
    let err = err_of(Coordinator::new(builtin::fig2(), cfg));
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}

#[test]
fn corrupt_manifest_is_clean_error() {
    let dir = std::env::temp_dir().join(format!("cxlms-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{not json").unwrap();
    let mut cfg = fast_cfg();
    cfg.backend = cxlmemsim::runtime::AnalyzerBackend::Pjrt;
    cfg.artifacts_dir = dir.to_str().unwrap().to_string();
    assert!(Coordinator::new(builtin::fig2(), cfg).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn artifact_shape_mismatch_is_detected() {
    // manifest claiming other shapes than requested must be rejected
    let dir = std::env::temp_dir().join(format!("cxlms-shape-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"pools":2,"switches":2,"nbins":8,"batch":2,
            "single":"x.hlo.txt","batch_module":"y.hlo.txt"}"#,
    )
    .unwrap();
    let topo = builtin::fig2();
    let t = TopoTensors::build(&topo, 8, 8).unwrap();
    let err = err_of(PjrtAnalyzer::new(&t, shapes::NUM_BINS, dir.to_str().unwrap()));
    assert!(err.contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oversized_topology_rejected_before_model_load() {
    // 9 pools > compiled P=8
    let mut src = String::from(
        "name = \"big\"\n[[node]]\nname = \"rc\"\nkind = \"root\"\nlatency_ns = 10\nbandwidth_gbps = 64\nstt_ns = 2\n",
    );
    for i in 0..9 {
        src.push_str(&format!(
            "[[node]]\nname = \"p{i}\"\nkind = \"pool\"\nparent = \"rc\"\nlatency_ns = 100\nbandwidth_gbps = 32\nstt_ns = 20\n"
        ));
    }
    let topo = Topology::from_toml_str(&src).unwrap();
    let err = err_of(Coordinator::new(topo, fast_cfg()));
    assert!(err.contains("pools"), "{err}");
}

#[test]
fn corrupt_traces_never_panic() {
    // bit-flip a valid trace at every 7th byte; reader must error or
    // return events, never panic.
    let mut wl = cxlmemsim::workload::by_name("sbrk", 0.001, 1).unwrap();
    let mut events = Vec::new();
    while let Some(ev) = wl.next_event() {
        events.push(ev);
        if events.len() > 200 {
            break;
        }
    }
    let mut buf = Vec::new();
    trace_io::write_binary(&mut buf, &events).unwrap();
    for i in (0..buf.len()).step_by(7) {
        let mut corrupted = buf.clone();
        corrupted[i] ^= 0xff;
        let _ = trace_io::read_binary(&corrupted); // must not panic
    }
    // truncations at every length
    for cut in 0..buf.len().min(64) {
        let _ = trace_io::read_binary(&buf[..cut]);
    }
}

#[test]
fn malformed_jsonl_lines_error_with_line_numbers() {
    let src = "{\"ev\":\"access\",\"addr\":64,\"w\":0}\n{\"ev\":\"access\",\"addr\":}\n";
    let err = trace_io::read_jsonl(src.as_bytes()).unwrap_err();
    assert!(err.contains("line 2"), "{err}");
}

#[test]
fn fuzz_json_parser_never_panics() {
    use cxlmemsim::util::rng::Rng;
    let mut rng = Rng::new(0xf00d);
    let alphabet: &[u8] = b"{}[]\",:0123456789.eE+-truefalsn\\ ";
    for _ in 0..2000 {
        let len = rng.below(64) as usize;
        let s: Vec<u8> = (0..len)
            .map(|_| alphabet[rng.below(alphabet.len() as u64) as usize])
            .collect();
        if let Ok(s) = String::from_utf8(s) {
            let _ = Json::parse(&s); // must not panic
        }
    }
}

#[test]
fn fuzz_toml_parser_never_panics() {
    use cxlmemsim::util::rng::Rng;
    let mut rng = Rng::new(0xbeef);
    let alphabet: &[u8] = b"[]\"=#\nabc_0123456789. -";
    for _ in 0..2000 {
        let len = rng.below(96) as usize;
        let s: Vec<u8> = (0..len)
            .map(|_| alphabet[rng.below(alphabet.len() as u64) as usize])
            .collect();
        if let Ok(s) = String::from_utf8(s) {
            let _ = TomlDoc::parse(&s); // must not panic
        }
    }
}

#[test]
fn zero_length_and_empty_workload_edge_cases() {
    // tiniest possible scale must still terminate and produce a report
    let mut cfg = fast_cfg();
    cfg.scale = 1e-9; // clamps to minimum working set
    let mut sim = Coordinator::new(builtin::direct(), cfg).unwrap();
    let rep = sim.run_workload("mmap_read").unwrap();
    assert!(rep.total_accesses > 0);
}

#[test]
fn bad_topology_configs_all_error_cleanly() {
    let cases = [
        "",                                     // empty
        "[[node]]\nname = \"x\"\nkind = \"pool\"\nlatency_ns = 1\nbandwidth_gbps = 1\nstt_ns = 1", // no root
        "nonsense without equals",
    ];
    for (i, src) in cases.iter().enumerate() {
        assert!(Topology::from_toml_str(src).is_err(), "case {i} should fail");
    }
}

// ------------------------------------------------------ fault plans

#[test]
fn corrupt_trace_errors_name_record_and_offset() {
    // end-to-end flavor of the io.rs unit tests: a damaged archive
    // must point at the damaged record, not say "truncated trace"
    let mut wl = cxlmemsim::workload::by_name("sbrk", 0.001, 1).unwrap();
    let mut events = Vec::new();
    while let Some(ev) = wl.next_event() {
        events.push(ev);
        if events.len() >= 50 {
            break;
        }
    }
    let mut buf = Vec::new();
    trace_io::write_binary(&mut buf, &events).unwrap();
    let err = trace_io::read_binary(&buf[..buf.len() - 2]).unwrap_err();
    assert!(err.contains("record"), "{err}");
    assert!(err.contains("at byte"), "{err}");
}

// ------------------------------------------------ CXLTRC v2 archives

/// A short real event stream for archive-corruption tests.
fn sample_events(n: usize) -> Vec<cxlmemsim::trace::WlEvent> {
    let mut wl = cxlmemsim::workload::by_name("sbrk", 0.001, 1).unwrap();
    let mut events = Vec::new();
    while let Some(ev) = wl.next_event() {
        events.push(ev);
        if events.len() >= n {
            break;
        }
    }
    events
}

#[test]
fn corrupt_v2_traces_never_panic() {
    // same contract as the v1 fuzz above: bit-flip every 7th byte of a
    // chunked archive; the reader must error or return events, never
    // panic or over-allocate on a corrupted directory.
    let events = sample_events(300);
    let mut buf = Vec::new();
    trace_io::write_binary_v2_chunked(&mut buf, &events, 64).unwrap();
    for i in (0..buf.len()).step_by(7) {
        let mut corrupted = buf.clone();
        corrupted[i] ^= 0xff;
        let _ = trace_io::read_binary_v2(&corrupted); // must not panic
        let _ = trace_io::read_binary_any(&corrupted);
    }
    // truncations at every length near both ends (header and footer)
    for cut in 0..buf.len().min(96) {
        let _ = trace_io::read_binary_v2(&buf[..cut]);
        let _ = trace_io::read_binary_v2(&buf[..buf.len() - cut]);
    }
}

#[test]
fn corrupt_v2_chunk_errors_name_chunk_and_byte() {
    // a damaged chunk payload must point at the chunk index and the
    // absolute byte offset, not say "truncated trace"
    let events = sample_events(300);
    let mut buf = Vec::new();
    trace_io::write_binary_v2_chunked(&mut buf, &events, 64).unwrap();
    let mut cur = std::io::Cursor::new(buf.as_slice());
    let idx = trace_io::V2Index::read(&mut cur).unwrap();
    assert!(idx.chunks.len() >= 3, "need several chunks");
    let off = idx.chunks[1].offset as usize;
    buf[off] = 9; // unknown record tag in chunk 1's first record
    let err = trace_io::read_binary_v2(&buf).unwrap_err();
    assert!(err.contains("chunk 1"), "{err}");
    assert!(err.contains("at byte"), "{err}");
}

#[test]
fn v2_stream_open_failures_error_cleanly() {
    use cxlmemsim::trace::stream::TraceStream;
    // nonexistent file
    assert!(TraceStream::open("/does/not/exist.bin").is_err());
    // v1 archives are in-memory only: the streaming reader must say so
    // rather than misparse the count-prefixed layout as a directory
    let events = sample_events(50);
    let mut buf = Vec::new();
    trace_io::write_binary(&mut buf, &events).unwrap();
    let path = std::env::temp_dir().join(format!("cxlms-v1-{}.bin", std::process::id()));
    std::fs::write(&path, &buf).unwrap();
    let err = match TraceStream::open(path.to_str().unwrap()) {
        Ok(_) => panic!("v1 archive must not open as a v2 stream"),
        Err(e) => e,
    };
    assert!(err.contains("v2"), "{err}");
    std::fs::remove_file(&path).ok();
    // the auto-detecting TraceWorkload front door still accepts it
    let mut ok = Vec::new();
    trace_io::write_binary(&mut ok, &events).unwrap();
    let path = std::env::temp_dir().join(format!("cxlms-v1ok-{}.bin", std::process::id()));
    std::fs::write(&path, &ok).unwrap();
    let wl = cxlmemsim::workload::TraceWorkload::open(path.to_str().unwrap());
    assert!(wl.is_ok(), "v1 must keep working through TraceWorkload");
    std::fs::remove_file(&path).ok();
}

#[test]
fn jsonl_mistyped_fields_error_with_line_and_key() {
    // strict decode: a mistyped field is a named, line-numbered error,
    // not a silently-zeroed access
    let src = "{\"ev\":\"access\",\"addr\":64,\"w\":0}\n{\"ev\":\"access\",\"addr\":\"yes\",\"w\":0}\n";
    let err = trace_io::read_jsonl(src.as_bytes()).unwrap_err();
    assert!(err.contains("line 2"), "{err}");
    assert!(err.contains("addr"), "{err}");
    let src = "{\"ev\":\"access\",\"w\":1}\n";
    let err = trace_io::read_jsonl(src.as_bytes()).unwrap_err();
    assert!(err.contains("line 1"), "{err}");
    assert!(err.contains("addr"), "{err}");
}

#[test]
fn malformed_fault_specs_all_error_cleanly() {
    use cxlmemsim::fault::{FaultError, FaultPlan};
    let topo = builtin::fig2();

    // parse-level failures: clear one-line messages, never a panic
    for (spec, what) in [
        ("", "empty"),
        ("storm", "missing pool@start"),
        ("storm:pool1", "missing @start"),
        ("storm:pool1@x+2:rd=10", "bad start"),
        ("storm:pool1@1+y:rd=10", "bad window"),
        ("storm:pool1@1+2:rd", "bad param"),
        ("storm:pool1@1+2:rd=abc", "bad value"),
        ("meteor:pool1@1+2", "unknown kind"),
        ("retrain:pool1@1+2:frac=0", "frac out of range"),
        ("retrain:pool1@1+2:frac=1.5", "frac out of range"),
    ] {
        match FaultPlan::parse_inline(spec) {
            Err(FaultError::Parse(msg)) => {
                assert!(!msg.is_empty(), "{what}: empty message")
            }
            other => panic!("{what}: expected a parse error, got {other:?}"),
        }
    }

    // resolve-level failures against a concrete topology
    let unknown = FaultPlan::parse_inline("storm:nosuch@1+2:rd=10").unwrap();
    assert!(matches!(unknown.resolve(&topo), Err(FaultError::UnknownPool(_))));
    let zero = FaultPlan::parse_inline("retrain:pool1@3+0:frac=0.5").unwrap();
    assert!(matches!(zero.resolve(&topo), Err(FaultError::ZeroWindow(_))));
    let overlap = FaultPlan::parse_inline("offline:pool0@1;offline:pool0@9").unwrap();
    assert!(matches!(overlap.resolve(&topo), Err(FaultError::OverlappingOffline(_))));

    // the same failures surface as clean errors through the driver
    let mut cfg = fast_cfg();
    cfg.faults = Some(unknown);
    let err =
        err_of(Coordinator::new(builtin::fig2(), cfg).and_then(|mut c| c.run_workload("stream")));
    assert!(err.contains("unknown pool"), "{err}");
}

#[test]
fn malformed_fault_toml_errors_cleanly() {
    use cxlmemsim::fault::{FaultError, FaultPlan};
    for (src, what) in [
        ("", "no events"),
        ("seed = 3\n", "no events"),
        ("[[fault]]\npool = \"pool1\"\nstart = 1\n", "missing kind"),
        ("[[fault]]\nkind = \"storm\"\nstart = 1\n", "missing pool"),
        (
            "[[fault]]\nkind = \"warp\"\npool = \"pool1\"\nstart = 1\n",
            "unknown kind",
        ),
        (
            "[[fault]]\nkind = \"retrain\"\npool = \"pool1\"\nstart = 1\nepochs = 2\nfrac = 2.0\n",
            "frac out of range",
        ),
    ] {
        assert!(
            matches!(FaultPlan::parse_toml(src), Err(FaultError::Parse(_))),
            "{what}: should be a parse error"
        );
    }
}

#[test]
fn fault_lifecycle_violations_all_error_cleanly() {
    use cxlmemsim::fault::{FaultError, FaultPlan};
    let topo = builtin::fig2();

    // an `online` with no open offline window on its pool
    let orphan = FaultPlan::parse_inline("online:pool0@5:warmup=2").unwrap();
    assert!(matches!(orphan.resolve(&topo), Err(FaultError::OnlineWithoutOffline(_))));
    // closing the wrong pool's window is the same error
    let wrong = FaultPlan::parse_inline("offline:pool0@2;online:pool1@5").unwrap();
    assert!(matches!(wrong.resolve(&topo), Err(FaultError::OnlineWithoutOffline(_))));
    // offline → online → online: the second online finds no open window
    let double =
        FaultPlan::parse_inline("offline:pool0@2;online:pool0@4;online:pool0@6").unwrap();
    assert!(matches!(double.resolve(&topo), Err(FaultError::OnlineWithoutOffline(_))));
    // offline → online → offline → offline: the re-opened window overlaps
    let reopen = FaultPlan::parse_inline(
        "offline:pool0@2;online:pool0@4;offline:pool0@6;offline:pool0@8",
    )
    .unwrap();
    assert!(matches!(reopen.resolve(&topo), Err(FaultError::OverlappingOffline(_))));

    // the lifecycle errors surface as clean errors through the driver
    let mut cfg = fast_cfg();
    cfg.faults = Some(FaultPlan::parse_inline("online:pool0@5").unwrap());
    let err =
        err_of(Coordinator::new(builtin::fig2(), cfg).and_then(|mut c| c.run_workload("stream")));
    assert!(err.contains("online"), "{err}");
    assert!(err.contains("offline"), "{err}");
}

#[test]
fn malformed_soak_specs_all_error_cleanly() {
    use cxlmemsim::fault::{FaultError, FaultPlan};
    for (spec, what) in [
        ("", "empty spec"),
        ("kinds=storm", "missing mtbf"),
        ("mtbf=0", "zero mtbf"),
        ("mtbf=abc", "bad mtbf"),
        ("mtbf=100,kinds=meteor", "unknown kind"),
        ("mtbf=100,kinds=online", "online without offline pairing"),
        ("mtbf=100,cadence=5", "unknown key"),
        ("mtbf=100,frac=1.5", "frac out of range"),
        ("mtbf=100,epochs=0", "zero horizon"),
    ] {
        match FaultPlan::generate(7, spec) {
            Err(FaultError::Parse(msg)) => {
                assert!(!msg.is_empty(), "{what}: empty message")
            }
            other => panic!("{what}: expected a parse error, got {other:?}"),
        }
    }
}

#[test]
fn host_scoped_faults_rejected_outside_multihost() {
    use cxlmemsim::fault::{FaultError, FaultPlan};
    // single-host drivers reject host-scoped plans outright
    let scoped = FaultPlan::parse_inline("storm:pool1@3+2:rd=20,host=h1").unwrap();
    assert!(matches!(scoped.resolve(&builtin::fig2()), Err(FaultError::HostScope(_))));
    // multihost rejects host-scoped events that are not retry storms
    let off = FaultPlan::parse_inline("offline:pool0@9:host=h0").unwrap();
    assert!(matches!(off.split_hosts(4), Err(FaultError::HostScope(_))));
    // and host names beyond the host count
    let beyond = FaultPlan::parse_inline("storm:pool1@3+2:rd=20,host=h7").unwrap();
    match beyond.split_hosts(2) {
        Err(FaultError::HostScope(msg)) => {
            assert!(msg.contains("h7"), "{msg}");
            assert!(msg.contains("h1"), "must name the valid range: {msg}");
        }
        other => panic!("expected a host-scope error, got {other:?}"),
    }
}

#[test]
fn faults_on_pjrt_backend_is_a_config_error() {
    let mut cfg = fast_cfg();
    cfg.backend = cxlmemsim::runtime::AnalyzerBackend::Pjrt;
    cfg.faults = Some(cxlmemsim::fault::FaultPlan::parse_inline("offline:pool0@2").unwrap());
    let err = err_of(Coordinator::new(builtin::fig2(), cfg.clone()));
    assert!(err.contains("--backend native"), "unhelpful error: {err}");
    // batched replay takes the same guard
    let mut wl = cxlmemsim::workload::by_name("stream", cfg.scale, cfg.seed).unwrap();
    let err = err_of(cxlmemsim::coordinator::run_batched(&builtin::fig2(), &cfg, wl.as_mut()));
    assert!(err.contains("--backend native"), "{err}");
}

// ---- sweep specs: every malformed spec must fail at parse time with
// a structured error that NAMES the offending table/axis/field, so a
// 200-cell grid never dies halfway through with a bare panic.

fn sweep_err(src: &str) -> String {
    match cxlmemsim::sweep::SweepSpec::parse(src) {
        Ok(_) => panic!("malformed spec parsed"),
        Err(e) => e.to_string(),
    }
}

#[test]
fn sweep_spec_missing_name_names_the_key() {
    let err = sweep_err("[grid]\ntopo = [\"direct\"]\n");
    assert!(err.contains("`name`"), "{err}");
}

#[test]
fn sweep_spec_unknown_axis_is_named() {
    let err = sweep_err("name = \"t\"\n[grid]\nlatencyz = [1, 2]\n");
    assert!(err.contains("`latencyz`"), "{err}");
    assert!(err.contains("[grid]"), "{err}");
}

#[test]
fn sweep_spec_bad_axis_value_names_axis_and_value() {
    let err = sweep_err("name = \"t\"\n[grid]\nworkload = [\"streem\"]\n");
    assert!(err.contains("`workload`"), "{err}");
    assert!(err.contains("`streem`"), "{err}");
}

#[test]
fn sweep_spec_baseline_must_pin_a_grid_axis_value() {
    // pinning an axis not in the grid
    let err = sweep_err(
        "name = \"t\"\n[grid]\ntopo = [\"direct\"]\n[baseline]\nworkload = \"stream\"\n",
    );
    assert!(err.contains("[baseline]"), "{err}");
    assert!(err.contains("`workload`"), "{err}");
    // pinning a value the axis does not contain
    let err = sweep_err(
        "name = \"t\"\n[grid]\ntopo = [\"direct\"]\n[baseline]\ntopo = \"fig2\"\n",
    );
    assert!(err.contains("`topo`"), "{err}");
    assert!(err.contains("fig2"), "{err}");
}

#[test]
fn sweep_spec_invariant_order_values_must_be_axis_values() {
    let err = sweep_err(concat!(
        "name = \"t\"\n[grid]\ntopo = [\"direct\", \"fig2\"]\n",
        "[[invariant]]\nmetric = \"delay_ms\"\naxis = \"topo\"\n",
        "order = [\"direct\", \"deep\"]\n",
    ));
    assert!(err.contains("[[invariant]]"), "{err}");
    assert!(err.contains("deep"), "{err}");
}

#[test]
fn sweep_spec_sharded_multihost_cell_is_rejected_at_parse_time() {
    let err = sweep_err(concat!(
        "name = \"t\"\n[grid]\nhosts = [1, 2]\n",
        "[config]\ndriver = \"multihost\"\nshards = 2\nworkload = \"stream\"\n",
    ));
    assert!(err.contains("cell"), "{err}");
    assert!(err.contains("shard"), "{err}");
}

#[test]
fn sweep_cli_reports_missing_spec_file_path() {
    let err = match cxlmemsim::sweep::SweepSpec::from_file("/does/not/exist.toml") {
        Ok(_) => panic!("parsed a nonexistent file"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("/does/not/exist.toml"), "{err}");
}

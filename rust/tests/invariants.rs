//! Randomized property tests over coordinator-facing invariants.
//!
//! proptest is not available offline, so this uses the repo's own
//! deterministic PRNG for many-seed randomized checks; each failure
//! message carries the seed, which is sufficient to reproduce (the
//! whole substrate is seed-deterministic).

use cxlmemsim::alloctrack::{AllocTracker, PolicyKind};
use cxlmemsim::cache::CacheHierarchy;
use cxlmemsim::runtime::native::NativeAnalyzer;
use cxlmemsim::runtime::{TimingInputs, TimingModel};
use cxlmemsim::topology::{builtin, HostParams, Node, NodeKind, TopoTensors, Topology, LOCAL_POOL};
use cxlmemsim::trace::{AllocEvent, AllocKind};
use cxlmemsim::util::rng::Rng;

// ------------------------------------------------------------ topology

/// Generate a random valid topology with up to 7 pools / 7 switches.
fn random_topology(seed: u64) -> Topology {
    let mut rng = Rng::new(seed);
    let n_switch = rng.below(5) as usize; // interior switches
    let n_pool = 1 + rng.below(6) as usize;
    let mut nodes = vec![Node {
        name: "rc".into(),
        kind: NodeKind::Root,
        parent: None,
        read_latency_ns: rng.range_f64(5.0, 40.0),
        write_latency_ns: rng.range_f64(5.0, 40.0),
        bandwidth: rng.range_f64(16.0, 128.0),
        stt_ns: rng.range_f64(0.5, 8.0),
        capacity_bytes: 0,
    }];
    for i in 0..n_switch {
        let parent = rng.below(nodes.len() as u64) as usize;
        // parents must be non-pool; all nodes so far are non-pool
        nodes.push(Node {
            name: format!("sw{i}"),
            kind: NodeKind::Switch,
            parent: Some(parent),
            read_latency_ns: rng.range_f64(10.0, 80.0),
            write_latency_ns: rng.range_f64(10.0, 80.0),
            bandwidth: rng.range_f64(8.0, 64.0),
            stt_ns: rng.range_f64(5.0, 50.0),
            capacity_bytes: 0,
        });
    }
    let interior = nodes.len();
    for i in 0..n_pool {
        let parent = rng.below(interior as u64) as usize;
        nodes.push(Node {
            name: format!("pool{i}"),
            kind: NodeKind::Pool,
            parent: Some(parent),
            read_latency_ns: rng.range_f64(60.0, 250.0),
            write_latency_ns: rng.range_f64(60.0, 280.0),
            bandwidth: rng.range_f64(8.0, 48.0),
            stt_ns: rng.range_f64(5.0, 40.0),
            capacity_bytes: (1 + rng.below(512)) << 30,
        });
    }
    Topology::new(&format!("rand{seed}"), HostParams::default(), nodes).unwrap()
}

#[test]
fn random_topologies_validate_and_tensorize() {
    for seed in 0..200 {
        let t = random_topology(seed);
        let tensors = TopoTensors::build(&t, 8, 8).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // invariant: every CXL pool routes through the RC row
        for pool in 1..t.num_pools() {
            assert_eq!(tensors.mask(0, pool), 1.0, "seed {seed} pool {pool} not under RC");
            // extra latency is nonnegative and consistent with the tree
            assert!(tensors.extra_read_lat[pool] >= 0.0, "seed {seed}");
        }
        // invariant: pool path latency >= RC hop latency
        for pool in 1..t.num_pools() {
            assert!(
                t.pool_read_latency(pool) >= t.nodes()[t.root()].read_latency_ns,
                "seed {seed}"
            );
        }
        // local pool is never masked
        for row in 0..8 {
            assert_eq!(tensors.mask(row, 0), 0.0, "seed {seed}");
        }
    }
}

#[test]
fn deeper_pools_have_larger_latency() {
    for seed in 0..100 {
        let t = random_topology(seed);
        for pool in 1..t.num_pools() {
            let path = t.path_to_root(pool);
            let partial: f64 = path[1..].iter().map(|&i| t.nodes()[i].read_latency_ns).sum();
            assert!(
                t.pool_read_latency(pool) > partial - 1e-9,
                "seed {seed}: pool hop must add latency"
            );
        }
    }
}

// ------------------------------------------------------- timing model

#[test]
fn analyzer_monotone_in_traffic() {
    // adding traffic anywhere never decreases total delay
    let topo = builtin::fig2();
    let tensors = TopoTensors::build(&topo, 8, 8).unwrap();
    let mut model = NativeAnalyzer::new(&tensors, 64);
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed);
        let n = 8 * 64;
        let reads: Vec<f32> = (0..n).map(|_| rng.below(30) as f32).collect();
        let writes: Vec<f32> = (0..n).map(|_| rng.below(15) as f32).collect();
        let base = model
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 500.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let mut more = reads.clone();
        let idx = (1 + rng.below(3)) as usize * 64 + rng.below(64) as usize; // a CXL pool row
        more[idx] += 10.0;
        let bumped = model
            .analyze(&TimingInputs {
                reads: &more,
                writes: &writes,
                bin_width: 500.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(
            bumped.total >= base.total - 1e-3,
            "seed {seed}: traffic increase reduced delay {} -> {}",
            base.total,
            bumped.total
        );
    }
}

#[test]
fn analyzer_scale_invariance_of_latency_term() {
    // with huge bin width (no congestion/bw), delay is exactly linear
    let topo = builtin::fig2();
    let tensors = TopoTensors::build(&topo, 8, 8).unwrap();
    let mut model = NativeAnalyzer::new(&tensors, 32);
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed ^ 0xabc);
        let n = 8 * 32;
        let reads: Vec<f32> = (0..n).map(|_| rng.below(10) as f32).collect();
        let writes = vec![0.0f32; n];
        let one = model
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1e9,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let doubled: Vec<f32> = reads.iter().map(|x| x * 2.0).collect();
        let two = model
            .analyze(&TimingInputs {
                reads: &doubled,
                writes: &writes,
                bin_width: 1e9,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let rel = (two.total - 2.0 * one.total).abs() / (one.total.max(1.0) * 2.0);
        assert!(rel < 1e-5, "seed {seed}: latency term not linear ({rel})");
    }
}

// ------------------------------------------------------------ tracker

#[test]
fn tracker_accounting_never_negative_and_conserves() {
    for seed in 0..100u64 {
        let topo = builtin::fig2();
        let mut rng = Rng::new(seed);
        let mut tracker = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
        let mut live: Vec<(u64, u64)> = Vec::new();
        for step in 0..200 {
            if live.is_empty() || rng.f64() < 0.6 {
                let addr = (1 + rng.below(1 << 20)) * 4096;
                let len = (1 + rng.below(64)) * 4096;
                tracker.on_alloc_event(&AllocEvent {
                    kind: AllocKind::Mmap,
                    addr,
                    len,
                    t_ns: step as f64,
                });
                // shadow model mirrors MAP_FIXED splitting: overlapped
                // parts are dropped, non-overlapping heads/tails kept
                let mut next = Vec::new();
                for (a, l) in live.drain(..) {
                    let end = a + l;
                    let new_end = addr + len;
                    if end <= addr || a >= new_end {
                        next.push((a, l)); // disjoint
                    } else {
                        if a < addr {
                            next.push((a, addr - a)); // head
                        }
                        if end > new_end {
                            next.push((new_end, end - new_end)); // tail
                        }
                    }
                }
                live = next;
                live.push((addr, len));
            } else {
                let pick = rng.below(live.len() as u64) as usize;
                let (addr, len) = live.swap_remove(pick);
                tracker.on_alloc_event(&AllocEvent {
                    kind: AllocKind::Munmap,
                    addr,
                    len,
                    t_ns: step as f64,
                });
            }
            let expect: u64 = live.iter().map(|(_, l)| *l).sum();
            assert_eq!(
                tracker.stats.live_bytes, expect,
                "seed {seed} step {step}: live bytes diverged"
            );
            let pool_sum: u64 = tracker.stats.pool_bytes.iter().sum();
            assert_eq!(pool_sum, expect, "seed {seed} step {step}: pool bytes diverged");
        }
    }
}

#[test]
fn tracker_lookup_respects_regions() {
    for seed in 0..50u64 {
        let topo = builtin::fig2();
        let mut rng = Rng::new(seed ^ 0x77);
        let mut tracker = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
        let addr = (1 + rng.below(1000)) * 0x10000;
        let len = (1 + rng.below(16)) * 4096;
        tracker.on_alloc_event(&AllocEvent { kind: AllocKind::Mmap, addr, len, t_ns: 0.0 });
        // inside: not local (CxlOnly)
        assert_ne!(tracker.pool_of(addr), LOCAL_POOL, "seed {seed}");
        assert_ne!(tracker.pool_of(addr + len - 1), LOCAL_POOL, "seed {seed}");
        // outside: local
        assert_eq!(tracker.pool_of(addr + len), LOCAL_POOL, "seed {seed}");
        assert_eq!(tracker.pool_of(addr.wrapping_sub(1)), LOCAL_POOL, "seed {seed}");
    }
}

// -------------------------------------------------------------- cache

#[test]
fn cache_hierarchy_hit_rate_increases_with_locality() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let mut narrow = CacheHierarchy::scaled(64);
        let mut wide = CacheHierarchy::scaled(64);
        for _ in 0..50_000 {
            narrow.access(rng.below(1 << 14) & !63, false); // 16 KB set
            wide.access(rng.below(1 << 26) & !63, false); // 64 MB set
        }
        assert!(
            narrow.stats.miss_rate() < wide.stats.miss_rate(),
            "seed {seed}: locality must reduce misses"
        );
    }
}

#[test]
fn cache_inclusive_invariant_no_stale_hits_after_eviction() {
    // after an LLC invalidation storm, previously-hot lines must miss
    let mut h = CacheHierarchy::scaled(512);
    for i in 0..8u64 {
        h.access(i * 64, true);
    }
    // stream far past LLC capacity
    for i in 1000..200_000u64 {
        h.access(i * 64, false);
    }
    let before = h.stats.misses;
    for i in 0..8u64 {
        h.access(i * 64, false);
    }
    assert!(h.stats.misses > before, "hot lines must have been evicted");
}

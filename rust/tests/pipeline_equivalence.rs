//! The batched event pipeline must be an *optimization*, never a
//! semantic change:
//!
//! * the coordinator with `event_batch > 1` (monomorphic pump) must
//!   produce a bit-identical `SimReport` to `event_batch = 1` (the
//!   legacy one-virtual-call-per-event loop);
//! * multihost with N host-phase threads must match the single-thread
//!   result bit-for-bit (deterministic epoch-barrier merge);
//! * `run_batched` (grouped analyzer flush) on the native backend must
//!   match the sequential coordinator, including the prefetcher traffic
//!   and epoch-policy invocation the pre-`EpochDriver` implementation
//!   silently dropped.

use cxlmemsim::coordinator::{run_batched, run_batched_with, Coordinator, SimConfig, SimReport};
use cxlmemsim::multihost::{run_shared_threads, MultiHostReport};
use cxlmemsim::policy::EpochPolicy;
use cxlmemsim::prelude::*;
use cxlmemsim::workload;

fn fast_cfg() -> SimConfig {
    SimConfig {
        scale: 0.002,
        cache_scale: 64,
        epoch_ms: 0.1,
        ..SimConfig::default()
    }
}

fn assert_reports_identical(a: &SimReport, b: &SimReport, ctx: &str) {
    assert_eq!(a.total_accesses, b.total_accesses, "{ctx}: accesses");
    assert_eq!(a.total_misses, b.total_misses, "{ctx}: misses");
    assert_eq!(a.writebacks, b.writebacks, "{ctx}: writebacks");
    assert_eq!(a.alloc_events, b.alloc_events, "{ctx}: allocs");
    assert_eq!(a.prefetches, b.prefetches, "{ctx}: prefetches");
    assert_eq!(a.epochs_run, b.epochs_run, "{ctx}: epochs");
    assert_eq!(a.pool_read_misses, b.pool_read_misses, "{ctx}: pool reads");
    assert_eq!(a.pool_write_misses, b.pool_write_misses, "{ctx}: pool writes");
    // f64 accumulators: same inputs in the same order => bit-identical
    assert_eq!(a.native_ns, b.native_ns, "{ctx}: native_ns");
    assert_eq!(a.delay_ns, b.delay_ns, "{ctx}: delay_ns");
    assert_eq!(a.lat_delay_ns, b.lat_delay_ns, "{ctx}: lat");
    assert_eq!(a.cong_delay_ns, b.cong_delay_ns, "{ctx}: cong");
    assert_eq!(a.bwd_delay_ns, b.bwd_delay_ns, "{ctx}: bwd");
    assert_eq!(a.simulated_ns, b.simulated_ns, "{ctx}: simulated_ns");
}

fn run_with_batch(wl: &str, event_batch: usize, mutate: impl Fn(&mut SimConfig)) -> SimReport {
    let mut cfg = fast_cfg();
    cfg.event_batch = event_batch;
    mutate(&mut cfg);
    let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
    sim.run_workload(wl).unwrap()
}

#[test]
fn batched_pipeline_bit_identical_to_per_event_loop() {
    for wl in ["mcf_like", "stream"] {
        let per_event = run_with_batch(wl, 1, |_| {});
        for batch in [7usize, 4096] {
            let batched = run_with_batch(wl, batch, |_| {});
            assert_reports_identical(&per_event, &batched, &format!("{wl} batch={batch}"));
        }
    }
}

#[test]
fn batched_pipeline_identical_with_prefetcher_and_sampling() {
    for wl in ["stream", "wrf_like"] {
        let mk = |batch: usize| {
            run_with_batch(wl, batch, |cfg| {
                cfg.prefetcher = Some("nextline".into());
                cfg.sample_period = 4;
            })
        };
        let per_event = mk(1);
        let batched = mk(4096);
        assert!(per_event.prefetches > 0, "{wl}: prefetcher must fire");
        assert_reports_identical(&per_event, &batched, wl);
    }
}

#[test]
fn batched_pipeline_identical_under_max_epochs() {
    let mk = |batch: usize| {
        run_with_batch("uniform", batch, |cfg| {
            cfg.scale = 0.05;
            cfg.max_epochs = Some(3);
        })
    };
    let per_event = mk(1);
    let batched = mk(4096);
    assert_eq!(per_event.epochs_run, 3);
    assert_reports_identical(&per_event, &batched, "max_epochs");
}

// ---------------------------------------------------------- multihost

fn assert_multihost_identical(a: &MultiHostReport, b: &MultiHostReport) {
    assert_eq!(a.epochs, b.epochs);
    assert_eq!(a.invalidations, b.invalidations);
    assert_eq!(a.coherence_msgs, b.coherence_msgs);
    assert_eq!(a.total_delay_ns, b.total_delay_ns);
    assert_eq!(a.cong_delay_ns, b.cong_delay_ns);
    assert_eq!(a.bwd_delay_ns, b.bwd_delay_ns);
    assert_eq!(a.hosts.len(), b.hosts.len());
    for (x, y) in a.hosts.iter().zip(&b.hosts) {
        assert_eq!(x.misses, y.misses);
        assert_eq!(x.native_ns, y.native_ns);
        assert_eq!(x.delay_ns, y.delay_ns);
    }
}

#[test]
fn multihost_threaded_matches_single_thread_bit_exactly() {
    for wl in ["stream", "shared"] {
        let mk_hosts = || -> Vec<Box<dyn Workload>> {
            (0..4)
                .map(|i| workload::by_name(wl, 0.002, i as u64).unwrap())
                .collect()
        };
        let one = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), 1).unwrap();
        for threads in [2usize, 4, 16] {
            let many =
                run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), threads).unwrap();
            assert_multihost_identical(&one, &many);
        }
    }
}

// ------------------------------------------------- batched replay mode

#[test]
fn run_batched_native_matches_sequential_coordinator() {
    // the native batch analyzer is a loop over the per-epoch analyzer,
    // so grouped replay must match the sequential coordinator exactly
    let cfg = fast_cfg();
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("zipfian").unwrap();

    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();

    assert_eq!(seq_rep.epochs_run, bat_rep.epochs_run);
    assert_eq!(seq_rep.total_misses, bat_rep.total_misses);
    assert_eq!(seq_rep.native_ns, bat_rep.native_ns);
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns, "grouped flush drifted");
    assert_eq!(seq_rep.lat_delay_ns, bat_rep.lat_delay_ns);
    assert_eq!(seq_rep.cong_delay_ns, bat_rep.cong_delay_ns);
    assert_eq!(seq_rep.bwd_delay_ns, bat_rep.bwd_delay_ns);
}

#[test]
fn run_batched_honors_max_epochs() {
    // regression: the grouped flush only pushes epochs to the report at
    // group boundaries, so a max_epochs check based on report.epochs_run
    // would overshoot by up to batch-1 epochs
    let mut cfg = fast_cfg();
    cfg.scale = 0.05;
    cfg.max_epochs = Some(3);
    let mut wl = workload::by_name("uniform", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert_eq!(bat_rep.epochs_run, 3);

    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("uniform").unwrap();
    assert_eq!(seq_rep.epochs_run, bat_rep.epochs_run);
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns);
}

#[test]
fn run_batched_carries_prefetcher_traffic() {
    // regression: the pre-EpochDriver run_batched dropped prefetcher
    // traffic entirely
    let mut cfg = fast_cfg();
    cfg.prefetcher = Some("nextline".into());
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("stream").unwrap();
    assert!(seq_rep.prefetches > 0);

    let mut wl = workload::by_name("stream", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert_eq!(
        seq_rep.prefetches, bat_rep.prefetches,
        "batched replay must bin the same prefetch traffic"
    );
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns);
}

/// Counts invocations; proves batched replay drives installed policies.
struct ProbePolicy {
    calls: u64,
}

impl EpochPolicy for ProbePolicy {
    fn name(&self) -> &'static str {
        "probe"
    }
    fn on_epoch(
        &mut self,
        _tracker: &mut cxlmemsim::alloctrack::AllocTracker,
        _bins: &cxlmemsim::trace::binning::EpochBins,
        _out: &cxlmemsim::runtime::TimingOutputs,
    ) {
        self.calls += 1;
    }
    fn migrations(&self) -> u64 {
        0
    }
}

#[test]
fn run_batched_invokes_epoch_policy() {
    // regression: the pre-EpochDriver run_batched never called policies
    let cfg = fast_cfg();
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let mut probe = ProbePolicy { calls: 0 };
    let rep =
        run_batched_with(&builtin::fig2(), &cfg, wl.as_mut(), Some(&mut probe)).unwrap();
    assert!(rep.epochs_run > 0);
    assert_eq!(
        probe.calls, rep.epochs_run,
        "policy must be invoked once per epoch at group-flush time"
    );
}

//! The batched event pipeline must be an *optimization*, never a
//! semantic change:
//!
//! * the coordinator with `event_batch > 1` (monomorphic pump) must
//!   produce a bit-identical `SimReport` to `event_batch = 1` (the
//!   legacy one-virtual-call-per-event loop);
//! * multihost with N host-phase threads must match the single-thread
//!   result bit-for-bit (deterministic epoch-barrier merge);
//! * `run_batched` (grouped analyzer flush) on the native backend must
//!   match the sequential coordinator, including the prefetcher traffic
//!   and epoch-policy invocation the pre-`EpochDriver` implementation
//!   silently dropped;
//! * pipelined epoch execution (`SimConfig::pipeline` — analysis on a
//!   dedicated worker behind a depth-1 rendezvous) must match the
//!   serial drivers bit-for-bit for every thread/group/kernel knob,
//!   with live policy stacks, under fault plans, and composed with
//!   streaming v2 replay. CI's determinism matrix re-runs this whole
//!   file with `CXLMEMSIM_TEST_PIPELINE=1`, which flips every
//!   `fast_cfg()`-based test onto the pipelined drivers.

use cxlmemsim::coordinator::{run_batched, run_batched_with, Coordinator, SimConfig, SimReport};
use cxlmemsim::multihost::{run_shared_threads, run_shared_threads_with, MultiHostReport};
use cxlmemsim::policy::{EpochPolicy, HotnessMigration, PolicySpec, PolicyStack};
use cxlmemsim::prelude::*;
use cxlmemsim::workload;

fn fast_cfg() -> SimConfig {
    let mut cfg = SimConfig {
        scale: 0.002,
        cache_scale: 64,
        epoch_ms: 0.1,
        ..SimConfig::default()
    };
    // CI's determinism matrix adds a scan-kernel leg: every
    // equivalence test here compares like against like, so both
    // kernels must hold every bit-exactness claim (`exact` is
    // additionally golden-pinned; `blocked` vs `exact` is covered by
    // the tolerance tests below)
    if let Some(k) = std::env::var("CXLMEMSIM_TEST_KERNEL")
        .ok()
        .and_then(|v| cxlmemsim::runtime::ScanKernel::parse(&v))
    {
        cfg.scan_kernel = k;
    }
    // CI's determinism matrix also runs a pipelined leg: with
    // `CXLMEMSIM_TEST_PIPELINE=1`, every test built on this config
    // drives the pipelined flushes — all the bit-exactness claims in
    // this file must hold there unchanged
    if std::env::var("CXLMEMSIM_TEST_PIPELINE").as_deref() == Ok("1") {
        cfg.pipeline = true;
    }
    cfg
}

/// Worker counts the determinism tests exercise against the 1-thread
/// baseline. CI's determinism matrix pins this via
/// `CXLMEMSIM_TEST_THREADS` (1 / 2 / 8) so every knob value runs on a
/// real multi-core runner; locally (unset) a spread of counts runs in
/// one pass.
fn knob_threads(defaults: &[usize]) -> Vec<usize> {
    match std::env::var("CXLMEMSIM_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(n) => vec![n],
        None => defaults.to_vec(),
    }
}

fn assert_reports_identical(a: &SimReport, b: &SimReport, ctx: &str) {
    assert_eq!(a.total_accesses, b.total_accesses, "{ctx}: accesses");
    assert_eq!(a.total_misses, b.total_misses, "{ctx}: misses");
    assert_eq!(a.writebacks, b.writebacks, "{ctx}: writebacks");
    assert_eq!(a.alloc_events, b.alloc_events, "{ctx}: allocs");
    assert_eq!(a.prefetches, b.prefetches, "{ctx}: prefetches");
    assert_eq!(a.epochs_run, b.epochs_run, "{ctx}: epochs");
    assert_eq!(a.pool_read_misses, b.pool_read_misses, "{ctx}: pool reads");
    assert_eq!(a.pool_write_misses, b.pool_write_misses, "{ctx}: pool writes");
    // f64 accumulators: same inputs in the same order => bit-identical
    assert_eq!(a.native_ns, b.native_ns, "{ctx}: native_ns");
    assert_eq!(a.delay_ns, b.delay_ns, "{ctx}: delay_ns");
    assert_eq!(a.lat_delay_ns, b.lat_delay_ns, "{ctx}: lat");
    assert_eq!(a.cong_delay_ns, b.cong_delay_ns, "{ctx}: cong");
    assert_eq!(a.bwd_delay_ns, b.bwd_delay_ns, "{ctx}: bwd");
    assert_eq!(a.simulated_ns, b.simulated_ns, "{ctx}: simulated_ns");
    // tracer counters: the pool_of call sequence and the number of
    // staged samples don't depend on batch grouping (the number of
    // bulk flushes legitimately does, so it is not compared)
    assert_eq!(a.pool_mru_hits, b.pool_mru_hits, "{ctx}: mru hits");
    assert_eq!(a.bins_staged, b.bins_staged, "{ctx}: staged samples");
    // policy engine: empty/no stack must agree exactly here too
    assert_eq!(a.mig_delay_ns, b.mig_delay_ns, "{ctx}: mig stall");
    assert_eq!(a.migrations, b.migrations, "{ctx}: migrations");
    assert_eq!(a.migrated_bytes, b.migrated_bytes, "{ctx}: migrated bytes");
}

fn run_with_batch(wl: &str, event_batch: usize, mutate: impl Fn(&mut SimConfig)) -> SimReport {
    let mut cfg = fast_cfg();
    cfg.event_batch = event_batch;
    mutate(&mut cfg);
    let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
    sim.run_workload(wl).unwrap()
}

#[test]
fn batched_pipeline_bit_identical_to_per_event_loop() {
    for wl in ["mcf_like", "stream"] {
        let per_event = run_with_batch(wl, 1, |_| {});
        for batch in [7usize, 4096] {
            let batched = run_with_batch(wl, batch, |_| {});
            assert_reports_identical(&per_event, &batched, &format!("{wl} batch={batch}"));
        }
    }
}

#[test]
fn batched_pipeline_identical_with_prefetcher_and_sampling() {
    for wl in ["stream", "wrf_like"] {
        let mk = |batch: usize| {
            run_with_batch(wl, batch, |cfg| {
                cfg.prefetcher = Some("nextline".into());
                cfg.sample_period = 4;
            })
        };
        let per_event = mk(1);
        let batched = mk(4096);
        assert!(per_event.prefetches > 0, "{wl}: prefetcher must fire");
        assert_reports_identical(&per_event, &batched, wl);
    }
}

#[test]
fn batched_pipeline_identical_under_max_epochs() {
    let mk = |batch: usize| {
        run_with_batch("uniform", batch, |cfg| {
            cfg.scale = 0.05;
            cfg.max_epochs = Some(3);
        })
    };
    let per_event = mk(1);
    let batched = mk(4096);
    assert_eq!(per_event.epochs_run, 3);
    assert_reports_identical(&per_event, &batched, "max_epochs");
}

// ------------------------------------------- bulk bins accounting

/// Property-style differential: staging samples as `(pool, rw, bin,
/// weight)` deltas and scattering them in arbitrary batch groupings
/// must be bit-identical to calling the scalar `record` per sample —
/// including clamped edges (negative times, past-the-end times, the
/// exact epoch boundary).
#[test]
fn record_bulk_matches_per_event_record() {
    use cxlmemsim::trace::binning::{BinDelta, EpochBins};
    use cxlmemsim::util::rng::Rng;

    let (pools, nbins, epoch_ns) = (8usize, 64usize, 1e5f64);
    let mut scalar = EpochBins::new(pools, nbins, epoch_ns);
    let mut bulk = EpochBins::new(pools, nbins, epoch_ns);
    let mut staged: Vec<BinDelta> = Vec::new();
    let mut rng = Rng::new(0xb1f5);
    for _ in 0..50_000u64 {
        let pool = rng.below(pools as u64) as usize;
        let is_write = rng.below(2) == 1;
        let t = match rng.below(20) {
            0 => -rng.range_f64(0.0, 50.0),             // clamps low
            1 => epoch_ns + rng.range_f64(0.0, 50.0),   // clamps high
            2 => epoch_ns,                              // boundary
            _ => rng.range_f64(0.0, epoch_ns),
        };
        let weight = if rng.below(4) == 0 { rng.below(4096) as f32 } else { 1.0 };
        scalar.record(pool, is_write, t, weight);
        bulk.stage(pool, is_write, t, weight, &mut staged);
        // scatter at random points so flush grouping is exercised
        if rng.below(97) == 0 {
            bulk.record_bulk(&staged);
            staged.clear();
        }
    }
    bulk.record_bulk(&staged); // tail
    assert_eq!(scalar.reads, bulk.reads, "read tensors diverged");
    assert_eq!(scalar.writes, bulk.writes, "write tensors diverged");
    assert_eq!(scalar.total_events, bulk.total_events);
    assert_eq!(scalar.clamped, bulk.clamped);
}

// ------------------------------------------- fused batch analyzer

/// The fused-scan batched kernel must equal the scalar per-epoch
/// analyzer bit-exactly, including sparse epochs (whole pools empty —
/// the skipped matmul columns) and a fully empty epoch (the early-exit
/// path), with scratch reused across the E-epoch loop.
#[test]
fn fused_batch_analyzer_matches_scalar_bit_exactly() {
    use cxlmemsim::runtime::native::{NativeAnalyzer, NativeBatchAnalyzer};
    use cxlmemsim::runtime::shapes;
    use cxlmemsim::runtime::{BatchTimingModel, TimingModel};
    use cxlmemsim::topology::TopoTensors;
    use cxlmemsim::util::rng::Rng;

    let topo = builtin::fig2();
    let t = TopoTensors::build(&topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES).unwrap();
    let (p, s, b, e) = (shapes::NUM_POOLS, shapes::NUM_SWITCHES, 32usize, 6usize);
    let n = p * b;
    let mut rng = Rng::new(0xfa57);
    let mut reads = vec![0.0f32; e * n];
    let mut writes = vec![0.0f32; e * n];
    for ep in 0..e {
        for pool in 0..p {
            // sparse epochs: leave whole pools empty; epoch 3 fully so
            if ep == 3 || rng.below(3) == 0 {
                continue;
            }
            for i in 0..b {
                reads[ep * n + pool * b + i] = rng.below(50) as f32;
                writes[ep * n + pool * b + i] = rng.below(25) as f32;
            }
        }
    }
    let mut single = NativeAnalyzer::new(&t, b);
    let mut batch = NativeBatchAnalyzer::new(&t, b, e);
    let out = batch.analyze_batch(&reads, &writes, 250.0, 64.0).unwrap();
    assert_eq!(out.total.len(), e);
    for ep in 0..e {
        let sr = single
            .analyze(&TimingInputs {
                reads: &reads[ep * n..(ep + 1) * n],
                writes: &writes[ep * n..(ep + 1) * n],
                bin_width: 250.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total[ep], sr.total, "epoch {ep}: total");
        let one = out.epoch(ep, p, s);
        assert_eq!(one.lat, sr.lat, "epoch {ep}: lat");
        assert_eq!(one.cong, sr.cong, "epoch {ep}: cong");
        assert_eq!(one.bwd, sr.bwd, "epoch {ep}: bwd");
    }
    assert_eq!(out.total[3], 0.0, "empty epoch must be exactly free");
}

// ---------------------------------------------------------- multihost

fn assert_multihost_identical(a: &MultiHostReport, b: &MultiHostReport) {
    assert_eq!(a.epochs, b.epochs);
    assert_eq!(a.invalidations, b.invalidations);
    assert_eq!(a.coherence_msgs, b.coherence_msgs);
    assert_eq!(a.total_delay_ns, b.total_delay_ns);
    assert_eq!(a.cong_delay_ns, b.cong_delay_ns);
    assert_eq!(a.bwd_delay_ns, b.bwd_delay_ns);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.migrated_bytes, b.migrated_bytes);
    assert_eq!(a.mig_stall_ns, b.mig_stall_ns);
    // fault counters (all zero on fault-free runs)
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.retry_delay_ns, b.retry_delay_ns);
    assert_eq!(a.throttled_epochs, b.throttled_epochs);
    assert_eq!(a.pools_offline, b.pools_offline);
    assert_eq!(a.failover_migrated_bytes, b.failover_migrated_bytes);
    assert_eq!(a.pools_reonlined, b.pools_reonlined);
    assert_eq!(a.warmup_delay_ns, b.warmup_delay_ns);
    assert_eq!(a.drain_migrated_bytes, b.drain_migrated_bytes);
    assert_eq!(a.hosts.len(), b.hosts.len());
    for (x, y) in a.hosts.iter().zip(&b.hosts) {
        assert_eq!(x.misses, y.misses);
        assert_eq!(x.native_ns, y.native_ns);
        assert_eq!(x.delay_ns, y.delay_ns);
        assert_eq!(x.migrations, y.migrations);
        assert_eq!(x.failover_migrated_bytes, y.failover_migrated_bytes);
        assert_eq!(x.drain_migrated_bytes, y.drain_migrated_bytes);
    }
}

#[test]
fn multihost_threaded_matches_single_thread_bit_exactly() {
    for wl in ["stream", "shared"] {
        let mk_hosts = || -> Vec<Box<dyn Workload>> {
            (0..4)
                .map(|i| workload::by_name(wl, 0.002, i as u64).unwrap())
                .collect()
        };
        let one = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), 1).unwrap();
        for threads in knob_threads(&[2, 4, 16]) {
            let many =
                run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), threads).unwrap();
            assert_multihost_identical(&one, &many);
        }
    }
}

#[test]
fn multihost_persistent_pool_uneven_shards_bit_exact() {
    // 5 hosts never split evenly over 2 or 3 workers: the persistent
    // pool's once-per-run shard split must still merge in host order
    // and match the inline single-thread run bit-for-bit, including
    // coherence traffic ("shared" hosts write-share lines)
    let mk_hosts = || -> Vec<Box<dyn Workload>> {
        (0..5)
            .map(|i| workload::by_name("shared", 0.002, i as u64).unwrap())
            .collect()
    };
    let one = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), 1).unwrap();
    assert!(one.invalidations > 0);
    for threads in knob_threads(&[2, 3, 64]) {
        let many =
            run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_hosts(), threads).unwrap();
        assert_multihost_identical(&one, &many);
    }
}

// ------------------------------------- work-stealing host phase

/// One huge host + tiny peers: per epoch the huge host dominates, so
/// whichever worker claims it is pinned there and the others MUST
/// claim hosts outside their nominal shard to drain the queue (the
/// zipfian host is cache-friendly and does ~10x the events per epoch
/// of the miss-bound tiny streams).
fn mk_skewed_hosts() -> Vec<Box<dyn Workload>> {
    let mut v: Vec<Box<dyn Workload>> = vec![workload::by_name("zipfian", 0.01, 0).unwrap()];
    for i in 1..5 {
        v.push(workload::by_name("stream", 0.0005, i as u64).unwrap());
    }
    v
}

#[test]
fn work_stealing_pathological_skew_bit_exact_and_steals() {
    let one = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_skewed_hosts(), 1).unwrap();
    assert_eq!(one.steals, 0, "inline runs cannot steal");
    for threads in knob_threads(&[2, 4]) {
        let many =
            run_shared_threads(&builtin::fig2(), &fast_cfg(), mk_skewed_hosts(), threads)
                .unwrap();
        assert_multihost_identical(&one, &many);
        if threads > 1 {
            assert!(
                many.steals > 0,
                "{threads} workers on one-huge-host skew must steal to stay busy"
            );
            assert!(many.shard_rebalances > 0);
            assert_eq!(many.worker_busy_fracs.len(), many.host_workers);
        }
    }
}

#[test]
fn work_stealing_hosts_fewer_than_workers_bit_exact() {
    // 2 hosts under 8/64 requested workers: the pool clamps to one
    // worker per host and the claim queue must not run past the end
    let mk = || -> Vec<Box<dyn Workload>> {
        (0..2)
            .map(|i| workload::by_name("shared", 0.002, i as u64).unwrap())
            .collect()
    };
    let one = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk(), 1).unwrap();
    assert!(one.invalidations > 0);
    for threads in knob_threads(&[8, 64]) {
        let many = run_shared_threads(&builtin::fig2(), &fast_cfg(), mk(), threads).unwrap();
        assert_multihost_identical(&one, &many);
        assert!(many.host_workers <= 2, "workers must clamp to the host count");
    }
}

// ------------------------------------- sharded batched analyzer

/// The sharded E-epoch analyzer loop must be an optimization, never a
/// semantic change: `run --batched` with any `analyzer_threads` value
/// produces a bit-identical `SimReport` to the sequential (1-thread)
/// batched run — epochs are independent and each worker writes
/// disjoint `[E, ·]` output rows with its own scratch.
#[test]
fn run_batched_sharded_analyzer_bit_identical_across_thread_counts() {
    let run = |threads: usize| {
        let mut cfg = fast_cfg();
        cfg.analyzer_threads = threads;
        let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
        run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
    };
    let base = run(1);
    assert_eq!(base.analyzer_threads_used, 1);
    for threads in knob_threads(&[2, 8]) {
        let sharded = run(threads);
        assert_reports_identical(&base, &sharded, &format!("analyzer_threads={threads}"));
        assert!(sharded.analyzer_threads_used >= 1);
    }
    // 0 = auto (one per core, capped): still identical
    let auto = run(0);
    assert_reports_identical(&base, &auto, "analyzer_threads=auto");
}

/// Same bit-exactness with a live policy stack: both policy phases run
/// on the coordinator thread (phase-2 at group-flush time), so
/// sharding the analyzer cannot reorder any policy effect.
#[test]
fn run_batched_sharded_analyzer_identical_with_policy_stack() {
    let run = |threads: usize| {
        let mut cfg = fast_cfg();
        cfg.scale = 0.004;
        cfg.analyzer_threads = threads;
        cfg.epoch_policy = Some(PolicySpec::parse("hotness:1,prefetch:0.5").unwrap());
        let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
        run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
    };
    let base = run(1);
    assert!(base.migrations > 0, "hotness:1 on zipfian must migrate");
    for threads in knob_threads(&[2, 8]) {
        let sharded = run(threads);
        assert_reports_identical(
            &base,
            &sharded,
            &format!("policy stack, analyzer_threads={threads}"),
        );
    }
}

// --------------------------------------------- scan kernel tolerance

/// The blocked max-plus kernel reassociates float adds, so it is held
/// to a tolerance contract instead of bit-identity: end-to-end delay
/// within 1e-5 relative of the exact reference on every driver, with
/// identical event accounting.
#[test]
fn blocked_kernel_within_tolerance_of_exact_end_to_end() {
    use cxlmemsim::runtime::ScanKernel;
    let run = |kernel: ScanKernel| {
        let mut cfg = fast_cfg();
        cfg.scan_kernel = kernel;
        let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
        run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
    };
    let exact = run(ScanKernel::Exact);
    let blocked = run(ScanKernel::Blocked);
    assert_eq!(exact.scan_kernel, "exact");
    assert_eq!(blocked.scan_kernel, "blocked");
    assert_eq!(exact.total_misses, blocked.total_misses, "substrate is kernel-blind");
    assert_eq!(exact.epochs_run, blocked.epochs_run);
    assert!(exact.delay_ns > 0.0);
    for (name, a, b) in [
        ("delay", exact.delay_ns, blocked.delay_ns),
        ("cong", exact.cong_delay_ns, blocked.cong_delay_ns),
        ("bwd", exact.bwd_delay_ns, blocked.bwd_delay_ns),
    ] {
        let rel = (a - b).abs() / a.abs().max(1e-9);
        assert!(rel < 1e-5, "{name}: exact {a} vs blocked {b} (rel {rel})");
    }
    // the latency term never goes through a scan: bit-identical
    assert_eq!(exact.lat_delay_ns, blocked.lat_delay_ns);
}

// ------------------------------------------------- batch group size

/// Without a policy stack, the native group size only changes the
/// flush cadence — epochs are independent, so any `batch_group` must
/// be bit-identical to any other (and to the sequential coordinator,
/// under the same kernel).
#[test]
fn batch_group_sizes_bit_identical_without_policy() {
    let run = |group: usize| {
        let mut cfg = fast_cfg();
        cfg.batch_group = group;
        let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
        run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
    };
    let base = run(0); // default = 16
    assert_eq!(base.batch_group, 16);
    for group in [1usize, 7, 256] {
        let rep = run(group);
        assert_eq!(rep.batch_group, group as u64);
        assert_reports_identical(&base, &rep, &format!("batch_group={group}"));
    }
    // and large groups still honor max_epochs exactly
    let mut cfg = fast_cfg();
    cfg.scale = 0.05;
    cfg.batch_group = 256;
    cfg.max_epochs = Some(3);
    let mut wl = workload::by_name("uniform", cfg.scale, cfg.seed).unwrap();
    let capped = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert_eq!(capped.epochs_run, 3);
}

/// With a policy stack, a big group defers phase-2 up to group−1
/// epochs (the documented lateness trade) — both phases still run
/// exactly once per epoch, and the migration cost model still
/// conserves traffic.
#[test]
fn batch_group_256_policy_phases_and_conservation() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let mut cfg = fast_cfg();
    cfg.scale = 0.004;
    cfg.batch_group = 256;
    let (before, after) = (Arc::new(AtomicU64::new(0)), Arc::new(AtomicU64::new(0)));
    let mut stack = PolicyStack::new(0.1).with(Box::new(HotnessMigration::new(1, u64::MAX)));
    stack.add(Box::new(ProbePolicy { before: before.clone(), after: after.clone() }));
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let rep = run_batched_with(&builtin::fig2(), &cfg, wl.as_mut(), Some(&mut stack)).unwrap();
    assert!(rep.epochs_run > 0);
    assert_eq!(before.load(Ordering::SeqCst), rep.epochs_run, "phase-1 per epoch");
    assert_eq!(
        after.load(Ordering::SeqCst),
        rep.epochs_run,
        "phase-2 per epoch, deferred to group flush"
    );
    assert!(stack.migrations() > 0, "hotness:1 on zipfian must migrate");
    let moved = stack.moved_bytes() as f64;
    assert_eq!(
        stack.injected_read_bytes() + stack.pending_bytes(),
        moved,
        "read-side conservation under a 256-epoch group"
    );
    assert_eq!(
        stack.injected_write_bytes() + stack.pending_bytes(),
        moved,
        "write-side conservation under a 256-epoch group"
    );
}

// ------------------------------------------------- batched replay mode

#[test]
fn run_batched_native_matches_sequential_coordinator() {
    // the native batch analyzer is a loop over the per-epoch analyzer,
    // so grouped replay must match the sequential coordinator exactly
    let cfg = fast_cfg();
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("zipfian").unwrap();

    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();

    assert_eq!(seq_rep.epochs_run, bat_rep.epochs_run);
    assert_eq!(seq_rep.total_misses, bat_rep.total_misses);
    assert_eq!(seq_rep.native_ns, bat_rep.native_ns);
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns, "grouped flush drifted");
    assert_eq!(seq_rep.lat_delay_ns, bat_rep.lat_delay_ns);
    assert_eq!(seq_rep.cong_delay_ns, bat_rep.cong_delay_ns);
    assert_eq!(seq_rep.bwd_delay_ns, bat_rep.bwd_delay_ns);
}

#[test]
fn run_batched_honors_max_epochs() {
    // regression: the grouped flush only pushes epochs to the report at
    // group boundaries, so a max_epochs check based on report.epochs_run
    // would overshoot by up to batch-1 epochs
    let mut cfg = fast_cfg();
    cfg.scale = 0.05;
    cfg.max_epochs = Some(3);
    let mut wl = workload::by_name("uniform", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert_eq!(bat_rep.epochs_run, 3);

    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("uniform").unwrap();
    assert_eq!(seq_rep.epochs_run, bat_rep.epochs_run);
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns);
}

#[test]
fn run_batched_carries_prefetcher_traffic() {
    // regression: the pre-EpochDriver run_batched dropped prefetcher
    // traffic entirely
    let mut cfg = fast_cfg();
    cfg.prefetcher = Some("nextline".into());
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let seq_rep = seq.run_workload("stream").unwrap();
    assert!(seq_rep.prefetches > 0);

    let mut wl = workload::by_name("stream", cfg.scale, cfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert_eq!(
        seq_rep.prefetches, bat_rep.prefetches,
        "batched replay must bin the same prefetch traffic"
    );
    assert_eq!(seq_rep.delay_ns, bat_rep.delay_ns);
}

/// Counts invocations per phase; proves batched replay drives both
/// hooks of installed policy stacks.
struct ProbePolicy {
    before: std::sync::Arc<std::sync::atomic::AtomicU64>,
    after: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl EpochPolicy for ProbePolicy {
    fn name(&self) -> &'static str {
        "probe"
    }
    fn before_analysis(
        &mut self,
        _bins: &mut cxlmemsim::trace::binning::EpochBins,
        _ctx: &mut cxlmemsim::policy::PolicyCtx,
    ) {
        self.before.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    }
    fn after_analysis(
        &mut self,
        _bins: &cxlmemsim::trace::binning::EpochBins,
        _out: &cxlmemsim::runtime::TimingOutputs,
        _ctx: &mut cxlmemsim::policy::PolicyCtx,
    ) {
        self.after.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    }
}

#[test]
fn run_batched_invokes_both_policy_phases() {
    // regression: the pre-EpochDriver run_batched never called policies
    // at all, and the pre-stack engine never called phase-1 hooks
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let cfg = fast_cfg();
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let (before, after) = (Arc::new(AtomicU64::new(0)), Arc::new(AtomicU64::new(0)));
    let mut stack = PolicyStack::new(0.0);
    stack.add(Box::new(ProbePolicy { before: before.clone(), after: after.clone() }));
    let rep =
        run_batched_with(&builtin::fig2(), &cfg, wl.as_mut(), Some(&mut stack)).unwrap();
    assert!(rep.epochs_run > 0);
    assert_eq!(
        before.load(Ordering::SeqCst),
        rep.epochs_run,
        "phase-1 must run once per epoch, at epoch-boundary time"
    );
    assert_eq!(
        after.load(Ordering::SeqCst),
        rep.epochs_run,
        "phase-2 must run once per epoch, at group-flush time"
    );
}

// ------------------------------------------- two-phase policy engine

/// The engine's zero-cost guarantee: an installed-but-empty stack must
/// be bit-identical to no stack at all, on every driver.
#[test]
fn empty_policy_stack_bit_identical_on_all_drivers() {
    let cfg = fast_cfg();
    // sequential
    let mut plain = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let plain_rep = plain.run_workload("zipfian").unwrap();
    let mut stacked = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    stacked.set_policy_stack(PolicyStack::new(0.0625));
    let stacked_rep = stacked.run_workload("zipfian").unwrap();
    assert_reports_identical(&plain_rep, &stacked_rep, "sequential empty stack");

    // batched replay
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let plain_bat = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let mut empty = PolicyStack::new(0.0625);
    let stacked_bat =
        run_batched_with(&builtin::fig2(), &cfg, wl.as_mut(), Some(&mut empty)).unwrap();
    assert_reports_identical(&plain_bat, &stacked_bat, "batched empty stack");

    // multihost (per-host empty stacks)
    let mk_hosts = || -> Vec<Box<dyn Workload>> {
        (0..3)
            .map(|i| workload::by_name("stream", 0.002, i as u64).unwrap())
            .collect()
    };
    let plain_mh = run_shared_threads(&builtin::fig2(), &cfg, mk_hosts(), 2).unwrap();
    let stacks: Vec<PolicyStack> = (0..3).map(|_| PolicyStack::new(0.0625)).collect();
    let stacked_mh =
        run_shared_threads_with(&builtin::fig2(), &cfg, mk_hosts(), Some(stacks), 2).unwrap();
    assert_multihost_identical(&plain_mh, &stacked_mh);
    assert_eq!(stacked_mh.migrations, 0);
    assert_eq!(stacked_mh.mig_stall_ns, 0.0);
}

/// Migration cost conservation: every migrated byte must show up as
/// injected link traffic (read on the source pool + write on the
/// destination) or still be pending a next epoch — never vanish.
#[test]
fn migration_traffic_conservation() {
    let mut cfg = fast_cfg();
    cfg.scale = 0.004;
    let mut stack = PolicyStack::new(0.1).with(Box::new(HotnessMigration::new(1, u64::MAX)));
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let rep = run_batched_with(&builtin::fig2(), &cfg, wl.as_mut(), Some(&mut stack)).unwrap();
    assert!(stack.migrations() > 0, "hotness:1 on zipfian must migrate");
    let moved = stack.moved_bytes() as f64;
    assert!(moved > 0.0);
    assert_eq!(
        stack.injected_read_bytes() + stack.pending_bytes(),
        moved,
        "read-side: injected + pending must equal migrated"
    );
    assert_eq!(
        stack.injected_write_bytes() + stack.pending_bytes(),
        moved,
        "write-side: injected + pending must equal migrated"
    );
    // the stall reached the report: moved bytes x 0.1 ns/B (summed
    // per-migration, so compare with an ulp-scale tolerance)
    assert!(
        (rep.mig_delay_ns - moved * 0.1).abs() <= 1e-9 * moved.max(1.0),
        "stall {} != bytes*rate {}",
        rep.mig_delay_ns,
        moved * 0.1
    );
    assert_eq!(rep.migrated_bytes as f64, moved);
}

/// Acceptance: a hotness+prefetch stack runs end-to-end on all three
/// drivers, with migrations and injected migration traffic visible in
/// the reports.
#[test]
fn hotness_prefetch_stack_runs_on_all_drivers() {
    let spec = PolicySpec::parse("hotness:1,prefetch:0.5").unwrap();
    let mut cfg = fast_cfg();
    cfg.scale = 0.004;
    cfg.epoch_policy = Some(spec);

    // sequential: stack built from the config (the CLI path)
    let mut sim = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let rep = sim.run_workload("zipfian").unwrap();
    assert!(rep.migrations > 0, "sequential: must migrate");
    assert!(rep.mig_injected_read_bytes > 0.0, "sequential: traffic must inject");
    assert!(rep.mig_delay_ns > 0.0, "sequential: stall must be charged");
    assert_eq!(rep.policies.len(), 2);

    // batched replay, same config
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let bat = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert!(bat.migrations > 0, "batched: must migrate");
    assert!(bat.mig_delay_ns > 0.0);

    // multihost, same config (per-host stacks)
    let hosts: Vec<Box<dyn Workload>> = (0..3)
        .map(|i| workload::by_name("zipfian", cfg.scale, i as u64).unwrap())
        .collect();
    let mh = run_shared_threads(&builtin::fig2(), &cfg, hosts, 2).unwrap();
    assert!(mh.migrations > 0, "multihost: must migrate");
    assert!(mh.mig_stall_ns > 0.0);
}

// ---------------------------------------- multihost bulk accounting

#[test]
fn multihost_staged_bins_match_scalar_record() {
    // event_batch == 1 keeps the scalar per-miss `record` baseline in
    // `advance_host_epoch`; larger batches stage + bulk-scatter — the
    // two accounting paths must be bit-identical (incl. coherence
    // traffic, which records into the *shared* bins either way)
    for wl in ["stream", "shared"] {
        let mk_hosts = || -> Vec<Box<dyn Workload>> {
            (0..3)
                .map(|i| workload::by_name(wl, 0.002, i as u64).unwrap())
                .collect()
        };
        let mut scalar_cfg = fast_cfg();
        scalar_cfg.event_batch = 1;
        let mut staged_cfg = fast_cfg();
        staged_cfg.event_batch = 4096;
        let scalar = run_shared_threads(&builtin::fig2(), &scalar_cfg, mk_hosts(), 1).unwrap();
        let staged = run_shared_threads(&builtin::fig2(), &staged_cfg, mk_hosts(), 1).unwrap();
        assert_multihost_identical(&scalar, &staged);
    }
}

// ---------------------------------------------------- fault injection

use cxlmemsim::fault::FaultPlan;

fn assert_fault_stats_identical(a: &SimReport, b: &SimReport, ctx: &str) {
    assert_eq!(a.faults_injected, b.faults_injected, "{ctx}: faults_injected");
    assert_eq!(a.retry_delay_ns, b.retry_delay_ns, "{ctx}: retry_delay_ns");
    assert_eq!(a.throttled_epochs, b.throttled_epochs, "{ctx}: throttled_epochs");
    assert_eq!(a.pools_offline, b.pools_offline, "{ctx}: pools_offline");
    assert_eq!(
        a.failover_migrated_bytes, b.failover_migrated_bytes,
        "{ctx}: failover_migrated_bytes"
    );
    assert_eq!(a.pools_reonlined, b.pools_reonlined, "{ctx}: pools_reonlined");
    assert_eq!(a.warmup_delay_ns, b.warmup_delay_ns, "{ctx}: warmup_delay_ns");
    assert_eq!(
        a.drain_migrated_bytes, b.drain_migrated_bytes,
        "{ctx}: drain_migrated_bytes"
    );
}

/// Epoch count of the fault-free baseline run — faults never change
/// the event stream, so every faulted run sees the same count, and the
/// chaos schedule below can be placed mid-run at any workload scale.
fn baseline_epochs(cfg: &SimConfig) -> u64 {
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let e = run_batched(&builtin::fig2(), cfg, wl.as_mut()).unwrap().epochs_run;
    assert!(e >= 4, "need >= 4 epochs for a mid-run fault schedule, got {e}");
    e
}

/// All three RAS kinds in one plan: retry storms on pool0 and pool1
/// (pool0 — PoolId 1 — is the first CxlOnly round-robin target, so it
/// always carries traffic and holds bytes), link retraining on pool0's
/// switch path, then pool0 is hot-removed mid-run. Four events total.
fn chaos_plan(epochs: u64) -> FaultPlan {
    let w = (epochs / 4).max(1);
    FaultPlan::parse_inline(&format!(
        "storm:pool0@1+{w}:rd=250,wr=125;storm:pool1@1+{w}:rd=250,wr=125;\
         retrain:pool0@1+{w}:frac=0.5;offline:pool0@{}",
        epochs / 2
    ))
    .unwrap()
}

/// Acceptance: a mid-run pool-offline run completes with graceful
/// failover, and the chaos run is bit-identical between the sequential
/// coordinator and batched replay.
#[test]
fn fault_run_completes_with_failover_and_matches_across_drivers() {
    let cfg = fast_cfg();
    let mut base_wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let base = run_batched(&builtin::fig2(), &cfg, base_wl.as_mut()).unwrap();
    assert!(base.epochs_run >= 4, "need >= 4 epochs, got {}", base.epochs_run);

    let mut fcfg = cfg.clone();
    fcfg.faults = Some(chaos_plan(base.epochs_run));
    let mut seq = Coordinator::new(builtin::fig2(), fcfg.clone()).unwrap();
    let seq_rep = seq.run_workload("zipfian").unwrap();

    // degradation is graceful and visible
    assert_eq!(seq_rep.epochs_run, base.epochs_run, "faults must not change the event stream");
    assert_eq!(seq_rep.total_misses, base.total_misses);
    assert_eq!(seq_rep.faults_injected, 4, "storms + retrain + offline all fired");
    assert_eq!(seq_rep.pools_offline, 1);
    assert!(seq_rep.failover_migrated_bytes > 0, "pool0 held bytes: failover must move them");
    assert!(seq_rep.throttled_epochs > 0);
    assert!(seq_rep.retry_delay_ns > 0.0, "pool1 carried traffic during the storm");
    assert!(
        seq_rep.retry_delay_ns <= seq_rep.lat_delay_ns,
        "retry delay is a sub-component of lat, not an addition"
    );
    // the auto-installed (empty) stack migrates only for failover
    assert_eq!(seq_rep.failover_migrated_bytes, seq_rep.migrated_bytes);
    assert!(seq_rep.mig_delay_ns > 0.0, "failover copy stall must be charged");
    assert!(seq_rep.delay_ns != base.delay_ns, "faults must perturb the timing");

    // batched replay: same plan, bit-identical
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let bat_rep = run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap();
    assert_reports_identical(&seq_rep, &bat_rep, "faults: sequential vs batched");
    assert_fault_stats_identical(&seq_rep, &bat_rep, "faults: sequential vs batched");
}

/// The chaos run must be bit-identical for any analyzer thread count
/// and any native group size — the overlay-revision early flush keeps
/// one `analyze_batch` call from ever spanning two overlays.
#[test]
fn fault_run_bit_identical_across_threads_and_groups() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let run = |threads: usize, group: usize| {
        let mut fcfg = cfg.clone();
        fcfg.faults = Some(chaos_plan(epochs));
        fcfg.analyzer_threads = threads;
        fcfg.batch_group = group;
        let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
        run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap()
    };
    let base = run(1, 1);
    assert_eq!(base.pools_offline, 1);
    assert!(base.failover_migrated_bytes > 0);
    for threads in knob_threads(&[2, 8]) {
        for group in [1usize, 16, 256] {
            let rep = run(threads, group);
            let ctx = format!("faults: threads={threads} group={group}");
            assert_reports_identical(&base, &rep, &ctx);
            assert_fault_stats_identical(&base, &rep, &ctx);
        }
    }
}

/// Failover rides the same cost-modeled migration machinery as policy
/// moves: every evacuated byte is injected as copy traffic or still
/// pending — never dropped.
#[test]
fn pool_offline_failover_conserves_migration_traffic() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let mut fcfg = cfg.clone();
    fcfg.faults =
        Some(FaultPlan::parse_inline(&format!("offline:pool0@{}", epochs / 2)).unwrap());
    let mut stack = PolicyStack::new(fcfg.mig_stall_ns_per_byte);
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let rep = run_batched_with(&builtin::fig2(), &fcfg, wl.as_mut(), Some(&mut stack)).unwrap();
    assert_eq!(rep.pools_offline, 1);
    assert!(rep.failover_migrated_bytes > 0);
    let moved = stack.moved_bytes() as f64;
    assert_eq!(rep.failover_migrated_bytes as f64, moved, "only failover migrates here");
    assert_eq!(
        stack.injected_read_bytes() + stack.pending_bytes(),
        moved,
        "read-side: injected + pending must equal evacuated"
    );
    assert_eq!(
        stack.injected_write_bytes() + stack.pending_bytes(),
        moved,
        "write-side: injected + pending must equal evacuated"
    );
}

/// A plan whose windows never open must be indistinguishable from a
/// fault-free run — the zero-overhead contract of the fault-free path,
/// including the auto-installed empty policy stack.
#[test]
fn unreached_fault_plan_bit_identical_to_fault_free() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(
        FaultPlan::parse_inline(&format!(
            "storm:pool1@{0}+2:rd=250;offline:pool0@{0}",
            epochs * 10
        ))
        .unwrap(),
    );
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let plain = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let armed = run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap();
    assert_reports_identical(&plain, &armed, "unreached plan");
    assert_eq!(armed.faults_injected, 0);
    assert_eq!(armed.throttled_epochs, 0);
    assert_eq!(armed.retry_delay_ns, 0.0);
}

/// Stage 1 of the analyzer is linear in the per-pool bin counts, so a
/// storm's latency share is recoverable in closed form: the faulted
/// run's lat term must exceed the fault-free one by `retry_delay_ns`
/// (up to f32 accumulation noise in the analyzer).
#[test]
fn retry_storm_attribution_matches_lat_inflation() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(
        FaultPlan::parse_inline(&format!(
            "storm:pool0@0+{epochs}:rd=400,wr=200;storm:pool1@0+{epochs}:rd=400,wr=200;\
             storm:direct0@0+{epochs}:rd=400,wr=200"
        ))
        .unwrap(),
    );
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let plain = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let stormed = run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap();
    assert!(stormed.retry_delay_ns > 0.0);
    assert_eq!(stormed.throttled_epochs, stormed.epochs_run, "whole-run windows");
    // bins are identical (no offline, no policy), so the lat delta IS
    // the storm contribution — f32 analyzer arithmetic vs the f64
    // attribution leaves only accumulation noise
    let delta = stormed.lat_delay_ns - plain.lat_delay_ns;
    let rel = (delta - stormed.retry_delay_ns).abs() / stormed.retry_delay_ns;
    assert!(
        rel < 5e-3,
        "lat inflation {delta} vs attributed {} (rel {rel})",
        stormed.retry_delay_ns
    );
    // everything the analyzer did not re-time is untouched
    assert_eq!(plain.total_misses, stormed.total_misses);
    assert_eq!(plain.epochs_run, stormed.epochs_run);
}

/// Taking every pool offline leaves no failover target: the run must
/// end with the structured no-reachable-pool error, never a panic.
#[test]
fn all_pools_offline_is_a_clean_error() {
    let mut fcfg = fast_cfg();
    fcfg.faults = Some(
        FaultPlan::parse_inline(
            "offline:local@1;offline:pool0@1;offline:pool1@1;offline:direct0@1",
        )
        .unwrap(),
    );
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let err = run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap_err();
    assert!(
        format!("{err:#}").contains("no reachable pool"),
        "want the structured degradation error, got: {err:#}"
    );
    let mut seq = Coordinator::new(builtin::fig2(), fcfg).unwrap();
    let err = seq.run_workload("zipfian").unwrap_err();
    assert!(format!("{err:#}").contains("no reachable pool"), "sequential: {err:#}");
}

/// Multihost chaos: the fault schedule advances on the coordinator
/// thread at the epoch barrier, so any worker count is bit-identical —
/// including per-host failover sweeps in host order.
#[test]
fn multihost_fault_run_bit_identical_across_worker_counts() {
    let cfg = fast_cfg();
    let mk_hosts = || -> Vec<Box<dyn Workload>> {
        (0..3)
            .map(|i| workload::by_name("stream", 0.002, i as u64).unwrap())
            .collect()
    };
    let plain = run_shared_threads(&builtin::fig2(), &cfg, mk_hosts(), 1).unwrap();
    assert!(plain.epochs >= 4, "need >= 4 epochs, got {}", plain.epochs);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(chaos_plan(plain.epochs));
    let one = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), 1).unwrap();
    assert_eq!(one.epochs, plain.epochs, "faults must not change the event stream");
    assert_eq!(one.faults_injected, 4);
    assert_eq!(one.pools_offline, 1);
    assert!(one.failover_migrated_bytes > 0, "hosts held pool0 bytes");
    assert!(one.retry_delay_ns > 0.0);
    let host_sum: u64 = one.hosts.iter().map(|h| h.failover_migrated_bytes).sum();
    assert_eq!(host_sum, one.failover_migrated_bytes, "per-host failover must sum to total");
    for threads in knob_threads(&[2, 4]) {
        let many = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), threads).unwrap();
        assert_multihost_identical(&one, &many);
    }
}

// ------------------------------------------- availability lifecycle

use cxlmemsim::policy::FaultDrain;

/// offline → online (with a short warm-up) → offline again on the same
/// pool: the full availability round trip, placed mid-run.
fn availability_plan(epochs: u64) -> FaultPlan {
    let w = (epochs / 4).max(1);
    FaultPlan::parse_inline(&format!(
        "offline:pool0@{w};online:pool0@{}:warmup=1,rd=150,wr=75;offline:pool0@{}",
        2 * w,
        3 * w
    ))
    .unwrap()
}

/// The availability round trip must be bit-identical across every
/// driver: sequential, batched replay at both group-size extremes, and
/// the pipelined variants — the re-online edge is an overlay revision
/// edge exactly like the offline edge.
#[test]
fn reonline_chaos_bit_identical_across_drivers() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(availability_plan(epochs));

    let mut seq = Coordinator::new(builtin::fig2(), fcfg.clone()).unwrap();
    let base = seq.run_workload("zipfian").unwrap();
    assert_eq!(base.faults_injected, 3, "offline + online + offline all fired");
    assert_eq!(base.pools_offline, 2, "offline transitions, not distinct pools");
    assert_eq!(base.pools_reonlined, 1);
    assert!(base.failover_migrated_bytes > 0, "first offline sweeps pool0's bytes");

    for group in [1usize, 256] {
        let mut gcfg = fcfg.clone();
        gcfg.batch_group = group;
        let mut wl = workload::by_name("zipfian", gcfg.scale, gcfg.seed).unwrap();
        let rep = run_batched(&builtin::fig2(), &gcfg, wl.as_mut()).unwrap();
        let ctx = format!("reonline: batched group={group}");
        assert_reports_identical(&base, &rep, &ctx);
        assert_fault_stats_identical(&base, &rep, &ctx);
    }
    let mut pcfg = fcfg.clone();
    pcfg.pipeline = true;
    let mut piped = Coordinator::new(builtin::fig2(), pcfg.clone()).unwrap();
    let rep = piped.run_workload("zipfian").unwrap();
    assert_reports_identical(&base, &rep, "reonline: pipelined sequential");
    assert_fault_stats_identical(&base, &rep, "reonline: pipelined sequential");
    let mut wl = workload::by_name("zipfian", pcfg.scale, pcfg.seed).unwrap();
    let rep = run_batched(&builtin::fig2(), &pcfg, wl.as_mut()).unwrap();
    assert_reports_identical(&base, &rep, "reonline: pipelined batched");
    assert_fault_stats_identical(&base, &rep, "reonline: pipelined batched");
}

/// The same round trip under multihost: the coordinator advances the
/// schedule at the barrier, so every worker count matches bit-for-bit.
#[test]
fn reonline_multihost_bit_identical_across_worker_counts() {
    let cfg = fast_cfg();
    let mk_hosts = || -> Vec<Box<dyn Workload>> {
        (0..4)
            .map(|i| workload::by_name("stream", 0.002, i as u64).unwrap())
            .collect()
    };
    let plain = run_shared_threads(&builtin::fig2(), &cfg, mk_hosts(), 1).unwrap();
    assert!(plain.epochs >= 4, "need >= 4 epochs, got {}", plain.epochs);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(availability_plan(plain.epochs));
    let one = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), 1).unwrap();
    assert_eq!(one.epochs, plain.epochs, "faults must not change the event stream");
    assert_eq!(one.faults_injected, 3);
    assert_eq!(one.pools_reonlined, 1);
    assert!(one.failover_migrated_bytes > 0, "hosts held pool0 bytes");
    for threads in knob_threads(&[2, 4]) {
        let many = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), threads).unwrap();
        assert_multihost_identical(&one, &many);
    }
}

/// The availability byte balance: a `drain` stack member evacuates the
/// hot region off the storming pool before the offline sweep, and
/// re-admits it once the pool is back — and every migrated byte in the
/// whole chain is either drain/re-admit traffic or failover traffic,
/// with the copy-traffic conservation invariant exact end to end.
#[test]
fn reonline_round_trip_conserves_drain_failover_and_readmit() {
    let mut cfg = fast_cfg();
    cfg.scale = 0.004;
    let epochs = baseline_epochs(&cfg);
    let w = (epochs / 4).max(1);
    // degrade pool0 (the drain window), hot-remove it, then bring it
    // back instantly, leaving the run's tail for the re-admit
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(
        FaultPlan::parse_inline(&format!(
            "storm:pool0@{w}+{w}:rd=300,wr=150;offline:pool0@{};online:pool0@{}",
            2 * w,
            3 * w
        ))
        .unwrap(),
    );
    let mut stack = PolicyStack::new(fcfg.mig_stall_ns_per_byte)
        .with(Box::new(FaultDrain::new(u64::MAX)));
    let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
    let rep = run_batched_with(&builtin::fig2(), &fcfg, wl.as_mut(), Some(&mut stack)).unwrap();
    assert_eq!(rep.pools_offline, 1);
    assert_eq!(rep.pools_reonlined, 1);
    // zipfian's single (hot) region lives on pool0: the storm window
    // drains it before the offline sweep, the tail re-admits it home
    let (_, drain_migs, _) = stack
        .per_policy_stats()
        .into_iter()
        .find(|(n, _, _)| *n == "fault-drain")
        .unwrap();
    assert_eq!(drain_migs, 2, "proactive drain + post-recovery re-admit");
    assert!(rep.drain_migrated_bytes > 0);
    assert_eq!(rep.drain_migrated_bytes, stack.drained_bytes());
    assert_eq!(
        rep.migrated_bytes,
        rep.failover_migrated_bytes + rep.drain_migrated_bytes,
        "drain + re-admit + failover must account for every migrated byte"
    );
    let moved = stack.moved_bytes() as f64;
    assert_eq!(
        stack.injected_read_bytes() + stack.pending_bytes(),
        moved,
        "read-side conservation across the whole availability chain"
    );
    assert_eq!(
        stack.injected_write_bytes() + stack.pending_bytes(),
        moved,
        "write-side conservation across the whole availability chain"
    );
}

/// A re-onlined pool charges its decaying warm-up adder on the traffic
/// it receives while re-populating. `malloc` keeps allocating 64 KB
/// chunks round-robin for the whole run, so pool0 starts receiving
/// fresh chunks (and their sweep traffic) right after it comes back.
#[test]
fn reonline_warmup_charges_decaying_adder() {
    let mut cfg = fast_cfg();
    cfg.scale = 0.02;
    let mut wl = workload::by_name("malloc", cfg.scale, cfg.seed).unwrap();
    let plain = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();
    assert!(plain.epochs_run >= 4, "need a mid-run re-online, got {}", plain.epochs_run);
    let e = plain.epochs_run;
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(
        FaultPlan::parse_inline(&format!(
            "offline:pool0@1;online:pool0@2:warmup={e},rd=400,wr=200"
        ))
        .unwrap(),
    );
    let mut wl = workload::by_name("malloc", fcfg.scale, fcfg.seed).unwrap();
    let rep = run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap();
    assert_eq!(rep.pools_reonlined, 1);
    assert!(rep.warmup_delay_ns > 0.0, "fresh chunks land on the warming pool");
    assert!(
        rep.warmup_delay_ns <= rep.lat_delay_ns,
        "warm-up is a sub-component of lat, not an addition"
    );
    assert_eq!(rep.retry_delay_ns, 0.0, "no storms: warm-up is attributed separately");
    assert_eq!(plain.total_misses, rep.total_misses, "faults never change the event stream");
}

/// A soak plan whose seeded schedule lands entirely beyond the run
/// horizon must be indistinguishable from a fault-free run — the
/// armed-but-idle zero-overhead contract.
#[test]
fn unreached_soak_plan_bit_identical_to_fault_free() {
    let cfg = fast_cfg();
    let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
    let plain = run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap();

    // MTBF of 10M epochs over a huge horizon: the schedule is real (the
    // plan has events) but its first arrival is ~10M epochs out
    let plan =
        FaultPlan::generate(cfg.seed, "mtbf=10000000,epochs=100000000").unwrap();
    assert!(!plan.events.is_empty(), "soak horizon must schedule events");
    assert!(
        plan.events.iter().all(|e| e.start > plain.epochs_run),
        "seeded schedule must land beyond the run horizon"
    );
    let mut scfg = cfg.clone();
    scfg.faults = Some(plan);
    let mut wl = workload::by_name("zipfian", scfg.scale, scfg.seed).unwrap();
    let armed = run_batched(&builtin::fig2(), &scfg, wl.as_mut()).unwrap();
    assert_reports_identical(&plain, &armed, "unreached soak plan");
    assert_fault_stats_identical(&plain, &armed, "unreached soak plan");
    assert_eq!(armed.faults_injected, 0);
}

/// Seeded MTBF soak reproducibility: the same seed twice yields a
/// byte-identical `SimReport` JSON; a different seed redraws the
/// schedule.
#[test]
fn soak_plan_same_seed_reproduces_report_json() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let spec = format!("mtbf=1,epochs={epochs},kinds=storm|retrain|offline+online,warmup=1");
    let run = || {
        let mut fcfg = cfg.clone();
        fcfg.faults = Some(FaultPlan::generate(fcfg.seed, &spec).unwrap());
        let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
        run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.faults_injected > 0, "mtbf=1 over the whole horizon must fire");
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "same seed, same soak schedule, same bits"
    );
    let starts = |seed: u64| {
        FaultPlan::generate(seed, &spec)
            .unwrap()
            .events
            .iter()
            .map(|e| e.start)
            .collect::<Vec<_>>()
    };
    assert_ne!(starts(1), starts(2), "different seeds must redraw the schedule");
}

/// Per-host fault plans stay confined: a retry storm scoped to h0 must
/// leave h1's `HostReport` byte-identical to its fault-free self, on
/// every worker count.
#[test]
fn per_host_fault_plan_isolates_unfaulted_hosts() {
    let cfg = fast_cfg();
    let mk_hosts = || -> Vec<Box<dyn Workload>> {
        (0..2)
            .map(|i| workload::by_name("stream", 0.002, i as u64).unwrap())
            .collect()
    };
    let plain = run_shared_threads(&builtin::fig2(), &cfg, mk_hosts(), 1).unwrap();
    assert!(plain.epochs >= 4, "need >= 4 epochs, got {}", plain.epochs);
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(
        FaultPlan::parse_inline(&format!(
            "storm:pool0@1+{}:rd=400,wr=200,host=h0",
            plain.epochs
        ))
        .unwrap(),
    );
    let faulted = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), 1).unwrap();
    assert_eq!(faulted.epochs, plain.epochs);
    assert!(faulted.retry_delay_ns > 0.0, "h0 streams over pool0: the storm must bite");
    let (f0, p0) = (&faulted.hosts[0], &plain.hosts[0]);
    assert!(f0.delay_ns > p0.delay_ns, "h0 pays its own storm");
    let (f1, p1) = (&faulted.hosts[1], &plain.hosts[1]);
    assert_eq!(f1.misses, p1.misses);
    assert_eq!(f1.native_ns, p1.native_ns);
    assert_eq!(f1.delay_ns, p1.delay_ns, "host-scoped storm must not leak to h1");
    assert_eq!(f1.simulated_ns, p1.simulated_ns);
    assert_eq!(f1.migrations, p1.migrations);
    for threads in knob_threads(&[2]) {
        let many = run_shared_threads(&builtin::fig2(), &fcfg, mk_hosts(), threads).unwrap();
        assert_multihost_identical(&faulted, &many);
    }
}

// ------------------------------------------- streaming trace replay

use cxlmemsim::trace::io as trace_io;
use cxlmemsim::trace::stream::DECODE_AHEAD_DEPTH;
use cxlmemsim::trace::WlEvent;
use cxlmemsim::workload::TraceReplay;

/// Record `wl` into a CXLTRC v2 temp file through the streaming
/// writer (bounded memory, same path `cmd_record` uses) and return the
/// path plus the full in-memory event list for the baseline replay.
fn record_v2_tempfile(
    wl: &str,
    scale: f64,
    seed: u64,
    chunk_events: usize,
    tag: &str,
) -> (std::path::PathBuf, Vec<WlEvent>) {
    let mut src = workload::by_name(wl, scale, seed).unwrap();
    let mut events: Vec<WlEvent> = Vec::new();
    while src.next_batch(&mut events, 4096) {}
    let path = std::env::temp_dir().join(format!(
        "cxlms-eq-{}-{}-{}.bin",
        std::process::id(),
        tag,
        chunk_events
    ));
    let f = std::fs::File::create(&path).unwrap();
    let mut w = trace_io::V2Writer::with_chunk_events(f, chunk_events).unwrap();
    w.push_slice(&events).unwrap();
    w.finish().unwrap();
    (path, events)
}

/// Streaming replay (chunk-resident events, decode-ahead thread) must
/// produce a `SimReport` bit-identical to replaying the same trace
/// fully decoded in memory — with and without the decode-ahead thread,
/// under both scan kernels (via `fast_cfg`'s CI knob).
#[test]
fn streaming_replay_bit_identical_to_in_memory() {
    let cfg = fast_cfg();
    let (path, events) = record_v2_tempfile("zipfian", cfg.scale, 9, 512, "bitident");
    let p = path.to_str().unwrap();

    let mut mem = TraceReplay::new("replay:mem", events);
    let baseline = run_batched(&builtin::fig2(), &cfg, &mut mem).unwrap();
    assert!(baseline.epochs_run > 0, "trace must span epochs");

    for ahead in [true, false] {
        let mut st = TraceStream::open_with(p, ahead).unwrap();
        assert!(st.chunks() > 2, "need several chunks to exercise refills");
        let rep = run_batched(&builtin::fig2(), &cfg, &mut st).unwrap();
        assert!(st.take_error().is_none(), "clean trace, ahead={ahead}");
        assert_reports_identical(&baseline, &rep, &format!("stream ahead={ahead}"));
    }
    std::fs::remove_file(&path).ok();
}

/// The full determinism matrix: analyzer threads x batch-group sizes,
/// each on a fresh `TraceStream`, must all match the sequential
/// in-memory coordinator bit-for-bit. CI pins the thread leg via
/// `CXLMEMSIM_TEST_THREADS` (1 / 2 / 8).
#[test]
fn streaming_replay_identical_across_batched_knobs() {
    let cfg = fast_cfg();
    let (path, events) = record_v2_tempfile("mcf_like", cfg.scale, 7, 768, "knobs");
    let p = path.to_str().unwrap();

    let mut mem = TraceReplay::new("replay:mem", events);
    let mut seq = Coordinator::new(builtin::fig2(), cfg.clone()).unwrap();
    let baseline = seq.run(&mut mem).unwrap();

    for threads in knob_threads(&[1, 2, 8]) {
        for group in [1usize, 16, 256] {
            let mut kcfg = cfg.clone();
            kcfg.analyzer_threads = threads;
            kcfg.batch_group = group;
            let mut st = TraceStream::open(p).unwrap();
            let rep = run_batched(&builtin::fig2(), &kcfg, &mut st).unwrap();
            assert!(st.take_error().is_none());
            assert_reports_identical(
                &baseline,
                &rep,
                &format!("stream threads={threads} group={group}"),
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Resident decoded-event memory is O(chunk), not O(trace): the peak
/// in-flight counter (consumer chunk + channel slot + decoder scratch)
/// must stay within `(DECODE_AHEAD_DEPTH + 2) x max_chunk_events`, and
/// everything must be retired once the stream drains.
#[test]
fn streaming_replay_memory_bounded_by_chunks_in_flight() {
    let cfg = fast_cfg();
    let (path, events) = record_v2_tempfile("zipfian", cfg.scale, 5, 256, "memory");
    let p = path.to_str().unwrap();

    let mut st = TraceStream::open(p).unwrap();
    assert!(st.chunks() >= 4, "need enough chunks for the pipeline to fill");
    let mut sink = Vec::new();
    let mut total = 0usize;
    while st.next_batch(&mut sink, 1024) {
        total += sink.len();
        sink.clear();
    }
    assert_eq!(total as u64, events.len() as u64, "drained the whole trace");
    assert!(st.take_error().is_none());

    let bound = (DECODE_AHEAD_DEPTH as u64 + 2) * st.max_chunk_events();
    let peak = st.peak_decoded_in_flight();
    assert!(peak > 0, "pipeline never filled");
    assert!(peak <= bound, "peak {peak} exceeds O(chunk) bound {bound}");
    assert_eq!(st.decoded_in_flight(), 0, "all chunks retired after drain");
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------- pipelined epoch execution

use cxlmemsim::workload::TraceWorkload;

/// Pipelined sequential runs (analysis on the dedicated worker, pump
/// one epoch ahead) must match the serial coordinator bit-for-bit, and
/// the observability fields must say what actually happened: depth 1
/// with no stack, analysis time measured.
#[test]
fn pipelined_sequential_bit_identical_to_serial() {
    for wl in ["zipfian", "stream"] {
        let run = |pipeline: bool| {
            let mut cfg = fast_cfg();
            cfg.pipeline = pipeline;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload(wl).unwrap()
        };
        let serial = run(false);
        let piped = run(true);
        assert_reports_identical(&serial, &piped, &format!("{wl}: pipelined sequential"));
        assert_eq!(piped.pipeline_depth, 1, "{wl}: no stack -> overlapped");
        assert!(piped.analyze_busy_ns > 0.0, "{wl}: worker must have analyzed");
        assert!(piped.pump_busy_ns > 0.0);
        assert!((0.0..=1.0).contains(&piped.overlap_frac));
    }
}

/// Pipelined batched replay must match serial batched replay for every
/// knob combination: analyzer threads (CI-pinned 1/2/8) x native group
/// size x both scan kernels (via `fast_cfg`'s kernel knob).
#[test]
fn pipelined_batched_bit_identical_across_knobs() {
    use cxlmemsim::runtime::ScanKernel;
    let base_cfg = fast_cfg();
    for kernel in [ScanKernel::Exact, ScanKernel::Blocked] {
        for threads in knob_threads(&[1, 2, 8]) {
            for group in [1usize, 256] {
                let run = |pipeline: bool| {
                    let mut cfg = base_cfg.clone();
                    cfg.scan_kernel = kernel;
                    cfg.analyzer_threads = threads;
                    cfg.batch_group = group;
                    cfg.pipeline = pipeline;
                    let mut wl = workload::by_name("mcf_like", cfg.scale, cfg.seed).unwrap();
                    run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
                };
                let serial = run(false);
                let piped = run(true);
                let ctx = format!("batched {kernel:?} threads={threads} group={group}");
                assert_reports_identical(&serial, &piped, &ctx);
                assert_eq!(piped.pipeline_depth, 1, "{ctx}: no stack -> overlapped");
                assert_eq!(piped.batch_group, serial.batch_group, "{ctx}");
                assert_eq!(
                    piped.analyzer_threads_used, serial.analyzer_threads_used,
                    "{ctx}"
                );
            }
        }
    }
}

/// `--pipeline` composed with streaming v2 replay: decode -> pump ->
/// analyze, three threads deep, still bit-identical to the in-memory
/// serial baseline.
#[test]
fn pipelined_streaming_replay_bit_identical() {
    let cfg = fast_cfg();
    let (path, events) = record_v2_tempfile("zipfian", cfg.scale, 11, 384, "pipelined");
    let p = path.to_str().unwrap();

    let mut mem = TraceReplay::new("replay:mem", events);
    let baseline = run_batched(&builtin::fig2(), &cfg, &mut mem).unwrap();
    assert!(baseline.epochs_run > 0);

    let mut pcfg = cfg.clone();
    pcfg.pipeline = true;
    let mut st = TraceStream::open(p).unwrap();
    let rep = run_batched(&builtin::fig2(), &pcfg, &mut st).unwrap();
    assert!(st.take_error().is_none());
    assert_reports_identical(&baseline, &rep, "pipelined streaming replay");
    assert_eq!(rep.pipeline_depth, 1);
    std::fs::remove_file(&path).ok();
}

/// A live policy stack under the pipeline: phase-2 feeds back into
/// event routing, so the pipeline drains lock-step — bit-identical by
/// construction, depth reported as 0, and the stack's migrations run
/// exactly as they do serially. Both drivers.
#[test]
fn pipelined_with_live_policy_stack_locks_step() {
    let mk_cfg = |pipeline: bool| {
        let mut cfg = fast_cfg();
        cfg.scale = 0.004;
        cfg.epoch_policy = Some(PolicySpec::parse("hotness:1,prefetch:0.5").unwrap());
        cfg.mig_stall_ns_per_byte = 0.25;
        cfg.pipeline = pipeline;
        cfg
    };
    // sequential driver
    let run_seq = |pipeline: bool| {
        let mut sim = Coordinator::new(builtin::fig2(), mk_cfg(pipeline)).unwrap();
        sim.run_workload("zipfian").unwrap()
    };
    let serial = run_seq(false);
    let piped = run_seq(true);
    assert!(piped.migrations > 0, "stack must migrate under the pipeline");
    assert_reports_identical(&serial, &piped, "policy stack: sequential");
    assert_eq!(piped.pipeline_depth, 0, "live stack -> lock-step");
    // batched driver
    let run_bat = |pipeline: bool| {
        let cfg = mk_cfg(pipeline);
        let mut wl = workload::by_name("zipfian", cfg.scale, cfg.seed).unwrap();
        run_batched(&builtin::fig2(), &cfg, wl.as_mut()).unwrap()
    };
    let bserial = run_bat(false);
    let bpiped = run_bat(true);
    assert!(bpiped.migrations > 0);
    assert_reports_identical(&bserial, &bpiped, "policy stack: batched");
    assert_eq!(bpiped.pipeline_depth, 0, "live stack -> lock-step");
}

/// The PR-6 chaos fault plan under the pipeline: overlay revision
/// edges drain the in-flight analysis, so no analysis ever spans two
/// overlays — fault stats and reports stay bit-identical on both
/// drivers. (The auto-installed failover stack is empty, so the
/// overlapped mode stays engaged.)
#[test]
fn pipelined_fault_run_bit_identical() {
    let cfg = fast_cfg();
    let epochs = baseline_epochs(&cfg);
    let mk = |pipeline: bool| {
        let mut fcfg = cfg.clone();
        fcfg.faults = Some(chaos_plan(epochs));
        fcfg.pipeline = pipeline;
        fcfg
    };
    // sequential driver
    let run_seq = |pipeline: bool| {
        let mut sim = Coordinator::new(builtin::fig2(), mk(pipeline)).unwrap();
        sim.run_workload("zipfian").unwrap()
    };
    let serial = run_seq(false);
    let piped = run_seq(true);
    assert_eq!(piped.faults_injected, 4, "whole chaos plan must fire");
    assert!(piped.failover_migrated_bytes > 0);
    assert_reports_identical(&serial, &piped, "faults: pipelined sequential");
    assert_fault_stats_identical(&serial, &piped, "faults: pipelined sequential");
    // batched driver
    let run_bat = |pipeline: bool| {
        let fcfg = mk(pipeline);
        let mut wl = workload::by_name("zipfian", fcfg.scale, fcfg.seed).unwrap();
        run_batched(&builtin::fig2(), &fcfg, wl.as_mut()).unwrap()
    };
    let bserial = run_bat(false);
    let bpiped = run_bat(true);
    assert_reports_identical(&bserial, &bpiped, "faults: pipelined batched");
    assert_fault_stats_identical(&bserial, &bpiped, "faults: pipelined batched");
}

// ---------------------------------------------------- sharded replay

/// Shards partition the trace: replaying every shard `i/N` must cover
/// each event exactly once — per-shard `total_accesses`/`alloc_events`
/// sum to the full-replay counts (miss counts are NOT additive: the
/// cache resets per shard). Also holds when N exceeds the chunk count
/// (trailing shards are legitimately empty).
#[test]
fn shard_union_event_counts_sum_to_full_replay() {
    let cfg = fast_cfg();
    let (path, _events) = record_v2_tempfile("zipfian", cfg.scale, 13, 256, "shard");
    let p = path.to_str().unwrap();

    let mut full = TraceWorkload::open(p).unwrap();
    let full_rep = run_batched(&builtin::fig2(), &cfg, &mut full).unwrap();
    assert!(full.take_error().is_none());

    for n in [4usize, 64] {
        let (mut accesses, mut allocs) = (0u64, 0u64);
        let mut empty_shards = 0;
        for i in 0..n {
            let mut shard = TraceWorkload::open_shard(p, i, n).unwrap();
            let rep = run_batched(&builtin::fig2(), &cfg, &mut shard).unwrap();
            assert!(shard.take_error().is_none(), "shard {i}/{n}");
            if rep.total_accesses == 0 {
                empty_shards += 1;
            }
            accesses += rep.total_accesses;
            allocs += rep.alloc_events;
        }
        assert_eq!(accesses, full_rep.total_accesses, "{n} shards: access union");
        assert_eq!(allocs, full_rep.alloc_events, "{n} shards: alloc union");
        let chunks = TraceStream::open(p).unwrap().file_chunks();
        if n > chunks {
            assert!(empty_shards > 0, "{n} shards over {chunks} chunks must leave empties");
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Sharding a directory-less trace is a structured error, not a silent
/// full replay.
#[test]
fn shard_of_non_v2_trace_is_structured_error() {
    let dir = std::env::temp_dir();
    let v1 = dir.join(format!("cxlms-eq-{}-shard-v1.bin", std::process::id()));
    let mut wl = workload::by_name("zipfian", 0.002, 3).unwrap();
    let mut events = Vec::new();
    while wl.next_batch(&mut events, 4096) {}
    let mut f = std::fs::File::create(&v1).unwrap();
    trace_io::write_binary(&mut f, &events).unwrap();
    drop(f);
    let err = TraceWorkload::open_shard(v1.to_str().unwrap(), 0, 4).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("requires a CXLTRC v2"), "{msg}");
    assert!(msg.contains("v1"), "{msg}");
    std::fs::remove_file(&v1).ok();

    let jl = dir.join(format!("cxlms-eq-{}-shard.jsonl", std::process::id()));
    std::fs::write(&jl, "{\"a\":1}\n").unwrap();
    let err = TraceWorkload::open_shard(jl.to_str().unwrap(), 0, 4).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("JSONL"), "{msg}");
    std::fs::remove_file(&jl).ok();
}

//! Sweep-engine integration tests: the artifact must be a pure
//! function of the spec (byte-identical across runs AND worker
//! counts), grids must expand completely, baseline deltas and
//! invariant verdicts must land in the artifact, and the sharded /
//! multihost execution paths must agree with their sequential
//! counterparts.

use cxlmemsim::sweep::{self, SweepOptions, SweepSpec};
use cxlmemsim::trace::io as trace_io;
use cxlmemsim::util::json::Json;
use cxlmemsim::workload;

const SMOKE: &str = r#"
name = "t"
[grid]
topo = ["direct", "fig2"]
workload = ["stream", "zipfian"]
[config]
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 20
[baseline]
topo = "direct"
[[invariant]]
metric = "delay_ms"
axis = "topo"
order = ["direct", "fig2"]
rel_tol = 0.02
"#;

fn run(src: &str, workers: usize) -> sweep::SweepOutcome {
    let spec = SweepSpec::parse(src).unwrap();
    sweep::run_spec(&spec, &SweepOptions { workers, ..SweepOptions::default() })
}

fn cells_of(artifact: &Json) -> &[Json] {
    artifact.get("cells").and_then(|c| c.as_arr()).unwrap()
}

#[test]
fn artifact_is_byte_identical_across_runs_and_worker_counts() {
    let one = run(SMOKE, 1).artifact.to_string();
    let again = run(SMOKE, 1).artifact.to_string();
    let four = run(SMOKE, 4).artifact.to_string();
    assert_eq!(one, again, "same spec twice must produce identical bytes");
    assert_eq!(one, four, "worker count leaked into the artifact");
}

#[test]
fn grid_expands_fully_and_cells_carry_reports() {
    let out = run(SMOKE, 2);
    assert_eq!(out.cells, 4, "2 topos x 2 workloads");
    assert_eq!(out.cell_failures, 0);
    assert_eq!(out.invariant_failures, 0);
    let cells = cells_of(&out.artifact);
    assert_eq!(cells.len(), 4);
    for cell in cells {
        let rep = cell.get("report").expect("every cell succeeded");
        assert!(rep.get("delay_ms").and_then(Json::as_f64).is_some());
        // nondeterministic observability must be stripped
        assert!(rep.get("wall_s").is_none(), "wall_s survived sanitize");
    }
    let summary = out.artifact.get("summary").unwrap();
    assert_eq!(summary.get("cells").and_then(Json::as_f64), Some(4.0));
}

#[test]
fn baseline_delta_is_zero_against_itself() {
    let out = run(SMOKE, 2);
    for cell in cells_of(&out.artifact) {
        let id = cell.get("id").and_then(Json::as_str).unwrap();
        let delta = cell.get("delta").expect("baseline pins topo: every cell has a delta");
        let vs = delta.get("vs").and_then(Json::as_str).unwrap();
        assert!(vs.contains("topo=direct"), "delta target must be the direct cell: {vs}");
        if id == vs {
            // the baseline cell compares against itself: all-zero delta
            let Json::Obj(map) = delta else { panic!("delta must be an object") };
            for (k, v) in map {
                if k != "vs" {
                    assert_eq!(v.as_f64(), Some(0.0), "nonzero self-delta for {k}");
                }
            }
        }
    }
}

#[test]
fn violated_invariant_is_reported_and_counted() {
    // same grid, deliberately reversed ordering: fig2 adds a switch
    // tier, so claiming fig2 <= direct must fail.
    let bad = SMOKE.replace(
        r#"order = ["direct", "fig2"]"#,
        r#"order = ["fig2", "direct"]"#,
    );
    let out = run(&bad, 2);
    assert_eq!(out.cell_failures, 0);
    assert_eq!(out.invariant_failures, 1);
    let invs = out.artifact.get("invariants").and_then(|i| i.as_arr()).unwrap();
    assert_eq!(invs.len(), 1);
    assert!(matches!(invs[0].get("holds"), Some(Json::Bool(false))));
    let viols = invs[0].get("violations").and_then(|v| v.as_arr()).unwrap();
    assert!(!viols.is_empty(), "violations must name the offending cell pairs");
    assert!(viols[0].get("from").and_then(Json::as_str).is_some());
    assert!(viols[0].get("to_value").and_then(Json::as_f64).is_some());
}

#[test]
fn scan_kernel_axis_cells_agree_on_miss_counts() {
    let src = r#"
name = "t"
[grid]
scan_kernel = ["exact", "blocked"]
[config]
topo = "direct"
workload = "mcf_like"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 20
"#;
    let out = run(src, 2);
    assert_eq!(out.cells, 2);
    assert_eq!(out.cell_failures, 0);
    let cells = cells_of(&out.artifact);
    let acc: Vec<f64> = cells
        .iter()
        .map(|c| c.get("report").unwrap().get("accesses").and_then(Json::as_f64).unwrap())
        .collect();
    assert_eq!(acc[0], acc[1], "scan kernel must not change what is simulated");
}

#[test]
fn in_process_shard_fanout_matches_unsharded_replay() {
    // record a real trace, then sweep it with a `shards` axis: the
    // merged 2-shard report must cover the same events as the
    // unsharded replay of the same file.
    let path = std::env::temp_dir().join(format!("cxlms-sweep-shard-{}.bin", std::process::id()));
    let f = std::fs::File::create(&path).unwrap();
    let mut w = trace_io::V2Writer::with_chunk_events(f, 512).unwrap();
    let mut wl = workload::by_name("stream", 0.002, 9).unwrap();
    let mut buf = Vec::new();
    while wl.next_batch(&mut buf, 2048) {
        w.push_slice(&buf).unwrap();
        buf.clear();
    }
    w.push_slice(&buf).unwrap();
    w.finish().unwrap();

    let src = format!(
        r#"
name = "t"
[grid]
shards = [1, 2]
[config]
topo = "fig2"
workload = "trace:{}"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
"#,
        path.display()
    );
    // shard_exe = None -> shards run in-process through open_shard()
    let out = run(&src, 2);
    assert_eq!(out.cell_failures, 0, "{}", out.artifact.to_string());
    let cells = cells_of(&out.artifact);
    let get = |c: &Json, k: &str| c.get("report").unwrap().get(k).and_then(Json::as_f64).unwrap();
    let (a, b) = (&cells[0], &cells[1]);
    assert_eq!(get(a, "accesses"), get(b, "accesses"), "shards dropped or duplicated events");
    assert_eq!(get(a, "alloc_events"), get(b, "alloc_events"));
    let sharded = if cells[0].get("id").and_then(Json::as_str).unwrap().contains("shards=2") {
        &cells[0]
    } else {
        &cells[1]
    };
    assert_eq!(
        sharded.get("report").unwrap().get("shards").and_then(Json::as_f64),
        Some(2.0)
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn multihost_cells_report_congestion_ordering() {
    let src = r#"
name = "t"
[grid]
hosts = [1, 2]
[config]
driver = "multihost"
topo = "fig2"
workload = "stream"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 30
[[invariant]]
metric = "total_delay_ms"
axis = "hosts"
order = [1, 2]
rel_tol = 0.02
"#;
    let out = run(src, 2);
    assert_eq!(out.cells, 2);
    assert_eq!(out.cell_failures, 0, "{}", out.artifact.to_string());
    assert_eq!(out.invariant_failures, 0, "{}", out.artifact.to_string());
    for cell in cells_of(&out.artifact) {
        let rep = cell.get("report").unwrap();
        assert!(rep.get("total_delay_ms").and_then(Json::as_f64).is_some());
        assert!(rep.get("delay_ms").and_then(Json::as_f64).is_some(), "cross-driver alias");
        // scheduling observability is nondeterministic -> stripped
        assert!(rep.get("steals").is_none());
        assert!(rep.get("worker_busy_fracs").is_none());
    }
}

const SOAK_SPEC: &str = "mtbf=4,epochs=30,kinds=storm,rd=200,wr=100";

#[test]
fn fault_soak_axis_is_deterministic_and_ordered_after_fault_free() {
    let src = format!(
        r#"
name = "t"
[grid]
fault_soak = ["none", "{SOAK_SPEC}"]
[config]
topo = "fig2"
workload = "stream"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 30
seed = 1
[baseline]
fault_soak = "none"
[[invariant]]
metric = "delay_ms"
axis = "fault_soak"
order = ["none", "{SOAK_SPEC}"]
rel_tol = 0.02
"#
    );
    let one = run(&src, 1);
    assert_eq!(one.cells, 2);
    assert_eq!(one.cell_failures, 0, "{}", one.artifact.to_string());
    assert_eq!(one.invariant_failures, 0, "{}", one.artifact.to_string());
    // the soak plan is generated from the cell's seed, not from engine
    // scheduling: worker counts must not perturb the artifact
    let four = run(&src, 4);
    assert_eq!(one.artifact.to_string(), four.artifact.to_string());
    for cell in cells_of(&one.artifact) {
        let id = cell.get("id").and_then(Json::as_str).unwrap();
        let injected =
            cell.get("report").unwrap().get("faults_injected").and_then(Json::as_f64).unwrap();
        if id.contains("mtbf=4") {
            assert!(injected > 0.0, "soak cell drew no events inside the horizon: {id}");
        } else {
            assert_eq!(injected, 0.0, "fault-free cell injected faults: {id}");
        }
    }
}

#[test]
fn faults_axis_reads_plan_file_per_cell() {
    let path = std::env::temp_dir().join(format!("cxlms-sweep-plan-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        "[[fault]]\nkind = \"storm\"\npool = \"pool0\"\nstart = 4\nepochs = 8\n\
         rd_add_ns = 120\nwr_add_ns = 60\n",
    )
    .unwrap();
    let src = format!(
        r#"
name = "t"
[grid]
faults = ["none", "{p}"]
[config]
topo = "fig2"
workload = "zipfian"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 20
[baseline]
faults = "none"
[[invariant]]
metric = "delay_ms"
axis = "faults"
order = ["none", "{p}"]
rel_tol = 0.02
"#,
        p = path.display()
    );
    let out = run(&src, 2);
    assert_eq!(out.cells, 2);
    assert_eq!(out.cell_failures, 0, "{}", out.artifact.to_string());
    assert_eq!(out.invariant_failures, 0, "{}", out.artifact.to_string());
    for cell in cells_of(&out.artifact) {
        let id = cell.get("id").and_then(Json::as_str).unwrap();
        let injected =
            cell.get("report").unwrap().get("faults_injected").and_then(Json::as_f64).unwrap();
        if id.contains("faults=none") {
            assert_eq!(injected, 0.0, "fault-free cell injected faults: {id}");
        } else {
            assert_eq!(injected, 1.0, "plan file schedules exactly one storm: {id}");
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn faults_and_fault_soak_are_mutually_exclusive_per_cell() {
    let path = std::env::temp_dir().join(format!("cxlms-sweep-clash-{}.toml", std::process::id()));
    std::fs::write(&path, "[[fault]]\nkind = \"offline\"\npool = \"pool0\"\nstart = 4\n").unwrap();
    let src = format!(
        r#"
name = "t"
[grid]
workload = ["stream"]
[config]
topo = "fig2"
scale = 0.002
cache_scale = 64
epoch_ms = 0.1
max_epochs = 10
faults = "{p}"
fault_soak = "{SOAK_SPEC}"
"#,
        p = path.display()
    );
    let out = run(&src, 1);
    assert_eq!(out.cell_failures, 1, "clashing fault sources must fail the cell");
    let cell = &cells_of(&out.artifact)[0];
    let err = cell.get("error").and_then(Json::as_str).unwrap();
    assert!(err.contains("mutually exclusive"), "unhelpful error: {err}");
    std::fs::remove_file(&path).ok();
}

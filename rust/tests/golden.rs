//! Three-way differential test: the AOT HLO module (through PJRT), the
//! pure-rust native analyzer, and the python oracle's golden vectors
//! (`artifacts/golden.json`, produced by `kernels/ref.py` at `make
//! artifacts` time) must all agree on the same inputs.
//!
//! This is the repo's cross-language correctness anchor: if the Pallas
//! kernel, the JAX model, the HLO lowering, the PJRT runtime, or the
//! rust mirror drift apart, this test fails.

use cxlmemsim::runtime::native::NativeAnalyzer;
#[cfg(feature = "pjrt")]
use cxlmemsim::runtime::pjrt::PjrtAnalyzer;
use cxlmemsim::runtime::shapes;
use cxlmemsim::runtime::{ScanKernel, TimingInputs, TimingModel};
use cxlmemsim::topology::TopoTensors;
use cxlmemsim::util::json::Json;

struct Golden {
    pools: usize,
    switches: usize,
    nbins: usize,
    reads: Vec<f32>,
    writes: Vec<f32>,
    extra_rd: Vec<f32>,
    extra_wr: Vec<f32>,
    desc_mask: Vec<f32>,
    stt: Vec<f32>,
    bw: Vec<f32>,
    bin_width: f32,
    bytes_per_ev: f32,
    out_total: f64,
    out_lat: Vec<f32>,
    out_cong: Vec<f32>,
    out_bwd: Vec<f32>,
    out_backlog: Vec<f32>,
}

/// Loads the golden vectors, or None when `make artifacts` has not
/// been run (tests then skip instead of failing — the python toolchain
/// is not available in every build environment).
fn load_golden() -> Option<Golden> {
    let dir = shapes::artifacts_dir();
    let src = match std::fs::read_to_string(format!("{dir}/golden.json")) {
        Ok(src) => src,
        Err(_) => {
            eprintln!("skipping golden test: no artifacts (run `make artifacts`)");
            return None;
        }
    };
    let v = Json::parse(&src).unwrap();
    let sh = v.get("shapes").unwrap();
    let inp = v.get("inputs").unwrap();
    let out = v.get("outputs").unwrap();
    let fv = |o: &Json, k: &str| -> Vec<f32> { o.get(k).unwrap().as_f32_vec().unwrap() };
    Some(Golden {
        pools: sh.get("pools").unwrap().as_usize().unwrap(),
        switches: sh.get("switches").unwrap().as_usize().unwrap(),
        nbins: sh.get("nbins").unwrap().as_usize().unwrap(),
        reads: fv(inp, "reads"),
        writes: fv(inp, "writes"),
        extra_rd: fv(inp, "extra_read_lat"),
        extra_wr: fv(inp, "extra_write_lat"),
        desc_mask: fv(inp, "desc_mask"),
        stt: fv(inp, "stt"),
        bw: fv(inp, "bw"),
        bin_width: fv(inp, "bin_width")[0],
        bytes_per_ev: fv(inp, "bytes_per_ev")[0],
        out_total: out.get("total").unwrap().as_f64().unwrap(),
        out_lat: fv(out, "lat"),
        out_cong: fv(out, "cong"),
        out_bwd: fv(out, "bwd"),
        out_backlog: fv(out, "cong_backlog"),
    })
}

fn tensors_of(g: &Golden) -> TopoTensors {
    TopoTensors {
        pools: g.pools,
        switches: g.switches,
        extra_read_lat: g.extra_rd.clone(),
        extra_write_lat: g.extra_wr.clone(),
        desc_mask: g.desc_mask.clone(),
        stt: g.stt.clone(),
        bw: g.bw.clone(),
    }
}

fn assert_close(name: &str, got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "{name} length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * b.abs();
        assert!(
            (a - b).abs() <= tol,
            "{name}[{i}]: got {a}, want {b} (tol {tol})"
        );
    }
}

fn check_model(model: &mut dyn TimingModel, g: &Golden) {
    // backlog export defaults off (hot-path optimization); the golden
    // vectors include the full profile, so opt in here
    model.set_export_backlog(true);
    let out = model
        .analyze(&TimingInputs {
            reads: &g.reads,
            writes: &g.writes,
            bin_width: g.bin_width,
            bytes_per_ev: g.bytes_per_ev,
        })
        .unwrap();
    let rel = (out.total - g.out_total).abs() / g.out_total.max(1.0);
    assert!(
        rel < 1e-4,
        "{}: total {} vs golden {} (rel {rel})",
        model.backend_name(),
        out.total,
        g.out_total
    );
    assert_close("lat", &out.lat, &g.out_lat, 1e-4, 1e-2);
    assert_close("cong", &out.cong, &g.out_cong, 1e-3, 1.0);
    assert_close("bwd", &out.bwd, &g.out_bwd, 1e-3, 1.0);
    assert_close("backlog", &out.cong_backlog, &g.out_backlog, 1e-3, 1.0);
}

#[test]
fn native_matches_python_golden() {
    let Some(g) = load_golden() else { return };
    // pinned to the `exact` kernel: this is the bit-identity anchor —
    // the blocked kernel is validated separately, to tolerance only
    let mut m = NativeAnalyzer::with_kernel(&tensors_of(&g), g.nbins, ScanKernel::Exact);
    assert_eq!(m.kernel(), ScanKernel::Exact);
    check_model(&mut m, &g);
}

#[test]
fn blocked_kernel_matches_python_golden_within_tolerance() {
    // the max-plus blocked kernel reassociates float adds, so it is
    // checked against the golden vectors with the same tolerances the
    // cross-language (HLO vs rust) comparison already uses — NOT the
    // exact kernel's bit-identity contract
    let Some(g) = load_golden() else { return };
    let mut m = NativeAnalyzer::with_kernel(&tensors_of(&g), g.nbins, ScanKernel::Blocked);
    check_model(&mut m, &g);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_matches_python_golden() {
    let Some(g) = load_golden() else { return };
    let mut m = PjrtAnalyzer::new(&tensors_of(&g), g.nbins, &shapes::artifacts_dir()).unwrap();
    check_model(&mut m, &g);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_agree_on_random_inputs() {
    let Some(g) = load_golden() else { return };
    let t = tensors_of(&g);
    let dir = shapes::artifacts_dir();
    let mut pjrt = PjrtAnalyzer::new(&t, g.nbins, &dir).unwrap();
    let mut native = NativeAnalyzer::new(&t, g.nbins);
    let mut rng = cxlmemsim::util::rng::Rng::new(99);
    for round in 0..5 {
        let n = g.pools * g.nbins;
        let reads: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
        let writes: Vec<f32> = (0..n).map(|_| rng.below(10) as f32).collect();
        let inp = TimingInputs {
            reads: &reads,
            writes: &writes,
            bin_width: 1000.0,
            bytes_per_ev: 64.0,
        };
        let a = pjrt.analyze(&inp).unwrap();
        let b = native.analyze(&inp).unwrap();
        let rel = (a.total - b.total).abs() / b.total.max(1.0);
        assert!(rel < 1e-3, "round {round}: pjrt {} vs native {}", a.total, b.total);
        assert_close("lat", &a.lat, &b.lat, 1e-3, 1e-1);
        assert_close("cong", &a.cong, &b.cong, 1e-3, 1.0);
        assert_close("bwd", &a.bwd, &b.bwd, 1e-3, 1.0);
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn batch_module_matches_single() {
    use cxlmemsim::runtime::pjrt::PjrtBatchAnalyzer;
    let Some(g) = load_golden() else { return };
    let t = tensors_of(&g);
    let dir = shapes::artifacts_dir();
    let mut single = PjrtAnalyzer::new(&t, g.nbins, &dir).unwrap();
    let mut batch = PjrtBatchAnalyzer::new(&t, g.nbins, &dir).unwrap();
    let e = batch.batch;
    let n = g.pools * g.nbins;
    let mut rng = cxlmemsim::util::rng::Rng::new(7);
    let reads: Vec<f32> = (0..e * n).map(|_| rng.below(12) as f32).collect();
    let writes: Vec<f32> = (0..e * n).map(|_| rng.below(6) as f32).collect();
    let out = batch.analyze_batch(&reads, &writes, 1000.0, 64.0).unwrap();
    assert_eq!(out.total.len(), e);
    for i in [0, e / 2, e - 1] {
        let s = single
            .analyze(&TimingInputs {
                reads: &reads[i * n..(i + 1) * n],
                writes: &writes[i * n..(i + 1) * n],
                bin_width: 1000.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let rel = (out.total[i] - s.total).abs() / s.total.max(1.0);
        assert!(rel < 1e-3, "epoch {i}: batch {} vs single {}", out.total[i], s.total);
    }
}

//! Sweep specification: a TOML grid of simulation settings.
//!
//! A spec names a (topology × policy × workload × knob) grid, a base
//! `[config]`, an optional `[baseline]` cell selector for per-cell
//! deltas, and `[[invariant]]` entries — the coarse accuracy harness
//! that pins relative metric *orderings* across an axis (not absolute
//! nanoseconds). Parsing reuses [`crate::util::toml::TomlDoc`] and
//! fails with structured, field-naming [`SweepError`]s.
//!
//! ```toml
//! name = "topology_sweep"
//! workers = 0                      # 0 = one per core
//!
//! [grid]
//! topo = ["direct", "fig2", "deep"]
//! workload = ["stream", "mcf_like"]
//!
//! [config]
//! scale = 0.002
//! cache_scale = 64
//!
//! [baseline]
//! topo = "direct"                  # every cell's delta is vs the
//!                                  # same-coords cell with topo=direct
//!
//! [[invariant]]
//! metric = "delay_ms"
//! axis = "topo"
//! order = ["direct", "fig2", "deep"]
//! ```

use std::collections::BTreeMap;

use crate::alloctrack::PolicyKind;
use crate::coordinator::SimConfig;
use crate::policy::PolicySpec;
use crate::runtime::ScanKernel;
use crate::topology::builtin;
use crate::util::toml::{TomlDoc, TomlValue};
use crate::workload::ALL_WORKLOADS;

/// Structured sweep-spec errors. Every variant names the table / key /
/// axis at fault so a misspelled spec fails with an actionable message
/// (asserted in `tests/failures.rs`).
#[derive(Debug, Clone, PartialEq)]
pub enum SweepError {
    /// The file is not parseable TOML (line-numbered message).
    Toml(String),
    /// A required key is absent.
    MissingKey { table: String, key: String },
    /// A key is present but its value is malformed.
    BadValue { table: String, key: String, msg: String },
    /// `[grid]` names a setting the engine does not sweep.
    UnknownAxis { axis: String },
    /// A grid axis value fails that setting's validation.
    BadAxisValue { axis: String, value: String, msg: String },
    /// A grid axis with no values (or a non-array value).
    EmptyAxis { axis: String },
    /// The spec has no `[grid]` axes at all.
    EmptyGrid,
    /// `[baseline]` pins an axis that is not in the grid, or to a
    /// value the axis does not contain.
    BadBaseline { axis: String, msg: String },
    /// An `[[invariant]]` entry is malformed (0-based index).
    BadInvariant { index: usize, msg: String },
    /// A cell combination is contradictory (e.g. sharded multihost).
    BadCell { cell: String, msg: String },
    /// A sharded cell's child process failed. Carries the child's
    /// captured stderr (last lines) so CI failures are diagnosable
    /// from the artifact, not just the exit status.
    ShardChild { cell: String, shard: String, status: String, stderr: String },
    /// Spec file could not be read.
    Io { path: String, msg: String },
}

impl std::fmt::Display for SweepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SweepError::Toml(m) => write!(f, "sweep spec is not valid TOML: {m}"),
            SweepError::MissingKey { table, key } => {
                write!(f, "sweep spec {}: missing required key `{key}`", table_name(table))
            }
            SweepError::BadValue { table, key, msg } => {
                write!(f, "sweep spec {}: bad value for `{key}`: {msg}", table_name(table))
            }
            SweepError::UnknownAxis { axis } => {
                write!(f, "sweep spec [grid]: unknown axis `{axis}` (see `cxlmemsim list`)")
            }
            SweepError::BadAxisValue { axis, value, msg } => {
                write!(f, "sweep spec [grid] axis `{axis}`: bad value `{value}`: {msg}")
            }
            SweepError::EmptyAxis { axis } => {
                write!(f, "sweep spec [grid] axis `{axis}`: expected a non-empty array of values")
            }
            SweepError::EmptyGrid => write!(f, "sweep spec: [grid] must define at least one axis"),
            SweepError::BadBaseline { axis, msg } => {
                write!(f, "sweep spec [baseline] `{axis}`: {msg}")
            }
            SweepError::BadInvariant { index, msg } => {
                write!(f, "sweep spec [[invariant]] #{index}: {msg}")
            }
            SweepError::BadCell { cell, msg } => write!(f, "sweep spec cell `{cell}`: {msg}"),
            SweepError::ShardChild { cell, shard, status, stderr } => {
                write!(f, "cell `{cell}` shard {shard}: child exited with {status}")?;
                if stderr.is_empty() {
                    write!(f, " (no stderr)")
                } else {
                    write!(f, "; stderr: {stderr}")
                }
            }
            SweepError::Io { path, msg } => write!(f, "sweep spec {path}: {msg}"),
        }
    }
}

impl std::error::Error for SweepError {}

fn table_name(table: &str) -> String {
    if table.is_empty() {
        "top level".to_string()
    } else {
        format!("[{table}]")
    }
}

/// Which driver executes a cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Driver {
    /// Sequential coordinator (`cxlmemsim run`).
    Run,
    /// Grouped-analyzer replay driver (`run --batched`).
    Batched,
    /// Shared-pool multi-host runner (`cxlmemsim multihost`).
    Multihost,
}

/// One grid axis: a setting name plus its values in spec order.
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub values: Vec<String>,
}

/// One accuracy-harness invariant: along `axis`, `metric` must be
/// non-decreasing over `order` (strictly increasing with `strict`),
/// for every combination of the remaining axes (or only the `pins`ned
/// one). `rel_tol` loosens the non-strict comparison to
/// `next >= prev * (1 - rel_tol)` so near-equal cells don't flap.
#[derive(Debug, Clone)]
pub struct Invariant {
    pub metric: String,
    pub axis: String,
    pub order: Vec<String>,
    pub strict: bool,
    pub rel_tol: f64,
    pub pins: BTreeMap<String, String>,
}

/// One expanded grid cell: its index in canonical order and its
/// axis → value coordinates.
#[derive(Debug, Clone)]
pub struct Cell {
    pub index: usize,
    pub coords: BTreeMap<String, String>,
}

impl Cell {
    /// Canonical cell id: `axis=value` pairs joined with `,`, axes in
    /// sorted order. This is the artifact's cell key and the baseline
    /// lookup key.
    pub fn id(&self) -> String {
        coords_id(&self.coords)
    }
}

/// Canonical id for any axis → value map (see [`Cell::id`]).
pub fn coords_id(coords: &BTreeMap<String, String>) -> String {
    coords
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// Everything needed to execute one cell.
#[derive(Debug, Clone)]
pub struct CellPlan {
    pub cfg: SimConfig,
    pub driver: Driver,
    pub topo: String,
    pub workload: String,
    /// Host count for [`Driver::Multihost`] cells.
    pub hosts: usize,
    /// Shard fan-out for `trace:` cells (1 = unsharded).
    pub shards: usize,
    /// The spec's `epoch_policy` string, kept verbatim so shard child
    /// processes receive the exact `--epoch-policy` the cell parsed.
    pub epoch_policy_src: Option<String>,
    /// The spec's `faults` plan-file path, kept verbatim for shard
    /// child processes (`--faults`). `None` without a fault axis.
    pub faults_src: Option<String>,
    /// The spec's `fault_soak` MTBF spec, kept verbatim for shard
    /// child processes (`--fault-soak`).
    pub fault_soak_src: Option<String>,
}

/// Settings the engine understands, as grid axes or `[config]` keys.
/// `topo` / `workload` / `driver` / `hosts` / `shards` select what
/// runs; the rest map 1:1 onto [`SimConfig`] fields (CLI flag names
/// with `-` spelled `_`).
pub const KNOWN_SETTINGS: &[&str] = &[
    "topo",
    "workload",
    "driver",
    "hosts",
    "shards",
    "policy",
    "epoch_policy",
    "prefetch",
    "scan_kernel",
    "pipeline",
    "epoch_ms",
    "scale",
    "seed",
    "sample_period",
    "cache_scale",
    "event_batch",
    "analyzer_threads",
    "batch_group",
    "heat_decay",
    "mig_stall_ns_per_byte",
    "max_epochs",
    "mlp",
    "cpi_ns",
    "faults",
    "fault_soak",
];

/// A parsed, validated sweep specification.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Worker threads for the cell pool (0 = one per core).
    pub workers: usize,
    /// Grid axes, sorted by name (canonical expansion order).
    pub axes: Vec<Axis>,
    /// Base `[config]` settings applied to every cell.
    pub base: BTreeMap<String, String>,
    /// `[baseline]` axis pins (empty = no deltas).
    pub baseline: BTreeMap<String, String>,
    pub invariants: Vec<Invariant>,
}

impl SweepSpec {
    pub fn from_file(path: &str) -> Result<SweepSpec, SweepError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| SweepError::Io { path: path.to_string(), msg: e.to_string() })?;
        SweepSpec::parse(&src)
    }

    pub fn parse(src: &str) -> Result<SweepSpec, SweepError> {
        let doc = TomlDoc::parse(src).map_err(SweepError::Toml)?;
        let top = doc.table("").cloned().unwrap_or_default();
        let name = top
            .get("name")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| SweepError::MissingKey { table: String::new(), key: "name".into() })?;
        let workers = match top.get("workers") {
            None => 0,
            Some(v) => v.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).ok_or_else(|| {
                SweepError::BadValue {
                    table: String::new(),
                    key: "workers".into(),
                    msg: "expected a non-negative integer".into(),
                }
            })? as usize,
        };

        // ---- [grid]: every key is an axis, every axis a non-empty
        // array of validated setting values
        let grid = doc.table("grid").cloned().unwrap_or_default();
        let mut axes = Vec::new();
        for (raw_key, val) in &grid {
            let axis = normalize_key(raw_key);
            if !KNOWN_SETTINGS.contains(&axis.as_str()) {
                return Err(SweepError::UnknownAxis { axis });
            }
            let vals = match val {
                TomlValue::Arr(items) if !items.is_empty() => items,
                _ => return Err(SweepError::EmptyAxis { axis }),
            };
            let mut values = Vec::with_capacity(vals.len());
            for item in vals {
                let v = value_str(item).ok_or_else(|| SweepError::BadAxisValue {
                    axis: axis.clone(),
                    value: format!("{item:?}"),
                    msg: "expected a scalar (string, number, or bool)".into(),
                })?;
                validate_setting(&axis, &v).map_err(|msg| SweepError::BadAxisValue {
                    axis: axis.clone(),
                    value: v.clone(),
                    msg,
                })?;
                if values.contains(&v) {
                    return Err(SweepError::BadAxisValue {
                        axis: axis.clone(),
                        value: v,
                        msg: "duplicate axis value".into(),
                    });
                }
                values.push(v);
            }
            axes.push(Axis { name: axis, values });
        }
        if axes.is_empty() {
            return Err(SweepError::EmptyGrid);
        }
        axes.sort_by(|a, b| a.name.cmp(&b.name));

        // ---- [config]: base settings, overridden per cell by coords
        let mut base = BTreeMap::new();
        for (raw_key, val) in doc.table("config").cloned().unwrap_or_default() {
            let key = normalize_key(&raw_key);
            if !KNOWN_SETTINGS.contains(&key.as_str()) {
                return Err(SweepError::BadValue {
                    table: "config".into(),
                    key,
                    msg: "unknown setting (see docs/CLI.md)".into(),
                });
            }
            let v = value_str(&val).ok_or_else(|| SweepError::BadValue {
                table: "config".into(),
                key: key.clone(),
                msg: "expected a scalar value".into(),
            })?;
            validate_setting(&key, &v).map_err(|msg| SweepError::BadValue {
                table: "config".into(),
                key: key.clone(),
                msg,
            })?;
            base.insert(key, v);
        }

        // ---- [baseline]: a subset of grid axes pinned to grid values
        let mut baseline = BTreeMap::new();
        for (raw_key, val) in doc.table("baseline").cloned().unwrap_or_default() {
            let key = normalize_key(&raw_key);
            let v = value_str(&val).ok_or_else(|| SweepError::BadBaseline {
                axis: key.clone(),
                msg: "expected a scalar value".into(),
            })?;
            let axis = axes.iter().find(|a| a.name == key).ok_or_else(|| {
                SweepError::BadBaseline { axis: key.clone(), msg: "not a [grid] axis".into() }
            })?;
            if !axis.values.contains(&v) {
                return Err(SweepError::BadBaseline {
                    axis: key,
                    msg: format!("value `{v}` is not in the axis (values: {:?})", axis.values),
                });
            }
            baseline.insert(key, v);
        }

        // ---- [[invariant]]: the accuracy harness
        let mut invariants = Vec::new();
        for (index, tbl) in doc.array("invariant").iter().enumerate() {
            invariants.push(parse_invariant(index, tbl, &axes)?);
        }

        let spec = SweepSpec { name, workers, axes, base, baseline, invariants };
        // contradictory combinations fail at parse, not mid-sweep
        for cell in spec.expand() {
            spec.plan(&cell)?;
        }
        Ok(spec)
    }

    /// Expand the grid into cells, in canonical order: axes sorted by
    /// name, the last axis varying fastest, values in spec order. The
    /// order (and therefore every cell `index`) is a pure function of
    /// the spec — worker scheduling cannot perturb it.
    pub fn expand(&self) -> Vec<Cell> {
        let total: usize = self.axes.iter().map(|a| a.values.len()).product();
        let mut cells = Vec::with_capacity(total);
        let mut odometer = vec![0usize; self.axes.len()];
        for index in 0..total {
            let coords: BTreeMap<String, String> = self
                .axes
                .iter()
                .zip(&odometer)
                .map(|(a, &i)| (a.name.clone(), a.values[i].clone()))
                .collect();
            cells.push(Cell { index, coords });
            for pos in (0..odometer.len()).rev() {
                odometer[pos] += 1;
                if odometer[pos] < self.axes[pos].values.len() {
                    break;
                }
                odometer[pos] = 0;
            }
        }
        cells
    }

    /// The baseline cell id for `cell`: its coords with the
    /// `[baseline]` pins substituted. `None` without a `[baseline]`
    /// table. A cell can be its own baseline (delta zero).
    pub fn baseline_id(&self, cell: &Cell) -> Option<String> {
        if self.baseline.is_empty() {
            return None;
        }
        let mut coords = cell.coords.clone();
        for (axis, v) in &self.baseline {
            coords.insert(axis.clone(), v.clone());
        }
        Some(coords_id(&coords))
    }

    /// Effective settings for a cell: `[config]` overlaid with the
    /// cell's coordinates.
    pub fn merged(&self, cell: &Cell) -> BTreeMap<String, String> {
        let mut m = self.base.clone();
        for (k, v) in &cell.coords {
            m.insert(k.clone(), v.clone());
        }
        m
    }

    /// Resolve a cell into an executable plan. Values were validated
    /// at parse time; this builds the `SimConfig` and checks
    /// cross-setting consistency.
    pub fn plan(&self, cell: &Cell) -> Result<CellPlan, SweepError> {
        let m = self.merged(cell);
        let bad = |key: &str, msg: String| SweepError::BadValue {
            table: "config".into(),
            key: key.into(),
            msg,
        };
        let mut cfg = SimConfig::default();
        for (key, v) in &m {
            match key.as_str() {
                // fault sources resolve after the loop: `seed` sorts
                // after `fault_soak` in the BTreeMap walk, and the soak
                // generator must see the cell's final seed
                "topo" | "workload" | "driver" | "hosts" | "shards" | "faults" | "fault_soak" => {}
                "policy" => {
                    cfg.policy = PolicyKind::parse(v)
                        .ok_or_else(|| bad(key, format!("unknown policy `{v}`")))?;
                }
                "epoch_policy" => {
                    if v != "none" {
                        cfg.epoch_policy =
                            Some(PolicySpec::parse(v).map_err(|e| bad(key, e.to_string()))?);
                    }
                }
                "prefetch" => {
                    if v != "none" {
                        cfg.prefetcher = Some(v.clone());
                    }
                }
                "scan_kernel" => {
                    cfg.scan_kernel = ScanKernel::parse(v)
                        .ok_or_else(|| bad(key, format!("unknown scan kernel `{v}`")))?;
                }
                "pipeline" => cfg.pipeline = v == "true",
                "epoch_ms" => cfg.epoch_ms = parse_f64(key, v)?,
                "scale" => cfg.scale = parse_f64(key, v)?,
                "seed" => cfg.seed = parse_u64(key, v)?,
                "sample_period" => cfg.sample_period = parse_u64(key, v)? as u32,
                "cache_scale" => cfg.cache_scale = parse_u64(key, v)?,
                "event_batch" => cfg.event_batch = parse_u64(key, v)?.max(1) as usize,
                "analyzer_threads" => cfg.analyzer_threads = parse_u64(key, v)? as usize,
                "batch_group" => cfg.batch_group = parse_u64(key, v)? as usize,
                "heat_decay" => cfg.heat_decay = parse_f64(key, v)?,
                "mig_stall_ns_per_byte" => cfg.mig_stall_ns_per_byte = parse_f64(key, v)?,
                "max_epochs" => {
                    cfg.max_epochs = if v == "none" { None } else { Some(parse_u64(key, v)?) };
                }
                "mlp" => cfg.mlp = parse_f64(key, v)?,
                "cpi_ns" => cfg.cpi_ns = parse_f64(key, v)?,
                other => return Err(bad(other, "unknown setting".into())),
            }
        }
        let driver = match m.get("driver").map(|s| s.as_str()).unwrap_or("run") {
            "run" => Driver::Run,
            "batched" => Driver::Batched,
            "multihost" => Driver::Multihost,
            other => return Err(bad("driver", format!("unknown driver `{other}`"))),
        };
        let topo = m.get("topo").cloned().unwrap_or_else(|| "fig2".into());
        let workload = m.get("workload").cloned().unwrap_or_else(|| "mmap_read".into());
        let hosts =
            m.get("hosts").map(|v| parse_u64("hosts", v)).transpose()?.unwrap_or(2) as usize;
        let shards =
            m.get("shards").map(|v| parse_u64("shards", v)).transpose()?.unwrap_or(1) as usize;
        let cell_err = |msg: &str| SweepError::BadCell { cell: cell.id(), msg: msg.into() };
        if driver == Driver::Multihost && workload.starts_with("trace:") {
            return Err(cell_err("the multihost driver replays synthetic workloads, not traces"));
        }
        if shards > 1 {
            if !workload.starts_with("trace:") {
                return Err(cell_err("shards > 1 requires a `trace:FILE` workload (v2 format)"));
            }
            if driver == Driver::Multihost {
                return Err(cell_err("shards > 1 cannot combine with the multihost driver"));
            }
        }
        let epoch_policy_src = m.get("epoch_policy").filter(|v| v.as_str() != "none").cloned();
        // fault-plan axes (`none` = fault-free cell): `faults` is a
        // plan-file path read per cell, `fault_soak` an MTBF spec
        // generated against the cell's (now final) seed
        let faults_src = m.get("faults").filter(|v| v.as_str() != "none").cloned();
        let fault_soak_src = m.get("fault_soak").filter(|v| v.as_str() != "none").cloned();
        if faults_src.is_some() && fault_soak_src.is_some() {
            return Err(cell_err("`faults` and `fault_soak` are mutually exclusive"));
        }
        if let Some(path) = &faults_src {
            let src = std::fs::read_to_string(path).map_err(|e| {
                bad("faults", format!("reading fault plan `{path}`: {e}"))
            })?;
            cfg.faults = Some(
                crate::fault::FaultPlan::parse_toml(&src)
                    .map_err(|e| bad("faults", e.to_string()))?,
            );
        } else if let Some(soak) = &fault_soak_src {
            cfg.faults = Some(
                crate::fault::FaultPlan::generate(cfg.seed, soak)
                    .map_err(|e| bad("fault_soak", e.to_string()))?,
            );
        }
        Ok(CellPlan {
            cfg,
            driver,
            topo,
            workload,
            hosts,
            shards,
            epoch_policy_src,
            faults_src,
            fault_soak_src,
        })
    }
}

fn parse_invariant(
    index: usize,
    tbl: &BTreeMap<String, TomlValue>,
    axes: &[Axis],
) -> Result<Invariant, SweepError> {
    let err = |msg: String| SweepError::BadInvariant { index, msg };
    let metric = tbl
        .get("metric")
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| err("missing string key `metric` (a report key, e.g. `delay_ms`)".into()))?;
    let axis_name = tbl
        .get("axis")
        .and_then(|v| v.as_str())
        .map(normalize_key)
        .ok_or_else(|| err("missing string key `axis` (a [grid] axis)".into()))?;
    let axis = axes
        .iter()
        .find(|a| a.name == axis_name)
        .ok_or_else(|| err(format!("axis `{axis_name}` is not a [grid] axis")))?;
    let order_val = tbl.get("order").ok_or_else(|| {
        err("missing key `order` (the expected non-decreasing axis-value sequence)".into())
    })?;
    let order: Vec<String> = match order_val {
        TomlValue::Arr(items) => items
            .iter()
            .map(|v| value_str(v).ok_or_else(|| err("order values must be scalars".into())))
            .collect::<Result<_, _>>()?,
        _ => return Err(err("`order` must be an array of axis values".into())),
    };
    if order.len() < 2 {
        return Err(err("`order` needs at least two axis values".into()));
    }
    for v in &order {
        if !axis.values.contains(v) {
            return Err(err(format!(
                "order value `{v}` is not in axis `{axis_name}` (values: {:?})",
                axis.values
            )));
        }
    }
    let strict = match tbl.get("strict") {
        None => false,
        Some(v) => v.as_bool().ok_or_else(|| err("`strict` must be a bool".into()))?,
    };
    let rel_tol = match tbl.get("rel_tol") {
        None => 0.0,
        Some(v) => v
            .as_f64()
            .filter(|t| *t >= 0.0)
            .ok_or_else(|| err("`rel_tol` must be a non-negative number".into()))?,
    };
    let mut pins = BTreeMap::new();
    for (raw_key, val) in tbl {
        let key = normalize_key(raw_key);
        if matches!(key.as_str(), "metric" | "axis" | "order" | "strict" | "rel_tol") {
            continue;
        }
        let pin_axis = axes
            .iter()
            .find(|a| a.name == key)
            .ok_or_else(|| err(format!("pin `{key}` is not a [grid] axis")))?;
        if pin_axis.name == axis_name {
            return Err(err(format!("cannot pin the swept axis `{key}` itself")));
        }
        let v = value_str(val).ok_or_else(|| err(format!("pin `{key}` must be a scalar")))?;
        if !pin_axis.values.contains(&v) {
            return Err(err(format!(
                "pin `{key}` value `{v}` is not in that axis (values: {:?})",
                pin_axis.values
            )));
        }
        pins.insert(key, v);
    }
    Ok(Invariant { metric, axis: axis_name, order, strict, rel_tol, pins })
}

/// Spec keys accept `-` or `_`; settings are stored with `_`.
fn normalize_key(k: &str) -> String {
    k.trim().replace('-', "_")
}

/// Canonical string form of a scalar TOML value. Numbers format like
/// the JSON writer (integral values without a fraction), so axis
/// values, `order` entries, and cell ids all agree on e.g. `2` vs
/// `2.0`.
fn value_str(v: &TomlValue) -> Option<String> {
    match v {
        TomlValue::Str(s) => Some(s.clone()),
        TomlValue::Bool(b) => Some(if *b { "true" } else { "false" }.to_string()),
        TomlValue::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                Some(format!("{}", *n as i64))
            } else {
                Some(format!("{n}"))
            }
        }
        TomlValue::Arr(_) => None,
    }
}

fn parse_f64(key: &str, v: &str) -> Result<f64, SweepError> {
    v.parse::<f64>().map_err(|_| SweepError::BadValue {
        table: "config".into(),
        key: key.into(),
        msg: format!("`{v}` is not a number"),
    })
}

fn parse_u64(key: &str, v: &str) -> Result<u64, SweepError> {
    v.parse::<u64>().map_err(|_| SweepError::BadValue {
        table: "config".into(),
        key: key.into(),
        msg: format!("`{v}` is not a non-negative integer"),
    })
}

/// Validate one setting value (shared by `[grid]` axes and `[config]`
/// keys). Returns a message naming what was expected.
fn validate_setting(key: &str, v: &str) -> Result<(), String> {
    match key {
        "topo" => {
            if builtin::by_name(v).is_some() || std::path::Path::new(v).exists() {
                Ok(())
            } else {
                Err(format!(
                    "not a builtin topology ({}) and no such file",
                    builtin::BUILTIN_NAMES.join("|")
                ))
            }
        }
        "workload" => {
            if ALL_WORKLOADS.contains(&v) || v.starts_with("trace:") {
                Ok(())
            } else {
                Err(format!(
                    "unknown workload (builtin: {}; or `trace:FILE`)",
                    ALL_WORKLOADS.join(", ")
                ))
            }
        }
        "driver" => match v {
            "run" | "batched" | "multihost" => Ok(()),
            _ => Err("expected run|batched|multihost".into()),
        },
        "policy" => PolicyKind::parse(v)
            .map(|_| ())
            .ok_or_else(|| "unknown allocation policy (see `cxlmemsim list`)".into()),
        "epoch_policy" => {
            if v == "none" {
                Ok(())
            } else {
                PolicySpec::parse(v).map(|_| ()).map_err(|e| e.to_string())
            }
        }
        "prefetch" => match v {
            "none" | "nextline" | "stride" => Ok(()),
            _ => Err("expected none|nextline|stride".into()),
        },
        "scan_kernel" => {
            ScanKernel::parse(v).map(|_| ()).ok_or_else(|| "expected blocked|exact".into())
        }
        "pipeline" => match v {
            "true" | "false" => Ok(()),
            _ => Err("expected true|false".into()),
        },
        "heat_decay" => {
            let n: f64 = v.parse().map_err(|_| format!("`{v}` is not a number"))?;
            if (0.0..=1.0).contains(&n) {
                Ok(())
            } else {
                Err(format!("must be in [0, 1], got {n}"))
            }
        }
        "hosts" | "shards" => {
            let n: u64 = v.parse().map_err(|_| format!("`{v}` is not an integer"))?;
            if n >= 1 {
                Ok(())
            } else {
                Err("must be >= 1".into())
            }
        }
        "seed" | "sample_period" | "cache_scale" | "event_batch" | "analyzer_threads"
        | "batch_group" => {
            v.parse::<u64>().map(|_| ()).map_err(|_| format!("`{v}` is not an integer"))
        }
        "faults" => {
            if v == "none" || std::path::Path::new(v).exists() {
                Ok(())
            } else {
                Err(format!("no such fault plan file `{v}` (or `none` for a fault-free cell)"))
            }
        }
        "fault_soak" => {
            if v == "none" {
                Ok(())
            } else {
                // syntax check only; the cell's seed applies at plan time
                crate::fault::FaultPlan::generate(0, v).map(|_| ()).map_err(|e| e.to_string())
            }
        }
        "max_epochs" => {
            if v == "none" {
                Ok(())
            } else {
                v.parse::<u64>().map(|_| ()).map_err(|_| format!("`{v}` is not an integer"))
            }
        }
        _ => {
            // remaining numeric knobs: epoch_ms, scale, mlp, cpi_ns, ...
            v.parse::<f64>().map(|_| ()).map_err(|_| format!("`{v}` is not a number"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
name = "t"
workers = 2

[grid]
topo = ["direct", "fig2"]
workload = ["stream", "zipfian"]

[config]
scale = 0.002
cache_scale = 64
max_epochs = 20

[baseline]
topo = "direct"

[[invariant]]
metric = "delay_ms"
axis = "topo"
order = ["direct", "fig2"]
"#;

    #[test]
    fn parses_and_expands() {
        let spec = SweepSpec::parse(SPEC).unwrap();
        assert_eq!(spec.name, "t");
        assert_eq!(spec.workers, 2);
        let cells = spec.expand();
        assert_eq!(cells.len(), 4);
        // canonical order: axes sorted (topo, workload), last fastest
        assert_eq!(cells[0].id(), "topo=direct,workload=stream");
        assert_eq!(cells[1].id(), "topo=direct,workload=zipfian");
        assert_eq!(cells[2].id(), "topo=fig2,workload=stream");
        assert_eq!(cells[3].id(), "topo=fig2,workload=zipfian");
    }

    #[test]
    fn baseline_substitutes_pinned_axes() {
        let spec = SweepSpec::parse(SPEC).unwrap();
        let cells = spec.expand();
        assert_eq!(spec.baseline_id(&cells[3]).unwrap(), "topo=direct,workload=zipfian");
        // the baseline cell is its own baseline
        assert_eq!(spec.baseline_id(&cells[0]).unwrap(), cells[0].id());
    }

    #[test]
    fn plan_merges_config_and_coords() {
        let spec = SweepSpec::parse(SPEC).unwrap();
        let cells = spec.expand();
        let plan = spec.plan(&cells[2]).unwrap();
        assert_eq!(plan.topo, "fig2");
        assert_eq!(plan.workload, "stream");
        assert_eq!(plan.driver, Driver::Run);
        assert!((plan.cfg.scale - 0.002).abs() < 1e-12);
        assert_eq!(plan.cfg.cache_scale, 64);
        assert_eq!(plan.cfg.max_epochs, Some(20));
    }

    #[test]
    fn missing_name_is_structured() {
        let e = SweepSpec::parse("[grid]\ntopo = [\"fig2\", \"deep\"]").unwrap_err();
        assert_eq!(e, SweepError::MissingKey { table: String::new(), key: "name".into() });
    }

    #[test]
    fn unknown_axis_is_named() {
        let e = SweepSpec::parse("name = \"x\"\n[grid]\ntopology = [\"fig2\", \"deep\"]")
            .unwrap_err();
        assert!(matches!(e, SweepError::UnknownAxis { ref axis } if axis == "topology"), "{e}");
    }

    #[test]
    fn bad_axis_value_names_axis_and_value() {
        let e = SweepSpec::parse("name = \"x\"\n[grid]\ntopo = [\"nope\"]").unwrap_err();
        match e {
            SweepError::BadAxisValue { axis, value, .. } => {
                assert_eq!(axis, "topo");
                assert_eq!(value, "nope");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn numeric_axis_values_canonicalize() {
        let spec = SweepSpec::parse("name = \"x\"\n[grid]\nepoch_ms = [0.5, 1.0, 2.0]").unwrap();
        assert_eq!(spec.axes[0].values, vec!["0.5", "1", "2"]);
    }

    #[test]
    fn sharded_multihost_cell_rejected() {
        let e =
            SweepSpec::parse("name = \"x\"\n[grid]\ndriver = [\"multihost\"]\n[config]\nshards = 2")
                .unwrap_err();
        assert!(matches!(e, SweepError::BadCell { .. }), "{e}");
    }

    #[test]
    fn dashes_normalize_to_underscores() {
        let spec = SweepSpec::parse(
            "name = \"x\"\n[grid]\nscan-kernel = [\"blocked\", \"exact\"]\n[config]\ncache-scale = 64",
        )
        .unwrap();
        assert_eq!(spec.axes[0].name, "scan_kernel");
        assert_eq!(spec.base.get("cache_scale").unwrap(), "64");
    }
}

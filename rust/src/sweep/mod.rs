//! `cxlmemsim sweep`: the scenario sweep engine.
//!
//! A TOML [`SweepSpec`] expands a (topology × policy × workload ×
//! knob) grid into cells ([`SweepSpec::expand`]); the engine executes
//! them across a process-wide work-stealing worker pool (the multihost
//! queue pattern — workers claim cell indices from a shared atomic
//! counter until it drains) and assembles ONE machine-readable JSON
//! comparison artifact: per-cell sanitized reports, deltas vs a named
//! baseline cell, and accuracy-harness invariant verdicts
//! (`artifact`).
//!
//! Three execution paths per cell, selected by the spec:
//!
//! * `driver = "run"` / `"batched"` — the sequential coordinator or
//!   the grouped-analyzer replay driver, over a synthetic workload or
//!   a recorded trace (`workload = "trace:FILE"`).
//! * `shards = N` (trace cells only) — multi-process fan-out: the
//!   engine launches N `cxlmemsim replay --shard i/N --json` child
//!   processes (PR 8's leftover driver) and merges the per-shard
//!   reports through [`crate::coordinator::report::merge_shard_json`];
//!   without a child executable ([`SweepOptions::shard_exe`] = None,
//!   e.g. under `cargo test`) the shards run in-process instead,
//!   producing the same merged report.
//! * `driver = "multihost"` — `hosts` copies of the workload sharing
//!   the topology's pools ([`crate::multihost::run_shared_threads`],
//!   pinned to one host-phase thread per cell so the sweep pool owns
//!   the parallelism).
//!
//! Determinism: cell order is a pure function of the spec, results
//! land in a per-cell slot, the artifact is assembled single-threaded
//! in cell order, and every report is stripped of scheduling /
//! wall-clock observability ([`artifact::sanitize`]) — so the artifact
//! is byte-identical for any worker count (`tests/sweep.rs`, CI).

pub mod artifact;
pub mod spec;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::report::{finalize_shard_merge, merge_shard_json};
use crate::coordinator::{run_batched, Coordinator};
use crate::multihost;
use crate::topology::Topology;
use crate::util::json::{self, Json};
use crate::workload::{self, TraceWorkload};

pub use spec::{Axis, Cell, CellPlan, Driver, Invariant, SweepError, SweepSpec, KNOWN_SETTINGS};

/// Engine options (everything NOT allowed to affect the artifact).
#[derive(Debug, Clone, Default)]
pub struct SweepOptions {
    /// Worker-pool override: 0 = use the spec's `workers` (which
    /// itself defaults to one per core).
    pub workers: usize,
    /// Executable to launch for `shards = N` fan-out (the CLI passes
    /// `std::env::current_exe()`). None = run shards in-process.
    pub shard_exe: Option<std::path::PathBuf>,
}

/// One sweep's result: the comparison artifact plus the failure
/// counts the CLI turns into an exit code.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub artifact: Json,
    pub cells: usize,
    pub cell_failures: usize,
    pub invariant_failures: usize,
}

/// Execute a spec and assemble the comparison artifact.
pub fn run_spec(spec: &SweepSpec, opts: &SweepOptions) -> SweepOutcome {
    let cells = spec.expand();
    let plans: Vec<Result<CellPlan, SweepError>> = cells.iter().map(|c| spec.plan(c)).collect();
    let results: Vec<Mutex<Option<Result<Json, String>>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();

    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let requested = if opts.workers > 0 {
        opts.workers
    } else if spec.workers > 0 {
        spec.workers
    } else {
        auto
    };
    let workers = requested.clamp(1, cells.len().max(1));

    // ---- work-stealing cell pool (the multihost queue pattern, one
    // level up): workers claim cell indices by fetch_add until the
    // queue drains, so a slow cell pins one worker while the rest
    // absorb the remainder. Each result lands in its cell's slot;
    // which worker ran a cell cannot change what the cell computes.
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let res = match &plans[i] {
                    Ok(plan) => run_cell(plan, opts).map_err(|e| format!("{e:#}")),
                    Err(e) => Err(e.to_string()),
                };
                *results[i].lock().unwrap() = Some(res);
            });
        }
    });

    // ---- artifact assembly: single-threaded, canonical cell order
    let mut outcomes: Vec<(String, BTreeMap<String, String>, Result<Json, String>)> =
        Vec::with_capacity(cells.len());
    for (cell, slot) in cells.iter().zip(results) {
        let res = slot
            .into_inner()
            .unwrap()
            .unwrap_or_else(|| Err("cell was never executed".to_string()));
        outcomes.push((cell.id(), cell.coords.clone(), res));
    }
    let reports: BTreeMap<String, &Json> = outcomes
        .iter()
        .filter_map(|(id, _, res)| res.as_ref().ok().map(|r| (id.clone(), r)))
        .collect();

    let mut cell_failures = 0usize;
    let mut cell_json = Vec::with_capacity(cells.len());
    for (cell, (id, coords, res)) in cells.iter().zip(&outcomes) {
        let coords_obj = Json::Obj(coords.iter().map(|(k, v)| (k.clone(), json::s(v))).collect());
        let mut fields = vec![("id", json::s(id)), ("coords", coords_obj)];
        match res {
            Ok(report) => {
                fields.push(("report", report.clone()));
                if let Some(base_id) = spec.baseline_id(cell) {
                    if let Some(base) = reports.get(&base_id) {
                        fields.push(("delta", artifact::deltas(report, base, &base_id)));
                    }
                }
            }
            Err(msg) => {
                cell_failures += 1;
                fields.push(("error", json::s(msg)));
            }
        }
        cell_json.push(json::obj(fields));
    }

    let mut invariant_failures = 0usize;
    let mut inv_json = Vec::with_capacity(spec.invariants.len());
    for inv in &spec.invariants {
        let (out, holds) = artifact::eval_invariant(spec, inv, &reports);
        if !holds {
            invariant_failures += 1;
        }
        inv_json.push(out);
    }

    let (grid, config, baseline) = artifact::spec_json(spec);
    let artifact = json::obj(vec![
        ("spec_name", json::s(&spec.name)),
        ("grid", grid),
        ("config", config),
        ("baseline", baseline),
        ("cells", Json::Arr(cell_json)),
        ("invariants", Json::Arr(inv_json)),
        (
            "summary",
            json::obj(vec![
                ("cells", json::num(cells.len() as f64)),
                ("cell_failures", json::num(cell_failures as f64)),
                ("invariants", json::num(spec.invariants.len() as f64)),
                ("invariant_failures", json::num(invariant_failures as f64)),
            ]),
        ),
    ]);
    SweepOutcome { artifact, cells: cells.len(), cell_failures, invariant_failures }
}

/// Execute one cell and return its sanitized report JSON.
fn run_cell(plan: &CellPlan, opts: &SweepOptions) -> anyhow::Result<Json> {
    let topo = Topology::resolve(&plan.topo)?;
    let mut report = match plan.driver {
        Driver::Multihost => {
            let workloads: Result<Vec<_>, _> = (0..plan.hosts)
                .map(|i| {
                    workload::by_name(&plan.workload, plan.cfg.scale, plan.cfg.seed + i as u64)
                        .ok_or_else(|| anyhow::anyhow!("unknown workload `{}`", plan.workload))
                })
                .collect();
            // one host-phase thread per cell: the sweep pool owns the
            // parallelism, and the result is thread-count-invariant
            multihost::run_shared_threads(&topo, &plan.cfg, workloads?, 1)?.to_json()
        }
        Driver::Run | Driver::Batched => match plan.workload.strip_prefix("trace:") {
            Some(path) if plan.shards > 1 => run_sharded(plan, path, opts)?,
            Some(path) => {
                let mut replay = TraceWorkload::open(path)?;
                let rep = drive(plan, &topo, &mut replay)?;
                if let Some(e) = replay.take_error() {
                    anyhow::bail!("replay of {path}: {e}");
                }
                rep
            }
            None => {
                let mut wl = workload::by_name(&plan.workload, plan.cfg.scale, plan.cfg.seed)
                    .ok_or_else(|| anyhow::anyhow!("unknown workload `{}`", plan.workload))?;
                drive(plan, &topo, wl.as_mut())?
            }
        },
    };
    artifact::sanitize(&mut report);
    Ok(report)
}

/// Drive one in-process simulation with the cell's driver.
fn drive(
    plan: &CellPlan,
    topo: &Topology,
    wl: &mut dyn workload::Workload,
) -> anyhow::Result<Json> {
    let rep = match plan.driver {
        Driver::Batched => run_batched(topo, &plan.cfg, wl)?,
        _ => {
            let mut sim = Coordinator::new(topo.clone(), plan.cfg.clone())?;
            sim.run(wl)?
        }
    };
    Ok(rep.to_json())
}

/// Multi-process shard fan-out: run the cell's trace as `plan.shards`
/// shard replays and merge their reports. With a `shard_exe` the
/// shards are real `replay --shard i/N --json` child processes
/// (launched concurrently, collected in shard order); without one
/// they run in-process through [`TraceWorkload::open_shard`]. Both
/// paths sanitize each shard report before the deterministic merge,
/// so the merged cell is identical either way.
fn run_sharded(plan: &CellPlan, path: &str, opts: &SweepOptions) -> anyhow::Result<Json> {
    let n = plan.shards;
    let mut shard_reports = Vec::with_capacity(n);
    match &opts.shard_exe {
        Some(exe) => {
            let mut children = Vec::with_capacity(n);
            for i in 0..n {
                let mut cmd = std::process::Command::new(exe);
                cmd.arg("replay")
                    .args(["--trace", path])
                    .args(["--shard", &format!("{i}/{n}")])
                    .arg("--json")
                    .args(shard_flags(plan))
                    .stdout(std::process::Stdio::piped())
                    .stderr(std::process::Stdio::piped());
                children.push(cmd.spawn().map_err(|e| {
                    anyhow::anyhow!("spawning shard {i}/{n} ({}): {e}", exe.display())
                })?);
            }
            for (i, child) in children.into_iter().enumerate() {
                let out = child.wait_with_output()?;
                if !out.status.success() {
                    // structured failure carrying the child's stderr
                    // (tail), so a CI sweep artifact names the actual
                    // error instead of just an exit status
                    let stderr = String::from_utf8_lossy(&out.stderr);
                    let stderr = stderr.trim();
                    let tail = if stderr.len() > 2000 {
                        format!("...{}", &stderr[stderr.len() - 2000..])
                    } else {
                        stderr.to_string()
                    };
                    return Err(SweepError::ShardChild {
                        cell: plan.workload.clone(),
                        shard: format!("{i}/{n}"),
                        status: out.status.to_string(),
                        stderr: tail,
                    }
                    .into());
                }
                let stdout = String::from_utf8_lossy(&out.stdout);
                let rep = Json::parse(stdout.trim()).map_err(|e| {
                    anyhow::anyhow!("shard {i}/{n} emitted unparseable JSON: {e}")
                })?;
                shard_reports.push(rep);
            }
        }
        None => {
            for i in 0..n {
                let mut replay = TraceWorkload::open_shard(path, i, n)?;
                let topo = Topology::resolve(&plan.topo)?;
                let rep = drive(plan, &topo, &mut replay)?;
                if let Some(e) = replay.take_error() {
                    anyhow::bail!("shard {i}/{n} replay of {path}: {e}");
                }
                shard_reports.push(rep);
            }
        }
    }
    let mut it = shard_reports.into_iter();
    let mut acc = it.next().ok_or_else(|| anyhow::anyhow!("no shard reports"))?;
    artifact::sanitize(&mut acc);
    for mut shard in it {
        artifact::sanitize(&mut shard);
        merge_shard_json(&mut acc, &shard);
    }
    finalize_shard_merge(&mut acc, n);
    Ok(acc)
}

/// CLI flags reproducing this cell's `SimConfig` for a shard child
/// process. `workload` / `hosts` / `shards` are handled by the caller;
/// `driver = "batched"` becomes `--batched`.
fn shard_flags(plan: &CellPlan) -> Vec<String> {
    let cfg = &plan.cfg;
    let mut flags = vec!["--topo".to_string(), plan.topo.clone()];
    let mut push = |k: &str, v: String| {
        flags.push(format!("--{k}"));
        flags.push(v);
    };
    push("epoch-ms", format!("{}", cfg.epoch_ms));
    push("scale", format!("{}", cfg.scale));
    push("seed", format!("{}", cfg.seed));
    push("sample-period", format!("{}", cfg.sample_period));
    push("cache-scale", format!("{}", cfg.cache_scale));
    push("event-batch", format!("{}", cfg.event_batch));
    push("analyzer-threads", format!("{}", cfg.analyzer_threads));
    push("batch-group", format!("{}", cfg.batch_group));
    push("heat-decay", format!("{}", cfg.heat_decay));
    push("mig-stall-ns-per-byte", format!("{}", cfg.mig_stall_ns_per_byte));
    push("mlp", format!("{}", cfg.mlp));
    push("cpi-ns", format!("{}", cfg.cpi_ns));
    let kernel = match cfg.scan_kernel {
        crate::runtime::ScanKernel::Exact => "exact",
        crate::runtime::ScanKernel::Blocked => "blocked",
    };
    push("scan-kernel", kernel.to_string());
    push("pipeline", if cfg.pipeline { "true" } else { "false" }.to_string());
    if let Some(max) = cfg.max_epochs {
        push("max-epochs", format!("{max}"));
    }
    if let Some(p) = &cfg.prefetcher {
        push("prefetch", p.clone());
    }
    if let Some(src) = &plan.epoch_policy_src {
        push("epoch-policy", src.clone());
    }
    // fault axes pass through verbatim: the child re-parses the plan
    // file / re-generates the soak plan from the same seed, so its
    // schedule is identical to an in-process run of the cell
    if let Some(src) = &plan.faults_src {
        push("faults", src.clone());
    }
    if let Some(src) = &plan.fault_soak_src {
        push("fault-soak", src.clone());
    }
    if plan.driver == Driver::Batched {
        push("batched", "true".to_string());
    }
    flags
}

//! Sweep comparison artifact: sanitized per-cell reports, deltas vs
//! the baseline cell, and accuracy-harness invariant verdicts, as one
//! JSON document.
//!
//! Byte-identity across sweep worker counts is a hard requirement
//! (tested in `tests/sweep.rs`, re-run by CI): the artifact is
//! assembled single-threaded in canonical cell order, `Json::Obj`
//! serializes with sorted keys, and [`sanitize`] strips every report
//! key that observes the run rather than the simulation (wall-clock,
//! pipeline busy times, worker scheduling counters).

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

use super::spec::{coords_id, Invariant, SweepSpec};

/// Report keys that depend on wall-clock or scheduling, not on the
/// simulation result. Stripped from every cell report so artifacts
/// are bit-identical across worker counts and machines.
pub const NONDET_KEYS: &[&str] = &[
    "wall_s",
    "pump_busy_ms",
    "analyze_busy_ms",
    "overlap_frac",
    "host_workers",
    "steals",
    "shard_rebalances",
    "worker_busy_fracs",
];

/// Remove non-deterministic observability keys, recursively (the
/// multihost report nests per-host objects).
pub fn sanitize(j: &mut Json) {
    match j {
        Json::Obj(m) => {
            for k in NONDET_KEYS {
                m.remove(*k);
            }
            for v in m.values_mut() {
                sanitize(v);
            }
        }
        Json::Arr(v) => {
            for x in v.iter_mut() {
                sanitize(x);
            }
        }
        _ => {}
    }
}

/// Metrics compared against the baseline cell. Whichever of these both
/// reports carry produce a `<key>` entry in the cell's `delta` object
/// (cell − baseline), so the same machinery serves `run`/`batched`
/// cells (SimReport keys) and `multihost` cells (MultiHostReport keys).
pub const DELTA_KEYS: &[&str] = &[
    "native_ms",
    "simulated_ms",
    "delay_ms",
    "lat_delay_ms",
    "cong_delay_ms",
    "bwd_delay_ms",
    "mig_delay_ms",
    "sim_slowdown",
    "total_delay_ms",
    "mean_slowdown",
];

/// Build the delta object for one cell vs its baseline report.
pub fn deltas(cell: &Json, base: &Json, base_id: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("vs".to_string(), json::s(base_id));
    for key in DELTA_KEYS {
        if let (Some(a), Some(b)) = (
            cell.get(key).and_then(|v| v.as_f64()),
            base.get(key).and_then(|v| v.as_f64()),
        ) {
            m.insert(key.to_string(), json::num(a - b));
        }
    }
    Json::Obj(m)
}

/// Numeric metric lookup in a cell report.
pub fn metric_of(report: &Json, metric: &str) -> Option<f64> {
    report.get(metric).and_then(|v| v.as_f64())
}

/// Evaluate one invariant over the successful cell reports.
///
/// For every combination of the non-swept, non-pinned axes, walk the
/// `order` sequence pairwise and require the metric to be
/// non-decreasing (strictly increasing with `strict`; `rel_tol`
/// loosens the non-strict bound to `next >= prev * (1 - rel_tol)`).
/// Combinations whose cells errored (or lack the metric) are counted
/// as `missing`, not as violations — cell failures already fail the
/// sweep on their own.
pub fn eval_invariant(
    spec: &SweepSpec,
    inv: &Invariant,
    reports: &BTreeMap<String, &Json>,
) -> (Json, bool) {
    // the context axes: everything except the swept axis, with pinned
    // axes fixed to their single pin value
    let free: Vec<(&str, &[String])> = spec
        .axes
        .iter()
        .filter(|a| a.name != inv.axis && !inv.pins.contains_key(&a.name))
        .map(|a| (a.name.as_str(), a.values.as_slice()))
        .collect();
    let mut checked = 0usize;
    let mut missing = 0usize;
    let mut violations = Vec::new();

    let mut odometer = vec![0usize; free.len()];
    let combos: usize = free.iter().map(|(_, vs)| vs.len()).product();
    for _ in 0..combos {
        let mut ctx: BTreeMap<String, String> = inv.pins.clone();
        for ((axis, values), &i) in free.iter().zip(&odometer) {
            ctx.insert(axis.to_string(), values[i].clone());
        }
        for pair in inv.order.windows(2) {
            let mut a = ctx.clone();
            a.insert(inv.axis.clone(), pair[0].clone());
            let mut b = ctx.clone();
            b.insert(inv.axis.clone(), pair[1].clone());
            let ma = reports.get(&coords_id(&a)).and_then(|r| metric_of(r, &inv.metric));
            let mb = reports.get(&coords_id(&b)).and_then(|r| metric_of(r, &inv.metric));
            let (ma, mb) = match (ma, mb) {
                (Some(x), Some(y)) => (x, y),
                _ => {
                    missing += 1;
                    continue;
                }
            };
            checked += 1;
            let holds = if inv.strict {
                mb > ma
            } else {
                mb >= ma * (1.0 - inv.rel_tol) - 1e-9
            };
            if !holds {
                violations.push(json::obj(vec![
                    ("at", json::s(&coords_id(&ctx))),
                    ("from", json::s(&pair[0])),
                    ("from_value", json::num(ma)),
                    ("to", json::s(&pair[1])),
                    ("to_value", json::num(mb)),
                ]));
            }
        }
        for pos in (0..odometer.len()).rev() {
            odometer[pos] += 1;
            if odometer[pos] < free[pos].1.len() {
                break;
            }
            odometer[pos] = 0;
        }
    }

    let holds = violations.is_empty();
    let pins = Json::Obj(inv.pins.iter().map(|(k, v)| (k.clone(), json::s(v))).collect());
    let out = json::obj(vec![
        ("metric", json::s(&inv.metric)),
        ("axis", json::s(&inv.axis)),
        ("order", Json::Arr(inv.order.iter().map(|v| json::s(v)).collect())),
        ("strict", Json::Bool(inv.strict)),
        ("rel_tol", json::num(inv.rel_tol)),
        ("pins", pins),
        ("checked", json::num(checked as f64)),
        ("missing", json::num(missing as f64)),
        ("violations", Json::Arr(violations)),
        ("holds", Json::Bool(holds)),
    ]);
    (out, holds)
}

/// The spec's own description inside the artifact (grid, base config,
/// baseline pins) so an artifact is self-describing.
pub fn spec_json(spec: &SweepSpec) -> (Json, Json, Json) {
    let grid = Json::Obj(
        spec.axes
            .iter()
            .map(|a| (a.name.clone(), Json::Arr(a.values.iter().map(|v| json::s(v)).collect())))
            .collect(),
    );
    let config = Json::Obj(spec.base.iter().map(|(k, v)| (k.clone(), json::s(v))).collect());
    let baseline = if spec.baseline.is_empty() {
        Json::Null
    } else {
        Json::Obj(spec.baseline.iter().map(|(k, v)| (k.clone(), json::s(v))).collect())
    };
    (grid, config, baseline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::SweepSpec;

    #[test]
    fn sanitize_strips_nondeterministic_keys_recursively() {
        let mut j = Json::parse(
            r#"{"wall_s": 1.5, "delay_ms": 3, "hosts": [{"wall_s": 2, "misses": 7}],
                "steals": 4, "worker_busy_fracs": [0.5]}"#,
        )
        .unwrap();
        sanitize(&mut j);
        assert_eq!(j.to_string(), r#"{"delay_ms":3,"hosts":[{"misses":7}]}"#);
    }

    #[test]
    fn deltas_cover_shared_keys_only() {
        let cell = Json::parse(r#"{"delay_ms": 5, "sim_slowdown": 1.5, "accesses": 10}"#).unwrap();
        let base = Json::parse(r#"{"delay_ms": 2, "sim_slowdown": 1.2, "accesses": 10}"#).unwrap();
        let d = deltas(&cell, &base, "topo=direct");
        assert_eq!(d.get("vs").unwrap().as_str(), Some("topo=direct"));
        assert_eq!(d.get("delay_ms").unwrap().as_f64(), Some(3.0));
        assert!(d.get("accesses").is_none(), "accesses is not a delta key");
        assert!(d.get("total_delay_ms").is_none(), "absent in both reports");
    }

    fn two_axis_spec() -> SweepSpec {
        SweepSpec::parse(
            "name = \"x\"\n[grid]\ntopo = [\"direct\", \"fig2\"]\n\
             workload = [\"stream\", \"zipfian\"]\n\
             [[invariant]]\nmetric = \"delay_ms\"\naxis = \"topo\"\n\
             order = [\"direct\", \"fig2\"]\n",
        )
        .unwrap()
    }

    #[test]
    fn invariant_checks_every_free_combination() {
        let spec = two_axis_spec();
        let r1 = Json::parse(r#"{"delay_ms": 1}"#).unwrap();
        let r2 = Json::parse(r#"{"delay_ms": 2}"#).unwrap();
        let mut reports: BTreeMap<String, &Json> = BTreeMap::new();
        reports.insert("topo=direct,workload=stream".into(), &r1);
        reports.insert("topo=fig2,workload=stream".into(), &r2);
        reports.insert("topo=direct,workload=zipfian".into(), &r1);
        reports.insert("topo=fig2,workload=zipfian".into(), &r2);
        let (out, holds) = eval_invariant(&spec, &spec.invariants[0], &reports);
        assert!(holds);
        assert_eq!(out.get("checked").unwrap().as_f64(), Some(2.0));
        assert_eq!(out.get("missing").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn invariant_violation_names_the_pair() {
        let spec = two_axis_spec();
        let lo = Json::parse(r#"{"delay_ms": 1}"#).unwrap();
        let hi = Json::parse(r#"{"delay_ms": 2}"#).unwrap();
        let mut reports: BTreeMap<String, &Json> = BTreeMap::new();
        // zipfian ordering inverted => exactly one violation
        reports.insert("topo=direct,workload=stream".into(), &lo);
        reports.insert("topo=fig2,workload=stream".into(), &hi);
        reports.insert("topo=direct,workload=zipfian".into(), &hi);
        reports.insert("topo=fig2,workload=zipfian".into(), &lo);
        let (out, holds) = eval_invariant(&spec, &spec.invariants[0], &reports);
        assert!(!holds);
        let v = out.get("violations").unwrap().idx(0).unwrap();
        assert_eq!(v.get("at").unwrap().as_str(), Some("workload=zipfian"));
        assert_eq!(v.get("from").unwrap().as_str(), Some("direct"));
        assert_eq!(v.get("to_value").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn missing_cells_count_as_missing_not_violations() {
        let spec = two_axis_spec();
        let r = Json::parse(r#"{"delay_ms": 1}"#).unwrap();
        let mut reports: BTreeMap<String, &Json> = BTreeMap::new();
        reports.insert("topo=direct,workload=stream".into(), &r);
        let (out, holds) = eval_invariant(&spec, &spec.invariants[0], &reports);
        assert!(holds, "missing data is not a violation");
        assert_eq!(out.get("missing").unwrap().as_f64(), Some(2.0));
        assert_eq!(out.get("checked").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rel_tol_permits_near_equal_metrics() {
        let spec = SweepSpec::parse(
            "name = \"x\"\n[grid]\nepoch_ms = [0.5, 1.0]\n\
             [[invariant]]\nmetric = \"simulated_ms\"\naxis = \"epoch_ms\"\n\
             order = [1.0, 0.5]\nrel_tol = 0.1\n",
        )
        .unwrap();
        let a = Json::parse(r#"{"simulated_ms": 100}"#).unwrap();
        let b = Json::parse(r#"{"simulated_ms": 95}"#).unwrap();
        let mut reports: BTreeMap<String, &Json> = BTreeMap::new();
        reports.insert("epoch_ms=1".into(), &a);
        reports.insert("epoch_ms=0.5".into(), &b);
        let (_, holds) = eval_invariant(&spec, &spec.invariants[0], &reports);
        assert!(holds, "95 >= 100 * 0.9 must pass at rel_tol 0.1");
    }
}

//! Allocation tracker — the eBPF-consumer substitute.
//!
//! The paper's Tracer hooks allocation syscalls with eBPF so CXLMemSim
//! knows, for every sampled address, which memory pool it lives in.
//! This module consumes the same (syscall, range, time) stream from the
//! workload engine, maintains an interval map of live regions, and maps
//! addresses to pools according to a pluggable *placement policy*
//! (page- or region-granular, matching the paper's "cache-line vs page
//! memory management" research agenda).
//!
//! Each region also carries a cheap *heat* counter, bumped on the
//! `pool_of` lookup fast path (one increment per answered lookup) and
//! folded back into the region map lazily (`sync_heat`). The two-phase
//! policy engine (`crate::policy`) uses it so migration policies
//! promote the hottest region, not merely the largest.

pub mod policy;

use std::collections::BTreeMap;

use crate::topology::{PoolId, Topology, LOCAL_POOL};
use crate::trace::AllocEvent;
pub use policy::{Placement, PlacementPolicy, PolicyKind};

/// A live allocated region and where its bytes were placed.
#[derive(Clone, Debug)]
pub struct Region {
    pub start: u64,
    pub len: u64,
    pub placement: Placement,
    /// Access-heat counter: +1 per `pool_of` lookup answered by this
    /// region. Bumps land on the flat-index copy (the lookup hot path)
    /// and are folded back into the source of truth lazily — call
    /// [`AllocTracker::sync_heat`] before reading via `live_regions`.
    /// Migration policies use it to pick the hottest victim. Reset on
    /// split (partial unmap) and on reallocation; carried across
    /// migration.
    pub heat: u64,
    /// Allocation generation: fresh per allocate/split, kept across
    /// migration. Heat folding matches on it so a freed-and-
    /// reallocated slot (same start+len, no lookup in between) can
    /// never inherit the dead region's pending heat deltas.
    pub(crate) id: u64,
}

impl Region {
    #[inline]
    pub fn end(&self) -> u64 {
        self.start + self.len
    }

    /// Pool owning `addr` (caller guarantees addr is inside the region).
    #[inline]
    pub fn pool_of(&self, addr: u64) -> PoolId {
        match &self.placement {
            Placement::Single(p) => *p,
            Placement::Interleaved { pools, page_bytes } => {
                let page = (addr - self.start) / page_bytes;
                pools[(page % pools.len() as u64) as usize]
            }
        }
    }

    /// Visit each `(pool, bytes)` span of the region — one call for a
    /// `Single` placement, one per page for an interleaved one. The
    /// single source of truth for how the region's bytes map to pools;
    /// used by the tracker's byte accounting and by the policy
    /// engine's migration cost attribution.
    pub fn for_each_span(&self, mut f: impl FnMut(PoolId, u64)) {
        match &self.placement {
            Placement::Single(p) => f(*p, self.len),
            Placement::Interleaved { pools, page_bytes } => {
                let pages = self.len.div_ceil(*page_bytes);
                for page in 0..pages {
                    let p = pools[(page % pools.len() as u64) as usize];
                    f(p, (*page_bytes).min(self.len - page * page_bytes));
                }
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrackerStats {
    pub allocs: u64,
    pub frees: u64,
    pub lookup_misses: u64,
    pub live_bytes: u64,
    /// Bytes currently resident per pool (index = PoolId).
    pub pool_bytes: Vec<u64>,
    /// `pool_of` lookups answered by the one-entry MRU region cache.
    pub mru_hits: u64,
    /// Times the flat interval index was rebuilt after alloc/free.
    pub index_rebuilds: u64,
}

/// Interval map of live regions + placement policy + per-pool usage.
///
/// Lookup hot path (one call per LLC miss): a one-entry MRU region
/// cache backed by a flat sorted-`Vec` interval index, rebuilt lazily
/// after allocation-map mutations and binary-searched on MRU misses.
/// Misses have strong spatial locality (streams, stencils), so the MRU
/// entry absorbs the vast majority of lookups; the `BTreeMap` stays the
/// source of truth for mutation (split/merge on partial unmap).
pub struct AllocTracker {
    /// start -> region; regions never overlap. Source of truth.
    regions: BTreeMap<u64, Region>,
    /// Flat copy of `regions` sorted by start; rebuilt lazily when
    /// `index_dirty`. Binary-searched by `pool_of`.
    index: Vec<Region>,
    index_dirty: bool,
    /// Index into `index` of the last region that answered a lookup
    /// (usize::MAX = invalid).
    mru: usize,
    policy: Box<dyn PlacementPolicy>,
    pub stats: TrackerStats,
    num_pools: usize,
    /// Next allocation generation for `Region::id`.
    next_id: u64,
    /// Per-epoch multiplicative heat decay in [0, 1]; 1.0 (default)
    /// keeps counters lifetime-cumulative. Applied by
    /// [`AllocTracker::decay_heat`], which drivers call once per epoch.
    heat_decay: f64,
}

impl AllocTracker {
    pub fn new(topo: &Topology, policy: Box<dyn PlacementPolicy>) -> AllocTracker {
        let num_pools = topo.num_pools();
        AllocTracker {
            regions: BTreeMap::new(),
            index: Vec::new(),
            index_dirty: false,
            mru: usize::MAX,
            policy,
            stats: TrackerStats { pool_bytes: vec![0; num_pools], ..Default::default() },
            num_pools,
            next_id: 0,
            heat_decay: 1.0,
        }
    }

    /// Set the per-epoch multiplicative heat decay (clamped to
    /// [0, 1]; 1.0 = no decay, the lifetime-cumulative default).
    pub fn set_heat_decay(&mut self, decay: f64) {
        self.heat_decay = decay.clamp(0.0, 1.0);
    }

    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    pub fn num_pools(&self) -> usize {
        self.num_pools
    }

    /// Apply one allocation event from the trace.
    pub fn on_alloc_event(&mut self, ev: &AllocEvent) {
        if ev.kind.is_release() {
            self.release(ev.addr, ev.len);
        } else {
            self.allocate(ev);
        }
    }

    fn allocate(&mut self, ev: &AllocEvent) {
        if ev.len == 0 {
            return;
        }
        self.index_dirty = true;
        // Overlapping re-allocation: drop any overlapped live regions
        // first (matches kernel mmap MAP_FIXED semantics and keeps the
        // interval map consistent for malformed traces).
        self.release(ev.addr, ev.len);
        let placement = self.policy.place(ev, &self.stats);
        let region =
            Region { start: ev.addr, len: ev.len, placement, heat: 0, id: self.fresh_id() };
        self.account(&region, true);
        self.stats.allocs += 1;
        self.regions.insert(ev.addr, region);
    }

    fn release(&mut self, addr: u64, len: u64) {
        self.index_dirty = true;
        let end = if len == 0 { addr + 1 } else { addr + len };
        // collect candidate starts overlapping [addr, end)
        let starts: Vec<u64> = self
            .regions
            .range(..end)
            .rev()
            .take_while(|(_, r)| r.end() > addr)
            .map(|(s, _)| *s)
            .collect();
        for s in starts {
            if let Some(r) = self.regions.remove(&s) {
                if r.end() > addr && r.start < end {
                    self.account(&r, false);
                    self.stats.frees += 1;
                    // partial unmap: keep the non-overlapping tail/head
                    if r.start < addr {
                        let head = Region {
                            start: r.start,
                            len: addr - r.start,
                            placement: r.placement.clone(),
                            heat: 0,
                            id: self.fresh_id(),
                        };
                        self.account(&head, true);
                        self.regions.insert(head.start, head);
                    }
                    if r.end() > end {
                        let tail = Region {
                            start: end,
                            len: r.end() - end,
                            placement: r.placement.clone(),
                            heat: 0,
                            id: self.fresh_id(),
                        };
                        self.account(&tail, true);
                        self.regions.insert(tail.start, tail);
                    }
                } else {
                    self.regions.insert(s, r); // not actually overlapping
                }
            }
        }
    }

    fn account(&mut self, region: &Region, add: bool) {
        // distribute bytes across pools per placement
        let stats = &mut self.stats;
        region.for_each_span(|p, sz| {
            if add {
                stats.pool_bytes[p] += sz;
                stats.live_bytes += sz;
            } else {
                stats.pool_bytes[p] = stats.pool_bytes[p].saturating_sub(sz);
                stats.live_bytes = stats.live_bytes.saturating_sub(sz);
            }
        });
    }

    /// Pool owning an address. Unknown addresses (stack, code, ...) are
    /// local DRAM, like the real tool's default for untracked ranges.
    ///
    /// Fast path: one-entry MRU cache, then binary search over the flat
    /// interval index (rebuilt lazily after alloc/free). Equivalent to
    /// [`AllocTracker::pool_of_btree`] — asserted by differential test.
    #[inline]
    pub fn pool_of(&mut self, addr: u64) -> PoolId {
        if self.index_dirty {
            self.rebuild_index();
        }
        if let Some(r) = self.index.get_mut(self.mru) {
            if addr >= r.start && addr < r.end() {
                self.stats.mru_hits += 1;
                r.heat += 1;
                return r.pool_of(addr);
            }
        }
        // regions are disjoint and sorted by start: the candidate is
        // the last region whose start is <= addr
        let i = self.index.partition_point(|r| r.start <= addr);
        if i > 0 {
            let r = &mut self.index[i - 1];
            if addr < r.end() {
                r.heat += 1;
                self.mru = i - 1;
                return r.pool_of(addr);
            }
        }
        self.stats.lookup_misses += 1;
        LOCAL_POOL
    }

    /// The pre-optimization lookup (a `BTreeMap::range` walk), kept as
    /// the differential-test oracle and the `benches/hotpath.rs`
    /// baseline. Does not touch stats or the MRU cache.
    #[inline]
    pub fn pool_of_btree(&self, addr: u64) -> PoolId {
        if let Some((_, r)) = self.regions.range(..=addr).next_back() {
            if addr < r.end() {
                return r.pool_of(addr);
            }
        }
        LOCAL_POOL
    }

    #[cold]
    fn rebuild_index(&mut self) {
        // fold heat deltas accumulated on the flat copies back into the
        // source of truth before discarding them; the copies restart at
        // zero so deltas are never double-counted. Matching is by
        // allocation generation (`Region::id`) — a freed-and-
        // reallocated slot has a fresh id, so it can never inherit the
        // dead region's heat, while migration keeps the id (heat
        // survives a pool move).
        self.fold_heat();
        self.index.clear();
        self.index.extend(self.regions.values().map(|r| Region { heat: 0, ..r.clone() }));
        self.index_dirty = false;
        self.mru = usize::MAX;
        self.stats.index_rebuilds += 1;
    }

    fn fold_heat(&mut self) {
        for r in &mut self.index {
            if r.heat == 0 {
                continue;
            }
            if let Some(m) = self.regions.get_mut(&r.start) {
                if m.id == r.id {
                    m.heat += r.heat;
                }
            }
            r.heat = 0;
        }
    }

    /// Fold heat deltas from the lookup fast path into the live
    /// regions so [`AllocTracker::live_regions`] sees up-to-date
    /// counters. Migration policies call this once per epoch before
    /// picking a victim — O(live regions), off the hot path.
    pub fn sync_heat(&mut self) {
        self.fold_heat();
    }

    /// Age region heat by one epoch: fold the pending fast-path deltas
    /// (the sync_heat step — decay rides the same fold), then scale
    /// every live region's counter by the configured per-epoch decay.
    /// A no-op at `heat_decay == 1.0`, so default runs stay
    /// bit-identical to the lifetime-cumulative behavior. Drivers call
    /// this once per epoch *after* the epoch's policy hooks: the
    /// current epoch's lookups enter victim selection at full weight,
    /// and heat from k epochs ago is worth `decay^k` — a formerly-hot,
    /// now-cold region stops outranking currently-hot ones
    /// (`crate::policy` tests).
    pub fn decay_heat(&mut self) {
        if self.heat_decay >= 1.0 {
            return;
        }
        self.fold_heat();
        for r in self.regions.values_mut() {
            r.heat = (r.heat as f64 * self.heat_decay) as u64;
        }
    }

    /// The live region starting exactly at `start`, if any.
    pub fn region_at(&self, start: u64) -> Option<&Region> {
        self.regions.get(&start)
    }

    /// Move a whole region (page-set) to another pool — the migration
    /// hook used by `policy::migration` research experiments.
    pub fn migrate_region(&mut self, start: u64, to: PoolId) -> bool {
        if to >= self.num_pools {
            return false;
        }
        // remove + reinsert to fix accounting
        if let Some(r) = self.regions.remove(&start) {
            self.index_dirty = true;
            self.account(&r, false);
            let moved = Region { placement: Placement::Single(to), ..r };
            self.account(&moved, true);
            self.regions.insert(start, moved);
            true
        } else {
            false
        }
    }

    pub fn live_regions(&self) -> impl Iterator<Item = &Region> {
        self.regions.values()
    }

    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;
    use crate::trace::AllocKind;

    fn ev(kind: AllocKind, addr: u64, len: u64) -> AllocEvent {
        AllocEvent { kind, addr, len, t_ns: 0.0 }
    }

    fn tracker(policy: PolicyKind) -> AllocTracker {
        let topo = builtin::fig2();
        AllocTracker::new(&topo, policy.build(&topo))
    }

    #[test]
    fn alloc_then_lookup() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000));
        let p = t.pool_of(0x1800);
        assert!(p >= 1, "CxlOnly must place on a CXL pool, got {p}");
        assert_eq!(t.stats.lookup_misses, 0);
    }

    #[test]
    fn unknown_address_is_local() {
        let mut t = tracker(PolicyKind::CxlOnly);
        assert_eq!(t.pool_of(0xdead_beef), LOCAL_POOL);
        assert_eq!(t.stats.lookup_misses, 1);
    }

    #[test]
    fn free_forgets_region() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Malloc, 0x1000, 0x1000));
        assert_ne!(t.pool_of(0x1800), LOCAL_POOL);
        t.on_alloc_event(&ev(AllocKind::Free, 0x1000, 0x1000));
        assert_eq!(t.pool_of(0x1800), LOCAL_POOL);
        assert_eq!(t.stats.live_bytes, 0);
    }

    #[test]
    fn partial_munmap_keeps_tail() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 0x4000));
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x10000, 0x1000));
        assert_eq!(t.pool_of(0x10800), LOCAL_POOL); // unmapped head
        assert_ne!(t.pool_of(0x12000), LOCAL_POOL); // live tail
    }

    #[test]
    fn partial_munmap_keeps_head() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 0x4000));
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x13000, 0x1000));
        assert_ne!(t.pool_of(0x10800), LOCAL_POOL);
        assert_eq!(t.pool_of(0x13800), LOCAL_POOL);
    }

    #[test]
    fn interleave_stripes_pages() {
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(
            &topo,
            PolicyKind::Interleave { page_bytes: 4096 }.build(&topo),
        );
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x0, 4096 * 6));
        let pools: Vec<PoolId> = (0..6).map(|i| t.pool_of(i * 4096 + 64)).collect();
        // must hit more than one pool, cyclically
        assert!(pools.windows(2).any(|w| w[0] != w[1]), "{pools:?}");
        assert_eq!(pools[0], pools[3]); // 3 CXL pools in fig2 -> period 3
    }

    #[test]
    fn accounting_tracks_pool_bytes() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x0, 1 << 20));
        assert_eq!(t.stats.live_bytes, 1 << 20);
        let cxl_total: u64 = t.stats.pool_bytes[1..].iter().sum();
        assert_eq!(cxl_total, 1 << 20);
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x0, 1 << 20));
        assert_eq!(t.stats.live_bytes, 0);
    }

    #[test]
    fn overlapping_remap_replaces() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000));
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000)); // MAP_FIXED-style
        assert_eq!(t.stats.live_bytes, 0x2000);
        assert_eq!(t.region_count(), 1);
    }

    #[test]
    fn migrate_region_moves_bytes() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        let before = t.pool_of(0x1800);
        assert!(t.migrate_region(0x1000, LOCAL_POOL));
        assert_eq!(t.pool_of(0x1800), LOCAL_POOL);
        assert!(before != LOCAL_POOL);
        assert_eq!(t.stats.pool_bytes[LOCAL_POOL], 0x1000);
    }

    #[test]
    fn migrate_unknown_region_fails() {
        let mut t = tracker(PolicyKind::CxlOnly);
        assert!(!t.migrate_region(0x9999, LOCAL_POOL));
    }

    #[test]
    fn fast_lookup_matches_btree_walk_under_churn() {
        use crate::util::rng::Rng;
        let mut t = tracker(PolicyKind::CxlOnly);
        let mut rng = Rng::new(0x100c);
        for round in 0..2000u64 {
            let slot = rng.below(64);
            let addr = 0x10_0000 + slot * 0x4000;
            match rng.below(4) {
                0 => t.on_alloc_event(&ev(AllocKind::Mmap, addr, 0x1000 + rng.below(0x3000))),
                1 => t.on_alloc_event(&ev(AllocKind::Munmap, addr, 0x2000)),
                2 => {
                    t.migrate_region(addr, (rng.below(4)) as usize);
                }
                _ => {}
            }
            for _ in 0..8 {
                let q = 0x10_0000 + rng.below(64 * 0x4000 + 0x8000);
                assert_eq!(
                    t.pool_of(q),
                    t.pool_of_btree(q),
                    "round {round}, addr {q:#x}"
                );
            }
        }
        assert!(t.stats.index_rebuilds > 0);
    }

    #[test]
    fn mru_absorbs_spatially_local_lookups() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 1 << 20));
        for i in 0..1000u64 {
            t.pool_of(0x10000 + i * 64);
        }
        // first lookup warms the MRU; the rest must hit it
        assert_eq!(t.stats.mru_hits, 999);
        assert_eq!(t.stats.lookup_misses, 0);
    }

    #[test]
    fn heat_accumulates_and_syncs() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 1 << 20));
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x200000, 1 << 20));
        for i in 0..50u64 {
            t.pool_of(0x10000 + i * 64); // MRU-hit path
        }
        t.pool_of(0x200000); // binary-search path
        // deltas live on the flat index until synced
        assert!(t.region_at(0x10000).unwrap().heat == 0);
        t.sync_heat();
        assert_eq!(t.region_at(0x10000).unwrap().heat, 50);
        assert_eq!(t.region_at(0x200000).unwrap().heat, 1);
        // sync is idempotent (deltas are zeroed once folded)
        t.sync_heat();
        assert_eq!(t.region_at(0x10000).unwrap().heat, 50);
    }

    #[test]
    fn heat_decay_ages_counters_and_default_is_noop() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 1 << 20));
        for _ in 0..100u64 {
            t.pool_of(0x10000);
        }
        // default (1.0): decay_heat never touches the counters
        t.decay_heat();
        t.sync_heat();
        assert_eq!(t.region_at(0x10000).unwrap().heat, 100, "decay 1.0 must be a no-op");
        // 0.5 per epoch: halves each call, folding pending deltas first
        t.set_heat_decay(0.5);
        t.decay_heat();
        assert_eq!(t.region_at(0x10000).unwrap().heat, 50);
        t.pool_of(0x10000); // a fresh delta parked on the flat index
        t.decay_heat(); // fold (50 + 1 = 51) then decay -> 25
        assert_eq!(t.region_at(0x10000).unwrap().heat, 25);
        // decay drives ancient heat all the way to zero
        for _ in 0..10 {
            t.decay_heat();
        }
        assert_eq!(t.region_at(0x10000).unwrap().heat, 0);
    }

    #[test]
    fn heat_survives_migration_but_not_reallocation() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        for _ in 0..10 {
            t.pool_of(0x1800);
        }
        t.migrate_region(0x1000, LOCAL_POOL);
        t.sync_heat();
        assert_eq!(t.region_at(0x1000).unwrap().heat, 10, "migration keeps heat");
        // free + re-allocate the same slot: fresh region, fresh heat
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x1000, 0x1000));
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        t.sync_heat();
        assert_eq!(t.region_at(0x1000).unwrap().heat, 0, "realloc must reset heat");
    }

    #[test]
    fn realloc_without_sync_does_not_inherit_stale_heat() {
        // regression: with UNSYNCED heat deltas still parked on the
        // flat index (no rebuild between free and realloc — the
        // classic allocator block-reuse pattern), the fold after the
        // next lookup must not credit the dead region's heat to the
        // fresh same-start-same-len allocation
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        for _ in 0..25 {
            t.pool_of(0x1800); // heat parks on the index copy
        }
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x1000, 0x1000));
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        t.pool_of(0x1800); // rebuild folds the stale deltas
        t.sync_heat();
        assert_eq!(
            t.region_at(0x1000).unwrap().heat,
            1, // only the post-realloc lookup
            "reused slot must not inherit the dead region's heat"
        );
    }

    #[test]
    fn migration_invalidates_fast_index() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        let before = t.pool_of(0x1800);
        assert_ne!(before, LOCAL_POOL);
        assert!(t.migrate_region(0x1000, LOCAL_POOL));
        assert_eq!(t.pool_of(0x1800), LOCAL_POOL, "stale MRU/index after migrate");
    }
}

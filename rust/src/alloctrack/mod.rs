//! Allocation tracker — the eBPF-consumer substitute.
//!
//! The paper's Tracer hooks allocation syscalls with eBPF so CXLMemSim
//! knows, for every sampled address, which memory pool it lives in.
//! This module consumes the same (syscall, range, time) stream from the
//! workload engine, maintains an interval map of live regions, and maps
//! addresses to pools according to a pluggable *placement policy*
//! (page- or region-granular, matching the paper's "cache-line vs page
//! memory management" research agenda).

pub mod policy;

use std::collections::BTreeMap;

use crate::topology::{PoolId, Topology, LOCAL_POOL};
use crate::trace::AllocEvent;
pub use policy::{Placement, PlacementPolicy, PolicyKind};

/// A live allocated region and where its bytes were placed.
#[derive(Clone, Debug)]
pub struct Region {
    pub start: u64,
    pub len: u64,
    pub placement: Placement,
}

impl Region {
    #[inline]
    pub fn end(&self) -> u64 {
        self.start + self.len
    }

    /// Pool owning `addr` (caller guarantees addr is inside the region).
    #[inline]
    pub fn pool_of(&self, addr: u64) -> PoolId {
        match &self.placement {
            Placement::Single(p) => *p,
            Placement::Interleaved { pools, page_bytes } => {
                let page = (addr - self.start) / page_bytes;
                pools[(page % pools.len() as u64) as usize]
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrackerStats {
    pub allocs: u64,
    pub frees: u64,
    pub lookup_misses: u64,
    pub live_bytes: u64,
    /// Bytes currently resident per pool (index = PoolId).
    pub pool_bytes: Vec<u64>,
}

/// Interval map of live regions + placement policy + per-pool usage.
pub struct AllocTracker {
    /// start -> region; regions never overlap.
    regions: BTreeMap<u64, Region>,
    policy: Box<dyn PlacementPolicy>,
    pub stats: TrackerStats,
    num_pools: usize,
}

impl AllocTracker {
    pub fn new(topo: &Topology, policy: Box<dyn PlacementPolicy>) -> AllocTracker {
        let num_pools = topo.num_pools();
        AllocTracker {
            regions: BTreeMap::new(),
            policy,
            stats: TrackerStats { pool_bytes: vec![0; num_pools], ..Default::default() },
            num_pools,
        }
    }

    pub fn num_pools(&self) -> usize {
        self.num_pools
    }

    /// Apply one allocation event from the trace.
    pub fn on_alloc_event(&mut self, ev: &AllocEvent) {
        if ev.kind.is_release() {
            self.release(ev.addr, ev.len);
        } else {
            self.allocate(ev);
        }
    }

    fn allocate(&mut self, ev: &AllocEvent) {
        if ev.len == 0 {
            return;
        }
        // Overlapping re-allocation: drop any overlapped live regions
        // first (matches kernel mmap MAP_FIXED semantics and keeps the
        // interval map consistent for malformed traces).
        self.release(ev.addr, ev.len);
        let placement = self.policy.place(ev, &self.stats);
        let region = Region { start: ev.addr, len: ev.len, placement };
        self.account(&region, true);
        self.stats.allocs += 1;
        self.regions.insert(ev.addr, region);
    }

    fn release(&mut self, addr: u64, len: u64) {
        let end = if len == 0 { addr + 1 } else { addr + len };
        // collect candidate starts overlapping [addr, end)
        let starts: Vec<u64> = self
            .regions
            .range(..end)
            .rev()
            .take_while(|(_, r)| r.end() > addr)
            .map(|(s, _)| *s)
            .collect();
        for s in starts {
            if let Some(r) = self.regions.remove(&s) {
                if r.end() > addr && r.start < end {
                    self.account(&r, false);
                    self.stats.frees += 1;
                    // partial unmap: keep the non-overlapping tail/head
                    if r.start < addr {
                        let head = Region {
                            start: r.start,
                            len: addr - r.start,
                            placement: r.placement.clone(),
                        };
                        self.account(&head, true);
                        self.regions.insert(head.start, head);
                    }
                    if r.end() > end {
                        let tail = Region {
                            start: end,
                            len: r.end() - end,
                            placement: r.placement.clone(),
                        };
                        self.account(&tail, true);
                        self.regions.insert(tail.start, tail);
                    }
                } else {
                    self.regions.insert(s, r); // not actually overlapping
                }
            }
        }
    }

    fn account(&mut self, region: &Region, add: bool) {
        // distribute bytes across pools per placement
        match &region.placement {
            Placement::Single(p) => {
                if add {
                    self.stats.pool_bytes[*p] += region.len;
                    self.stats.live_bytes += region.len;
                } else {
                    self.stats.pool_bytes[*p] =
                        self.stats.pool_bytes[*p].saturating_sub(region.len);
                    self.stats.live_bytes = self.stats.live_bytes.saturating_sub(region.len);
                }
            }
            Placement::Interleaved { pools, page_bytes } => {
                let pages = region.len.div_ceil(*page_bytes);
                for page in 0..pages {
                    let p = pools[(page % pools.len() as u64) as usize];
                    let sz = (*page_bytes).min(region.len - page * page_bytes);
                    if add {
                        self.stats.pool_bytes[p] += sz;
                        self.stats.live_bytes += sz;
                    } else {
                        self.stats.pool_bytes[p] = self.stats.pool_bytes[p].saturating_sub(sz);
                        self.stats.live_bytes = self.stats.live_bytes.saturating_sub(sz);
                    }
                }
            }
        }
    }

    /// Pool owning an address. Unknown addresses (stack, code, ...) are
    /// local DRAM, like the real tool's default for untracked ranges.
    #[inline]
    pub fn pool_of(&mut self, addr: u64) -> PoolId {
        if let Some((_, r)) = self.regions.range(..=addr).next_back() {
            if addr < r.end() {
                return r.pool_of(addr);
            }
        }
        self.stats.lookup_misses += 1;
        LOCAL_POOL
    }

    /// Move a whole region (page-set) to another pool — the migration
    /// hook used by `policy::migration` research experiments.
    pub fn migrate_region(&mut self, start: u64, to: PoolId) -> bool {
        if to >= self.num_pools {
            return false;
        }
        // remove + reinsert to fix accounting
        if let Some(r) = self.regions.remove(&start) {
            self.account(&r, false);
            let moved = Region { placement: Placement::Single(to), ..r };
            self.account(&moved, true);
            self.regions.insert(start, moved);
            true
        } else {
            false
        }
    }

    pub fn live_regions(&self) -> impl Iterator<Item = &Region> {
        self.regions.values()
    }

    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;
    use crate::trace::AllocKind;

    fn ev(kind: AllocKind, addr: u64, len: u64) -> AllocEvent {
        AllocEvent { kind, addr, len, t_ns: 0.0 }
    }

    fn tracker(policy: PolicyKind) -> AllocTracker {
        let topo = builtin::fig2();
        AllocTracker::new(&topo, policy.build(&topo))
    }

    #[test]
    fn alloc_then_lookup() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000));
        let p = t.pool_of(0x1800);
        assert!(p >= 1, "CxlOnly must place on a CXL pool, got {p}");
        assert_eq!(t.stats.lookup_misses, 0);
    }

    #[test]
    fn unknown_address_is_local() {
        let mut t = tracker(PolicyKind::CxlOnly);
        assert_eq!(t.pool_of(0xdead_beef), LOCAL_POOL);
        assert_eq!(t.stats.lookup_misses, 1);
    }

    #[test]
    fn free_forgets_region() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Malloc, 0x1000, 0x1000));
        assert_ne!(t.pool_of(0x1800), LOCAL_POOL);
        t.on_alloc_event(&ev(AllocKind::Free, 0x1000, 0x1000));
        assert_eq!(t.pool_of(0x1800), LOCAL_POOL);
        assert_eq!(t.stats.live_bytes, 0);
    }

    #[test]
    fn partial_munmap_keeps_tail() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 0x4000));
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x10000, 0x1000));
        assert_eq!(t.pool_of(0x10800), LOCAL_POOL); // unmapped head
        assert_ne!(t.pool_of(0x12000), LOCAL_POOL); // live tail
    }

    #[test]
    fn partial_munmap_keeps_head() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x10000, 0x4000));
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x13000, 0x1000));
        assert_ne!(t.pool_of(0x10800), LOCAL_POOL);
        assert_eq!(t.pool_of(0x13800), LOCAL_POOL);
    }

    #[test]
    fn interleave_stripes_pages() {
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(
            &topo,
            PolicyKind::Interleave { page_bytes: 4096 }.build(&topo),
        );
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x0, 4096 * 6));
        let pools: Vec<PoolId> = (0..6).map(|i| t.pool_of(i * 4096 + 64)).collect();
        // must hit more than one pool, cyclically
        assert!(pools.windows(2).any(|w| w[0] != w[1]), "{pools:?}");
        assert_eq!(pools[0], pools[3]); // 3 CXL pools in fig2 -> period 3
    }

    #[test]
    fn accounting_tracks_pool_bytes() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x0, 1 << 20));
        assert_eq!(t.stats.live_bytes, 1 << 20);
        let cxl_total: u64 = t.stats.pool_bytes[1..].iter().sum();
        assert_eq!(cxl_total, 1 << 20);
        t.on_alloc_event(&ev(AllocKind::Munmap, 0x0, 1 << 20));
        assert_eq!(t.stats.live_bytes, 0);
    }

    #[test]
    fn overlapping_remap_replaces() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000));
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x2000)); // MAP_FIXED-style
        assert_eq!(t.stats.live_bytes, 0x2000);
        assert_eq!(t.region_count(), 1);
    }

    #[test]
    fn migrate_region_moves_bytes() {
        let mut t = tracker(PolicyKind::CxlOnly);
        t.on_alloc_event(&ev(AllocKind::Mmap, 0x1000, 0x1000));
        let before = t.pool_of(0x1800);
        assert!(t.migrate_region(0x1000, LOCAL_POOL));
        assert_eq!(t.pool_of(0x1800), LOCAL_POOL);
        assert!(before != LOCAL_POOL);
        assert_eq!(t.stats.pool_bytes[LOCAL_POOL], 0x1000);
    }

    #[test]
    fn migrate_unknown_region_fails() {
        let mut t = tracker(PolicyKind::CxlOnly);
        assert!(!t.migrate_region(0x9999, LOCAL_POOL));
    }
}

//! Placement policies: which pool serves a new allocation.
//!
//! The paper motivates CXLMemSim as a vehicle for exactly this research
//! ("memory scheduling for complex applications", page vs cache-line
//! management). These policies are the baseline set; the `policy`
//! module layers migration/prefetch on top.

use crate::topology::{PoolId, Topology, LOCAL_POOL};
use crate::trace::{AllocEvent, AllocKind};

use super::TrackerStats;

/// How a region's bytes are spread over pools.
#[derive(Clone, Debug, PartialEq)]
pub enum Placement {
    Single(PoolId),
    /// Page-granular round-robin striping over `pools`.
    Interleaved { pools: Vec<PoolId>, page_bytes: u64 },
}

/// Decides a placement for each allocation event, observing current
/// per-pool usage.
pub trait PlacementPolicy: Send {
    fn name(&self) -> &'static str;
    fn place(&mut self, ev: &AllocEvent, stats: &TrackerStats) -> Placement;
}

/// Named policy constructors for CLI/config use.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyKind {
    /// Everything local (the "native" baseline topology usage).
    LocalOnly,
    /// Everything on CXL pools, round-robin per allocation.
    CxlOnly,
    /// Local until a capacity cap, then spill to CXL (Pond-style).
    LocalFirst { local_cap_bytes: u64 },
    /// Page-interleave every allocation across all CXL pools.
    Interleave { page_bytes: u64 },
    /// Small allocations local, large ones to CXL (size-class tiering).
    SizeClass { threshold_bytes: u64 },
    /// Prefer the pool with the most free capacity (least-loaded).
    LeastLoaded,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        Some(match s {
            "local" => PolicyKind::LocalOnly,
            "cxl" => PolicyKind::CxlOnly,
            "localfirst" => PolicyKind::LocalFirst { local_cap_bytes: 1 << 30 },
            "interleave" => PolicyKind::Interleave { page_bytes: 4096 },
            "sizeclass" => PolicyKind::SizeClass { threshold_bytes: 2 << 20 },
            "leastloaded" => PolicyKind::LeastLoaded,
            _ => return None,
        })
    }

    pub fn build(&self, topo: &Topology) -> Box<dyn PlacementPolicy> {
        let cxl_pools: Vec<PoolId> = (1..topo.num_pools()).collect();
        let caps: Vec<u64> = (0..topo.num_pools()).map(|p| topo.pool_capacity(p)).collect();
        match self {
            PolicyKind::LocalOnly => Box::new(LocalOnly),
            PolicyKind::CxlOnly => Box::new(CxlOnly { pools: cxl_pools, next: 0 }),
            PolicyKind::LocalFirst { local_cap_bytes } => Box::new(LocalFirst {
                cap: *local_cap_bytes,
                pools: cxl_pools,
                next: 0,
            }),
            PolicyKind::Interleave { page_bytes } => Box::new(Interleave {
                pools: cxl_pools,
                page_bytes: *page_bytes,
            }),
            PolicyKind::SizeClass { threshold_bytes } => Box::new(SizeClass {
                threshold: *threshold_bytes,
                pools: cxl_pools,
                next: 0,
            }),
            PolicyKind::LeastLoaded => Box::new(LeastLoaded { caps }),
        }
    }
}

struct LocalOnly;

impl PlacementPolicy for LocalOnly {
    fn name(&self) -> &'static str {
        "local"
    }
    fn place(&mut self, _ev: &AllocEvent, _stats: &TrackerStats) -> Placement {
        Placement::Single(LOCAL_POOL)
    }
}

struct CxlOnly {
    pools: Vec<PoolId>,
    next: usize,
}

impl PlacementPolicy for CxlOnly {
    fn name(&self) -> &'static str {
        "cxl"
    }
    fn place(&mut self, _ev: &AllocEvent, _stats: &TrackerStats) -> Placement {
        if self.pools.is_empty() {
            return Placement::Single(LOCAL_POOL);
        }
        let p = self.pools[self.next % self.pools.len()];
        self.next += 1;
        Placement::Single(p)
    }
}

struct LocalFirst {
    cap: u64,
    pools: Vec<PoolId>,
    next: usize,
}

impl PlacementPolicy for LocalFirst {
    fn name(&self) -> &'static str {
        "localfirst"
    }
    fn place(&mut self, ev: &AllocEvent, stats: &TrackerStats) -> Placement {
        if stats.pool_bytes[LOCAL_POOL] + ev.len <= self.cap || self.pools.is_empty() {
            Placement::Single(LOCAL_POOL)
        } else {
            let p = self.pools[self.next % self.pools.len()];
            self.next += 1;
            Placement::Single(p)
        }
    }
}

struct Interleave {
    pools: Vec<PoolId>,
    page_bytes: u64,
}

impl PlacementPolicy for Interleave {
    fn name(&self) -> &'static str {
        "interleave"
    }
    fn place(&mut self, _ev: &AllocEvent, _stats: &TrackerStats) -> Placement {
        if self.pools.is_empty() {
            Placement::Single(LOCAL_POOL)
        } else {
            Placement::Interleaved {
                pools: self.pools.clone(),
                page_bytes: self.page_bytes,
            }
        }
    }
}

struct SizeClass {
    threshold: u64,
    pools: Vec<PoolId>,
    next: usize,
}

impl PlacementPolicy for SizeClass {
    fn name(&self) -> &'static str {
        "sizeclass"
    }
    fn place(&mut self, ev: &AllocEvent, _stats: &TrackerStats) -> Placement {
        // glibc-style heuristic: brk/sbrk (heap growth) stays local
        // regardless of size — the heap is hot and short-lived; only
        // big mmap/calloc regions go to CXL.
        let heapish = matches!(ev.kind, AllocKind::Sbrk | AllocKind::Brk);
        if heapish || ev.len < self.threshold || self.pools.is_empty() {
            Placement::Single(LOCAL_POOL)
        } else {
            let p = self.pools[self.next % self.pools.len()];
            self.next += 1;
            Placement::Single(p)
        }
    }
}

struct LeastLoaded {
    caps: Vec<u64>,
}

impl PlacementPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "leastloaded"
    }
    fn place(&mut self, _ev: &AllocEvent, stats: &TrackerStats) -> Placement {
        // pick the pool with the largest absolute free capacity,
        // considering local DRAM too.
        let mut best = LOCAL_POOL;
        let mut best_free = 0i128;
        for p in 0..self.caps.len() {
            let used = *stats.pool_bytes.get(p).unwrap_or(&0) as i128;
            let free = self.caps[p] as i128 - used;
            if free > best_free {
                best_free = free;
                best = p;
            }
        }
        Placement::Single(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;

    fn ev(len: u64, kind: AllocKind) -> AllocEvent {
        AllocEvent { kind, addr: 0x1000, len, t_ns: 0.0 }
    }

    fn stats(pools: usize) -> TrackerStats {
        TrackerStats { pool_bytes: vec![0; pools], ..Default::default() }
    }

    #[test]
    fn parse_known_policies() {
        for name in ["local", "cxl", "localfirst", "interleave", "sizeclass", "leastloaded"] {
            assert!(PolicyKind::parse(name).is_some(), "{name}");
        }
        assert!(PolicyKind::parse("fancy").is_none());
    }

    #[test]
    fn local_only_always_local() {
        let topo = builtin::fig2();
        let mut p = PolicyKind::LocalOnly.build(&topo);
        assert_eq!(
            p.place(&ev(1 << 30, AllocKind::Mmap), &stats(4)),
            Placement::Single(LOCAL_POOL)
        );
    }

    #[test]
    fn cxl_only_round_robins() {
        let topo = builtin::fig2(); // 3 CXL pools
        let mut p = PolicyKind::CxlOnly.build(&topo);
        let s = stats(4);
        let a = p.place(&ev(64, AllocKind::Malloc), &s);
        let b = p.place(&ev(64, AllocKind::Malloc), &s);
        let c = p.place(&ev(64, AllocKind::Malloc), &s);
        let d = p.place(&ev(64, AllocKind::Malloc), &s);
        assert_ne!(a, b);
        assert_eq!(a, d); // period 3
        let _ = c;
    }

    #[test]
    fn local_first_spills_at_cap() {
        let topo = builtin::fig2();
        let mut p = PolicyKind::LocalFirst { local_cap_bytes: 1000 }.build(&topo);
        let mut s = stats(4);
        assert_eq!(
            p.place(&ev(500, AllocKind::Mmap), &s),
            Placement::Single(LOCAL_POOL)
        );
        s.pool_bytes[LOCAL_POOL] = 900;
        match p.place(&ev(500, AllocKind::Mmap), &s) {
            Placement::Single(pool) => assert!(pool >= 1, "must spill to CXL"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn size_class_splits_by_threshold() {
        let topo = builtin::fig2();
        let mut p = PolicyKind::SizeClass { threshold_bytes: 1 << 20 }.build(&topo);
        let s = stats(4);
        assert_eq!(
            p.place(&ev(4096, AllocKind::Malloc), &s),
            Placement::Single(LOCAL_POOL)
        );
        match p.place(&ev(16 << 20, AllocKind::Mmap), &s) {
            Placement::Single(pool) => assert!(pool >= 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn size_class_keeps_heap_growth_local_regardless_of_size() {
        // regression: the `heapish && len < threshold` clause was dead
        // (subsumed by `len < threshold`), so a huge sbrk spilled to
        // CXL against the doc comment's intent
        let topo = builtin::fig2();
        let mut p = PolicyKind::SizeClass { threshold_bytes: 1 << 20 }.build(&topo);
        let s = stats(4);
        for kind in [AllocKind::Sbrk, AllocKind::Brk] {
            assert_eq!(
                p.place(&ev(16 << 20, kind), &s),
                Placement::Single(LOCAL_POOL),
                "{kind:?} above the threshold must still stay local"
            );
        }
        // non-heap allocations above the threshold still spill
        match p.place(&ev(16 << 20, AllocKind::Mmap), &s) {
            Placement::Single(pool) => assert!(pool >= 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn least_loaded_prefers_free_capacity() {
        let topo = builtin::fig2();
        let mut p = PolicyKind::LeastLoaded.build(&topo);
        let mut s = stats(topo.num_pools());
        // empty pools: the 128 GB pool has the most free capacity
        match p.place(&ev(64, AllocKind::Malloc), &s) {
            Placement::Single(pool) => assert_eq!(topo.pool_capacity(pool), 128 << 30),
            other => panic!("unexpected {other:?}"),
        }
        // fill the big pool -> local DRAM (96 GB) becomes most free
        for pool in 0..topo.num_pools() {
            if topo.pool_capacity(pool) == 128 << 30 {
                s.pool_bytes[pool] = 128 << 30;
            }
        }
        assert_eq!(
            p.place(&ev(64, AllocKind::Malloc), &s),
            Placement::Single(LOCAL_POOL)
        );
    }

    #[test]
    fn interleave_emits_striped_placement() {
        let topo = builtin::fig2();
        let mut p = PolicyKind::Interleave { page_bytes: 4096 }.build(&topo);
        match p.place(&ev(1 << 20, AllocKind::Mmap), &stats(4)) {
            Placement::Interleaved { pools, page_bytes } => {
                assert_eq!(pools.len(), 3);
                assert_eq!(page_bytes, 4096);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}

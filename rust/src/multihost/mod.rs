//! Multi-host simulation: several programs (one per simulated host)
//! sharing the same CXL pools and switches — the pool-coherency /
//! congestion study the paper's §1 promises ("evaluation of the
//! performance impact of CXL.mem pool coherency on applications that
//! share memory across multiple servers").
//!
//! Each host has its own cache hierarchy, allocation tracker (its own
//! address space), and per-epoch bins. Within an epoch every host
//! advances independently — which is why the host phase parallelizes:
//! a persistent worker pool (threads kept alive across epochs behind a
//! `std::sync::Barrier`) drains a shared atomic host-index queue each
//! epoch, so the host phase is *work-conserving*: a worker that
//! finishes its nominal share claims the next unclaimed host instead
//! of idling at the barrier, and one giant host can no longer
//! serialize the epoch behind idle peers (claims outside a worker's
//! nominal static shard are counted as `steals` in the report). Which
//! worker advances a host never changes what the host computes, and
//! per-host bins are merged into the shared bins at the epoch barrier,
//! always in host order, so the result is bit-identical for any
//! thread count (`tests/pipeline_equivalence.rs` and the CI
//! determinism matrix). The shared switches then see the union of the
//! traffic and the congestion/bandwidth scans charge everyone; the
//! computed epoch delay is attributed to hosts proportionally to
//! their traffic.
//!
//! CXL.mem pool coherency (paper §2): writes to the shared range are
//! logged during the host phase and applied at the barrier — each
//! delivered back-invalidation drops the line from the peer's caches
//! and transits the topology as a write message. Deferring delivery to
//! the barrier (epoch granularity, the simulator's native resolution)
//! is what makes the host phase embarrassingly parallel.
//!
//! The two-phase policy engine (`crate::policy`) runs here too: each
//! host carries its own [`PolicyStack`] (built per host from
//! `SimConfig::epoch_policy`, or passed explicitly to
//! [`run_shared_threads_with`]). Both phases execute on the
//! coordinator thread at the epoch barrier, always in host order —
//! phase 1 (bin shaping + migration-traffic injection) on the host's
//! own bins *before* they merge into the shared switch view, phase 2
//! (migration) after the shared analyze — so results stay bit-identical
//! for any worker-thread count. Modeled migration stall is charged to
//! the migrating host's delay (and the run total).
//!
//! Miss accounting in the host phase uses the same
//! `EpochBins::stage`/`record_bulk` bulk path as the epoch driver when
//! `event_batch > 1`; `event_batch == 1` keeps the scalar per-miss
//! `record` baseline, asserted bit-identical in
//! `tests/pipeline_equivalence.rs`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use crate::alloctrack::AllocTracker;
use crate::cache::{AccessOutcome, CacheHierarchy};
use crate::coordinator::SimConfig;
use crate::policy::PolicyStack;
use crate::runtime::{self, TimingInputs};
use crate::topology::{PoolId, TopoTensors, Topology};
use crate::trace::binning::{BinDelta, EpochBins};
use crate::trace::WlEvent;
use crate::workload::Workload;

/// Per-host outcome of a shared run.
#[derive(Clone, Debug)]
pub struct HostReport {
    pub workload: String,
    pub native_ns: f64,
    pub simulated_ns: f64,
    pub delay_ns: f64,
    pub misses: u64,
    /// Migrations performed by this host's policy stack.
    pub migrations: u64,
    pub migrated_bytes: u64,
    /// Bytes this host evacuated off pools taken offline by the fault
    /// schedule (a subset of `migrated_bytes`; 0 without `--faults`).
    pub failover_migrated_bytes: u64,
    /// Bytes this host's `drain` policy moved proactively off degraded
    /// pools plus post-recovery re-admissions (a subset of
    /// `migrated_bytes`; 0 without a `drain` stack member).
    pub drain_migrated_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct MultiHostReport {
    pub hosts: Vec<HostReport>,
    pub epochs: u64,
    pub total_delay_ns: f64,
    pub cong_delay_ns: f64,
    pub bwd_delay_ns: f64,
    /// CXL.mem coherence: back-invalidations delivered to peer caches
    /// because a host wrote a shared line (0 unless hosts share ranges).
    pub invalidations: u64,
    /// Coherence messages that transited the topology (charged to the
    /// shared line's pool path as write traffic).
    pub coherence_msgs: u64,
    /// Policy engine totals across all host stacks.
    pub migrations: u64,
    pub migrated_bytes: u64,
    /// Modeled migration stall charged to host delays (included in
    /// `total_delay_ns`), ns.
    pub mig_stall_ns: f64,
    /// Host-phase workers actually used (1 = inline, no pool).
    pub host_workers: usize,
    /// Work-conservation observability: hosts a worker advanced
    /// outside its nominal static shard (0 on inline runs). The value
    /// depends on scheduling — only the *simulation* outputs are
    /// thread-count-invariant.
    pub steals: u64,
    /// Epochs whose effective host→worker assignment deviated from
    /// the static partition (i.e. epochs with at least one steal).
    pub shard_rebalances: u64,
    /// Per-worker fraction of the total host-phase wall time spent
    /// advancing hosts (empty on inline runs). Near-equal fractions
    /// mean the queue kept every worker busy.
    pub worker_busy_fracs: Vec<f64>,
    /// Fault injection (`--faults`, `crate::fault`): events fired,
    /// exact retry-storm delay charged (part of `total_delay_ns`),
    /// epochs with a transient window active, distinct pools taken
    /// offline, bytes evacuated by failover across all hosts. All
    /// zero on fault-free runs.
    pub faults_injected: u64,
    pub retry_delay_ns: f64,
    pub throttled_epochs: u64,
    pub pools_offline: u64,
    pub failover_migrated_bytes: u64,
    /// Availability lifecycle (mirrors `SimReport`): pools brought
    /// back by `online` events, transient warm-up delay charged while
    /// re-onlined pools re-populated, and bytes moved by the hosts'
    /// `drain` policies (evacuation + re-admission).
    pub pools_reonlined: u64,
    pub warmup_delay_ns: f64,
    pub drain_migrated_bytes: u64,
    pub wall_s: f64,
}

impl MultiHostReport {
    /// Machine-readable export (ms units, mirroring
    /// `SimReport::to_json`). Shares the `delay_ms` / `cong_delay_ms` /
    /// `bwd_delay_ms` key names with the single-host report so sweep
    /// invariants and baseline deltas work across drivers; the
    /// scheduling observability keys (`host_workers`, `steals`,
    /// `shard_rebalances`, `worker_busy_fracs`, `wall_s`) are the ones
    /// the sweep artifact strips as non-deterministic.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{self, Json};
        json::obj(vec![
            (
                "hosts",
                Json::Arr(
                    self.hosts
                        .iter()
                        .map(|h| {
                            json::obj(vec![
                                ("workload", json::s(&h.workload)),
                                ("native_ms", json::num(h.native_ns / 1e6)),
                                ("simulated_ms", json::num(h.simulated_ns / 1e6)),
                                ("delay_ms", json::num(h.delay_ns / 1e6)),
                                ("misses", json::num(h.misses as f64)),
                                ("migrations", json::num(h.migrations as f64)),
                                ("migrated_bytes", json::num(h.migrated_bytes as f64)),
                                (
                                    "failover_migrated_bytes",
                                    json::num(h.failover_migrated_bytes as f64),
                                ),
                                (
                                    "drain_migrated_bytes",
                                    json::num(h.drain_migrated_bytes as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("epochs", json::num(self.epochs as f64)),
            ("total_delay_ms", json::num(self.total_delay_ns / 1e6)),
            ("delay_ms", json::num(self.total_delay_ns / 1e6)),
            ("cong_delay_ms", json::num(self.cong_delay_ns / 1e6)),
            ("bwd_delay_ms", json::num(self.bwd_delay_ns / 1e6)),
            ("invalidations", json::num(self.invalidations as f64)),
            ("coherence_msgs", json::num(self.coherence_msgs as f64)),
            ("migrations", json::num(self.migrations as f64)),
            ("migrated_bytes", json::num(self.migrated_bytes as f64)),
            ("mig_stall_ms", json::num(self.mig_stall_ns / 1e6)),
            ("mean_slowdown", json::num(self.mean_slowdown())),
            ("faults_injected", json::num(self.faults_injected as f64)),
            ("retry_delay_ms", json::num(self.retry_delay_ns / 1e6)),
            ("throttled_epochs", json::num(self.throttled_epochs as f64)),
            ("pools_offline", json::num(self.pools_offline as f64)),
            (
                "failover_migrated_bytes",
                json::num(self.failover_migrated_bytes as f64),
            ),
            ("pools_reonlined", json::num(self.pools_reonlined as f64)),
            ("warmup_delay_ms", json::num(self.warmup_delay_ns / 1e6)),
            ("drain_migrated_bytes", json::num(self.drain_migrated_bytes as f64)),
            ("host_workers", json::num(self.host_workers as f64)),
            ("steals", json::num(self.steals as f64)),
            ("shard_rebalances", json::num(self.shard_rebalances as f64)),
            ("worker_busy_fracs", json::arr_f64(&self.worker_busy_fracs)),
            ("wall_s", json::num(self.wall_s)),
        ])
    }

    /// Mean per-host simulated slowdown.
    pub fn mean_slowdown(&self) -> f64 {
        if self.hosts.is_empty() {
            return 1.0;
        }
        self.hosts
            .iter()
            .map(|h| if h.native_ns > 0.0 { h.simulated_ns / h.native_ns } else { 1.0 })
            .sum::<f64>()
            / self.hosts.len() as f64
    }
}

/// A write to the shared range, logged during the host phase and
/// delivered as back-invalidations at the epoch barrier.
struct SharedWrite {
    addr: u64,
    pool: PoolId,
    /// Writer's epoch-relative virtual time of the write.
    t_ns: f64,
}

struct Host {
    wl: Box<dyn Workload>,
    cache: CacheHierarchy,
    tracker: AllocTracker,
    /// This host's slice of the epoch's traffic; merged at the barrier.
    bins: EpochBins,
    /// Staged `(pool, rw, bin, weight)` deltas awaiting the bulk
    /// scatter into `bins` (`event_batch > 1`; scalar `record` is kept
    /// at `event_batch == 1` as the bit-identical baseline).
    staged: Vec<BinDelta>,
    /// This host's policy stack; both phases run at the epoch barrier,
    /// coordinator thread, host order.
    stack: Option<PolicyStack>,
    /// Carry-over event buffer (events pulled past the epoch boundary
    /// stay queued for the next epoch).
    buf: Vec<WlEvent>,
    cursor: usize,
    shared_writes: Vec<SharedWrite>,
    /// Bytes this host's regions were failover-migrated off offline
    /// pools (fault schedule only).
    failover_bytes: u64,
    native_ns: f64,
    epoch_vtime: f64,
    epoch_misses: f64,
    misses: u64,
    delay_ns: f64,
    /// The workload emitted its last event (buffer may still drain).
    src_done: bool,
    /// Fully finished: source exhausted and buffer drained.
    done: bool,
}

/// Advance one host to its epoch boundary (or to completion). Pure in
/// everything but the host's own state — safe to run hosts on separate
/// threads.
fn advance_host_epoch(
    h: &mut Host,
    topo: &Topology,
    cfg: &SimConfig,
    epoch_ns: f64,
    shared_base: u64,
    batch: usize,
) {
    if h.done {
        return;
    }
    // bulk miss accounting mirrors the epoch driver: stage pre-binned
    // deltas, scatter once per pulled batch; `event_batch == 1` keeps
    // the scalar per-miss path as the measurable (and bit-identical)
    // baseline
    let staging = batch > 1;
    loop {
        if h.epoch_vtime >= epoch_ns {
            break;
        }
        if h.cursor >= h.buf.len() {
            // drain staged deltas before pulling the next batch
            if !h.staged.is_empty() {
                h.bins.record_bulk(&h.staged);
                h.staged.clear();
            }
            if h.src_done {
                h.done = true;
                break;
            }
            h.buf.clear();
            h.cursor = 0;
            if !h.wl.next_batch(&mut h.buf, batch) {
                h.src_done = true;
            }
            if h.buf.is_empty() {
                h.done = true;
                break;
            }
        }
        let ev = h.buf[h.cursor];
        h.cursor += 1;
        match ev {
            WlEvent::Alloc(mut a) => {
                a.t_ns = h.native_ns + h.epoch_vtime;
                h.tracker.on_alloc_event(&a);
                h.epoch_vtime += cfg.alloc_cost_ns;
            }
            WlEvent::Access(a) => {
                let outcome = h.cache.access(a.addr, a.is_write);
                let mut cost = cfg.cpi_ns + h.cache.hit_latency_ns(outcome);
                let mut pool = usize::MAX;
                if let AccessOutcome::Miss { writeback } = outcome {
                    cost += if a.is_write {
                        topo.host.local_write_latency_ns
                    } else {
                        topo.host.local_read_latency_ns
                    } / cfg.mlp.max(1.0);
                    pool = h.tracker.pool_of(a.addr);
                    h.misses += 1;
                    h.epoch_misses += 1.0;
                    let t = h.epoch_vtime;
                    if staging {
                        h.bins.stage(pool, a.is_write, t, 1.0, &mut h.staged);
                    } else {
                        h.bins.record(pool, a.is_write, t, 1.0);
                    }
                    if let Some(wb) = writeback {
                        let wb_pool = h.tracker.pool_of(wb);
                        if staging {
                            h.bins.stage(wb_pool, true, t, 1.0, &mut h.staged);
                        } else {
                            h.bins.record(wb_pool, true, t, 1.0);
                        }
                    }
                }
                h.epoch_vtime += cost;
                // CXL.mem pool coherency: log the shared write; peers'
                // copies are back-invalidated at the epoch barrier.
                if a.is_write && a.addr >= shared_base {
                    if pool == usize::MAX {
                        pool = h.tracker.pool_of(a.addr);
                    }
                    h.shared_writes.push(SharedWrite { addr: a.addr, pool, t_ns: h.epoch_vtime });
                }
            }
        }
    }
    // tail scatter: the barrier merge must see the complete epoch
    if !h.staged.is_empty() {
        h.bins.record_bulk(&h.staged);
        h.staged.clear();
    }
}

/// Run `workloads` concurrently over one topology, sharding the host
/// phase over as many threads as the machine offers; round-robin epoch
/// barriers approximate concurrent execution at epoch granularity.
pub fn run_shared(
    topo: &Topology,
    cfg: &SimConfig,
    workloads: Vec<Box<dyn Workload>>,
) -> anyhow::Result<MultiHostReport> {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    run_shared_threads(topo, cfg, workloads, threads)
}

/// [`run_shared`] with an explicit host-phase thread count. The result
/// is bit-identical for every `threads` value (deterministic barrier
/// merge); `threads == 1` runs everything inline, with no worker pool.
/// Per-host policy stacks are built from `SimConfig::epoch_policy`.
pub fn run_shared_threads(
    topo: &Topology,
    cfg: &SimConfig,
    workloads: Vec<Box<dyn Workload>>,
    threads: usize,
) -> anyhow::Result<MultiHostReport> {
    let stacks = cfg.epoch_policy.as_ref().map(|spec| {
        (0..workloads.len())
            .map(|_| spec.build(cfg.mig_stall_ns_per_byte))
            .collect()
    });
    run_shared_threads_with(topo, cfg, workloads, stacks, threads)
}

/// [`run_shared_threads`] with explicit per-host policy stacks (None =
/// no policy engine; Some requires one stack per host, applied in host
/// order at the epoch barrier). Ignores `SimConfig::epoch_policy`.
pub fn run_shared_threads_with(
    topo: &Topology,
    cfg: &SimConfig,
    workloads: Vec<Box<dyn Workload>>,
    stacks: Option<Vec<PolicyStack>>,
    threads: usize,
) -> anyhow::Result<MultiHostReport> {
    let wall = std::time::Instant::now();
    crate::coordinator::ensure_fault_backend(cfg)?;
    let tensors = TopoTensors::build(
        topo,
        runtime::shapes::NUM_POOLS,
        runtime::shapes::NUM_SWITCHES,
    )?;
    let mut model = runtime::make_analyzer(
        cfg.backend,
        &tensors,
        cfg.nbins,
        &cfg.artifacts_dir,
        cfg.scan_kernel,
    )?;
    let mut bins = EpochBins::new(runtime::shapes::NUM_POOLS, cfg.nbins, cfg.epoch_ns());

    let batch = cfg.event_batch.max(1);
    let nhosts = workloads.len();
    // resolve the fault plan once against the shared topology; all
    // fault state lives on the coordinator thread (epoch barrier, host
    // order), so worker count cannot perturb it. Host-scoped events
    // (`host = "hN"` — retry storms only) split off into per-host
    // schedules whose adders never touch the shared analyzer overlay:
    // they are attributed closed-form from the owning host's own bins
    // (step 2c), so an unfaulted peer's report stays byte-identical to
    // its fault-free run.
    let (mut fault, mut host_faults): (Option<crate::fault::FaultState>, Vec<_>) =
        match &cfg.faults {
            Some(plan) => {
                let (global, per_host) = plan.split_hosts(nhosts)?;
                let hf = per_host
                    .iter()
                    .map(|p| {
                        if p.events.is_empty() { Ok(None) } else { p.resolve(topo).map(Some) }
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                (Some(global.resolve(topo)?), hf)
            }
            None => (None, (0..nhosts).map(|_| None).collect()),
        };
    let stacks: Vec<Option<PolicyStack>> = match stacks {
        Some(v) => {
            anyhow::ensure!(
                v.len() == nhosts,
                "run_shared_threads_with: {} stacks for {} hosts",
                v.len(),
                nhosts
            );
            v.into_iter().map(Some).collect()
        }
        // offline failover routes through each host's policy stack;
        // under faults every host gets an empty one (bit-identical to
        // no stack — `tests/pipeline_equivalence.rs`)
        None => (0..nhosts)
            .map(|_| fault.as_ref().map(|_| PolicyStack::new(cfg.mig_stall_ns_per_byte)))
            .collect(),
    };
    let hosts: Vec<Host> = workloads
        .into_iter()
        .zip(stacks)
        .map(|(wl, mut stack)| {
            if let Some(st) = &mut stack {
                st.begin_run(); // per-run accounting, even for caller-owned stacks
            }
            let mut tracker = AllocTracker::new(topo, cfg.policy.build(topo));
            tracker.set_heat_decay(cfg.heat_decay);
            Host {
                wl,
                cache: CacheHierarchy::scaled(cfg.cache_scale),
                tracker,
                bins: EpochBins::new(runtime::shapes::NUM_POOLS, cfg.nbins, cfg.epoch_ns()),
                staged: Vec::with_capacity(if batch > 1 { batch } else { 0 }),
                stack,
                buf: Vec::with_capacity(batch),
                cursor: 0,
                shared_writes: Vec::new(),
                failover_bytes: 0,
                native_ns: 0.0,
                epoch_vtime: 0.0,
                epoch_misses: 0.0,
                misses: 0,
                delay_ns: 0.0,
                src_done: false,
                done: false,
            }
        })
        .collect();

    let epoch_ns = cfg.epoch_ns();
    let bytes_per_ev = topo.host.cacheline_bytes as f32;
    let mut epochs = 0u64;
    let mut total_delay = 0.0;
    let mut cong_total = 0.0;
    let mut bwd_total = 0.0;
    let mut mig_stall_total = 0.0;
    let mut invalidations = 0u64;
    let mut coherence_msgs = 0u64;
    let shared_base = crate::workload::patterns::SHARED_BASE;
    let nworkers = threads.clamp(1, nhosts.max(1));
    let use_pool = nworkers > 1 && nhosts > 1;

    // ---- work-conserving persistent worker pool. Hosts live behind
    // individual Mutexes; each epoch the workers drain a shared atomic
    // host-index queue (claim-by-`fetch_add`), so a worker that runs
    // out of work steals the next unclaimed host instead of idling at
    // the barrier — one giant host can no longer serialize the epoch
    // behind idle peers (ROADMAP item; replaces the static per-worker
    // shards, whose early finishers sat at the barrier). The per-host
    // locks are never contended: the queue hands every index to
    // exactly one worker, and the Barrier alternates exclusive phases
    // (workers advance hosts while the coordinator is parked; the
    // coordinator merges while the workers are parked), so the Mutex
    // only carries ownership across threads for the borrow checker.
    // Which worker advances a host cannot change what the host
    // computes, and the coordinator still merges in host order, so
    // reports stay bit-identical for any worker count.
    //
    // `steals` counts claims outside a worker's nominal static shard
    // (a balanced partition: every worker gets floor(H/W) consecutive
    // hosts, the first H mod W workers one extra — never an empty
    // home, so a homeless worker can't inflate the count) —
    // observability for the work-conservation claim, not simulation
    // state. `busy_ns` accumulates per-worker host-phase time for the
    // report's busy fractions.
    let hosts: Vec<Mutex<Host>> = hosts.into_iter().map(Mutex::new).collect();
    let (shard_base, shard_rem) = (nhosts / nworkers, nhosts % nworkers);
    let home_of = |w: usize| {
        let start = w * shard_base + w.min(shard_rem);
        start..start + shard_base + usize::from(w < shard_rem)
    };
    let next_host = AtomicUsize::new(0);
    let steals = AtomicU64::new(0);
    let busy_ns: Vec<AtomicU64> = (0..nworkers).map(|_| AtomicU64::new(0)).collect();
    let mut shard_rebalances = 0u64;
    let mut phase_ns = 0u64;
    // two rendezvous per epoch: open the host phase, then collect it
    let barrier = Barrier::new(nworkers + 1);
    let stop = AtomicBool::new(false);
    let panicked = AtomicBool::new(false);
    // first worker panic wins the slot: (host index being advanced,
    // stringified panic payload), surfaced in the returned error so
    // callers don't have to scrape stderr
    let panic_info: Mutex<Option<(usize, String)>> = Mutex::new(None);
    let mut run_err: Option<anyhow::Error> = None;

    std::thread::scope(|s| {
        if use_pool {
            for w in 0..nworkers {
                let (hosts, barrier, stop, panicked, next_host, steals) =
                    (&hosts, &barrier, &stop, &panicked, &next_host, &steals);
                let panic_info = &panic_info;
                let busy = &busy_ns[w];
                let home = home_of(w);
                s.spawn(move || loop {
                    barrier.wait(); // parked until the epoch opens
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let t0 = std::time::Instant::now();
                    // a panic here must not strand the coordinator at
                    // the end-of-phase barrier (std Barrier has no
                    // poisoning): catch it per claimed host — so the
                    // payload can be paired with the host index being
                    // advanced — record both, make the rendezvous
                    // anyway; the coordinator turns the record into the
                    // returned error after the phase.
                    loop {
                        let i = next_host.fetch_add(1, Ordering::Relaxed);
                        if i >= nhosts {
                            break; // queue drained: this epoch is done
                        }
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            let mut h = hosts[i].lock().unwrap();
                            if !h.done && !home.contains(&i) {
                                steals.fetch_add(1, Ordering::Relaxed);
                            }
                            advance_host_epoch(&mut h, topo, cfg, epoch_ns, shared_base, batch);
                        }));
                        if let Err(payload) = result {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|m| m.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "<non-string panic payload>".to_string());
                            let mut slot = panic_info.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some((i, msg));
                            }
                            drop(slot);
                            panicked.store(true, Ordering::Release);
                            break; // stop claiming; rendezvous below
                        }
                    }
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    barrier.wait(); // every claimed host advanced
                });
            }
        }

        loop {
            let live = hosts.iter().filter(|h| !h.lock().unwrap().done).count();
            if live == 0 {
                break;
            }

            // ---- host phase: every live host advances one epoch
            let steals_before = steals.load(Ordering::Relaxed);
            if use_pool {
                next_host.store(0, Ordering::Relaxed); // published by the barrier
                let t0 = std::time::Instant::now();
                barrier.wait(); // open the host phase
                barrier.wait(); // queue drained
                phase_ns += t0.elapsed().as_nanos() as u64;
                // check BEFORE locking hosts: a worker panic poisons
                // the host Mutex it held, so surface the error instead
                // of a PoisonError unwrap (or, worse, a silent hang at
                // the barrier, which is what a stranded rendezvous
                // gave)
                if panicked.load(Ordering::Acquire) {
                    let (hi, msg) = panic_info
                        .lock()
                        .unwrap()
                        .take()
                        .unwrap_or((usize::MAX, "<panic payload lost>".to_string()));
                    run_err = Some(anyhow::anyhow!(
                        "multihost worker panicked while advancing host {hi} \
                         (epoch {epochs}): {msg}"
                    ));
                    break;
                }
            } else {
                for h in &hosts {
                    let mut h = h.lock().unwrap();
                    advance_host_epoch(&mut h, topo, cfg, epoch_ns, shared_base, batch);
                }
            }
            if steals.load(Ordering::Relaxed) > steals_before {
                shard_rebalances += 1;
            }
            // lock every host, in host order, for the barrier phase
            // (uncontended: the workers are parked at the barrier)
            let mut guards: Vec<std::sync::MutexGuard<'_, Host>> =
                hosts.iter().map(|h| h.lock().unwrap()).collect();
            let mut all: Vec<&mut Host> = guards.iter_mut().map(|g| &mut **g).collect();

            // ---- epoch barrier (coordinator thread, host order =>
            // deterministic for any worker count)
            // 0. fault schedule: activate/expire windows in plan order,
            //    mirror the offline mask into every host's stack on a
            //    membership edge, then evacuate offline pools per host
            //    in host order through the cost-modeled migration
            //    machinery (copy traffic injects in phase 1 below)
            if let Some(f) = &mut fault {
                let changed = f.epoch_begin(epochs);
                if changed {
                    model.set_fault_overlay(f.overlay());
                }
                // advance the per-host schedules in host order; a host
                // whose own schedule moved refreshes its masks even
                // when the fabric-wide state is quiet
                for (hi, h) in all.iter_mut().enumerate() {
                    let host_changed = match &mut host_faults[hi] {
                        Some(hf) => hf.epoch_begin(epochs),
                        None => false,
                    };
                    if changed || host_changed {
                        if let Some(st) = &mut h.stack {
                            st.set_offline_pools(&f.offline);
                            // degraded = fabric-wide ∪ this host's own
                            let mut deg = f.degraded().to_vec();
                            if let Some(hf) = &host_faults[hi] {
                                for (d, &hd) in deg.iter_mut().zip(hf.degraded()) {
                                    *d |= hd;
                                }
                            }
                            st.set_degraded_pools(&deg);
                        }
                    }
                }
                if f.any_offline() {
                    let mut fo_err = None;
                    'hosts: for h in all.iter_mut() {
                        let Host { stack, tracker, failover_bytes, .. } = &mut **h;
                        let st = stack.as_mut().expect("fault runs install per-host stacks");
                        for from in 0..f.offline.len() {
                            if f.offline[from]
                                && tracker.stats.pool_bytes.get(from).copied().unwrap_or(0) > 0
                            {
                                match f.fallback_pool(from) {
                                    Ok(to) => {
                                        let moved =
                                            st.failover_pool(tracker, from, to, bytes_per_ev);
                                        *failover_bytes += moved;
                                        f.failover_migrated_bytes += moved;
                                    }
                                    Err(e) => {
                                        fo_err = Some(e);
                                        break 'hosts;
                                    }
                                }
                            }
                        }
                    }
                    if let Some(e) = fo_err {
                        run_err = Some(e.into());
                        break;
                    }
                }
            }
            // 1a. policy phase 1, per host in host order: inject the
            //     previous epoch's migration traffic and run bin
            //     shaping on the host's OWN bins, before they merge
            //     into the shared switch view
            for h in all.iter_mut() {
                let Host { stack, bins: hbins, tracker, .. } = &mut **h;
                if let Some(st) = stack {
                    st.before_analysis(hbins, tracker, bytes_per_ev);
                }
            }
            // 1b. merge per-host traffic into the shared switch view
            //     (host bins survive until after phase 2 — migration
            //     policies read them to find the dominant pool)
            for h in all.iter_mut() {
                bins.merge_from(&h.bins);
            }
            // 2. deliver coherence back-invalidations for shared writes
            for hi in 0..all.len() {
                if all[hi].shared_writes.is_empty() {
                    continue;
                }
                let writes = std::mem::take(&mut all[hi].shared_writes);
                for w in &writes {
                    for pj in 0..all.len() {
                        if pj == hi {
                            continue;
                        }
                        if all[pj].cache.coherence_invalidate(w.addr) {
                            invalidations += 1;
                            coherence_msgs += 1;
                            bins.record(w.pool, true, w.t_ns, 1.0);
                        }
                    }
                }
                // hand the (cleared) allocation back to the host
                let mut writes = writes;
                writes.clear();
                all[hi].shared_writes = writes;
            }

            // 2b. exact retry-storm / warm-up attribution over the
            //     merged shared bins (the per-pool adders are linear in
            //     the pool's read/write counts — see `crate::fault`)
            if let Some(f) = &mut fault {
                f.attribute_epoch_delays(|p| bins.read_count(p), |p| bins.write_count(p));
            }
            // 2c. host-scoped storms: their adders are NOT in the
            //     shared analyzer overlay (that would charge every
            //     host), so the extra latency is computed closed-form
            //     from the owning host's own post-phase-1 bins and
            //     charged to that host and the run total — stage-1
            //     linearity makes this exact, and peers without a
            //     scoped schedule stay byte-identical to fault-free
            for (hi, h) in all.iter_mut().enumerate() {
                if let Some(hf) = &mut host_faults[hi] {
                    let before = hf.retry_delay_ns;
                    hf.attribute_epoch_delays(
                        |p| h.bins.read_count(p),
                        |p| h.bins.write_count(p),
                    );
                    let d = hf.retry_delay_ns - before;
                    h.delay_ns += d;
                    total_delay += d;
                }
            }

            // 3. one analyzer call for everyone
            let out = match model.analyze(&TimingInputs {
                reads: &bins.reads,
                writes: &bins.writes,
                bin_width: bins.bin_width_ns() as f32,
                bytes_per_ev: topo.host.cacheline_bytes as f32,
            }) {
                Ok(out) => out,
                Err(e) => {
                    // fall through to the shutdown barrier below so the
                    // scope can join the parked workers
                    run_err = Some(e);
                    break;
                }
            };
            epochs += 1;
            total_delay += out.total;
            cong_total += out.cong_total();
            bwd_total += out.bwd_total();

            // 4. policy phase 2, per host in host order: migrations
            //    against the shared analyzer outputs; the modeled
            //    stall is charged to the migrating host AND the run
            //    total (attribution stays conservative)
            for h in all.iter_mut() {
                let Host { stack, bins: hbins, tracker, delay_ns, .. } = &mut **h;
                if let Some(st) = stack {
                    let stall = st.after_analysis(hbins, &out, tracker, bytes_per_ev);
                    *delay_ns += stall;
                    total_delay += stall;
                    mig_stall_total += stall;
                }
            }

            // 5. attribute delay to hosts by their miss share this
            //    epoch. A zero-miss epoch can still carry delay (the
            //    policy engine's injected copy traffic); split it
            //    evenly so attribution always sums to the total.
            let epoch_misses: f64 = all.iter().map(|h| h.epoch_misses).sum();
            let even_share = 1.0 / all.len().max(1) as f64;
            for h in all.iter_mut() {
                let share =
                    if epoch_misses > 0.0 { h.epoch_misses / epoch_misses } else { even_share };
                h.delay_ns += out.total * share;
                h.native_ns += h.epoch_vtime;
                h.epoch_vtime = 0.0;
                h.epoch_misses = 0.0;
                h.bins.clear();
                // age region heat one epoch after the host's policy
                // phases (no-op at heat_decay = 1.0), mirroring the
                // epoch driver's boundary decay
                h.tracker.decay_heat();
            }
            bins.clear();
            if let Some(max) = cfg.max_epochs {
                if epochs >= max {
                    break;
                }
            }
        }

        if use_pool {
            // wake the parked workers into the stop check so they exit
            // and the scope join returns
            stop.store(true, Ordering::Release);
            barrier.wait();
        }
    });
    if let Some(e) = run_err {
        return Err(e);
    }

    let worker_busy_fracs: Vec<f64> = if use_pool && phase_ns > 0 {
        busy_ns
            .iter()
            .map(|b| b.load(Ordering::Relaxed) as f64 / phase_ns as f64)
            .collect()
    } else {
        Vec::new()
    };
    let mut hosts_out = Vec::with_capacity(nhosts);
    let mut migrations_total = 0u64;
    let mut migrated_bytes_total = 0u64;
    let mut drain_bytes_total = 0u64;
    for m in hosts {
        let h = m.into_inner().unwrap();
        let (migs, moved, drained) = h
            .stack
            .as_ref()
            .map(|s| (s.migrations(), s.moved_bytes(), s.drained_bytes()))
            .unwrap_or((0, 0, 0));
        migrations_total += migs;
        migrated_bytes_total += moved;
        drain_bytes_total += drained;
        hosts_out.push(HostReport {
            workload: h.wl.name().to_string(),
            native_ns: h.native_ns,
            simulated_ns: h.native_ns + h.delay_ns,
            delay_ns: h.delay_ns,
            misses: h.misses,
            migrations: migs,
            migrated_bytes: moved,
            failover_migrated_bytes: h.failover_bytes,
            drain_migrated_bytes: drained,
        });
    }
    let (
        mut faults_injected,
        mut retry_delay_ns,
        throttled_epochs,
        pools_offline,
        failover_bytes,
        pools_reonlined,
        warmup_delay_ns,
    ) = match &fault {
        Some(f) => (
            f.faults_injected,
            f.retry_delay_ns,
            f.throttled_epochs,
            f.pools_offline,
            f.failover_migrated_bytes,
            f.pools_reonlined,
            f.warmup_delay_ns,
        ),
        None => (0, 0.0, 0, 0, 0, 0, 0.0),
    };
    // fold host-scoped schedules into the run totals (their delay is
    // already inside `total_delay` via step 2c); `throttled_epochs`
    // stays the fabric-wide count — summing per-host windows would
    // double-count epochs where several schedules overlap
    for hf in host_faults.iter_mut().flatten() {
        faults_injected += hf.faults_injected;
        retry_delay_ns += hf.retry_delay_ns;
    }
    Ok(MultiHostReport {
        hosts: hosts_out,
        epochs,
        total_delay_ns: total_delay,
        cong_delay_ns: cong_total,
        bwd_delay_ns: bwd_total,
        invalidations,
        coherence_msgs,
        migrations: migrations_total,
        migrated_bytes: migrated_bytes_total,
        mig_stall_ns: mig_stall_total,
        host_workers: if use_pool { nworkers } else { 1 },
        steals: steals.load(Ordering::Relaxed),
        shard_rebalances,
        worker_busy_fracs,
        faults_injected,
        retry_delay_ns,
        throttled_epochs,
        pools_offline,
        failover_migrated_bytes: failover_bytes,
        pools_reonlined,
        warmup_delay_ns,
        drain_migrated_bytes: drain_bytes_total,
        wall_s: wall.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;
    use crate::workload;

    fn cfg() -> SimConfig {
        SimConfig {
            scale: 0.002,
            cache_scale: 64,
            epoch_ms: 0.1,
            ..SimConfig::default()
        }
    }

    fn mk_hosts(n: usize) -> Vec<Box<dyn Workload>> {
        (0..n)
            .map(|i| workload::by_name("stream", 0.002, i as u64).unwrap())
            .collect()
    }

    #[test]
    fn single_host_runs() {
        let rep = run_shared(&builtin::fig2(), &cfg(), mk_hosts(1)).unwrap();
        assert_eq!(rep.hosts.len(), 1);
        assert!(rep.hosts[0].misses > 0);
        assert!(rep.epochs > 0);
    }

    #[test]
    fn more_hosts_more_congestion() {
        let one = run_shared(&builtin::fig2(), &cfg(), mk_hosts(1)).unwrap();
        let four = run_shared(&builtin::fig2(), &cfg(), mk_hosts(4)).unwrap();
        // per-epoch shared-switch pressure must grow with host count
        let c1 = one.cong_delay_ns / one.epochs.max(1) as f64;
        let c4 = four.cong_delay_ns / four.epochs.max(1) as f64;
        assert!(c4 > c1, "4-host congestion/epoch {c4} <= 1-host {c1}");
    }

    #[test]
    fn delay_attribution_sums() {
        let rep = run_shared(&builtin::fig2(), &cfg(), mk_hosts(3)).unwrap();
        let attributed: f64 = rep.hosts.iter().map(|h| h.delay_ns).sum();
        assert!(
            (attributed - rep.total_delay_ns).abs() < 1e-6 * rep.total_delay_ns.max(1.0),
            "attribution {attributed} != total {}",
            rep.total_delay_ns
        );
    }

    #[test]
    fn mean_slowdown_above_one_with_cxl() {
        let rep = run_shared(&builtin::fig2(), &cfg(), mk_hosts(2)).unwrap();
        assert!(rep.mean_slowdown() > 1.0);
    }

    fn mk_shared(n: usize) -> Vec<Box<dyn Workload>> {
        (0..n)
            .map(|i| workload::by_name("shared", 0.002, i as u64).unwrap())
            .collect()
    }

    #[test]
    fn shared_writes_generate_coherence_traffic() {
        let rep = run_shared(&builtin::fig2(), &cfg(), mk_shared(3)).unwrap();
        assert!(
            rep.invalidations > 0,
            "peers caching the same lines must see back-invalidations"
        );
        assert_eq!(rep.coherence_msgs, rep.invalidations);
    }

    #[test]
    fn private_workloads_have_no_coherence_traffic() {
        let rep = run_shared(&builtin::fig2(), &cfg(), mk_hosts(3)).unwrap();
        assert_eq!(rep.invalidations, 0);
    }

    #[test]
    fn coherence_invalidations_grow_with_hosts() {
        let two = run_shared(&builtin::fig2(), &cfg(), mk_shared(2)).unwrap();
        let four = run_shared(&builtin::fig2(), &cfg(), mk_shared(4)).unwrap();
        // per-epoch invalidation pressure grows with sharers
        let r2 = two.invalidations as f64 / two.epochs.max(1) as f64;
        let r4 = four.invalidations as f64 / four.epochs.max(1) as f64;
        assert!(r4 > r2, "4 sharers {r4} <= 2 sharers {r2}");
    }

    #[test]
    fn coherence_increases_miss_rate() {
        // invalidated lines must re-miss: with sharing, misses per host
        // exceed a lone host's on the same workload
        let one = run_shared(&builtin::fig2(), &cfg(), mk_shared(1)).unwrap();
        let four = run_shared(&builtin::fig2(), &cfg(), mk_shared(4)).unwrap();
        let lone = one.hosts[0].misses;
        let max_shared = four.hosts.iter().map(|h| h.misses).max().unwrap();
        assert!(
            max_shared > lone,
            "sharing must add coherence misses: {max_shared} <= {lone}"
        );
    }

    #[test]
    fn explicit_thread_counts_run() {
        for threads in [1usize, 2, 8] {
            let rep =
                run_shared_threads(&builtin::fig2(), &cfg(), mk_hosts(3), threads).unwrap();
            assert_eq!(rep.hosts.len(), 3);
            assert!(rep.epochs > 0);
        }
    }

    #[test]
    fn uneven_shards_and_excess_threads_run() {
        // 5 hosts over 3 workers leaves a short tail shard; 64 threads
        // clamps to one host per shard — the persistent pool must
        // handle both and keep hosts in order
        for threads in [3usize, 64] {
            let rep =
                run_shared_threads(&builtin::fig2(), &cfg(), mk_hosts(5), threads).unwrap();
            assert_eq!(rep.hosts.len(), 5);
            assert!(rep.epochs > 0);
            for (i, h) in rep.hosts.iter().enumerate() {
                assert_eq!(h.workload, "stream", "host {i} out of place");
                assert!(h.misses > 0);
            }
        }
    }

    #[test]
    fn per_host_policy_stacks_migrate_and_charge_stall() {
        let mut c = cfg();
        c.scale = 0.004;
        c.epoch_policy =
            Some(crate::policy::PolicySpec::parse("hotness:1").unwrap());
        c.mig_stall_ns_per_byte = 0.25;
        let rep = run_shared(&builtin::fig2(), &c, mk_hosts(3)).unwrap();
        assert!(rep.migrations > 0, "hotness:1 must migrate on a CXL-heavy run");
        assert!(rep.migrated_bytes > 0);
        assert!(rep.mig_stall_ns > 0.0);
        // stall is charged to hosts and to the run total consistently
        let attributed: f64 = rep.hosts.iter().map(|h| h.delay_ns).sum();
        assert!(
            (attributed - rep.total_delay_ns).abs() < 1e-6 * rep.total_delay_ns.max(1.0),
            "attribution {attributed} != total {} with stall",
            rep.total_delay_ns
        );
        let per_host: u64 = rep.hosts.iter().map(|h| h.migrations).sum();
        assert_eq!(per_host, rep.migrations);
    }

    #[test]
    fn policy_stacks_deterministic_across_thread_counts() {
        let mut c = cfg();
        c.scale = 0.004;
        c.epoch_policy =
            Some(crate::policy::PolicySpec::parse("hotness:1,prefetch:0.5").unwrap());
        let run = |threads| run_shared_threads(&builtin::fig2(), &c, mk_hosts(4), threads).unwrap();
        let one = run(1);
        assert!(one.migrations > 0);
        for threads in [2usize, 4] {
            let many = run(threads);
            assert_eq!(one.migrations, many.migrations, "{threads} threads");
            assert_eq!(one.migrated_bytes, many.migrated_bytes);
            assert_eq!(one.mig_stall_ns, many.mig_stall_ns);
            assert_eq!(one.total_delay_ns, many.total_delay_ns);
            for (a, b) in one.hosts.iter().zip(&many.hosts) {
                assert_eq!(a.delay_ns, b.delay_ns);
                assert_eq!(a.migrations, b.migrations);
            }
        }
    }

    #[test]
    fn inline_run_reports_no_stealing() {
        let rep = run_shared_threads(&builtin::fig2(), &cfg(), mk_hosts(3), 1).unwrap();
        assert_eq!(rep.host_workers, 1);
        assert_eq!(rep.steals, 0, "inline runs have nothing to steal from");
        assert_eq!(rep.shard_rebalances, 0);
        assert!(rep.worker_busy_fracs.is_empty());
    }

    #[test]
    fn pooled_run_reports_worker_accounting() {
        let rep = run_shared_threads(&builtin::fig2(), &cfg(), mk_hosts(4), 2).unwrap();
        assert_eq!(rep.host_workers, 2);
        assert_eq!(rep.worker_busy_fracs.len(), 2);
        // every worker's busy time is measured strictly inside the
        // coordinator's host-phase window, so fractions are in [0, 1]
        // (small slack for clock-read jitter)
        for f in &rep.worker_busy_fracs {
            assert!((0.0..=1.01).contains(f), "busy fraction {f} out of range");
        }
        assert!(rep.shard_rebalances <= rep.epochs);
        assert!(rep.steals <= rep.epochs * rep.hosts.len() as u64);
    }

    #[test]
    fn to_json_mirrors_single_host_report_keys() {
        let rep = run_shared_threads(&builtin::fig2(), &cfg(), mk_hosts(2), 1).unwrap();
        let j = rep.to_json();
        assert_eq!(j.get("hosts").unwrap().as_arr().unwrap().len(), 2);
        // `delay_ms` aliases `total_delay_ms` so cross-driver sweep
        // invariants can use one metric name
        assert_eq!(
            j.get("delay_ms").unwrap().as_f64(),
            j.get("total_delay_ms").unwrap().as_f64()
        );
        assert!(j.get("mean_slowdown").unwrap().as_f64().unwrap() > 1.0);
        let h0 = j.get("hosts").unwrap().idx(0).unwrap();
        assert!(h0.get("misses").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn zero_hosts_is_an_empty_run() {
        let rep = run_shared_threads(&builtin::fig2(), &cfg(), Vec::new(), 4).unwrap();
        assert!(rep.hosts.is_empty());
        assert_eq!(rep.epochs, 0);
        assert_eq!(rep.total_delay_ns, 0.0);
    }
}

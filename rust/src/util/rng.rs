//! Deterministic PRNG + distributions for the workload engine.
//!
//! No external `rand` crate is available offline, so this implements
//! xoshiro256** (Blackman/Vigna) seeded via SplitMix64 — the standard
//! combination — plus the distributions the workloads need (uniform,
//! zipfian, poisson, exponential). Everything is reproducible from a
//! single u64 seed, which the CLI exposes as `--seed`.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Lemire's multiply-shift rejection-free bound.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponential with the given mean.
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Poisson via inversion for small lambda, normal approx for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numeric guard
                }
            }
        } else {
            // normal approximation with continuity correction
            let g = self.gaussian();
            let v = lambda + lambda.sqrt() * g + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Standard normal via Box–Muller (one value; wastes the pair — fine).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipfian sampler over [0, n) with parameter `theta` (YCSB-style).
/// Precomputes the harmonic normalizer; sampling is O(1) using the
/// Gray/Jim-Gray "quick zipf" approximation.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "zipf over empty domain");
        assert!(theta > 0.0 && theta < 1.0, "theta must be in (0,1)");
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta, zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // exact for small n, Euler–Maclaurin tail for large n
        if n <= 10_000 {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=10_000u64).map(|i| 1.0 / (i as f64).powf(theta)).sum();
            let a = 10_000f64;
            let b = n as f64;
            head + (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta)
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        v.min(self.n - 1)
    }

    pub fn domain(&self) -> u64 {
        self.n
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential(5.0)).sum();
        assert!((s / n as f64 - 5.0).abs() < 0.2);
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let s: u64 = (0..n).map(|_| r.poisson(3.0)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(19);
        let n = 20_000;
        let s: u64 = (0..n).map(|_| r.poisson(200.0)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 200.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_skews_to_head() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(29);
        let n = 100_000;
        let mut head = 0u64;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // with theta=.99 the top-1% gets far more than 1% of traffic
        assert!(head as f64 / n as f64 > 0.3, "head={head}");
    }

    #[test]
    fn zipf_in_domain() {
        let z = Zipf::new(50, 0.7);
        let mut r = Rng::new(31);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 50);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}

//! Minimal TOML-subset parser for topology / simulation configs.
//!
//! Supports the subset the repo's configs use: top-level keys, `[table]`
//! headers, `[[array-of-tables]]` headers, string / float / integer /
//! boolean values, inline arrays of scalars, and `#` comments. Dotted
//! keys and inline tables are intentionally out of scope.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

pub type Table = BTreeMap<String, TomlValue>;

/// A parsed document: scalar tables by path plus array-of-tables.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// `""` holds top-level keys; `"host"` holds `[host]`, etc.
    pub tables: BTreeMap<String, Table>,
    /// `[[node]]` entries, in file order, keyed by header name.
    pub arrays: BTreeMap<String, Vec<Table>>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        doc.tables.insert(String::new(), Table::new());
        // current insertion point: either a named table or the last
        // element of an array-of-tables.
        enum Cur {
            Table(String),
            Array(String),
        }
        let mut cur = Cur::Table(String::new());

        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.arrays.entry(name.clone()).or_default().push(Table::new());
                cur = Cur::Array(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default();
                cur = Cur::Table(name);
            } else if let Some(eq) = find_eq(&line) {
                let key = line[..eq].trim().to_string();
                if key.is_empty() {
                    return Err(format!("line {}: empty key", lineno + 1));
                }
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
                let tbl = match &cur {
                    Cur::Table(name) => doc.tables.get_mut(name).unwrap(),
                    Cur::Array(name) => doc.arrays.get_mut(name).unwrap().last_mut().unwrap(),
                };
                tbl.insert(key, val);
            } else {
                return Err(format!("line {}: cannot parse `{}`", lineno + 1, line));
            }
        }
        Ok(doc)
    }

    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the `=` separating key from value (not inside a string).
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> =
            split_top_level(inner).iter().map(|x| parse_value(x)).collect();
        return Ok(TomlValue::Arr(items?));
    }
    // numbers, allowing underscores per TOML
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value `{s}`"))
}

/// Split an inline-array body at top-level commas (no nested arrays of
/// arrays in our configs, but strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Typed accessors with contextual error messages.
pub fn req_str(t: &Table, key: &str, ctx: &str) -> Result<String, String> {
    t.get(key)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| format!("{ctx}: missing string key `{key}`"))
}

pub fn req_f64(t: &Table, key: &str, ctx: &str) -> Result<f64, String> {
    t.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("{ctx}: missing numeric key `{key}`"))
}

pub fn opt_f64(t: &Table, key: &str, default: f64) -> f64 {
    t.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
}

pub fn opt_str(t: &Table, key: &str, default: &str) -> String {
    t.get(key)
        .and_then(|v| v.as_str())
        .unwrap_or(default)
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
title = "demo"
count = 42
ratio = 0.5

[host]
local_latency_ns = 88.9  # trailing comment
name = "i9-12900k # not a comment"

[[node]]
name = "rc0"
kind = "root"

[[node]]
name = "sw0"
parent = "rc0"
ports = [1, 2, 3]
"#;

    #[test]
    fn parses_top_level() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        let top = d.table("").unwrap();
        assert_eq!(top["title"].as_str(), Some("demo"));
        assert_eq!(top["count"].as_f64(), Some(42.0));
        assert_eq!(top["ratio"].as_f64(), Some(0.5));
    }

    #[test]
    fn parses_named_table() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        let host = d.table("host").unwrap();
        assert_eq!(host["local_latency_ns"].as_f64(), Some(88.9));
        assert_eq!(host["name"].as_str(), Some("i9-12900k # not a comment"));
    }

    #[test]
    fn parses_array_of_tables() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        let nodes = d.array("node");
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0]["name"].as_str(), Some("rc0"));
        assert_eq!(nodes[1]["parent"].as_str(), Some("rc0"));
        assert_eq!(
            nodes[1]["ports"],
            TomlValue::Arr(vec![
                TomlValue::Num(1.0),
                TomlValue::Num(2.0),
                TomlValue::Num(3.0)
            ])
        );
    }

    #[test]
    fn underscored_numbers() {
        let d = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(d.table("").unwrap()["big"].as_f64(), Some(1e6));
    }

    #[test]
    fn bools() {
        let d = TomlDoc::parse("a = true\nb = false").unwrap();
        assert_eq!(d.table("").unwrap()["a"].as_bool(), Some(true));
        assert_eq!(d.table("").unwrap()["b"].as_bool(), Some(false));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("this is not toml").is_err());
        assert!(TomlDoc::parse("x =").is_err());
    }

    #[test]
    fn empty_array() {
        let d = TomlDoc::parse("xs = []").unwrap();
        assert_eq!(d.table("").unwrap()["xs"], TomlValue::Arr(vec![]));
    }
}

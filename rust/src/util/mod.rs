//! Dependency-free utility layer: PRNG, JSON, TOML-subset, CLI args,
//! and a small benchmarking harness. These exist because offline builds
//! only have the `xla` crate's dependency closure available.

pub mod benchutil;
pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;

//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments. Subcommand dispatch lives in `main.rs`; this
//! module only tokenizes and provides typed accessors with defaults.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse a raw arg list (without the program / subcommand name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.u64(key, default as u64) as usize
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--epoch-ms", "5", "--topo=fig2", "run"]);
        assert_eq!(a.str("epoch-ms", ""), "5");
        assert_eq!(a.str("topo", ""), "fig2");
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--verbose", "--native"]);
        assert!(a.bool("verbose"));
        assert!(a.bool("native"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--dry-run", "--seed", "7"]);
        assert!(a.bool("dry-run"));
        assert_eq!(a.u64("seed", 0), 7);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[]);
        assert_eq!(a.f64("x", 2.5), 2.5);
        assert_eq!(a.usize("n", 3), 3);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--bias=-3.5"]);
        assert_eq!(a.f64("bias", 0.0), -3.5);
    }
}

//! Minimal JSON parser + writer.
//!
//! Offline builds only have the `xla` crate closure available, so the
//! artifacts manifest, golden vectors, and machine-readable reports go
//! through this ~300-line implementation instead of serde_json. It
//! supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bool, null) which is all the repo needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Flatten a JSON array of numbers into f32s (golden vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        match self {
            Json::Arr(v) => v.iter().map(|x| x.as_f64().map(|f| f as f32)).collect(),
            Json::Num(n) => Some(vec![*n as f32]),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes at once
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected :"));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Convenience builder for object literals in report code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(value: &str) -> Json {
    Json::Str(value.to_string())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\n")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_float_forms() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5e-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap().as_str(),
            Some("é")
        );
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.0, 2.5, 3.0]));
    }

    #[test]
    fn big_array() {
        let src = format!("[{}]", (0..10_000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 10_000);
    }
}

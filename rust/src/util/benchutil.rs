//! Small benchmarking harness (criterion is unavailable offline).
//!
//! Each bench binary (`rust/benches/*.rs`, `harness = false`) uses this
//! to time closures with warmup, report mean / median / p95 / stddev,
//! and print both a human-readable markdown table (what the paper's
//! tables look like) and machine-readable JSON lines for EXPERIMENTS.md.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(name: &str, mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            median_s: xs[n / 2],
            p95_s: xs[((n as f64 * 0.95) as usize).min(n - 1)],
            stddev_s: var.sqrt(),
            min_s: xs[0],
            max_s: xs[n - 1],
        }
    }
}

/// Time `f` for `iters` measured runs after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(name, samples)
}

/// Time a single long run (Table-1 style wall-clock measurements).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Render a markdown table; `rows` are already-formatted cells.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            s.push_str(&format!(" {:<w$} |", c, w = w));
        }
        s.push('\n');
        s
    };
    out.push_str(&line(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&line(
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
        &widths,
    ));
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples("x", vec![1.0; 10]);
        assert_eq!(s.mean_s, 1.0);
        assert_eq!(s.median_s, 1.0);
        assert_eq!(s.stddev_s, 0.0);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 1.0);
    }

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples("x", (1..=100).map(|i| i as f64).collect());
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s && s.p95_s <= s.max_s);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0usize;
        let s = bench("count", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("| a"));
    }
}

//! Pipelined epoch execution: overlap the workload pump with analysis.
//!
//! Serial drivers alternate two phases on one thread — pump events
//! until the epoch boundary, then run the timing model over the frozen
//! bins — so end-to-end wall-clock is pump + analyze. PR 7 already
//! overlapped the *replay* side (`trace::stream`'s decode-ahead
//! thread); this module lifts the same bounded-rendezvous pattern into
//! the generation side. A dedicated analysis worker ("cxlms-analyze")
//! owns a `Send` native timing model; the pump snapshots each epoch's
//! `[P, B]` histograms into one of two recycled buffers and hands it
//! across a `sync_channel` rendezvous (never a race), then immediately
//! resumes pumping epoch N+1 while the worker analyzes epoch N. The
//! drained buffer rides the reply back, so steady state allocates
//! nothing and exactly two histogram buffers circulate — the
//! "double-buffered bins" in `--pipeline`'s one-line description.
//!
//! ## The handoff contract (what runs where)
//!
//! The split is pump-side vs. pure-side. Everything that mutates pump
//! state stays on the pump thread, at the epoch boundary, in exact
//! serial order: the fault barrier (schedule + failover sweep), policy
//! phase-1 (bin shaping + migration-traffic injection on the live
//! bins), storm attribution, phase-2 (`after_analysis`), and the
//! report push. Only the analyzer call itself moves to the worker —
//! a pure function of the snapshotted histograms, the shared read-only
//! topology tensors, and the fault overlay, which rides *in-band* with
//! the request so the worker never reads pump-side fault state.
//!
//! ## Bit-identity, and when the pipeline runs lock-step
//!
//! Reports must be bit-identical to serial runs. Two cases:
//!
//! * **No policy stack, or an empty one** (including the empty stack
//!   fault runs auto-install): phase-2 consumes the epoch's parked
//!   stall but touches neither tracker nor bins, so deferring it by
//!   one epoch is invisible — analyzer outputs are deterministic
//!   functions of the request, `push_epoch` runs in FIFO order, and
//!   the pump-side counters it interleaves with are disjoint fields.
//!   The pipeline keeps one epoch in flight (`pipeline_depth = 1`).
//! * **A stack with members**: phase-2 migrates regions, which changes
//!   `pool_of` for *subsequent pumped events* — running it even one
//!   epoch late would route different misses and break bit-identity
//!   (the batched driver tolerates that lateness only because its
//!   serial baseline has the same lateness). So the pipeline detects
//!   this (`PolicyStack::is_empty`) and drains the rendezvous in lock
//!   step: send, then immediately receive, putting phase-2 in its
//!   exact serial position. Same code path, no overlap
//!   (`pipeline_depth = 0`, `overlap_frac ≈ 0`) — bit-identity beats
//!   throughput when the two conflict. Overlap therefore benefits the
//!   common characterization paths: policy-free runs, fault runs, and
//!   trace replay.
//!
//! Like `BatchedFlush`'s early flush, the pipeline drains on every
//! fault-overlay revision edge before the first request under the new
//! overlay is sent, so one in-flight analysis never spans two overlays
//! (the in-band overlay would keep results correct regardless; the
//! drain keeps the invariant structural rather than incidental).
//!
//! Per-epoch stall/injected bookkeeping is parked with each in-flight
//! epoch and restored before its phase-2, exactly like `BatchedFlush`
//! parks them across a group — including the fault barrier's failover
//! stall, which accrues at boundary N+1 but belongs to epoch N+1, not
//! to the in-flight epoch N drained at that boundary.
//!
//! ## Observability
//!
//! The worker times each analyze call; the pump times its blocking
//! `recv`s. `SimReport` gets `pipeline_depth`, `pump_busy_ns`
//! (pipeline wall minus rendezvous waits), `analyze_busy_ns`, and
//! `overlap_frac` = 1 − wait/analyze — the fraction of analysis hidden
//! behind the pump (→ 1.0 when the pump is the bottleneck, → 0.0 when
//! the run is lock-step or analysis-bound with an idle pump). These
//! observe wall-clock and are excluded from bit-identity comparisons,
//! like `wall_s`. The gated `pipeline_overlap` hotpath bench proves
//! wall-clock approaches max(pump, analyze) instead of their sum.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::alloctrack::AllocTracker;
use crate::fault::{FaultOverlay, FaultState};
use crate::policy::PolicyStack;
use crate::runtime::{BatchOutputs, BatchTimingModel, TimingInputs, TimingModel, TimingOutputs};
use crate::trace::binning::EpochBins;

use super::driver::{fault_epoch_barrier, EpochFlush, PendingEpoch};
use super::report::SimReport;

/// Epochs (sequential) or groups (batched) the pump may run ahead of
/// the analysis worker. Depth 1 is the double-buffer point: the pump
/// fills one histogram while the worker drains the other, which
/// already achieves max(pump, analyze) — deeper queues add latency and
/// buffers without adding overlap (same argument as
/// `trace::stream::DECODE_AHEAD_DEPTH`).
pub const PIPELINE_DEPTH: usize = 1;

/// One epoch's snapshot crossing the rendezvous to the worker. The
/// buffers come back in the reply and are recycled.
struct AnalyzeReq {
    reads: Vec<f32>,
    writes: Vec<f32>,
    /// Install `overlay` before analyzing. Sent only on overlay
    /// revision edges (overlays are piecewise-constant between edges,
    /// and the pipeline drains on every edge).
    set_overlay: bool,
    overlay: Option<FaultOverlay>,
}

struct AnalyzeRes {
    out: TimingOutputs,
    reads: Vec<f32>,
    writes: Vec<f32>,
    analyze_ns: u64,
}

type AnalyzeReply = Result<AnalyzeRes, String>;

/// Pump-side bookkeeping for the epoch whose analysis is in flight.
struct InFlight {
    native_ns: f64,
    events: u64,
    /// Parked phase-1 state, restored before this epoch's phase-2
    /// (see `PendingEpoch` — same contract, depth 1 instead of E).
    injected: Vec<f64>,
    stall_ns: f64,
}

fn spawn_analyze_worker(
    mut model: Box<dyn TimingModel + Send>,
    bin_width: f32,
    bytes_per_ev: f32,
) -> std::io::Result<(SyncSender<AnalyzeReq>, Receiver<AnalyzeReply>, JoinHandle<()>)> {
    let (req_tx, req_rx) = sync_channel::<AnalyzeReq>(PIPELINE_DEPTH);
    let (res_tx, res_rx) = sync_channel::<AnalyzeReply>(PIPELINE_DEPTH);
    let handle = std::thread::Builder::new().name("cxlms-analyze".into()).spawn(move || {
        while let Ok(req) = req_rx.recv() {
            let AnalyzeReq { reads, writes, set_overlay, overlay } = req;
            if set_overlay {
                model.set_fault_overlay(overlay.as_ref());
            }
            let t0 = Instant::now();
            let out = model.analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width,
                bytes_per_ev,
            });
            let analyze_ns = t0.elapsed().as_nanos() as u64;
            let reply = match out {
                Ok(out) => Ok(AnalyzeRes { out, reads, writes, analyze_ns }),
                Err(e) => Err(format!("{e:#}")),
            };
            if res_tx.send(reply).is_err() {
                return; // pump gone (dropped mid-run); nothing to report to
            }
        }
    })?;
    Ok((req_tx, res_rx, handle))
}

/// Pipelined per-epoch analyze strategy: `PerEpochAnalyze` with the
/// analyzer call on a dedicated worker behind a depth-1 rendezvous.
/// See the module docs for the handoff contract and the lock-step
/// rule.
pub struct PipelinedAnalyze<'p> {
    req_tx: Option<SyncSender<AnalyzeReq>>,
    res_rx: Option<Receiver<AnalyzeReply>>,
    handle: Option<JoinHandle<()>>,
    pub stack: Option<&'p mut PolicyStack>,
    /// Fault schedule; drivers guarantee a stack is installed whenever
    /// this is set (failover needs the migration machinery).
    pub fault: Option<&'p mut FaultState>,
    bytes_per_ev: f32,
    keep_epoch_records: bool,
    /// Epoch counter for the fault schedule (0-based).
    epoch: u64,
    /// Send the current overlay with the next request (armed at start
    /// and on every revision edge).
    overlay_dirty: bool,
    in_flight: Option<InFlight>,
    /// The second buffer of the double buffer (the first is in flight
    /// or inside the reply channel).
    spare_buf: Option<(Vec<f32>, Vec<f32>)>,
    spare_meta: Option<InFlight>,
    /// Scratch bins handed to phase-2 when a drain runs deferred
    /// (allocated once, on demand; `None` until a stack needs it).
    policy_bins: Option<EpochBins>,
    pools: usize,
    nbins: usize,
    epoch_ns: f64,
    started: Option<Instant>,
    wait_ns: u64,
    analyze_busy_ns: u64,
}

impl<'p> PipelinedAnalyze<'p> {
    pub fn new(
        model: Box<dyn TimingModel + Send>,
        bytes_per_ev: f32,
        keep_epoch_records: bool,
        bin_width: f32,
        nbins: usize,
        epoch_ns: f64,
    ) -> anyhow::Result<PipelinedAnalyze<'p>> {
        let pools = model.pools();
        let (req_tx, res_rx, handle) = spawn_analyze_worker(model, bin_width, bytes_per_ev)?;
        Ok(PipelinedAnalyze {
            req_tx: Some(req_tx),
            res_rx: Some(res_rx),
            handle: Some(handle),
            stack: None,
            fault: None,
            bytes_per_ev,
            keep_epoch_records,
            epoch: 0,
            overlay_dirty: true,
            in_flight: None,
            spare_buf: None,
            spare_meta: None,
            policy_bins: None,
            pools,
            nbins,
            epoch_ns,
            started: None,
            wait_ns: 0,
            analyze_busy_ns: 0,
        })
    }

    /// Whether the rendezvous must drain immediately after every send:
    /// a stack with members runs phase-2 migrations that feed back
    /// into event routing, so phase-2 must hold its exact serial
    /// position (module docs).
    fn lock_step(&self) -> bool {
        self.stack.as_ref().is_some_and(|s| !s.is_empty())
    }

    fn send(&mut self, req: AnalyzeReq) -> anyhow::Result<()> {
        self.req_tx
            .as_ref()
            .expect("pipeline request channel alive until drop")
            .send(req)
            .map_err(|_| anyhow::anyhow!("pipelined analysis worker exited unexpectedly"))
    }

    /// Receive the in-flight epoch's outputs and run its pump-side
    /// tail: restore parked phase-1 state, phase-2, report push.
    fn drain_one(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        let Some(meta) = self.in_flight.take() else {
            return Ok(());
        };
        let t0 = Instant::now();
        let reply = self
            .res_rx
            .as_ref()
            .expect("pipeline reply channel alive until drop")
            .recv()
            .map_err(|_| anyhow::anyhow!("pipelined analysis worker exited unexpectedly"))?;
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        let res = reply.map_err(|e| anyhow::anyhow!("pipelined analyze failed: {e}"))?;
        self.analyze_busy_ns += res.analyze_ns;
        let mig_ns = if let Some(stack) = &mut self.stack {
            // rebuild this epoch's bins view for the phase-2 hooks
            // (the live bins already hold the next epoch)
            let bins = self
                .policy_bins
                .get_or_insert_with(|| EpochBins::new(self.pools, self.nbins, self.epoch_ns));
            bins.reads.copy_from_slice(&res.reads);
            bins.writes.copy_from_slice(&res.writes);
            bins.total_events = meta.events;
            stack.set_injected_events(&meta.injected);
            stack.credit_accrued_stall_ns(meta.stall_ns);
            stack.after_analysis(bins, &res.out, tracker, self.bytes_per_ev)
        } else {
            0.0
        };
        report.push_epoch(meta.native_ns, &res.out, mig_ns, meta.events, self.keep_epoch_records);
        self.spare_buf = Some((res.reads, res.writes));
        self.spare_meta = Some(meta);
        Ok(())
    }
}

impl EpochFlush for PipelinedAnalyze<'_> {
    fn on_epoch(
        &mut self,
        bins: &mut EpochBins,
        native_ns: f64,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let mut barrier_stall = 0.0;
        if self.fault.is_some() {
            let changed = {
                let fault = self.fault.as_mut().unwrap();
                if let Some(stack) = &mut self.stack {
                    fault_epoch_barrier(fault, stack, tracker, self.epoch, self.bytes_per_ev)?
                } else {
                    fault.epoch_begin(self.epoch)
                }
            };
            // the barrier's failover stall belongs to THIS epoch: park
            // it across the drain below, or the in-flight epoch's
            // phase-2 would take it (same placement rule as
            // `BatchedFlush`'s early flush)
            barrier_stall = match &mut self.stack {
                Some(stack) => stack.take_accrued_stall_ns(),
                None => 0.0,
            };
            if changed {
                // overlay edge: land the in-flight epoch under the
                // overlay it was sent with before anything runs under
                // the new one
                self.drain_one(tracker, report)?;
                self.overlay_dirty = true;
            }
        }
        // phase 1 runs on the live bins, pump-side, in serial order
        if let Some(stack) = &mut self.stack {
            stack.credit_accrued_stall_ns(barrier_stall);
            stack.before_analysis(bins, tracker, self.bytes_per_ev);
        }
        if let Some(fault) = &mut self.fault {
            // storm / warm-up attribution at boundary time on the live
            // post-injection bins — identical to the serial driver
            fault.attribute_epoch_delays(|p| bins.read_count(p), |p| bins.write_count(p));
        }
        let (mut reads, mut writes) = self.spare_buf.take().unwrap_or_default();
        reads.clear();
        reads.extend_from_slice(&bins.reads);
        writes.clear();
        writes.extend_from_slice(&bins.writes);
        let mut meta = self.spare_meta.take().unwrap_or_else(|| InFlight {
            native_ns: 0.0,
            events: 0,
            injected: Vec::new(),
            stall_ns: 0.0,
        });
        meta.native_ns = native_ns;
        meta.events = bins.total_events;
        meta.injected.clear();
        meta.stall_ns = 0.0;
        if let Some(stack) = &mut self.stack {
            meta.injected.extend_from_slice(stack.injected_events());
            meta.stall_ns = stack.take_accrued_stall_ns();
        }
        let (set_overlay, overlay) = if self.fault.is_some() && self.overlay_dirty {
            self.overlay_dirty = false;
            (true, self.fault.as_ref().unwrap().overlay().cloned())
        } else {
            (false, None)
        };
        // depth-1 rendezvous: the previous epoch must land before this
        // one is handed over
        self.drain_one(tracker, report)?;
        self.send(AnalyzeReq { reads, writes, set_overlay, overlay })?;
        self.in_flight = Some(meta);
        if self.lock_step() {
            self.drain_one(tracker, report)?;
        }
        self.epoch += 1;
        Ok(())
    }

    fn finish(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        self.drain_one(tracker, report)?;
        let wall_ns = self.started.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
        report.pipeline_depth = if self.lock_step() { 0 } else { PIPELINE_DEPTH as u64 };
        report.analyze_busy_ns = self.analyze_busy_ns as f64;
        report.pump_busy_ns = wall_ns.saturating_sub(self.wait_ns) as f64;
        report.overlap_frac = if self.analyze_busy_ns > 0 {
            (1.0 - self.wait_ns as f64 / self.analyze_busy_ns as f64).clamp(0.0, 1.0)
        } else {
            0.0
        };
        Ok(())
    }
}

impl Drop for PipelinedAnalyze<'_> {
    fn drop(&mut self) {
        // closing the request channel ends the worker loop; dropping
        // the reply receiver unblocks a worker mid-send after an
        // abandoned run. Then join — same shutdown order as
        // `trace::stream`.
        drop(self.req_tx.take());
        drop(self.res_rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One group's `[E, P, B]` scratch crossing the rendezvous.
struct BatchReq {
    reads: Vec<f32>,
    writes: Vec<f32>,
    set_overlay: bool,
    overlay: Option<FaultOverlay>,
}

struct BatchRes {
    out: BatchOutputs,
    reads: Vec<f32>,
    writes: Vec<f32>,
    analyze_ns: u64,
}

type BatchReply = Result<BatchRes, String>;

fn spawn_batch_worker(
    mut model: Box<dyn BatchTimingModel + Send>,
    bin_width: f32,
    bytes_per_ev: f32,
) -> std::io::Result<(SyncSender<BatchReq>, Receiver<BatchReply>, JoinHandle<()>)> {
    let (req_tx, req_rx) = sync_channel::<BatchReq>(PIPELINE_DEPTH);
    let (res_tx, res_rx) = sync_channel::<BatchReply>(PIPELINE_DEPTH);
    let handle = std::thread::Builder::new().name("cxlms-analyze".into()).spawn(move || {
        while let Ok(req) = req_rx.recv() {
            let BatchReq { reads, writes, set_overlay, overlay } = req;
            if set_overlay {
                model.set_fault_overlay(overlay.as_ref());
            }
            let t0 = Instant::now();
            let out = model.analyze_batch(&reads, &writes, bin_width, bytes_per_ev);
            let analyze_ns = t0.elapsed().as_nanos() as u64;
            let reply = match out {
                Ok(out) => Ok(BatchRes { out, reads, writes, analyze_ns }),
                Err(e) => Err(format!("{e:#}")),
            };
            if res_tx.send(reply).is_err() {
                return;
            }
        }
    })?;
    Ok((req_tx, res_rx, handle))
}

/// Pipelined grouped-analyze strategy: `BatchedFlush` with the
/// `analyze_batch` call on the worker behind a depth-1 rendezvous, so
/// the pump fills group G+1 while the worker analyzes group G (the
/// worker still shards its E-epoch loop across `--analyzer-threads`).
/// Phase-2 lateness with a live stack stays the serial batched
/// driver's documented ≤ group−1 bound, because a stack with members
/// forces lock-step draining exactly as in [`PipelinedAnalyze`] — the
/// overlap case is the empty/no-stack one, where phase-2 defers
/// harmlessly. The revision-edge early flush (one group = one overlay)
/// carries over unchanged, with the in-flight group drained on the
/// edge as well.
pub struct PipelinedBatchFlush<'p> {
    req_tx: Option<SyncSender<BatchReq>>,
    res_rx: Option<Receiver<BatchReply>>,
    handle: Option<JoinHandle<()>>,
    pub stack: Option<&'p mut PolicyStack>,
    /// Fault schedule; drivers guarantee a stack is installed whenever
    /// this is set.
    pub fault: Option<&'p mut FaultState>,
    bytes_per_ev: f32,
    keep_epoch_records: bool,
    /// Epoch counter for the fault schedule (0-based).
    epoch: u64,
    /// Snapshot of the overlay the *pending* group's epochs ran under
    /// (see `BatchedFlush::group_overlay`).
    group_overlay: Option<FaultOverlay>,
    overlay_dirty: bool,
    pending: Vec<PendingEpoch>,
    /// Recycled `PendingEpoch`s (see `BatchedFlush::spare`).
    spare: Vec<PendingEpoch>,
    /// Metadata of the group whose analysis is in flight (empty =
    /// nothing in flight).
    in_flight: Vec<PendingEpoch>,
    /// The second `[E, P, B]` scratch pair of the double buffer.
    spare_scratch: Option<(Vec<f32>, Vec<f32>)>,
    policy_bins: Option<EpochBins>,
    // model shapes, captured before the model moved to the worker
    batch: usize,
    pools: usize,
    switches: usize,
    nbins: usize,
    epoch_ns: f64,
    started: Option<Instant>,
    wait_ns: u64,
    analyze_busy_ns: u64,
}

impl<'p> PipelinedBatchFlush<'p> {
    pub fn new(
        model: Box<dyn BatchTimingModel + Send>,
        bytes_per_ev: f32,
        keep_epoch_records: bool,
        bin_width: f32,
        epoch_ns: f64,
    ) -> anyhow::Result<PipelinedBatchFlush<'p>> {
        let (batch, pools, switches, nbins) =
            (model.batch(), model.pools(), model.switches(), model.nbins());
        let (req_tx, res_rx, handle) = spawn_batch_worker(model, bin_width, bytes_per_ev)?;
        Ok(PipelinedBatchFlush {
            req_tx: Some(req_tx),
            res_rx: Some(res_rx),
            handle: Some(handle),
            stack: None,
            fault: None,
            bytes_per_ev,
            keep_epoch_records,
            epoch: 0,
            group_overlay: None,
            overlay_dirty: true,
            pending: Vec::with_capacity(batch),
            spare: Vec::with_capacity(batch),
            in_flight: Vec::with_capacity(batch),
            spare_scratch: None,
            policy_bins: None,
            batch,
            pools,
            switches,
            nbins,
            epoch_ns,
            started: None,
            wait_ns: 0,
            analyze_busy_ns: 0,
        })
    }

    fn lock_step(&self) -> bool {
        self.stack.as_ref().is_some_and(|s| !s.is_empty())
    }

    fn send(&mut self, req: BatchReq) -> anyhow::Result<()> {
        self.req_tx
            .as_ref()
            .expect("pipeline request channel alive until drop")
            .send(req)
            .map_err(|_| anyhow::anyhow!("pipelined analysis worker exited unexpectedly"))
    }

    /// Receive the in-flight group's outputs and run each epoch's
    /// pump-side tail (phase-2 + report push, in epoch order).
    fn drain_group(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.in_flight.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let reply = self
            .res_rx
            .as_ref()
            .expect("pipeline reply channel alive until drop")
            .recv()
            .map_err(|_| anyhow::anyhow!("pipelined analysis worker exited unexpectedly"))?;
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        let res = reply.map_err(|e| anyhow::anyhow!("pipelined analyze failed: {e}"))?;
        self.analyze_busy_ns += res.analyze_ns;
        let (p, s) = (self.pools, self.switches);
        let filled = self.in_flight.len();
        for i in 0..filled {
            let one = res.out.epoch(i, p, s);
            let ep = &self.in_flight[i];
            let mig_ns = if let Some(stack) = &mut self.stack {
                let bins = self
                    .policy_bins
                    .get_or_insert_with(|| EpochBins::new(p, self.nbins, self.epoch_ns));
                bins.reads.copy_from_slice(&ep.reads);
                bins.writes.copy_from_slice(&ep.writes);
                bins.total_events = ep.events;
                stack.set_injected_events(&ep.injected);
                stack.credit_accrued_stall_ns(ep.phase1_stall_ns);
                stack.after_analysis(bins, &one, tracker, self.bytes_per_ev)
            } else {
                0.0
            };
            report.push_epoch(ep.native_ns, &one, mig_ns, ep.events, self.keep_epoch_records);
        }
        self.spare.append(&mut self.in_flight);
        self.spare_scratch = Some((res.reads, res.writes));
        Ok(())
    }

    /// Pack the pending group into scratch and hand it to the worker
    /// (draining the previous group first — the rendezvous is depth
    /// 1).
    fn flush_group(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        self.drain_group(tracker, report)?;
        let (e, p, b) = (self.batch, self.pools, self.nbins);
        let (mut reads, mut writes) = self.spare_scratch.take().unwrap_or_default();
        reads.clear();
        reads.resize(e * p * b, 0.0);
        writes.clear();
        writes.resize(e * p * b, 0.0);
        for (i, ep) in self.pending.iter().enumerate() {
            reads[i * p * b..i * p * b + ep.reads.len()].copy_from_slice(&ep.reads);
            writes[i * p * b..i * p * b + ep.writes.len()].copy_from_slice(&ep.writes);
        }
        let (set_overlay, overlay) = if self.fault.is_some() && self.overlay_dirty {
            self.overlay_dirty = false;
            (true, self.group_overlay.clone())
        } else {
            (false, None)
        };
        self.send(BatchReq { reads, writes, set_overlay, overlay })?;
        // `in_flight` is empty after the drain above; swap keeps both
        // Vecs' capacity alive
        std::mem::swap(&mut self.pending, &mut self.in_flight);
        if self.lock_step() {
            self.drain_group(tracker, report)?;
        }
        Ok(())
    }
}

impl EpochFlush for PipelinedBatchFlush<'_> {
    fn on_epoch(
        &mut self,
        bins: &mut EpochBins,
        native_ns: f64,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        if self.fault.is_some() {
            // Inline barrier, ordered like `BatchedFlush`: advance the
            // schedule, land everything parked or in flight under the
            // OLD masks/overlay, and only then mirror the new masks to
            // the stack and run the failover sweep — so a parked
            // group's phase-2 sees the pool state its epochs actually
            // ran under, and the failover stall accrues after the
            // flush and parks with THIS epoch's phase-1 stall.
            let changed = self.fault.as_mut().unwrap().epoch_begin(self.epoch);
            if changed {
                // overlay edge: everything parked or in flight ran
                // under the old overlay — land all of it first
                if self.pending.is_empty() {
                    self.drain_group(tracker, report)?;
                } else {
                    self.flush_group(tracker, report)?;
                    self.drain_group(tracker, report)?;
                }
                self.group_overlay = self.fault.as_ref().unwrap().overlay().cloned();
                self.overlay_dirty = true;
                let fault = self.fault.as_mut().unwrap();
                if let Some(stack) = &mut self.stack {
                    stack.set_offline_pools(&fault.offline);
                    stack.set_degraded_pools(fault.degraded());
                }
            }
            let fault = self.fault.as_mut().unwrap();
            if fault.any_offline() {
                if let Some(stack) = &mut self.stack {
                    for from in 0..fault.offline.len() {
                        if fault.offline[from]
                            && tracker.stats.pool_bytes.get(from).copied().unwrap_or(0) > 0
                        {
                            let to = fault.fallback_pool(from)?;
                            fault.failover_migrated_bytes +=
                                stack.failover_pool(tracker, from, to, self.bytes_per_ev);
                        }
                    }
                }
            }
        }
        // phase 1 on the live bins, before they are parked
        if let Some(stack) = &mut self.stack {
            stack.before_analysis(bins, tracker, self.bytes_per_ev);
        }
        if let Some(fault) = &mut self.fault {
            fault.attribute_epoch_delays(|p| bins.read_count(p), |p| bins.write_count(p));
        }
        let mut ep = self.spare.pop().unwrap_or_else(|| PendingEpoch {
            reads: Vec::with_capacity(bins.reads.len()),
            writes: Vec::with_capacity(bins.writes.len()),
            native_ns: 0.0,
            events: 0,
            injected: Vec::new(),
            phase1_stall_ns: 0.0,
        });
        ep.reads.clear();
        ep.reads.extend_from_slice(&bins.reads);
        ep.writes.clear();
        ep.writes.extend_from_slice(&bins.writes);
        ep.native_ns = native_ns;
        ep.events = bins.total_events;
        ep.injected.clear();
        ep.phase1_stall_ns = 0.0;
        if let Some(stack) = &mut self.stack {
            ep.injected.extend_from_slice(stack.injected_events());
            ep.phase1_stall_ns = stack.take_accrued_stall_ns();
        }
        self.pending.push(ep);
        debug_assert!(
            self.pending.len() <= self.batch,
            "pending group overflow: {} > {}",
            self.pending.len(),
            self.batch
        );
        if self.pending.len() == self.batch {
            self.flush_group(tracker, report)?;
        }
        self.epoch += 1;
        Ok(())
    }

    fn finish(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        self.flush_group(tracker, report)?;
        self.drain_group(tracker, report)?;
        let wall_ns = self.started.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
        report.pipeline_depth = if self.lock_step() { 0 } else { PIPELINE_DEPTH as u64 };
        report.analyze_busy_ns = self.analyze_busy_ns as f64;
        report.pump_busy_ns = wall_ns.saturating_sub(self.wait_ns) as f64;
        report.overlap_frac = if self.analyze_busy_ns > 0 {
            (1.0 - self.wait_ns as f64 / self.analyze_busy_ns as f64).clamp(0.0, 1.0)
        } else {
            0.0
        };
        Ok(())
    }
}

impl Drop for PipelinedBatchFlush<'_> {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        drop(self.res_rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

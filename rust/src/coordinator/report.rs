//! Simulation reports: per-epoch records, end-of-run summary, JSON
//! export. These are the numbers Table 1 and the characterization
//! benches print.

use crate::cache::CacheStats;
use crate::policy::PolicyStack;
use crate::runtime::TimingOutputs;
use crate::util::json::{self, Json};

/// Per-policy outcome of a run (one row per [`PolicyStack`] member).
#[derive(Clone, Debug)]
pub struct PolicyReport {
    pub name: String,
    pub migrations: u64,
    pub moved_bytes: u64,
}

/// Tracer fast-path counters for ONE run. The allocation tracker
/// deliberately persists across `Coordinator::run` calls, so its
/// lifetime-cumulative stats are snapshotted at run start and the
/// deltas reported here (`EpochDriver::tracer_run_stats`) — otherwise
/// a second run's MRU-hit-rate canary would include the first run's
/// hits and mask regressions.
#[derive(Clone, Copy, Debug, Default)]
pub struct TracerRunStats {
    pub mru_hits: u64,
    pub lookup_misses: u64,
    pub index_rebuilds: u64,
    pub bins_staged: u64,
    pub bins_bulk_flushes: u64,
}

/// One epoch's outcome (kept only with `keep_epoch_records`).
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub native_ns: f64,
    pub delay_ns: f64,
    pub lat_ns: f64,
    pub cong_ns: f64,
    pub bwd_ns: f64,
    /// Migration stall charged to this epoch by the policy stack.
    pub mig_ns: f64,
    pub events: u64,
}

/// End-of-run summary of one simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub workload: String,
    pub topology: String,
    pub backend: String,
    /// Virtual native execution time (all-local memory), ns.
    pub native_ns: f64,
    /// Simulated execution time on the CXL topology, ns.
    pub simulated_ns: f64,
    /// Injected delay total and breakdown, ns. The total is the sum of
    /// the latency/congestion/bandwidth analyzer components plus the
    /// policy engine's modeled migration stall.
    pub delay_ns: f64,
    pub lat_delay_ns: f64,
    pub cong_delay_ns: f64,
    pub bwd_delay_ns: f64,
    /// Migration stall charged by the policy stack (bytes moved ×
    /// per-byte stall), ns.
    pub mig_delay_ns: f64,
    /// Tool wall-clock (Table 1's metric), seconds.
    pub wall_s: f64,
    pub epochs_run: u64,
    pub total_accesses: u64,
    pub total_misses: u64,
    pub writebacks: u64,
    pub alloc_events: u64,
    /// Hardware-prefetch fills that transited the topology.
    pub prefetches: u64,
    /// LLC misses routed to each pool (reads, writes), index = PoolId.
    pub pool_read_misses: Vec<u64>,
    pub pool_write_misses: Vec<u64>,
    /// Tracer fast-path observability (perf-regression canaries —
    /// `benches/hotpath.rs` has the timings, these make the hit rates
    /// visible in every report; all values are deltas for THIS run):
    /// `pool_of` lookups answered by the one-entry MRU region cache,
    /// lookups that fell through to local DRAM, and flat-index
    /// rebuilds after allocation churn.
    pub pool_mru_hits: u64,
    pub pool_lookup_misses: u64,
    pub pool_index_rebuilds: u64,
    /// Bulk miss accounting: histogram deltas staged over the run and
    /// the number of `record_bulk` scatters that drained them
    /// (`bins_staged / bins_bulk_flushes` ≈ achieved amortization).
    pub bins_staged: u64,
    pub bins_bulk_flushes: u64,
    /// Shard workers the batched analyzer fanned its E-epoch loop
    /// across (work-conservation observability; `0` = the run used the
    /// per-epoch analyzer, `1` = batched but sequential). Results are
    /// identical for every value — this only records the parallelism.
    pub analyzer_threads_used: u64,
    /// Queueing-scan kernel the analyzer ran (`"exact"` = golden
    /// reference order, `"blocked"` = max-plus block scans; empty on
    /// reports produced without an analyzer).
    pub scan_kernel: String,
    /// Native batched-analyzer group size E (`0` = per-epoch run).
    /// With a policy stack installed, phase-2 hooks ran up to E−1
    /// epochs late.
    pub batch_group: u64,
    /// Pipelined-epoch observability (`--pipeline`,
    /// `coordinator::pipeline`). `pipeline_depth` is the number of
    /// epochs (or batch groups) the pump was allowed to keep in flight
    /// behind the analysis worker: `1` for an overlapped run, `0` for
    /// a serial run — and for a pipelined run whose policy stack has
    /// members, which drains the rendezvous in lock step to keep
    /// phase-2 in its exact serial position (bit-identity beats
    /// overlap there; see the module docs). `pump_busy_ns` is pipeline
    /// wall-clock minus time the pump spent blocked on the rendezvous,
    /// `analyze_busy_ns` is the worker's summed analyze time, and
    /// `overlap_frac` = 1 − wait/analyze (clamped to [0,1]): the
    /// fraction of analyzer time hidden behind the pump. None of these
    /// enter bit-identity comparisons — like `wall_s`, they observe
    /// the run, they are not part of the simulation result.
    pub pipeline_depth: u64,
    pub pump_busy_ns: f64,
    pub analyze_busy_ns: f64,
    pub overlap_frac: f64,
    /// Policy engine (empty without an installed stack): per-policy
    /// outcomes plus the migration cost model's conservation counters
    /// — every migrated byte becomes read traffic on the source pool
    /// and write traffic on the destination in the next epoch
    /// (`injected`), or is still awaiting a next epoch (`pending`).
    pub policies: Vec<PolicyReport>,
    pub migrations: u64,
    pub migrated_bytes: u64,
    pub mig_injected_read_bytes: f64,
    pub mig_injected_write_bytes: f64,
    pub mig_pending_bytes: f64,
    /// Fault injection (`--faults` / `--fault`, `crate::fault`): RAS
    /// events that fired this run, the exact retry-storm latency
    /// charged (a sub-component of `lat_delay_ns`), epochs that ran
    /// with at least one transient window active, distinct pools taken
    /// permanently offline, and bytes evacuated by offline failover
    /// (a subset of `migrated_bytes` when policies also migrate).
    pub faults_injected: u64,
    pub retry_delay_ns: f64,
    pub throttled_epochs: u64,
    pub pools_offline: u64,
    pub failover_migrated_bytes: u64,
    /// Availability lifecycle (`online` fault kind + `drain` policy):
    /// offline pools brought back by an `online` event, the transient
    /// warm-up latency charged while re-onlined pools re-populate (a
    /// sub-component of `lat_delay_ns`, disjoint from
    /// `retry_delay_ns`), and bytes the `FaultDrain` policy moved in
    /// either direction — proactive evacuation off degraded pools plus
    /// post-recovery re-admission (a subset of `migrated_bytes`).
    pub pools_reonlined: u64,
    pub warmup_delay_ns: f64,
    pub drain_migrated_bytes: u64,
    pub epochs: Vec<EpochRecord>,
}

impl SimReport {
    pub fn new(workload: &str, topology: &str, backend: &str, pools: usize) -> SimReport {
        SimReport {
            workload: workload.to_string(),
            topology: topology.to_string(),
            backend: backend.to_string(),
            native_ns: 0.0,
            simulated_ns: 0.0,
            delay_ns: 0.0,
            lat_delay_ns: 0.0,
            cong_delay_ns: 0.0,
            bwd_delay_ns: 0.0,
            mig_delay_ns: 0.0,
            wall_s: 0.0,
            epochs_run: 0,
            total_accesses: 0,
            total_misses: 0,
            writebacks: 0,
            alloc_events: 0,
            prefetches: 0,
            pool_read_misses: vec![0; pools],
            pool_write_misses: vec![0; pools],
            pool_mru_hits: 0,
            pool_lookup_misses: 0,
            pool_index_rebuilds: 0,
            bins_staged: 0,
            bins_bulk_flushes: 0,
            analyzer_threads_used: 0,
            scan_kernel: String::new(),
            batch_group: 0,
            pipeline_depth: 0,
            pump_busy_ns: 0.0,
            analyze_busy_ns: 0.0,
            overlap_frac: 0.0,
            policies: Vec::new(),
            migrations: 0,
            migrated_bytes: 0,
            mig_injected_read_bytes: 0.0,
            mig_injected_write_bytes: 0.0,
            mig_pending_bytes: 0.0,
            faults_injected: 0,
            retry_delay_ns: 0.0,
            throttled_epochs: 0,
            pools_offline: 0,
            failover_migrated_bytes: 0,
            pools_reonlined: 0,
            warmup_delay_ns: 0.0,
            drain_migrated_bytes: 0,
            epochs: Vec::new(),
        }
    }

    pub(crate) fn record_miss(&mut self, pool: usize, is_write: bool) {
        self.total_misses += 1;
        if is_write {
            self.pool_write_misses[pool] += 1;
        } else {
            self.pool_read_misses[pool] += 1;
        }
    }

    pub(crate) fn record_writeback(&mut self, pool: usize) {
        self.writebacks += 1;
        self.pool_write_misses[pool] += 1;
    }

    pub(crate) fn push_epoch(
        &mut self,
        native_ns: f64,
        out: &TimingOutputs,
        mig_ns: f64,
        events: u64,
        keep: bool,
    ) {
        self.epochs_run += 1;
        self.native_ns += native_ns;
        self.delay_ns += out.total + mig_ns;
        self.lat_delay_ns += out.lat_total();
        self.cong_delay_ns += out.cong_total();
        self.bwd_delay_ns += out.bwd_total();
        self.mig_delay_ns += mig_ns;
        self.simulated_ns += native_ns + out.total + mig_ns;
        if keep {
            self.epochs.push(EpochRecord {
                native_ns,
                delay_ns: out.total + mig_ns,
                lat_ns: out.lat_total(),
                cong_ns: out.cong_total(),
                bwd_ns: out.bwd_total(),
                mig_ns,
                events,
            });
        }
    }

    /// Copy the policy stack's end-of-run stats into the report. All
    /// values are THIS run's (the stack's counters reset at
    /// `PolicyStack::begin_run`, and the per-policy rows are deltas
    /// against run-start snapshots), mirroring `TracerRunStats`.
    pub(crate) fn record_policy_stats(&mut self, stack: &PolicyStack) {
        self.migrations = stack.migrations();
        self.migrated_bytes = stack.moved_bytes();
        self.mig_injected_read_bytes = stack.injected_read_bytes();
        self.mig_injected_write_bytes = stack.injected_write_bytes();
        self.mig_pending_bytes = stack.pending_bytes();
        self.policies = stack
            .per_policy_stats()
            .into_iter()
            .map(|(name, migrations, moved_bytes)| PolicyReport {
                name: name.to_string(),
                migrations,
                moved_bytes,
            })
            .collect();
        self.drain_migrated_bytes = stack.drained_bytes();
    }

    /// Copy the resolved fault schedule's end-of-run counters into the
    /// report (the drivers call this once after the epoch loop; a
    /// fault-free run never constructs a `FaultState`, so every field
    /// stays at its zero default).
    pub(crate) fn record_fault_stats(&mut self, fault: &crate::fault::FaultState) {
        self.faults_injected = fault.faults_injected;
        self.retry_delay_ns = fault.retry_delay_ns;
        self.throttled_epochs = fault.throttled_epochs;
        self.pools_offline = fault.pools_offline;
        self.failover_migrated_bytes = fault.failover_migrated_bytes;
        self.pools_reonlined = fault.pools_reonlined;
        self.warmup_delay_ns = fault.warmup_delay_ns;
    }

    pub(crate) fn finish(
        &mut self,
        cache: &CacheStats,
        tracer: TracerRunStats,
        wall: std::time::Duration,
    ) {
        self.total_accesses = cache.accesses;
        self.pool_mru_hits = tracer.mru_hits;
        self.pool_lookup_misses = tracer.lookup_misses;
        self.pool_index_rebuilds = tracer.index_rebuilds;
        self.bins_staged = tracer.bins_staged;
        self.bins_bulk_flushes = tracer.bins_bulk_flushes;
        self.wall_s = wall.as_secs_f64();
    }

    /// Simulated slowdown of the *program* caused by CXL placement.
    pub fn sim_slowdown(&self) -> f64 {
        if self.native_ns == 0.0 {
            1.0
        } else {
            self.simulated_ns / self.native_ns
        }
    }

    /// Tool overhead vs a native wall-clock measurement (Table 1).
    pub fn overhead_vs(&self, native_wall_s: f64) -> f64 {
        if native_wall_s == 0.0 {
            f64::INFINITY
        } else {
            self.wall_s / native_wall_s
        }
    }

    pub fn miss_rate(&self) -> f64 {
        if self.total_accesses == 0 {
            0.0
        } else {
            self.total_misses as f64 / self.total_accesses as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "workload {} on `{}` [{} backend]\n",
            self.workload, self.topology, self.backend
        ));
        s.push_str(&format!(
            "  native  {:>10.3} ms   simulated {:>10.3} ms   (x{:.3} slowdown)\n",
            self.native_ns / 1e6,
            self.simulated_ns / 1e6,
            self.sim_slowdown()
        ));
        s.push_str(&format!(
            "  delay   {:>10.3} ms = latency {:.3} + congestion {:.3} + bandwidth {:.3} \
             + migration {:.3}\n",
            self.delay_ns / 1e6,
            self.lat_delay_ns / 1e6,
            self.cong_delay_ns / 1e6,
            self.bwd_delay_ns / 1e6,
            self.mig_delay_ns / 1e6
        ));
        if !self.policies.is_empty() {
            let parts: Vec<String> = self
                .policies
                .iter()
                .map(|p| {
                    format!(
                        "{} ({} migrations, {:.1} KB moved)",
                        p.name,
                        p.migrations,
                        p.moved_bytes as f64 / 1024.0
                    )
                })
                .collect();
            s.push_str(&format!("  policies: {}\n", parts.join("; ")));
            s.push_str(&format!(
                "  migration traffic: {:.1} KB injected reads, {:.1} KB injected writes, \
                 {:.1} KB pending, {:.3} ms stall\n",
                self.mig_injected_read_bytes / 1024.0,
                self.mig_injected_write_bytes / 1024.0,
                self.mig_pending_bytes / 1024.0,
                self.mig_delay_ns / 1e6
            ));
        }
        if self.faults_injected > 0 {
            s.push_str(&format!(
                "  faults: {} injected, {:.3} ms retry delay, {} throttled epochs, \
                 {} pools offline, {:.1} KB failover-migrated\n",
                self.faults_injected,
                self.retry_delay_ns / 1e6,
                self.throttled_epochs,
                self.pools_offline,
                self.failover_migrated_bytes as f64 / 1024.0
            ));
            if self.pools_reonlined > 0 || self.drain_migrated_bytes > 0 {
                s.push_str(&format!(
                    "  recovery: {} pools re-onlined, {:.3} ms warm-up delay, \
                     {:.1} KB drain-migrated\n",
                    self.pools_reonlined,
                    self.warmup_delay_ns / 1e6,
                    self.drain_migrated_bytes as f64 / 1024.0
                ));
            }
        }
        s.push_str(&format!(
            "  {} epochs, {} accesses, {} LLC misses ({:.3}% miss rate), {} writebacks\n",
            self.epochs_run,
            self.total_accesses,
            self.total_misses,
            self.miss_rate() * 100.0,
            self.writebacks
        ));
        let per_pool: Vec<String> = (0..self.pool_read_misses.len())
            .filter(|&p| self.pool_read_misses[p] + self.pool_write_misses[p] > 0)
            .map(|p| {
                format!(
                    "pool{}: {}r/{}w",
                    p, self.pool_read_misses[p], self.pool_write_misses[p]
                )
            })
            .collect();
        s.push_str(&format!("  pool traffic: {}\n", per_pool.join("  ")));
        s.push_str(&format!(
            "  tracer: {} MRU hits / {} untracked lookups / {} index rebuilds; \
             {} bins staged in {} bulk flushes\n",
            self.pool_mru_hits,
            self.pool_lookup_misses,
            self.pool_index_rebuilds,
            self.bins_staged,
            self.bins_bulk_flushes
        ));
        if self.analyze_busy_ns > 0.0 {
            s.push_str(&format!(
                "  pipeline: depth {}, pump busy {:.3} ms, analyze busy {:.3} ms, \
                 {:.0}% of analysis hidden behind the pump\n",
                self.pipeline_depth,
                self.pump_busy_ns / 1e6,
                self.analyze_busy_ns / 1e6,
                self.overlap_frac * 100.0
            ));
        }
        s.push_str(&format!("  tool wall-clock {:.3} s\n", self.wall_s));
        s
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("workload", json::s(&self.workload)),
            ("topology", json::s(&self.topology)),
            ("backend", json::s(&self.backend)),
            ("native_ms", json::num(self.native_ns / 1e6)),
            ("simulated_ms", json::num(self.simulated_ns / 1e6)),
            ("sim_slowdown", json::num(self.sim_slowdown())),
            ("delay_ms", json::num(self.delay_ns / 1e6)),
            ("lat_delay_ms", json::num(self.lat_delay_ns / 1e6)),
            ("cong_delay_ms", json::num(self.cong_delay_ns / 1e6)),
            ("bwd_delay_ms", json::num(self.bwd_delay_ns / 1e6)),
            ("mig_delay_ms", json::num(self.mig_delay_ns / 1e6)),
            ("migrations", json::num(self.migrations as f64)),
            ("migrated_bytes", json::num(self.migrated_bytes as f64)),
            ("mig_injected_read_bytes", json::num(self.mig_injected_read_bytes)),
            ("mig_injected_write_bytes", json::num(self.mig_injected_write_bytes)),
            ("mig_pending_bytes", json::num(self.mig_pending_bytes)),
            ("faults_injected", json::num(self.faults_injected as f64)),
            ("retry_delay_ms", json::num(self.retry_delay_ns / 1e6)),
            ("throttled_epochs", json::num(self.throttled_epochs as f64)),
            ("pools_offline", json::num(self.pools_offline as f64)),
            ("failover_migrated_bytes", json::num(self.failover_migrated_bytes as f64)),
            ("pools_reonlined", json::num(self.pools_reonlined as f64)),
            ("warmup_delay_ms", json::num(self.warmup_delay_ns / 1e6)),
            ("drain_migrated_bytes", json::num(self.drain_migrated_bytes as f64)),
            (
                "policies",
                Json::Arr(
                    self.policies
                        .iter()
                        .map(|p| {
                            json::obj(vec![
                                ("name", json::s(&p.name)),
                                ("migrations", json::num(p.migrations as f64)),
                                ("moved_bytes", json::num(p.moved_bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("wall_s", json::num(self.wall_s)),
            ("epochs", json::num(self.epochs_run as f64)),
            ("accesses", json::num(self.total_accesses as f64)),
            ("llc_misses", json::num(self.total_misses as f64)),
            ("writebacks", json::num(self.writebacks as f64)),
            ("alloc_events", json::num(self.alloc_events as f64)),
            ("pool_mru_hits", json::num(self.pool_mru_hits as f64)),
            ("pool_lookup_misses", json::num(self.pool_lookup_misses as f64)),
            ("pool_index_rebuilds", json::num(self.pool_index_rebuilds as f64)),
            ("bins_staged", json::num(self.bins_staged as f64)),
            ("bins_bulk_flushes", json::num(self.bins_bulk_flushes as f64)),
            ("analyzer_threads_used", json::num(self.analyzer_threads_used as f64)),
            ("scan_kernel", json::s(&self.scan_kernel)),
            ("batch_group", json::num(self.batch_group as f64)),
            ("pipeline_depth", json::num(self.pipeline_depth as f64)),
            ("pump_busy_ms", json::num(self.pump_busy_ns / 1e6)),
            ("analyze_busy_ms", json::num(self.analyze_busy_ns / 1e6)),
            ("overlap_frac", json::num(self.overlap_frac)),
            (
                "pool_read_misses",
                json::arr_f64(&self.pool_read_misses.iter().map(|x| *x as f64).collect::<Vec<_>>()),
            ),
            (
                "pool_write_misses",
                json::arr_f64(
                    &self.pool_write_misses.iter().map(|x| *x as f64).collect::<Vec<_>>(),
                ),
            ),
        ])
    }
}

/// Report keys summed when merging per-shard replay reports
/// (`replay --shard i/N`, driven by the sweep engine). Shards
/// partition a v2 trace's chunk directory exactly, so event counters
/// and accumulated times are additive; cache and pool state reset per
/// shard, so the *rates* (`sim_slowdown`) are recomputed by
/// [`finalize_shard_merge`] instead of averaged.
pub const SHARD_SUM_KEYS: &[&str] = &[
    "native_ms",
    "simulated_ms",
    "delay_ms",
    "lat_delay_ms",
    "cong_delay_ms",
    "bwd_delay_ms",
    "mig_delay_ms",
    "migrations",
    "migrated_bytes",
    "mig_injected_read_bytes",
    "mig_injected_write_bytes",
    "mig_pending_bytes",
    "faults_injected",
    "retry_delay_ms",
    "throttled_epochs",
    "failover_migrated_bytes",
    "warmup_delay_ms",
    "drain_migrated_bytes",
    "epochs",
    "accesses",
    "llc_misses",
    "writebacks",
    "alloc_events",
    "prefetches",
    "pool_mru_hits",
    "pool_lookup_misses",
    "pool_index_rebuilds",
    "bins_staged",
    "bins_bulk_flushes",
];

/// Keys where the merged value is the per-shard maximum (offline pools
/// are the same set in every shard; thread/pipeline observability
/// reports the largest fan-out any shard used).
pub const SHARD_MAX_KEYS: &[&str] =
    &["pools_offline", "pools_reonlined", "analyzer_threads_used", "pipeline_depth"];

/// Merge one shard's `SimReport::to_json` object into an accumulator
/// (itself a shard report, typically shard 0's). Scalar counters sum
/// ([`SHARD_SUM_KEYS`]) or max ([`SHARD_MAX_KEYS`]), the per-pool miss
/// arrays add elementwise, and `policies` rows merge by policy name.
/// Identity keys (`workload`, `topology`, `backend`, `scan_kernel`,
/// `batch_group`) keep the accumulator's value. Call
/// [`finalize_shard_merge`] once after the last shard.
pub fn merge_shard_json(acc: &mut Json, shard: &Json) {
    let m = match acc {
        Json::Obj(m) => m,
        _ => return,
    };
    for key in SHARD_SUM_KEYS {
        if let Some(add) = shard.get(key).and_then(|v| v.as_f64()) {
            let slot = m.entry(key.to_string()).or_insert(Json::Num(0.0));
            if let Json::Num(n) = slot {
                *n += add;
            }
        }
    }
    for key in SHARD_MAX_KEYS {
        if let Some(other) = shard.get(key).and_then(|v| v.as_f64()) {
            let slot = m.entry(key.to_string()).or_insert(Json::Num(0.0));
            if let Json::Num(n) = slot {
                *n = n.max(other);
            }
        }
    }
    for key in ["pool_read_misses", "pool_write_misses"] {
        if let Some(add) = shard.get(key).and_then(|v| v.as_arr()).map(|a| a.to_vec()) {
            if let Some(Json::Arr(dst)) = m.get_mut(key) {
                for (i, v) in add.iter().enumerate() {
                    let inc = v.as_f64().unwrap_or(0.0);
                    if i < dst.len() {
                        if let Json::Num(n) = &mut dst[i] {
                            *n += inc;
                        }
                    } else {
                        dst.push(Json::Num(inc));
                    }
                }
            }
        }
    }
    if let Some(rows) = shard.get("policies").and_then(|v| v.as_arr()).map(|a| a.to_vec()) {
        if let Some(Json::Arr(dst)) = m.get_mut("policies") {
            for row in rows {
                let name = row.get("name").and_then(|v| v.as_str()).unwrap_or("");
                let existing = dst
                    .iter_mut()
                    .find(|r| r.get("name").and_then(|v| v.as_str()) == Some(name));
                match existing {
                    Some(Json::Obj(r)) => {
                        for k in ["migrations", "moved_bytes"] {
                            let inc = row.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                            if let Some(Json::Num(n)) = r.get_mut(k) {
                                *n += inc;
                            }
                        }
                    }
                    _ => dst.push(row),
                }
            }
        }
    }
}

/// Recompute the derived fields of a merged shard report and stamp the
/// shard count: `sim_slowdown` = merged simulated / merged native (per
/// shard it was a per-shard ratio, which does not average), plus a
/// `shards` key so artifacts show how the cell was produced.
pub fn finalize_shard_merge(acc: &mut Json, shards: usize) {
    let native = acc.get("native_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let sim = acc.get("simulated_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let slowdown = if native == 0.0 { 1.0 } else { sim / native };
    if let Json::Obj(m) = acc {
        m.insert("sim_slowdown".to_string(), Json::Num(slowdown));
        m.insert("shards".to_string(), Json::Num(shards as f64));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outputs(total: f64) -> TimingOutputs {
        TimingOutputs {
            total,
            lat: vec![total as f32 / 2.0],
            cong: vec![total as f32 / 4.0],
            bwd: vec![total as f32 / 4.0],
            cong_backlog: vec![],
        }
    }

    #[test]
    fn epoch_accumulation() {
        let mut r = SimReport::new("w", "t", "native", 2);
        r.push_epoch(1000.0, &outputs(500.0), 0.0, 10, false);
        r.push_epoch(1000.0, &outputs(300.0), 0.0, 5, false);
        assert_eq!(r.epochs_run, 2);
        assert!((r.native_ns - 2000.0).abs() < 1e-9);
        assert!((r.delay_ns - 800.0).abs() < 1e-9);
        assert!((r.simulated_ns - 2800.0).abs() < 1e-9);
        assert!((r.sim_slowdown() - 1.4).abs() < 1e-9);
    }

    #[test]
    fn migration_stall_lands_in_delay_and_breakdown() {
        let mut r = SimReport::new("w", "t", "native", 2);
        r.push_epoch(1000.0, &outputs(400.0), 100.0, 10, true);
        assert!((r.delay_ns - 500.0).abs() < 1e-9);
        assert!((r.mig_delay_ns - 100.0).abs() < 1e-9);
        assert!((r.simulated_ns - 1500.0).abs() < 1e-9);
        let sum = r.lat_delay_ns + r.cong_delay_ns + r.bwd_delay_ns + r.mig_delay_ns;
        assert!((sum - r.delay_ns).abs() < 1e-6);
        assert!((r.epochs[0].mig_ns - 100.0).abs() < 1e-9);
        assert!((r.epochs[0].delay_ns - 500.0).abs() < 1e-9);
    }

    #[test]
    fn miss_bookkeeping() {
        let mut r = SimReport::new("w", "t", "native", 3);
        r.record_miss(1, false);
        r.record_miss(1, true);
        r.record_writeback(2);
        assert_eq!(r.total_misses, 2);
        assert_eq!(r.writebacks, 1);
        assert_eq!(r.pool_read_misses[1], 1);
        assert_eq!(r.pool_write_misses[1], 1);
        assert_eq!(r.pool_write_misses[2], 1);
    }

    #[test]
    fn json_roundtrips() {
        let mut r = SimReport::new("w", "t", "pjrt", 2);
        r.push_epoch(100.0, &outputs(10.0), 0.0, 3, false);
        let j = r.to_json().to_string();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("workload").unwrap().as_str(), Some("w"));
        assert!(v.get("sim_slowdown").unwrap().as_f64().unwrap() > 1.0);
    }

    #[test]
    fn summary_contains_key_numbers() {
        let mut r = SimReport::new("mmap_read", "fig2", "native", 2);
        r.push_epoch(1e6, &outputs(5e5), 0.0, 100, false);
        let s = r.summary();
        assert!(s.contains("mmap_read"));
        assert!(s.contains("fig2"));
        assert!(s.contains("slowdown"));
    }

    #[test]
    fn overhead_vs_native() {
        let mut r = SimReport::new("w", "t", "native", 1);
        r.wall_s = 4.0;
        assert!((r.overhead_vs(1.0) - 4.0).abs() < 1e-12);
        assert!(r.overhead_vs(0.0).is_infinite());
    }

    fn shard_report(native: f64, delay: f64, misses: u64) -> Json {
        let mut r = SimReport::new("trace", "fig2", "native", 2);
        r.push_epoch(native, &outputs(delay), 0.0, 10, false);
        for _ in 0..misses {
            r.record_miss(1, false);
        }
        r.total_accesses = misses * 4;
        r.to_json()
    }

    #[test]
    fn shard_merge_sums_counters_and_recomputes_slowdown() {
        let mut acc = shard_report(1000.0, 500.0, 3);
        let other = shard_report(1000.0, 100.0, 5);
        merge_shard_json(&mut acc, &other);
        finalize_shard_merge(&mut acc, 2);
        assert_eq!(acc.get("llc_misses").unwrap().as_f64(), Some(8.0));
        assert_eq!(acc.get("accesses").unwrap().as_f64(), Some(32.0));
        assert_eq!(acc.get("epochs").unwrap().as_f64(), Some(2.0));
        assert_eq!(acc.get("shards").unwrap().as_f64(), Some(2.0));
        // merged slowdown is total/total, not a mean of ratios:
        // (2000 + 600) / 2000 = 1.3
        let sd = acc.get("sim_slowdown").unwrap().as_f64().unwrap();
        assert!((sd - 1.3).abs() < 1e-9, "slowdown {sd}");
        // per-pool arrays add elementwise
        let reads = acc.get("pool_read_misses").unwrap().as_arr().unwrap();
        assert_eq!(reads[1].as_f64(), Some(8.0));
    }

    #[test]
    fn shard_merge_combines_policy_rows_by_name() {
        let mk = |name: &str, migs: f64| {
            let mut r = SimReport::new("t", "t", "native", 1);
            r.policies.push(PolicyReport {
                name: name.to_string(),
                migrations: migs as u64,
                moved_bytes: 100,
            });
            r.to_json()
        };
        let mut acc = mk("hotness", 2.0);
        merge_shard_json(&mut acc, &mk("hotness", 3.0));
        merge_shard_json(&mut acc, &mk("rebalance", 1.0));
        let rows = acc.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("migrations").unwrap().as_f64(), Some(5.0));
        assert_eq!(rows[0].get("moved_bytes").unwrap().as_f64(), Some(200.0));
        assert_eq!(rows[1].get("name").unwrap().as_str(), Some("rebalance"));
    }

    #[test]
    fn shard_finalize_zero_native_is_unit_slowdown() {
        let mut acc = SimReport::new("t", "t", "native", 1).to_json();
        finalize_shard_merge(&mut acc, 4);
        assert_eq!(acc.get("sim_slowdown").unwrap().as_f64(), Some(1.0));
        assert_eq!(acc.get("shards").unwrap().as_f64(), Some(4.0));
    }
}

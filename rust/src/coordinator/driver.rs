//! The shared epoch driver — one optimized event pump for every
//! execution mode.
//!
//! Before this module, the cache→tracker→bins accounting loop existed
//! twice (the sequential coordinator and the batched replay runner)
//! and the copies had drifted: `run_batched` silently dropped
//! prefetcher traffic and never invoked the installed `EpochPolicy`.
//! [`EpochDriver`] owns that accounting once; execution modes differ
//! only in their [`EpochFlush`] strategy (per-epoch analyze vs.
//! grouped batch analyze). `gem5like` keeps its own accounting loop —
//! it models a different machine — but shares the batched event pump.
//!
//! The pump pulls events through [`Workload::next_batch`]
//! (`SimConfig::event_batch` events per virtual call) so the inner loop
//! is a monomorphic iteration over a `Vec<WlEvent>` instead of one dyn
//! dispatch per event — set `event_batch = 1` to recover the old
//! per-event behaviour as a measurable baseline (`benches/hotpath.rs`).
//!
//! Streaming workloads ride the same pump unchanged: the contract
//! already allows a `next_batch` call to push *fewer* than `budget`
//! events and return true, so `trace::stream::TraceStream` serves
//! each call from its resident chunk and blocks (briefly) on the
//! decode-ahead rendezvous only at chunk boundaries. Blocking inside
//! `next_batch` is invisible to determinism — the pump consumes
//! whatever arrives in order, and virtual time never depends on
//! wall-clock.
//! Miss accounting is bulk too: sampled misses, write-backs, and
//! prefetch fills are staged as pre-binned `(pool, rw, bin, weight)`
//! deltas and scattered into the `[P, B]` tensors once per event batch
//! (`EpochBins::record_bulk`) rather than one `record` call per sample.
//! Both paths produce bit-identical `SimReport`s
//! (`tests/pipeline_equivalence.rs`).

use crate::alloctrack::AllocTracker;
use crate::cache::{AccessOutcome, CacheHierarchy, Prefetcher};
use crate::fault::{FaultOverlay, FaultState};
use crate::policy::PolicyStack;
use crate::runtime::{BatchTimingModel, TimingInputs, TimingModel};
use crate::topology::Topology;
use crate::trace::binning::{BinDelta, EpochBins};
use crate::trace::WlEvent;
use crate::workload::Workload;

use super::report::{SimReport, TracerRunStats};
use super::SimConfig;

/// Default `SimConfig::event_batch`: events pulled per `next_batch`.
pub const DEFAULT_EVENT_BATCH: usize = 4096;

/// What happens when an epoch boundary fires. The driver hands over the
/// filled bins (mutably — phase-1 policies reshape them before
/// analysis), the epoch's native virtual time, and the tracker (epoch
/// policies migrate regions through it); the strategy is responsible
/// for calling `report.push_epoch` once per epoch, in order.
pub trait EpochFlush {
    fn on_epoch(
        &mut self,
        bins: &mut EpochBins,
        native_ns: f64,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()>;

    /// Called once after the workload exits (tail flush for grouped
    /// strategies).
    fn finish(
        &mut self,
        _tracker: &mut AllocTracker,
        _report: &mut SimReport,
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Owns the tracer substrate (cache hierarchy, allocation tracker,
/// epoch bins, optional hardware prefetcher) and drives a workload
/// through it epoch by epoch.
pub struct EpochDriver {
    pub cache: CacheHierarchy,
    pub tracker: AllocTracker,
    pub bins: EpochBins,
    pub prefetcher: Option<Box<dyn Prefetcher>>,
    epoch_ns: f64,
    cpi_ns: f64,
    alloc_cost_ns: f64,
    /// Precomputed `max(mlp, 1.0)` divisor.
    mlp_div: f64,
    sample_period: u32,
    local_read_ns: f64,
    local_write_ns: f64,
    event_batch: usize,
    // per-run state
    epoch_vtime: f64,
    sample_ctr: u32,
    buf: Vec<WlEvent>,
    /// Staged `(pool, rw, bin, weight)` deltas awaiting the bulk
    /// scatter into `bins` — filled by `on_event`, drained once per
    /// event batch (and at every epoch boundary) by `scatter_staged`.
    staged: Vec<BinDelta>,
    /// Deltas staged over the run (== samples binned); exported to
    /// `SimReport` so bulk-path regressions show up in reports.
    pub staged_total: u64,
    /// Bulk scatters performed (`record_bulk` calls with a non-empty
    /// staging buffer); `staged_total / bulk_flushes` is the achieved
    /// amortization factor.
    pub bulk_flushes: u64,
    /// Tracker-stat snapshots taken at `reset` — the tracker persists
    /// across runs, so per-run reports subtract these baselines
    /// (`tracer_run_stats`).
    mru_hits_base: u64,
    lookup_misses_base: u64,
    index_rebuilds_base: u64,
}

impl EpochDriver {
    pub fn new(topo: &Topology, cfg: &SimConfig) -> anyhow::Result<EpochDriver> {
        let prefetcher = match &cfg.prefetcher {
            Some(name) => Some(
                crate::cache::prefetch::by_name(name, topo.host.cacheline_bytes)
                    .ok_or_else(|| anyhow::anyhow!("unknown prefetcher `{name}`"))?,
            ),
            None => None,
        };
        let mut tracker = AllocTracker::new(topo, cfg.policy.build(topo));
        // per-epoch multiplicative heat decay (1.0 = off); applied by
        // `flush_epoch` after the epoch's policy hooks ran
        tracker.set_heat_decay(cfg.heat_decay);
        Ok(EpochDriver {
            cache: CacheHierarchy::scaled(cfg.cache_scale),
            tracker,
            bins: EpochBins::new(
                crate::runtime::shapes::NUM_POOLS,
                cfg.nbins,
                cfg.epoch_ns(),
            ),
            prefetcher,
            epoch_ns: cfg.epoch_ns(),
            cpi_ns: cfg.cpi_ns,
            alloc_cost_ns: cfg.alloc_cost_ns,
            mlp_div: cfg.mlp.max(1.0),
            sample_period: cfg.sample_period,
            local_read_ns: topo.host.local_read_latency_ns,
            local_write_ns: topo.host.local_write_latency_ns,
            event_batch: cfg.event_batch.max(1),
            epoch_vtime: 0.0,
            sample_ctr: 0,
            buf: Vec::with_capacity(cfg.event_batch.max(1)),
            staged: Vec::with_capacity(cfg.event_batch.max(1)),
            staged_total: 0,
            bulk_flushes: 0,
            mru_hits_base: 0,
            lookup_misses_base: 0,
            index_rebuilds_base: 0,
        })
    }

    /// Reset per-run state (cache stats, bins, epoch clock). The
    /// tracker deliberately persists across runs, matching the previous
    /// coordinator behaviour (allocations outlive a `run` call) — its
    /// counters are snapshotted here so reports show this run's deltas.
    pub fn reset(&mut self) {
        self.cache.reset_stats();
        self.bins.clear();
        self.epoch_vtime = 0.0;
        self.sample_ctr = 0;
        self.staged.clear();
        self.staged_total = 0;
        self.bulk_flushes = 0;
        self.mru_hits_base = self.tracker.stats.mru_hits;
        self.lookup_misses_base = self.tracker.stats.lookup_misses;
        self.index_rebuilds_base = self.tracker.stats.index_rebuilds;
    }

    /// This run's tracer fast-path counters (tracker deltas since the
    /// last `reset` plus the staging-buffer totals), for
    /// `SimReport::finish`.
    pub fn tracer_run_stats(&self) -> TracerRunStats {
        TracerRunStats {
            mru_hits: self.tracker.stats.mru_hits - self.mru_hits_base,
            lookup_misses: self.tracker.stats.lookup_misses - self.lookup_misses_base,
            index_rebuilds: self.tracker.stats.index_rebuilds - self.index_rebuilds_base,
            bins_staged: self.staged_total,
            bins_bulk_flushes: self.bulk_flushes,
        }
    }

    /// Drain the staging buffer into the bins tensors. Runs once per
    /// event batch (the common case: one scatter amortized over up to
    /// `event_batch` events) and at every epoch boundary.
    #[inline]
    fn scatter_staged(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        self.staged_total += self.staged.len() as u64;
        self.bulk_flushes += 1;
        self.bins.record_bulk(&self.staged);
        self.staged.clear();
    }

    /// Account one event: virtual time, cache walk, miss sampling,
    /// write-back traffic, prefetcher traffic.
    #[inline]
    fn on_event(&mut self, ev: WlEvent, report: &mut SimReport) {
        match ev {
            WlEvent::Alloc(mut a) => {
                a.t_ns = report.native_ns + self.epoch_vtime;
                self.tracker.on_alloc_event(&a);
                report.alloc_events += 1;
                self.epoch_vtime += self.alloc_cost_ns;
            }
            WlEvent::Access(a) => {
                let outcome = self.cache.access(a.addr, a.is_write);
                let mut cost = self.cpi_ns + self.cache.hit_latency_ns(outcome);
                if let AccessOutcome::Miss { writeback } = outcome {
                    // native run: the miss is served by local DRAM; the
                    // OoO core overlaps `mlp` misses on average
                    cost += if a.is_write { self.local_write_ns } else { self.local_read_ns }
                        / self.mlp_div;
                    let pool = self.tracker.pool_of(a.addr);
                    report.record_miss(pool, a.is_write);
                    self.sample_ctr += 1;
                    if self.sample_ctr >= self.sample_period {
                        self.sample_ctr = 0;
                        self.bins.stage(
                            pool,
                            a.is_write,
                            self.epoch_vtime,
                            self.sample_period as f32,
                            &mut self.staged,
                        );
                    }
                    if let Some(wb_addr) = writeback {
                        // dirty eviction: a write transits to the victim
                        // line's pool (unsampled, weight 1)
                        let wb_pool = self.tracker.pool_of(wb_addr);
                        report.record_writeback(wb_pool);
                        self.bins.stage(wb_pool, true, self.epoch_vtime, 1.0, &mut self.staged);
                    }
                }
                // hardware prefetcher: observe, fill, bin the traffic
                if let Some(pf) = &mut self.prefetcher {
                    let was_miss = matches!(outcome, AccessOutcome::Miss { .. });
                    let targets = pf.observe(a.addr, was_miss);
                    if !targets.is_empty() {
                        let fetched =
                            crate::cache::prefetch::issue_prefetches(&mut self.cache, &targets);
                        for t in fetched {
                            let pool = self.tracker.pool_of(t);
                            report.prefetches += 1;
                            self.bins.stage(pool, false, self.epoch_vtime, 1.0, &mut self.staged);
                        }
                    }
                }
                self.epoch_vtime += cost;
            }
        }
    }

    fn flush_epoch<F: EpochFlush + ?Sized>(
        &mut self,
        flush: &mut F,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        // the boundary can fire mid-batch: scatter pending deltas so
        // the strategy sees the complete epoch
        self.scatter_staged();
        flush.on_epoch(&mut self.bins, self.epoch_vtime, &mut self.tracker, report)?;
        // age region heat by one epoch AFTER the epoch's hooks, so
        // this epoch's lookups enter victim selection undecayed and
        // older heat fades exponentially (no-op at heat_decay = 1.0).
        // Under a grouped flush the phase-2 hooks run at group-flush
        // time and therefore see heat decayed up to group−1 epochs
        // further — part of batched replay's documented lateness.
        self.tracker.decay_heat();
        self.bins.clear();
        self.epoch_vtime = 0.0;
        Ok(())
    }

    /// The epoch loop (paper Figure 2): pump events, fire the Timer at
    /// every epoch boundary, flush through the strategy.
    pub fn run<F: EpochFlush + ?Sized>(
        &mut self,
        wl: &mut dyn Workload,
        flush: &mut F,
        report: &mut SimReport,
        max_epochs: Option<u64>,
    ) -> anyhow::Result<()> {
        let mut buf = std::mem::take(&mut self.buf);
        let mut done = false;
        // count boundaries fired here, NOT report.epochs_run: grouped
        // flush strategies only push to the report at group-flush time,
        // so the report count lags by up to a group and max_epochs
        // would overshoot
        let mut epochs_fired = 0u64;
        'pump: while !done {
            buf.clear();
            if !wl.next_batch(&mut buf, self.event_batch) {
                done = true;
            } else {
                debug_assert!(
                    !buf.is_empty(),
                    "Workload::next_batch returned true without pushing events"
                );
            }
            for i in 0..buf.len() {
                self.on_event(buf[i], report);
                // epoch boundary: the Timer fires
                if self.epoch_vtime >= self.epoch_ns {
                    self.flush_epoch(flush, report)?;
                    epochs_fired += 1;
                    if let Some(max) = max_epochs {
                        if epochs_fired >= max {
                            // remaining buffered events are discarded,
                            // exactly like the per-event loop that never
                            // pulled them
                            break 'pump;
                        }
                    }
                }
            }
            // bulk scatter: one `record_bulk` pass per event batch
            // instead of one `record` call per sampled miss
            self.scatter_staged();
        }
        // the program exited mid-epoch: flush the partial epoch
        if self.epoch_vtime > 0.0 {
            self.flush_epoch(flush, report)?;
        }
        self.buf = buf;
        flush.finish(&mut self.tracker, report)
    }
}

/// The shared epoch-barrier fault step, run identically by every
/// driver *before* the stack's phase-1 hooks:
///
/// 1. advance the schedule ([`FaultState::epoch_begin`], plan order);
/// 2. on an overlay-revision edge, mirror the offline and degraded
///    masks into the stack so hooks (and failover itself) refuse dead
///    destinations and fault-aware policies see degradation;
/// 3. sweep offline pools that still hold live bytes — each fails over
///    to the fallback pool through the stack's cost-modeled migration
///    machinery (copy traffic + stall charged like any policy move),
///    or the run ends with the structured no-reachable-pool error.
///
/// Returns whether the overlay revision changed (the batched driver's
/// early-flush signal).
pub(crate) fn fault_epoch_barrier(
    fault: &mut FaultState,
    stack: &mut PolicyStack,
    tracker: &mut AllocTracker,
    epoch: u64,
    bytes_per_ev: f32,
) -> anyhow::Result<bool> {
    let changed = fault.epoch_begin(epoch);
    if changed {
        stack.set_offline_pools(&fault.offline);
        stack.set_degraded_pools(fault.degraded());
    }
    if fault.any_offline() {
        // cheap byte check per pool; regions allocated onto an offline
        // pool later (placement policies are topology-static) are
        // caught by the same sweep at the next barrier
        for from in 0..fault.offline.len() {
            if fault.offline[from]
                && tracker.stats.pool_bytes.get(from).copied().unwrap_or(0) > 0
            {
                let to = fault.fallback_pool(from)?;
                fault.failover_migrated_bytes +=
                    stack.failover_pool(tracker, from, to, bytes_per_ev);
            }
        }
    }
    Ok(changed)
}

/// Per-epoch analyze strategy: the classic coordinator mode. Runs the
/// fault barrier (schedule + failover), the policy stack's phase-1
/// (bin shaping + migration-traffic injection) hooks, the timing model
/// (under the epoch's fault overlay, if any), then the stack's phase-2
/// (migration/rebalance) hooks — all on every epoch boundary, so
/// placement actions see fresh analyzer outputs and their modeled cost
/// lands in the very next epoch.
pub struct PerEpochAnalyze<'m, 'p> {
    pub model: &'m mut dyn TimingModel,
    pub stack: Option<&'p mut PolicyStack>,
    /// Fault schedule; drivers guarantee a stack is installed whenever
    /// this is set (failover needs the migration machinery).
    pub fault: Option<&'p mut FaultState>,
    pub bytes_per_ev: f32,
    pub keep_epoch_records: bool,
    /// Epoch counter for the fault schedule (0-based; callers start
    /// runs at 0).
    pub epoch: u64,
}

impl EpochFlush for PerEpochAnalyze<'_, '_> {
    fn on_epoch(
        &mut self,
        bins: &mut EpochBins,
        native_ns: f64,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if let Some(fault) = &mut self.fault {
            if let Some(stack) = &mut self.stack {
                fault_epoch_barrier(fault, stack, tracker, self.epoch, self.bytes_per_ev)?;
            } else {
                fault.epoch_begin(self.epoch);
            }
        }
        if let Some(stack) = &mut self.stack {
            stack.before_analysis(bins, tracker, self.bytes_per_ev);
        }
        if let Some(fault) = &mut self.fault {
            self.model.set_fault_overlay(fault.overlay());
            // exact storm / warm-up attribution: stage 1 is a linear
            // dot product over post-injection bins, so each adder's
            // share of `lat` is recoverable in closed form (a
            // sub-component of lat_delay_ns, not an addition to it)
            fault.attribute_epoch_delays(|p| bins.read_count(p), |p| bins.write_count(p));
        }
        let out = self.model.analyze(&TimingInputs {
            reads: &bins.reads,
            writes: &bins.writes,
            bin_width: bins.bin_width_ns() as f32,
            bytes_per_ev: self.bytes_per_ev,
        })?;
        let mig_ns = match &mut self.stack {
            Some(stack) => stack.after_analysis(bins, &out, tracker, self.bytes_per_ev),
            None => 0.0,
        };
        report.push_epoch(native_ns, &out, mig_ns, bins.total_events, self.keep_epoch_records);
        self.epoch += 1;
        Ok(())
    }
}

/// One epoch parked in a [`BatchedFlush`] group (or a
/// `pipeline::PipelinedBatchFlush` in-flight group), waiting for
/// analysis.
pub(crate) struct PendingEpoch {
    pub(crate) reads: Vec<f32>,
    pub(crate) writes: Vec<f32>,
    pub(crate) native_ns: f64,
    pub(crate) events: u64,
    /// Snapshot of the stack's injected-events vector taken when this
    /// epoch's phase-1 ran — restored before its phase-2 at flush time
    /// so the anti-cascade demand subtraction sees the right epoch's
    /// copy traffic (empty when no stack is installed).
    pub(crate) injected: Vec<f64>,
    /// Stall accrued by this epoch's phase-1 hooks (migrations in
    /// `before_analysis`), parked here and re-credited before the
    /// epoch's phase 2 so it lands in the right epoch's record.
    pub(crate) phase1_stall_ns: f64,
}

/// Grouped-analyze strategy: accumulates E epochs of histograms and
/// flushes them through one [`BatchTimingModel`] call (PJRT dispatch
/// amortization for offline replay; a plain loop on the native
/// backend). The policy stack still runs both phases: phase-1 (bin
/// shaping + migration-traffic injection) at epoch-boundary time, on
/// the live bins, *before* they are parked in the group; phase-2
/// (migration/rebalance) per epoch at group-flush time, so placement
/// actions take effect up to E−1 epochs late — the documented fidelity
/// trade of batched replay (delays never feed back into the event
/// stream either way).
pub struct BatchedFlush<'m, 'p> {
    pub model: &'m mut dyn BatchTimingModel,
    pub stack: Option<&'p mut PolicyStack>,
    /// Fault schedule; drivers guarantee a stack is installed whenever
    /// this is set. Overlays are piecewise-constant over fault windows,
    /// so the pending group is flushed early on every overlay-revision
    /// edge and one `analyze_batch` call never spans two overlays —
    /// which is what keeps group-1 and group-256 runs bit-identical
    /// under faults.
    pub fault: Option<&'p mut FaultState>,
    pub bytes_per_ev: f32,
    pub keep_epoch_records: bool,
    /// Epoch counter for the fault schedule (0-based).
    epoch: u64,
    /// Snapshot of the overlay the *pending* group's epochs ran under
    /// (the live [`FaultState`] may already have advanced past it when
    /// a revision edge triggers the early flush).
    group_overlay: Option<FaultOverlay>,
    pending: Vec<PendingEpoch>,
    /// Recycled `PendingEpoch`s: after a group flush their buffers are
    /// reused, so steady state allocates nothing per epoch.
    spare: Vec<PendingEpoch>,
    /// Scratch [E, P, B] upload buffers, reused across group flushes.
    scratch_reads: Vec<f32>,
    scratch_writes: Vec<f32>,
    /// Scratch bins handed to the policy (allocated once, on demand).
    policy_bins: Option<EpochBins>,
    bin_width: f32,
    nbins: usize,
    epoch_ns: f64,
}

impl<'m, 'p> BatchedFlush<'m, 'p> {
    pub fn new(
        model: &'m mut dyn BatchTimingModel,
        bytes_per_ev: f32,
        keep_epoch_records: bool,
        bin_width: f32,
        nbins: usize,
        epoch_ns: f64,
    ) -> BatchedFlush<'m, 'p> {
        let cap = model.batch();
        BatchedFlush {
            model,
            stack: None,
            fault: None,
            bytes_per_ev,
            keep_epoch_records,
            epoch: 0,
            group_overlay: None,
            pending: Vec::with_capacity(cap),
            spare: Vec::with_capacity(cap),
            scratch_reads: Vec::new(),
            scratch_writes: Vec::new(),
            policy_bins: None,
            bin_width,
            nbins,
            epoch_ns,
        }
    }

    fn flush_group(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let (e, p, s, b) = (
            self.model.batch(),
            self.model.pools(),
            self.model.switches(),
            self.model.nbins(),
        );
        let filled = self.pending.len();
        self.scratch_reads.clear();
        self.scratch_reads.resize(e * p * b, 0.0);
        self.scratch_writes.clear();
        self.scratch_writes.resize(e * p * b, 0.0);
        for (i, ep) in self.pending.iter().enumerate() {
            self.scratch_reads[i * p * b..i * p * b + ep.reads.len()]
                .copy_from_slice(&ep.reads);
            self.scratch_writes[i * p * b..i * p * b + ep.writes.len()]
                .copy_from_slice(&ep.writes);
        }
        if self.fault.is_some() {
            // every epoch in the group ran under this one overlay (the
            // revision-edge early flush guarantees it)
            self.model.set_fault_overlay(self.group_overlay.as_ref());
        }
        let out = self.model.analyze_batch(
            &self.scratch_reads,
            &self.scratch_writes,
            self.bin_width,
            self.bytes_per_ev,
        )?;
        for i in 0..filled {
            let one = out.epoch(i, p, s);
            let ep = &self.pending[i];
            let mig_ns = if let Some(stack) = &mut self.stack {
                // rebuild this epoch's bins view for the phase-2 hooks
                let bins = self
                    .policy_bins
                    .get_or_insert_with(|| EpochBins::new(p, self.nbins, self.epoch_ns));
                bins.reads.copy_from_slice(&ep.reads);
                bins.writes.copy_from_slice(&ep.writes);
                bins.total_events = ep.events;
                // restore THIS epoch's injected-copy vector and
                // phase-1 stall (the live ones belong to the most
                // recent boundary, not epoch i)
                stack.set_injected_events(&ep.injected);
                stack.credit_accrued_stall_ns(ep.phase1_stall_ns);
                stack.after_analysis(bins, &one, tracker, self.bytes_per_ev)
            } else {
                0.0
            };
            report.push_epoch(ep.native_ns, &one, mig_ns, ep.events, self.keep_epoch_records);
        }
        self.spare.append(&mut self.pending);
        Ok(())
    }
}

impl EpochFlush for BatchedFlush<'_, '_> {
    fn on_epoch(
        &mut self,
        bins: &mut EpochBins,
        native_ns: f64,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        if self.fault.is_some() {
            // the barrier steps run inline (not via fault_epoch_barrier)
            // because their order interleaves with the early flush: the
            // parked epochs' phase-2 hooks must run under the offline /
            // degraded masks their epochs ran under, so the schedule
            // advances and the group flushes BEFORE the new masks are
            // mirrored into the stack — and the failover sweep runs
            // after, so its stall is parked with THIS epoch below,
            // matching the sequential driver's stall placement
            let changed = self.fault.as_mut().unwrap().epoch_begin(self.epoch);
            if changed {
                // flush the parked epochs under the overlay and masks
                // they ran under, then re-snapshot for the new window
                if !self.pending.is_empty() {
                    self.flush_group(tracker, report)?;
                }
                let fault = self.fault.as_mut().unwrap();
                self.group_overlay = fault.overlay().cloned();
                if let Some(stack) = &mut self.stack {
                    stack.set_offline_pools(&fault.offline);
                    stack.set_degraded_pools(fault.degraded());
                }
            }
            let fault = self.fault.as_mut().unwrap();
            if fault.any_offline() {
                if let Some(stack) = &mut self.stack {
                    // same sweep as fault_epoch_barrier: evacuate
                    // offline pools that still hold live bytes
                    for from in 0..fault.offline.len() {
                        if fault.offline[from]
                            && tracker.stats.pool_bytes.get(from).copied().unwrap_or(0) > 0
                        {
                            let to = fault.fallback_pool(from)?;
                            fault.failover_migrated_bytes +=
                                stack.failover_pool(tracker, from, to, self.bytes_per_ev);
                        }
                    }
                }
            }
        }
        // phase 1 runs on the live bins, before they are parked — bin
        // shaping must happen before analysis, and this keeps the
        // shaped histograms in the group the analyzer will see
        if let Some(stack) = &mut self.stack {
            stack.before_analysis(bins, tracker, self.bytes_per_ev);
        }
        if let Some(fault) = &mut self.fault {
            // storm / warm-up attribution happens at boundary time, on
            // the live post-injection bins — identical to the
            // sequential driver regardless of when the group flushes
            fault.attribute_epoch_delays(|p| bins.read_count(p), |p| bins.write_count(p));
        }
        let mut ep = self.spare.pop().unwrap_or_else(|| PendingEpoch {
            reads: Vec::with_capacity(bins.reads.len()),
            writes: Vec::with_capacity(bins.writes.len()),
            native_ns: 0.0,
            events: 0,
            injected: Vec::new(),
            phase1_stall_ns: 0.0,
        });
        ep.reads.clear();
        ep.reads.extend_from_slice(&bins.reads);
        ep.writes.clear();
        ep.writes.extend_from_slice(&bins.writes);
        ep.native_ns = native_ns;
        ep.events = bins.total_events;
        ep.injected.clear();
        ep.phase1_stall_ns = 0.0;
        if let Some(stack) = &mut self.stack {
            ep.injected.extend_from_slice(stack.injected_events());
            ep.phase1_stall_ns = stack.take_accrued_stall_ns();
        }
        self.pending.push(ep);
        // the policy-lateness bound: phase-2 hooks of a parked epoch
        // run at most group−1 epochs after its boundary, because the
        // group can never hold more than `batch()` epochs
        debug_assert!(
            self.pending.len() <= self.model.batch(),
            "pending group overflow: {} > {}",
            self.pending.len(),
            self.model.batch()
        );
        if self.pending.len() == self.model.batch() {
            self.flush_group(tracker, report)?;
        }
        self.epoch += 1;
        Ok(())
    }

    fn finish(
        &mut self,
        tracker: &mut AllocTracker,
        report: &mut SimReport,
    ) -> anyhow::Result<()> {
        self.flush_group(tracker, report)
    }
}

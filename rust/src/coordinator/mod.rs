//! The CXLMemSim coordinator: the paper's §3 system.
//!
//! Attaches to a workload (the "unmodified program"), divides its
//! execution into epochs (the *Timer*), collects the epoch's allocation
//! + memory events through the tracer substrate (workload engine →
//! cache hierarchy → allocation tracker), bins them, invokes the
//! AOT-compiled *Timing Analyzer* through PJRT, and injects the
//! computed delay into the program's simulated clock.
//!
//! Time accounting:
//!
//! * **native virtual time** — what the program would take on the host
//!   with all memory local: per-access CPI + cache hit/miss latency
//!   (misses cost local-DRAM latency, since that is where the traced
//!   program's memory actually lives while profiling);
//! * **simulated time** — native time plus the analyzer's per-epoch
//!   latency/congestion/bandwidth delays: the tool's *output*;
//! * **wall time** — what running the tool costs us: Table 1's metric.

pub mod batch;
pub mod driver;
pub mod pipeline;
pub mod report;

pub use batch::{run_batched, run_batched_with};
pub use driver::{BatchedFlush, EpochDriver, EpochFlush, PerEpochAnalyze, DEFAULT_EVENT_BATCH};
pub use pipeline::{PipelinedAnalyze, PipelinedBatchFlush, PIPELINE_DEPTH};
pub use report::{EpochRecord, PolicyReport, SimReport, TracerRunStats};

use crate::alloctrack::{AllocTracker, PolicyKind};
use crate::policy::{PolicySpec, PolicyStack};
use crate::runtime::{self, AnalyzerBackend, TimingModel};
use crate::topology::{TopoTensors, Topology};
use crate::workload::{self, Workload};

/// Coordinator configuration (CLI flags map 1:1 onto these fields).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Epoch length in virtual milliseconds (paper: timer period).
    pub epoch_ms: f64,
    /// Time bins per epoch (must match the compiled artifact).
    pub nbins: usize,
    pub backend: AnalyzerBackend,
    pub policy: PolicyKind,
    /// PEBS-style sampling period: every k-th LLC miss is recorded,
    /// weighted by k.
    pub sample_period: u32,
    /// Workload working-set scale in (0, 1]; 1.0 = the paper's sizes.
    pub scale: f64,
    pub seed: u64,
    /// Cache-geometry shrink factor (1 = the paper's i9-12900K).
    pub cache_scale: u64,
    pub artifacts_dir: String,
    /// Stop after this many epochs (None = run to completion).
    pub max_epochs: Option<u64>,
    /// Base virtual cost per instruction window between accesses, ns.
    pub cpi_ns: f64,
    /// Memory-level parallelism: an OoO core overlaps this many
    /// outstanding misses, so a miss stalls the core local_lat/mlp ns
    /// on average (gem5like models the same effect with 16 MSHRs).
    /// Default 2.0 keeps a lone streaming host below switch saturation
    /// (ρ≈0.55); congestion then arises from host *sharing*, as in the
    /// paper's §2 discussion. Raise it to model aggressive OoO cores —
    /// at ρ>1 the open-loop fluid queue diverges by design (DESIGN.md §5).
    pub mlp: f64,
    /// Virtual cost of one allocation syscall, ns.
    pub alloc_cost_ns: f64,
    /// Keep every epoch record (memory!) instead of summarizing.
    pub keep_epoch_records: bool,
    /// Hardware prefetcher model: "nextline" | "stride" | None.
    /// Prefetched lines are fetched into L2/LLC (hiding future demand
    /// latency) and their link traffic is binned as reads — a
    /// conservative accounting documented in DESIGN.md §5.
    pub prefetcher: Option<String>,
    /// Events pulled per `Workload::next_batch` call in the epoch
    /// driver's pump. 1 = the legacy one-virtual-call-per-event loop
    /// (kept as a measurable baseline); larger values keep the inner
    /// loop monomorphic. Simulation output is identical for any value
    /// (`tests/pipeline_equivalence.rs`).
    pub event_batch: usize,
    /// Epoch-policy stack spec (`--epoch-policy
    /// hotness:3,prefetch:0.5,rebalance`). Every driver — sequential
    /// coordinator, batched replay, multihost (per host) — builds its
    /// stack(s) from this. None = no policy engine installed.
    pub epoch_policy: Option<PolicySpec>,
    /// Modeled migration cost: stall charged per migrated byte, ns
    /// (`crate::policy`). Default 0.0625 ns/B ≈ a 16 GB/s page-copy
    /// engine; the copy *traffic* is injected into the next epoch's
    /// bins regardless of this knob.
    pub mig_stall_ns_per_byte: f64,
    /// Worker threads the batched replay drivers shard the native
    /// analyzer's E-epoch loop across (`run --batched`, `replay
    /// --batched`): `0` = one per core (auto), `1` = sequential.
    /// Epochs are independent and each worker writes disjoint `[E, ·]`
    /// output rows, so results are bit-identical for every value
    /// (`tests/pipeline_equivalence.rs`); only wall-clock changes.
    pub analyzer_threads: usize,
    /// Native queueing-scan kernel (`--scan-kernel exact|blocked`).
    /// `blocked` (default) runs the max-plus block scans — fastest,
    /// tolerance-equal to the reference; `exact` runs the scalar
    /// reference recurrences, bit-identical to `artifacts/golden.json`
    /// (the golden tests and the CI determinism matrix pin it).
    pub scan_kernel: runtime::ScanKernel,
    /// Native batched-analyzer group size E (`--batch-group`; `0` =
    /// `shapes::BATCH` = 16). Long offline replays profit from larger
    /// groups (the sharded analyzer gets more epochs per fan-out — the
    /// bench measures 256); the trade is policy phase-2 hooks running
    /// up to E−1 epochs late at group-flush time (`coordinator::batch`).
    pub batch_group: usize,
    /// Per-epoch multiplicative decay applied to region heat counters
    /// at the epoch boundary (1.0 = no decay, today's lifetime-
    /// cumulative behavior). Below 1.0, old heat fades exponentially so
    /// migration policies chase *current* hot regions instead of
    /// regions that were hot long ago (`AllocTracker::decay_heat`).
    pub heat_decay: f64,
    /// Deterministic RAS fault schedule (`--faults file.toml` /
    /// `--fault inline-spec`, see `crate::fault`). Pool references are
    /// resolved against the run's topology at run start; None (the
    /// default) leaves the fault machinery entirely unconstructed.
    /// Requires the native backend (the AOT HLO has no overlay inputs).
    pub faults: Option<crate::fault::FaultPlan>,
    /// Pipelined epoch execution (`--pipeline`): run the analyzer on a
    /// dedicated worker behind a depth-1 rendezvous so the pump fills
    /// epoch N+1 while epoch N analyzes (`coordinator::pipeline`).
    /// Reports are bit-identical to serial runs; a policy stack with
    /// members forces lock-step draining (no overlap) to keep phase-2
    /// in its serial position. Requires the native backend (PJRT
    /// client handles are thread-local).
    pub pipeline: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            epoch_ms: 1.0,
            nbins: runtime::shapes::NUM_BINS,
            backend: AnalyzerBackend::Native,
            policy: PolicyKind::CxlOnly,
            sample_period: 1,
            scale: 1.0,
            seed: 0x5107,
            cache_scale: 1,
            artifacts_dir: runtime::shapes::artifacts_dir(),
            max_epochs: None,
            cpi_ns: 0.3,
            mlp: 2.0,
            alloc_cost_ns: 1_000.0,
            keep_epoch_records: false,
            prefetcher: None,
            event_batch: driver::DEFAULT_EVENT_BATCH,
            epoch_policy: None,
            mig_stall_ns_per_byte: 0.0625,
            analyzer_threads: 0,
            scan_kernel: runtime::ScanKernel::default(),
            batch_group: 0,
            heat_decay: 1.0,
            faults: None,
            pipeline: false,
        }
    }
}

impl SimConfig {
    pub fn epoch_ns(&self) -> f64 {
        self.epoch_ms * 1e6
    }
}

/// The simulator instance, bound to one topology + config.
pub struct Coordinator {
    pub topo: Topology,
    pub cfg: SimConfig,
    model: Box<dyn TimingModel>,
    driver: EpochDriver,
    stack: Option<PolicyStack>,
    /// Remembered so a pipelined run can arm its worker's model the
    /// same way `set_export_backlog` armed `self.model`.
    export_backlog: bool,
}

impl Coordinator {
    pub fn new(topo: Topology, cfg: SimConfig) -> anyhow::Result<Coordinator> {
        ensure_fault_backend(&cfg)?;
        ensure_pipeline_backend(&cfg)?;
        let tensors = TopoTensors::build(
            &topo,
            runtime::shapes::NUM_POOLS,
            runtime::shapes::NUM_SWITCHES,
        )?;
        // backlog export defaults off everywhere (hot path stays
        // allocation-light); nothing in the built-in policy engine
        // needs it — opt in through `TimingModel::set_export_backlog`
        let model = runtime::make_analyzer(
            cfg.backend,
            &tensors,
            cfg.nbins,
            &cfg.artifacts_dir,
            cfg.scan_kernel,
        )?;
        let driver = EpochDriver::new(&topo, &cfg)?;
        let stack = cfg
            .epoch_policy
            .as_ref()
            .map(|spec| spec.build(cfg.mig_stall_ns_per_byte));
        let mut coord =
            Coordinator { topo, cfg, model, driver, stack: None, export_backlog: false };
        if let Some(stack) = stack {
            coord.set_policy_stack(stack);
        }
        Ok(coord)
    }

    /// Install a two-phase policy stack (migration / prefetch /
    /// rebalance — see `crate::policy`). Replaces any stack built from
    /// `SimConfig::epoch_policy`. No analyzer mode changes: the
    /// built-in policies read the always-exported per-switch
    /// congestion totals, not the backlog profile, so the same stack
    /// runs the same analyzer path on every driver (a policy that
    /// needs the `[S, B]` profile can enable
    /// `TimingModel::set_export_backlog` itself).
    pub fn set_policy_stack(&mut self, stack: PolicyStack) {
        self.stack = Some(stack);
    }

    /// Opt into the analyzer's per-switch `[S, B]` backlog-profile
    /// export (`TimingOutputs::cong_backlog`) — costs an extra store +
    /// copy per epoch, so it is off unless a custom policy reads it.
    pub fn set_export_backlog(&mut self, on: bool) {
        self.export_backlog = on;
        self.model.set_export_backlog(on);
    }

    /// The installed stack, if any (inspection after a run).
    pub fn policy_stack(&self) -> Option<&PolicyStack> {
        self.stack.as_ref()
    }

    pub fn tracker(&self) -> &AllocTracker {
        &self.driver.tracker
    }

    pub fn backend_name(&self) -> &'static str {
        self.model.backend_name()
    }

    /// Convenience: construct a named workload and run it.
    pub fn run_workload(&mut self, name: &str) -> anyhow::Result<SimReport> {
        let mut wl = workload::by_name(name, self.cfg.scale, self.cfg.seed)
            .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))?;
        self.run(wl.as_mut())
    }

    /// The epoch loop (paper Figure 2), driven by the shared
    /// [`EpochDriver`] with a per-epoch analyze flush.
    pub fn run(&mut self, wl: &mut dyn Workload) -> anyhow::Result<SimReport> {
        let wall_start = std::time::Instant::now();
        let mut report = SimReport::new(
            wl.name(),
            &self.topo.name,
            self.model.backend_name(),
            self.topo.num_pools(),
        );
        report.scan_kernel = self.model.scan_kernel().name().to_string();
        self.driver.reset();
        // resolve the fault plan against this run's topology (names →
        // pool ids, validation, seeded jitter); fault-free runs never
        // construct any of this
        let mut fault = match &self.cfg.faults {
            Some(plan) => Some(plan.resolve(&self.topo)?),
            None => None,
        };
        if fault.is_some() && self.stack.is_none() {
            // pool-offline failover routes through the policy stack's
            // cost-modeled migration machinery; an empty stack is
            // bit-identical to no stack (tests/pipeline_equivalence.rs)
            self.stack = Some(PolicyStack::new(self.cfg.mig_stall_ns_per_byte));
        }
        if let Some(stack) = &mut self.stack {
            stack.begin_run(); // per-run policy accounting, like the tracker
        }
        if self.cfg.pipeline {
            // the worker owns its own Send model (cheap to build on
            // the native backend — `ensure_pipeline_backend` rejected
            // PJRT up front); `self.model` stays untouched, so a later
            // non-pipelined run on this coordinator is unaffected
            let tensors = TopoTensors::build(
                &self.topo,
                runtime::shapes::NUM_POOLS,
                runtime::shapes::NUM_SWITCHES,
            )?;
            let mut model = runtime::make_send_analyzer(
                self.cfg.backend,
                &tensors,
                self.cfg.nbins,
                self.cfg.scan_kernel,
            )?;
            model.set_export_backlog(self.export_backlog);
            let mut flush = PipelinedAnalyze::new(
                model,
                self.topo.host.cacheline_bytes as f32,
                self.cfg.keep_epoch_records,
                self.driver.bins.bin_width_ns() as f32,
                self.cfg.nbins,
                self.cfg.epoch_ns(),
            )?;
            flush.stack = self.stack.as_mut();
            flush.fault = fault.as_mut();
            self.driver.run(wl, &mut flush, &mut report, self.cfg.max_epochs)?;
        } else {
            let mut flush = PerEpochAnalyze {
                model: self.model.as_mut(),
                stack: self.stack.as_mut(),
                fault: fault.as_mut(),
                bytes_per_ev: self.topo.host.cacheline_bytes as f32,
                keep_epoch_records: self.cfg.keep_epoch_records,
                epoch: 0,
            };
            self.driver.run(wl, &mut flush, &mut report, self.cfg.max_epochs)?;
            // make sure a later fault-free run on this coordinator
            // doesn't inherit the overlay
            self.model.set_fault_overlay(None);
        }
        report.finish(
            &self.driver.cache.stats,
            self.driver.tracer_run_stats(),
            wall_start.elapsed(),
        );
        if let Some(stack) = &self.stack {
            report.record_policy_stats(stack);
        }
        if let Some(f) = &fault {
            report.record_fault_stats(f);
        }
        Ok(report)
    }
}

/// Fault plans need the native analyzer: the AOT HLO's input contract
/// has no per-epoch latency/bandwidth overlay tensors, so requesting
/// faults on the PJRT backend is a clean config error up front rather
/// than silently fault-free output.
pub(crate) fn ensure_fault_backend(cfg: &SimConfig) -> anyhow::Result<()> {
    if cfg.faults.is_some() && cfg.backend == AnalyzerBackend::Pjrt {
        anyhow::bail!(
            "fault injection requires `--backend native` (the AOT HLO artifacts \
             have no fault-overlay inputs)"
        );
    }
    Ok(())
}

/// Pipelined execution needs a model that can move to the analysis
/// worker thread; PJRT client handles are thread-local, so requesting
/// `--pipeline` there is a clean config error up front (mirrors
/// [`ensure_fault_backend`]).
pub(crate) fn ensure_pipeline_backend(cfg: &SimConfig) -> anyhow::Result<()> {
    if cfg.pipeline && cfg.backend == AnalyzerBackend::Pjrt {
        anyhow::bail!(
            "--pipeline requires `--backend native` (PJRT client handles are \
             thread-local and cannot move to the pipelined analysis worker)"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;

    fn cfg_fast() -> SimConfig {
        SimConfig {
            scale: 0.002,
            cache_scale: 64,
            epoch_ms: 0.1,
            ..SimConfig::default()
        }
    }

    #[test]
    fn run_mmap_read_end_to_end_native_backend() {
        let mut sim = Coordinator::new(builtin::fig2(), cfg_fast()).unwrap();
        let rep = sim.run_workload("mmap_read").unwrap();
        assert!(rep.total_accesses > 0);
        assert!(rep.total_misses > 0, "streaming read must miss");
        assert!(rep.epochs_run > 0);
        assert!(rep.native_ns > 0.0);
        assert!(
            rep.simulated_ns > rep.native_ns,
            "CXL placement must slow the program: sim={} native={}",
            rep.simulated_ns,
            rep.native_ns
        );
    }

    #[test]
    fn local_policy_means_no_slowdown() {
        let mut cfg = cfg_fast();
        cfg.policy = PolicyKind::LocalOnly;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("mmap_write").unwrap();
        assert!(rep.total_misses > 0);
        assert!(
            (rep.simulated_ns - rep.native_ns).abs() < 1e-3,
            "local-only placement must add zero delay, got +{}",
            rep.simulated_ns - rep.native_ns
        );
    }

    #[test]
    fn sample_period_preserves_delay_scale() {
        let mk = |period: u32| {
            let mut cfg = cfg_fast();
            cfg.sample_period = period;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("stream").unwrap()
        };
        let full = mk(1);
        let sampled = mk(8);
        assert!(full.delay_ns > 0.0);
        let ratio = sampled.delay_ns / full.delay_ns;
        assert!(
            (0.5..2.0).contains(&ratio),
            "period-8 sampling should roughly preserve total delay, ratio={ratio}"
        );
    }

    #[test]
    fn max_epochs_caps_run() {
        let mut cfg = cfg_fast();
        cfg.max_epochs = Some(3);
        cfg.scale = 0.05;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("uniform").unwrap();
        assert_eq!(rep.epochs_run, 3);
    }

    #[test]
    fn delay_monotone_in_pool_latency() {
        // deep topology (2 switch hops) must delay more than direct
        let run = |topo| {
            let mut sim = Coordinator::new(topo, cfg_fast()).unwrap();
            sim.run_workload("mmap_write").unwrap()
        };
        let direct = run(builtin::direct());
        let deep = run(builtin::deep());
        assert!(
            deep.delay_ns > direct.delay_ns,
            "deep {} <= direct {}",
            deep.delay_ns,
            direct.delay_ns
        );
    }

    #[test]
    fn unknown_workload_errors() {
        let mut sim = Coordinator::new(builtin::fig2(), cfg_fast()).unwrap();
        assert!(sim.run_workload("doom").is_err());
    }

    #[test]
    fn report_breakdown_sums_to_delay() {
        let mut sim = Coordinator::new(builtin::fig2(), cfg_fast()).unwrap();
        let rep = sim.run_workload("zipfian").unwrap();
        let sum = rep.lat_delay_ns + rep.cong_delay_ns + rep.bwd_delay_ns + rep.mig_delay_ns;
        assert!(
            (sum - rep.delay_ns).abs() <= 1e-6 * rep.delay_ns.max(1.0),
            "breakdown {sum} != total {}",
            rep.delay_ns
        );
    }

    #[test]
    fn report_breakdown_sums_to_delay_with_migrations() {
        // the 4-component breakdown must hold when the policy engine
        // charges migration stall
        let mut cfg = cfg_fast();
        cfg.scale = 0.004;
        cfg.epoch_policy = Some(crate::policy::PolicySpec::parse("hotness:1").unwrap());
        cfg.mig_stall_ns_per_byte = 0.25;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("zipfian").unwrap();
        assert!(rep.migrations > 0, "hotness:1 on zipfian must migrate");
        assert!(rep.mig_delay_ns > 0.0);
        let sum = rep.lat_delay_ns + rep.cong_delay_ns + rep.bwd_delay_ns + rep.mig_delay_ns;
        assert!(
            (sum - rep.delay_ns).abs() <= 1e-6 * rep.delay_ns.max(1.0),
            "breakdown {sum} != total {}",
            rep.delay_ns
        );
    }

    #[test]
    fn stack_built_from_config_reports_per_policy_stats() {
        let mut cfg = cfg_fast();
        cfg.scale = 0.004;
        cfg.epoch_policy =
            Some(crate::policy::PolicySpec::parse("hotness:1,prefetch:0.5").unwrap());
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("zipfian").unwrap();
        assert_eq!(rep.policies.len(), 2);
        assert_eq!(rep.policies[0].name, "hotness-migration");
        assert_eq!(rep.policies[1].name, "software-prefetch");
        assert!(rep.migrations > 0);
        assert!(rep.migrated_bytes > 0);
        // cost model: migrated bytes either already injected as link
        // traffic or still pending the next epoch — never lost
        let accounted = rep.mig_injected_read_bytes + rep.mig_pending_bytes;
        assert_eq!(accounted, rep.migrated_bytes as f64, "read-side conservation");
        let accounted_w = rep.mig_injected_write_bytes + rep.mig_pending_bytes;
        assert_eq!(accounted_w, rep.migrated_bytes as f64, "write-side conservation");
    }

    #[test]
    fn nextline_prefetcher_cuts_stream_misses() {
        let run = |pf: Option<&str>| {
            let mut cfg = cfg_fast();
            cfg.prefetcher = pf.map(|s| s.to_string());
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("stream").unwrap()
        };
        let off = run(None);
        let on = run(Some("nextline"));
        assert!(on.prefetches > 0, "prefetcher must issue fetches");
        assert!(
            on.total_misses < off.total_misses,
            "nextline must cut sequential demand misses: {} !< {}",
            on.total_misses,
            off.total_misses
        );
    }

    #[test]
    fn stride_prefetcher_works_on_stencil() {
        let run = |pf: Option<&str>| {
            let mut cfg = cfg_fast();
            cfg.prefetcher = pf.map(|s| s.to_string());
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("wrf_like").unwrap()
        };
        let off = run(None);
        let on = run(Some("stride"));
        assert!(on.total_misses <= off.total_misses);
    }

    #[test]
    fn unknown_prefetcher_is_error() {
        let mut cfg = cfg_fast();
        cfg.prefetcher = Some("oracle".into());
        assert!(Coordinator::new(builtin::fig2(), cfg).is_err());
    }

    #[test]
    fn tracer_counters_are_per_run_not_cumulative() {
        // the tracker persists across runs on one Coordinator; the
        // report must still carry THIS run's deltas. Invariant: MRU
        // hits can never exceed this run's pool_of lookups (one per
        // miss, write-back, and prefetch fill) — a cumulative counter
        // blows through that bound on the second run.
        let mut sim = Coordinator::new(builtin::fig2(), cfg_fast()).unwrap();
        let first = sim.run_workload("stream").unwrap();
        assert!(first.pool_mru_hits > 0);
        assert!(first.bins_staged > 0);
        let second = sim.run_workload("stream").unwrap();
        let lookups = second.total_misses + second.writebacks + second.prefetches;
        assert!(
            second.pool_mru_hits <= lookups,
            "second run reports {} MRU hits but only {} lookups — cumulative leak",
            second.pool_mru_hits,
            lookups
        );
    }

    #[test]
    fn scan_kernels_agree_end_to_end_and_are_reported() {
        // same workload through both kernels: identical event
        // accounting, delay totals within the blocked kernel's
        // tolerance, and the kernel name lands in the report
        let run = |kernel| {
            let mut cfg = cfg_fast();
            cfg.scan_kernel = kernel;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("zipfian").unwrap()
        };
        let exact = run(crate::runtime::ScanKernel::Exact);
        let blocked = run(crate::runtime::ScanKernel::Blocked);
        assert_eq!(exact.scan_kernel, "exact");
        assert_eq!(blocked.scan_kernel, "blocked");
        assert_eq!(exact.total_misses, blocked.total_misses, "substrate is kernel-blind");
        assert!(exact.delay_ns > 0.0);
        let rel = (exact.delay_ns - blocked.delay_ns).abs() / exact.delay_ns;
        assert!(
            rel < 1e-5,
            "kernels drifted: exact {} blocked {} (rel {rel})",
            exact.delay_ns,
            blocked.delay_ns
        );
    }

    #[test]
    fn heat_decay_without_policies_changes_nothing() {
        // heat is only read by migration policies; with no stack
        // installed a decaying run must match the default bit-for-bit
        let run = |decay: f64| {
            let mut cfg = cfg_fast();
            cfg.heat_decay = decay;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("stream").unwrap()
        };
        let plain = run(1.0);
        let decayed = run(0.5);
        assert_eq!(plain.delay_ns, decayed.delay_ns);
        assert_eq!(plain.total_misses, decayed.total_misses);
        assert_eq!(plain.simulated_ns, decayed.simulated_ns);
    }

    #[test]
    fn epoch_records_kept_when_asked() {
        let mut cfg = cfg_fast();
        cfg.keep_epoch_records = true;
        cfg.max_epochs = Some(5);
        cfg.scale = 0.05;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("stream").unwrap();
        assert_eq!(rep.epochs.len() as u64, rep.epochs_run);
    }

    #[test]
    fn pipelined_run_is_bit_identical_to_serial() {
        let run = |pipeline: bool| {
            let mut cfg = cfg_fast();
            cfg.pipeline = pipeline;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("zipfian").unwrap()
        };
        let serial = run(false);
        let piped = run(true);
        assert_eq!(serial.total_accesses, piped.total_accesses);
        assert_eq!(serial.total_misses, piped.total_misses);
        assert_eq!(serial.epochs_run, piped.epochs_run);
        assert_eq!(serial.native_ns, piped.native_ns);
        assert_eq!(serial.delay_ns, piped.delay_ns);
        assert_eq!(serial.lat_delay_ns, piped.lat_delay_ns);
        assert_eq!(serial.cong_delay_ns, piped.cong_delay_ns);
        assert_eq!(serial.bwd_delay_ns, piped.bwd_delay_ns);
        assert_eq!(serial.simulated_ns, piped.simulated_ns);
        // no policy stack -> overlapped mode: depth 1, analysis timed
        assert_eq!(serial.pipeline_depth, 0);
        assert_eq!(piped.pipeline_depth, 1);
        assert!(piped.analyze_busy_ns > 0.0);
        assert!(piped.pump_busy_ns > 0.0);
        assert!((0.0..=1.0).contains(&piped.overlap_frac));
    }

    #[test]
    fn pipelined_run_with_policy_stack_locks_step() {
        let run = |pipeline: bool| {
            let mut cfg = cfg_fast();
            cfg.scale = 0.004;
            cfg.epoch_policy =
                Some(crate::policy::PolicySpec::parse("hotness:1,prefetch:0.5").unwrap());
            cfg.mig_stall_ns_per_byte = 0.25;
            cfg.pipeline = pipeline;
            let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
            sim.run_workload("zipfian").unwrap()
        };
        let serial = run(false);
        let piped = run(true);
        assert!(piped.migrations > 0, "stack must stay live under the pipeline");
        assert_eq!(serial.migrations, piped.migrations);
        assert_eq!(serial.migrated_bytes, piped.migrated_bytes);
        assert_eq!(serial.delay_ns, piped.delay_ns);
        assert_eq!(serial.mig_delay_ns, piped.mig_delay_ns);
        assert_eq!(serial.simulated_ns, piped.simulated_ns);
        // phase-2 mutates placement, so the pipeline must have drained
        // lock-step: no overlap is claimed
        assert_eq!(piped.pipeline_depth, 0);
    }

    #[test]
    fn pipeline_rejects_pjrt_backend() {
        let mut cfg = cfg_fast();
        cfg.pipeline = true;
        cfg.backend = crate::runtime::AnalyzerBackend::Pjrt;
        let err = Coordinator::new(builtin::fig2(), cfg).unwrap_err();
        assert!(err.to_string().contains("--pipeline requires"), "got: {err:#}");
    }
}

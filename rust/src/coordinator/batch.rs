//! Batched offline replay: process a workload with a grouped analyzer
//! flush, amortizing analyzer dispatch across E epochs per call. On the
//! PJRT backend this uses the `timing_batch{E}` AOT artifact (§Perf:
//! ~46 µs/epoch vs ~150 µs single-shot); on the native backend it is a
//! plain loop, so batched replay works without artifacts and is
//! bit-identical to the sequential coordinator.
//!
//! Semantically equivalent to the sequential epoch loop because epoch
//! delays do not feed back into the event stream (the workload's events
//! are independent of injected delay); verified against the sequential
//! coordinator in `rust/tests/e2e.rs` and
//! `rust/tests/pipeline_equivalence.rs`.
//!
//! Event accounting runs through the shared [`super::EpochDriver`], so
//! this mode has full parity with the sequential coordinator —
//! prefetcher traffic, write-backs, sampling, and (via
//! [`run_batched_with`]) epoch policies, whose tracker mutations apply
//! at group-flush time, i.e. up to E−1 epochs late. The pre-driver
//! implementation silently dropped prefetcher traffic and never invoked
//! policies; `tests/pipeline_equivalence.rs` keeps that fixed.

use crate::policy::EpochPolicy;
use crate::runtime::{self, shapes};
use crate::topology::{TopoTensors, Topology};
use crate::workload::Workload;

use super::driver::{BatchedFlush, EpochDriver};
use super::report::SimReport;
use super::SimConfig;

/// Run a workload through the grouped analyzer (no epoch policy).
pub fn run_batched(
    topo: &Topology,
    cfg: &SimConfig,
    wl: &mut dyn Workload,
) -> anyhow::Result<SimReport> {
    run_batched_with(topo, cfg, wl, None)
}

/// Run a workload through the grouped analyzer, optionally applying an
/// epoch policy (invoked per epoch at group-flush time).
pub fn run_batched_with(
    topo: &Topology,
    cfg: &SimConfig,
    wl: &mut dyn Workload,
    policy: Option<&mut dyn EpochPolicy>,
) -> anyhow::Result<SimReport> {
    let wall_start = std::time::Instant::now();
    let tensors = TopoTensors::build(topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES)?;
    let mut model =
        runtime::make_batch_analyzer(cfg.backend, &tensors, cfg.nbins, &cfg.artifacts_dir)?;
    let mut driver = EpochDriver::new(topo, cfg)?;

    let mut report = SimReport::new(wl.name(), &topo.name, model.backend_name(), topo.num_pools());
    let mut flush = BatchedFlush::new(
        model.as_mut(),
        topo.host.cacheline_bytes as f32,
        cfg.keep_epoch_records,
        driver.bins.bin_width_ns() as f32,
        cfg.nbins,
        cfg.epoch_ns(),
    );
    flush.policy = policy;
    driver.run(wl, &mut flush, &mut report, cfg.max_epochs)?;
    report.finish(&driver.cache.stats, driver.tracer_run_stats(), wall_start.elapsed());
    Ok(report)
}

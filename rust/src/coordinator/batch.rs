//! Batched offline replay: process a recorded trace with the
//! `timing_batch{E}` AOT artifact, amortizing PJRT dispatch across E
//! epochs per call (§Perf: ~46 µs/epoch vs ~150 µs single-shot).
//!
//! Semantically identical to the sequential epoch loop because epoch
//! delays do not feed back into the event stream (the workload's events
//! are independent of injected delay); verified against the sequential
//! coordinator in `rust/tests/e2e.rs`.

use crate::alloctrack::AllocTracker;
use crate::cache::{AccessOutcome, CacheHierarchy};
use crate::runtime::pjrt::PjrtBatchAnalyzer;
use crate::runtime::shapes;
use crate::topology::{TopoTensors, Topology};
use crate::trace::binning::EpochBins;
use crate::trace::WlEvent;
use crate::workload::Workload;

use super::report::SimReport;
use super::SimConfig;

/// Run a workload through the batched analyzer. Bins all epochs first
/// (cache + tracker pass), then flushes them through PJRT in groups of
/// the artifact's batch size.
pub fn run_batched(
    topo: &Topology,
    cfg: &SimConfig,
    wl: &mut dyn Workload,
) -> anyhow::Result<SimReport> {
    let wall_start = std::time::Instant::now();
    let tensors = TopoTensors::build(topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES)?;
    let mut model = PjrtBatchAnalyzer::new(&tensors, cfg.nbins, &cfg.artifacts_dir)?;
    let e = model.batch;
    let (p, b) = (shapes::NUM_POOLS, cfg.nbins);

    let mut report = SimReport::new(wl.name(), &topo.name, "pjrt-batch", topo.num_pools());
    let mut cache = CacheHierarchy::scaled(cfg.cache_scale);
    let mut tracker = AllocTracker::new(topo, cfg.policy.build(topo));
    let mut bins = EpochBins::new(p, b, cfg.epoch_ns());

    let epoch_ns = cfg.epoch_ns();
    let mut epoch_vtime = 0.0f64;
    let mut sample_ctr = 0u32;
    // accumulated per-epoch histograms + native durations
    let mut batched_reads: Vec<f32> = Vec::with_capacity(e * p * b);
    let mut batched_writes: Vec<f32> = Vec::with_capacity(e * p * b);
    let mut natives: Vec<f64> = Vec::with_capacity(e);
    let mut done = false;

    let flush = |reads: &mut Vec<f32>,
                     writes: &mut Vec<f32>,
                     natives: &mut Vec<f64>,
                     report: &mut SimReport,
                     model: &mut PjrtBatchAnalyzer,
                     bin_width: f32|
     -> anyhow::Result<()> {
        if natives.is_empty() {
            return Ok(());
        }
        let filled = natives.len();
        reads.resize(e * p * b, 0.0);
        writes.resize(e * p * b, 0.0);
        let out = model.analyze_batch(
            reads,
            writes,
            bin_width,
            64.0, // cacheline bytes
        )?;
        for i in 0..filled {
            report.epochs_run += 1;
            report.native_ns += natives[i];
            report.delay_ns += out.total[i];
            report.simulated_ns += natives[i] + out.total[i];
            let s = shapes::NUM_SWITCHES;
            report.lat_delay_ns += out.lat[i * p..(i + 1) * p]
                .iter()
                .map(|x| *x as f64)
                .sum::<f64>();
            report.cong_delay_ns += out.cong[i * s..(i + 1) * s]
                .iter()
                .map(|x| *x as f64)
                .sum::<f64>();
            report.bwd_delay_ns += out.bwd[i * s..(i + 1) * s]
                .iter()
                .map(|x| *x as f64)
                .sum::<f64>();
        }
        reads.clear();
        writes.clear();
        natives.clear();
        Ok(())
    };

    while !done {
        match wl.next_event() {
            None => done = true,
            Some(WlEvent::Alloc(mut ev)) => {
                ev.t_ns = report.native_ns + epoch_vtime;
                tracker.on_alloc_event(&ev);
                report.alloc_events += 1;
                epoch_vtime += cfg.alloc_cost_ns;
            }
            Some(WlEvent::Access(a)) => {
                let outcome = cache.access(a.addr, a.is_write);
                let mut cost = cfg.cpi_ns + cache.hit_latency_ns(outcome);
                if let AccessOutcome::Miss { writeback } = outcome {
                    cost += if a.is_write {
                        topo.host.local_write_latency_ns
                    } else {
                        topo.host.local_read_latency_ns
                    } / cfg.mlp.max(1.0);
                    let pool = tracker.pool_of(a.addr);
                    report.record_miss(pool, a.is_write);
                    sample_ctr += 1;
                    if sample_ctr >= cfg.sample_period {
                        sample_ctr = 0;
                        bins.record(pool, a.is_write, epoch_vtime, cfg.sample_period as f32);
                    }
                    if let Some(wb) = writeback {
                        let wb_pool = tracker.pool_of(wb);
                        report.record_writeback(wb_pool);
                        bins.record(wb_pool, true, epoch_vtime, 1.0);
                    }
                }
                epoch_vtime += cost;
            }
        }
        if epoch_vtime >= epoch_ns || (done && epoch_vtime > 0.0) {
            batched_reads.extend_from_slice(&bins.reads);
            batched_writes.extend_from_slice(&bins.writes);
            natives.push(epoch_vtime);
            bins.clear();
            epoch_vtime = 0.0;
            if natives.len() == e {
                flush(
                    &mut batched_reads,
                    &mut batched_writes,
                    &mut natives,
                    &mut report,
                    &mut model,
                    bins.bin_width_ns() as f32,
                )?;
            }
            if let Some(max) = cfg.max_epochs {
                if report.epochs_run + natives.len() as u64 >= max {
                    done = true;
                }
            }
        }
    }
    flush(
        &mut batched_reads,
        &mut batched_writes,
        &mut natives,
        &mut report,
        &mut model,
        bins.bin_width_ns() as f32,
    )?;
    report.finish(&cache.stats, &tracker.stats, wall_start.elapsed());
    Ok(report)
}

//! Batched offline replay: process a workload with a grouped analyzer
//! flush, amortizing analyzer dispatch across E epochs per call. On the
//! PJRT backend this uses the `timing_batch{E}` AOT artifact (§Perf:
//! ~46 µs/epoch vs ~150 µs single-shot); on the native backend it is a
//! plain loop, so batched replay works without artifacts and is
//! bit-identical to the sequential coordinator.
//!
//! Semantically equivalent to the sequential epoch loop because epoch
//! delays do not feed back into the event stream (the workload's events
//! are independent of injected delay); verified against the sequential
//! coordinator in `rust/tests/e2e.rs` and
//! `rust/tests/pipeline_equivalence.rs`.
//!
//! Event accounting runs through the shared [`super::EpochDriver`], so
//! this mode has full parity with the sequential coordinator —
//! prefetcher traffic, write-backs, sampling, and the two-phase policy
//! engine. Phase-1 (bin shaping) hooks run at every epoch boundary on
//! the live bins; phase-2 (migration) hooks run per epoch at
//! group-flush time, i.e. their tracker mutations and injected
//! migration traffic apply up to E−1 epochs late — the documented
//! fidelity trade of batched replay. An empty stack remains
//! bit-identical to no stack (`tests/pipeline_equivalence.rs`).
//!
//! The native group size E is `SimConfig::batch_group`
//! (`--batch-group`; 0 = `shapes::BATCH` = 16). Without a policy
//! stack, any group size is bit-identical to any other (epochs are
//! independent; only the flush cadence changes), so long replays
//! should run large groups — `--batch-group 256` hands the sharded
//! analyzer (`--analyzer-threads`) 16× more epochs per fan-out. With a
//! stack, larger groups stretch the phase-2 lateness window to
//! E−1 epochs (asserted as the group-size bound in
//! [`super::driver::BatchedFlush`]); pick the group size accordingly.
//!
//! This is also the driver of choice for *streaming* replay
//! (`replay` / `run --trace` on a CXLTRC v2 file): the pump pulls
//! from `trace::stream::TraceStream`, which serves chunk-resident
//! events and overlaps next-chunk decode with the analyzer via a
//! rendezvous channel — O(chunk) memory, wall-clock approaching
//! max(decode, analyze), reports bit-identical to in-memory replay
//! for every thread/group/kernel knob (`tests/pipeline_equivalence.rs`).
//!
//! With `--pipeline` the group flush itself moves off the pump thread:
//! [`super::pipeline::PipelinedBatchFlush`] sends the packed group to
//! a dedicated analysis worker and keeps pumping the next group while
//! it runs. Without a live policy stack the in-flight group drains one
//! flush late (depth 1) and reports stay bit-identical; with a stack,
//! phase-2 already runs up to E−1 epochs late at group-flush time, so
//! the pipeline drains lock-step at each flush to keep that documented
//! bound — the lateness contract is unchanged either way. Composes
//! with streaming replay into decode → pump → analyze, three threads
//! deep.

use crate::policy::PolicyStack;
use crate::runtime::{self, shapes};
use crate::topology::{TopoTensors, Topology};
use crate::workload::Workload;

use super::driver::{BatchedFlush, EpochDriver};
use super::pipeline::PipelinedBatchFlush;
use super::report::SimReport;
use super::SimConfig;

/// Run a workload through the grouped analyzer. A policy stack is
/// built from `SimConfig::epoch_policy` when set.
pub fn run_batched(
    topo: &Topology,
    cfg: &SimConfig,
    wl: &mut dyn Workload,
) -> anyhow::Result<SimReport> {
    let mut own = cfg
        .epoch_policy
        .as_ref()
        .map(|spec| spec.build(cfg.mig_stall_ns_per_byte));
    run_batched_with(topo, cfg, wl, own.as_mut())
}

/// Run a workload through the grouped analyzer with an explicit policy
/// stack (ignores `SimConfig::epoch_policy`; pass None for no engine).
/// The caller keeps the stack, so its counters can be inspected after
/// the run — `tests/pipeline_equivalence.rs` uses this for the
/// migration-traffic conservation property.
pub fn run_batched_with(
    topo: &Topology,
    cfg: &SimConfig,
    wl: &mut dyn Workload,
    stack: Option<&mut PolicyStack>,
) -> anyhow::Result<SimReport> {
    let wall_start = std::time::Instant::now();
    super::ensure_fault_backend(cfg)?;
    super::ensure_pipeline_backend(cfg)?;
    let tensors = TopoTensors::build(topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES)?;
    let mut driver = EpochDriver::new(topo, cfg)?;
    let mut fault = match &cfg.faults {
        Some(plan) => Some(plan.resolve(topo)?),
        None => None,
    };
    // pool-offline failover needs the migration machinery; when faults
    // are configured and the caller brought no stack, install an empty
    // one (bit-identical to no stack — `tests/pipeline_equivalence.rs`)
    let mut fallback_stack = match (&fault, &stack) {
        (Some(_), None) => Some(PolicyStack::new(cfg.mig_stall_ns_per_byte)),
        _ => None,
    };
    let stack = stack.or(fallback_stack.as_mut());

    if cfg.pipeline {
        // the worker owns the batch model outright (Send-gated:
        // `ensure_pipeline_backend` rejected PJRT up front); the
        // analyzer's own thread pool still shards inside each
        // `analyze_batch` call, so `--analyzer-threads` composes
        let model = runtime::make_send_batch_analyzer(
            cfg.backend,
            &tensors,
            cfg.nbins,
            cfg.analyzer_threads,
            cfg.scan_kernel,
            cfg.batch_group,
        )?;
        let mut report =
            SimReport::new(wl.name(), &topo.name, model.backend_name(), topo.num_pools());
        report.analyzer_threads_used = model.threads() as u64;
        report.scan_kernel = model.scan_kernel().name().to_string();
        report.batch_group = model.batch() as u64;
        let mut flush = PipelinedBatchFlush::new(
            model,
            topo.host.cacheline_bytes as f32,
            cfg.keep_epoch_records,
            driver.bins.bin_width_ns() as f32,
            cfg.epoch_ns(),
        )?;
        flush.stack = stack;
        flush.fault = fault.as_mut();
        if let Some(st) = flush.stack.as_deref_mut() {
            st.begin_run(); // per-run accounting, even for caller-owned stacks
        }
        driver.run(wl, &mut flush, &mut report, cfg.max_epochs)?;
        report.finish(&driver.cache.stats, driver.tracer_run_stats(), wall_start.elapsed());
        // PipelinedBatchFlush has a Drop impl (joins the worker), so
        // its borrows live until the drop point — take the stack back
        // and drop explicitly before reading `fault` again
        let run_stack = flush.stack.take();
        drop(flush);
        if let Some(stack) = run_stack.as_deref() {
            report.record_policy_stats(stack);
        }
        if let Some(f) = &fault {
            report.record_fault_stats(f);
        }
        return Ok(report);
    }

    let mut model = runtime::make_batch_analyzer(
        cfg.backend,
        &tensors,
        cfg.nbins,
        &cfg.artifacts_dir,
        cfg.analyzer_threads,
        cfg.scan_kernel,
        cfg.batch_group,
    )?;
    let mut report = SimReport::new(wl.name(), &topo.name, model.backend_name(), topo.num_pools());
    report.analyzer_threads_used = model.threads() as u64;
    report.scan_kernel = model.scan_kernel().name().to_string();
    report.batch_group = model.batch() as u64;
    let mut flush = BatchedFlush::new(
        model.as_mut(),
        topo.host.cacheline_bytes as f32,
        cfg.keep_epoch_records,
        driver.bins.bin_width_ns() as f32,
        cfg.nbins,
        cfg.epoch_ns(),
    );
    flush.stack = stack;
    flush.fault = fault.as_mut();
    if let Some(st) = flush.stack.as_deref_mut() {
        st.begin_run(); // per-run accounting, even for caller-owned stacks
    }
    driver.run(wl, &mut flush, &mut report, cfg.max_epochs)?;
    report.finish(&driver.cache.stats, driver.tracer_run_stats(), wall_start.elapsed());
    if let Some(stack) = flush.stack.as_deref() {
        report.record_policy_stats(stack);
    }
    if let Some(f) = &fault {
        report.record_fault_stats(f);
    }
    Ok(report)
}

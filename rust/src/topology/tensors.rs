//! Tensorization: flatten the topology tree into the fixed-shape f32
//! arrays the AOT-compiled timing analyzer consumes (see
//! `python/compile/model.py` for the input contract).
//!
//! Padding convention: topologies smaller than the compiled (P, S)
//! shapes are zero-padded; zero `desc_mask` rows with zero stt/bw are
//! provably inert in the model (tested on both sides).

use super::{Topology, TopologyError};

/// The timing model's topology-dependent inputs, row-major.
#[derive(Clone, Debug)]
pub struct TopoTensors {
    /// Padded pool count P (pool 0 = local DRAM).
    pub pools: usize,
    /// Padded switch count S (row 0 = root complex).
    pub switches: usize,
    /// f32[P]: per-pool extra read latency vs local DRAM, ns.
    pub extra_read_lat: Vec<f32>,
    /// f32[P]: per-pool extra write latency vs local DRAM, ns.
    pub extra_write_lat: Vec<f32>,
    /// f32[S*P] row-major: 1.0 iff pool p routes through switch row s.
    pub desc_mask: Vec<f32>,
    /// f32[S]: serial transmission time per event, ns.
    pub stt: Vec<f32>,
    /// f32[S]: switch bandwidth, bytes/ns.
    pub bw: Vec<f32>,
}

impl TopoTensors {
    /// Build tensors padded to (pools=p, switches=s). Fails if the
    /// topology is larger than the compiled shapes.
    pub fn build(topo: &Topology, p: usize, s: usize) -> Result<TopoTensors, TopologyError> {
        if topo.num_pools() > p {
            return Err(TopologyError::Config(format!(
                "topology has {} pools but the compiled model supports {p}",
                topo.num_pools()
            )));
        }
        if topo.num_switches() > s {
            return Err(TopologyError::Config(format!(
                "topology has {} switches but the compiled model supports {s}",
                topo.num_switches()
            )));
        }
        let mut t = TopoTensors {
            pools: p,
            switches: s,
            extra_read_lat: vec![0.0; p],
            extra_write_lat: vec![0.0; p],
            desc_mask: vec![0.0; s * p],
            stt: vec![0.0; s],
            bw: vec![0.0; s],
        };
        for pool in 0..topo.num_pools() {
            t.extra_read_lat[pool] = topo.extra_read_latency(pool) as f32;
            t.extra_write_lat[pool] = topo.extra_write_latency(pool) as f32;
        }
        for (row, &node) in topo.switch_nodes().iter().enumerate() {
            t.stt[row] = topo.nodes()[node].stt_ns as f32;
            t.bw[row] = topo.nodes()[node].bandwidth as f32;
            for pool in 1..topo.num_pools() {
                if topo.routes_through(pool, node) {
                    t.desc_mask[row * p + pool] = 1.0;
                }
            }
        }
        Ok(t)
    }

    /// desc_mask entry accessor (tests & native analyzer).
    pub fn mask(&self, switch_row: usize, pool: usize) -> f32 {
        self.desc_mask[switch_row * self.pools + pool]
    }
}

#[cfg(test)]
mod tests {
    use super::super::builtin;
    use super::*;

    #[test]
    fn fig2_tensors_shape() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        assert_eq!(t.extra_read_lat.len(), 8);
        assert_eq!(t.desc_mask.len(), 64);
        // local pool contributes no extra latency
        assert_eq!(t.extra_read_lat[0], 0.0);
        // every CXL pool routes through the RC (row 0)
        for pool in 1..topo.num_pools() {
            assert_eq!(t.mask(0, pool), 1.0, "pool {pool} not under RC");
        }
        // padding rows are zeroed
        for row in topo.num_switches()..8 {
            assert_eq!(t.stt[row], 0.0);
            assert_eq!(t.bw[row], 0.0);
            for pool in 0..8 {
                assert_eq!(t.mask(row, pool), 0.0);
            }
        }
    }

    #[test]
    fn fig2_switch_membership() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        // fig2: sw0 (row 1) carries pool0+pool1 (= pools 1 and 2),
        // direct0 (pool 3) hangs off the RC only.
        assert_eq!(t.mask(1, 1), 1.0);
        assert_eq!(t.mask(1, 2), 1.0);
        assert_eq!(t.mask(1, 3), 0.0);
        assert_eq!(t.mask(0, 3), 1.0);
    }

    #[test]
    fn too_many_pools_rejected() {
        let topo = builtin::wide(); // 4 CXL pools + local = 5
        assert!(TopoTensors::build(&topo, 4, 8).is_err());
        assert!(TopoTensors::build(&topo, 5, 8).is_ok());
    }

    #[test]
    fn too_many_switches_rejected() {
        let topo = builtin::deep(); // RC + 2 switches
        assert!(TopoTensors::build(&topo, 8, 2).is_err());
        assert!(TopoTensors::build(&topo, 8, 3).is_ok());
    }

    #[test]
    fn local_pool_never_masked() {
        for name in builtin::BUILTIN_NAMES {
            let topo = builtin::by_name(name).unwrap();
            let t = TopoTensors::build(&topo, 8, 8).unwrap();
            for row in 0..8 {
                assert_eq!(t.mask(row, 0), 0.0, "{name} row {row}");
            }
        }
    }
}

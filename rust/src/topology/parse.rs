//! Topology configs: TOML parsing (see `configs/*.toml` for examples).
//!
//! Format:
//!
//! ```toml
//! name = "fig1"
//!
//! [host]
//! local_latency_ns = 88.9
//! local_write_latency_ns = 88.9   # optional, defaults to read
//! local_bandwidth_gbps = 38.4
//! local_capacity_gb = 96
//! cacheline_bytes = 64
//!
//! [[node]]
//! name = "rc0"
//! kind = "root"                    # root | switch | pool
//! latency_ns = 20                  # read latency of this hop
//! write_latency_ns = 20            # optional, defaults to latency_ns
//! bandwidth_gbps = 64
//! stt_ns = 2
//!
//! [[node]]
//! name = "pool0"
//! kind = "pool"
//! parent = "rc0"
//! latency_ns = 85
//! bandwidth_gbps = 32
//! stt_ns = 15
//! capacity_gb = 128
//! ```

use std::collections::BTreeMap;

use super::{HostParams, Node, NodeKind, Topology, TopologyError};
use crate::util::toml::{opt_f64, opt_str, req_f64, req_str, TomlDoc};

impl Topology {
    pub fn from_toml_str(src: &str) -> Result<Topology, TopologyError> {
        let doc = TomlDoc::parse(src).map_err(TopologyError::Config)?;
        let name = doc
            .table("")
            .and_then(|t| t.get("name"))
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();

        let mut host = HostParams::default();
        if let Some(h) = doc.table("host") {
            host.local_read_latency_ns = opt_f64(h, "local_latency_ns", host.local_read_latency_ns);
            host.local_write_latency_ns =
                opt_f64(h, "local_write_latency_ns", host.local_read_latency_ns);
            host.local_bandwidth = opt_f64(h, "local_bandwidth_gbps", host.local_bandwidth);
            host.local_capacity_bytes =
                (opt_f64(h, "local_capacity_gb", 96.0) * (1u64 << 30) as f64) as u64;
            host.cacheline_bytes = opt_f64(h, "cacheline_bytes", 64.0) as u64;
        }

        // first pass: collect names -> index
        let raw = doc.array("node");
        if raw.is_empty() {
            return Err(TopologyError::Config("no [[node]] entries".into()));
        }
        let mut index: BTreeMap<String, usize> = BTreeMap::new();
        for (i, t) in raw.iter().enumerate() {
            let n = req_str(t, "name", "node").map_err(TopologyError::Config)?;
            if index.insert(n.clone(), i).is_some() {
                return Err(TopologyError::DuplicateName(n));
            }
        }

        let mut nodes = Vec::with_capacity(raw.len());
        for t in raw {
            let name = req_str(t, "name", "node").map_err(TopologyError::Config)?;
            let ctx = format!("node `{name}`");
            let kind = match opt_str(t, "kind", "").as_str() {
                "root" => NodeKind::Root,
                "switch" => NodeKind::Switch,
                "pool" => NodeKind::Pool,
                other => {
                    return Err(TopologyError::Config(format!(
                        "{ctx}: kind must be root|switch|pool, got `{other}`"
                    )))
                }
            };
            let parent = match t.get("parent").and_then(|v| v.as_str()) {
                Some(p) => Some(
                    *index
                        .get(p)
                        .ok_or_else(|| TopologyError::UnknownParent(name.clone(), p.into()))?,
                ),
                None => None,
            };
            let lat = req_f64(t, "latency_ns", &ctx).map_err(TopologyError::Config)?;
            let wlat = opt_f64(t, "write_latency_ns", lat);
            let bw = req_f64(t, "bandwidth_gbps", &ctx).map_err(TopologyError::Config)?;
            let stt = req_f64(t, "stt_ns", &ctx).map_err(TopologyError::Config)?;
            let cap = (opt_f64(t, "capacity_gb", 0.0) * (1u64 << 30) as f64) as u64;
            nodes.push(Node {
                name,
                kind,
                parent,
                read_latency_ns: lat,
                write_latency_ns: wlat,
                bandwidth: bw,
                stt_ns: stt,
                capacity_bytes: cap,
            });
        }
        Topology::new(&name, host, nodes)
    }

    pub fn from_toml_file(path: &str) -> Result<Topology, TopologyError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| TopologyError::Config(format!("read {path}: {e}")))?;
        Topology::from_toml_str(&src)
    }

    /// Resolve `--topo` CLI values: builtin name or path to a .toml file.
    pub fn resolve(spec: &str) -> Result<Topology, TopologyError> {
        if let Some(t) = super::builtin::by_name(spec) {
            return Ok(t);
        }
        if spec.ends_with(".toml") {
            return Topology::from_toml_file(spec);
        }
        Err(TopologyError::Config(format!(
            "unknown topology `{spec}` (builtin: {:?}, or path to .toml)",
            super::builtin::BUILTIN_NAMES
        )))
    }

    /// Emit a TOML config for this topology (inverse of from_toml_str).
    pub fn to_toml(&self) -> String {
        let mut out = format!("name = \"{}\"\n\n[host]\n", self.name);
        out.push_str(&format!(
            "local_latency_ns = {}\nlocal_write_latency_ns = {}\nlocal_bandwidth_gbps = {}\n\
             local_capacity_gb = {}\ncacheline_bytes = {}\n",
            self.host.local_read_latency_ns,
            self.host.local_write_latency_ns,
            self.host.local_bandwidth,
            self.host.local_capacity_bytes >> 30,
            self.host.cacheline_bytes
        ));
        for n in self.nodes() {
            out.push_str("\n[[node]]\n");
            out.push_str(&format!("name = \"{}\"\n", n.name));
            out.push_str(&format!(
                "kind = \"{}\"\n",
                match n.kind {
                    NodeKind::Root => "root",
                    NodeKind::Switch => "switch",
                    NodeKind::Pool => "pool",
                }
            ));
            if let Some(p) = n.parent {
                out.push_str(&format!("parent = \"{}\"\n", self.nodes()[p].name));
            }
            out.push_str(&format!("latency_ns = {}\n", n.read_latency_ns));
            out.push_str(&format!("write_latency_ns = {}\n", n.write_latency_ns));
            out.push_str(&format!("bandwidth_gbps = {}\n", n.bandwidth));
            out.push_str(&format!("stt_ns = {}\n", n.stt_ns));
            if n.capacity_bytes > 0 {
                out.push_str(&format!("capacity_gb = {}\n", n.capacity_bytes >> 30));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::builtin;
    use super::*;

    #[test]
    fn parse_minimal() {
        let t = Topology::from_toml_str(
            r#"
name = "t"
[[node]]
name = "rc"
kind = "root"
latency_ns = 10
bandwidth_gbps = 64
stt_ns = 2
[[node]]
name = "p"
kind = "pool"
parent = "rc"
latency_ns = 100
bandwidth_gbps = 32
stt_ns = 20
capacity_gb = 64
"#,
        )
        .unwrap();
        assert_eq!(t.num_cxl_pools(), 1);
        assert!((t.pool_read_latency(1) - 110.0).abs() < 1e-9);
        assert_eq!(t.pool_capacity(1), 64 << 30);
    }

    #[test]
    fn roundtrip_builtins_through_toml() {
        for name in builtin::BUILTIN_NAMES {
            let t = builtin::by_name(name).unwrap();
            let t2 = Topology::from_toml_str(&t.to_toml()).unwrap();
            assert_eq!(t.num_pools(), t2.num_pools(), "{name}");
            assert_eq!(t.num_switches(), t2.num_switches(), "{name}");
            for p in 0..t.num_pools() {
                assert!(
                    (t.pool_read_latency(p) - t2.pool_read_latency(p)).abs() < 1e-9,
                    "{name} pool {p}"
                );
            }
        }
    }

    #[test]
    fn unknown_parent_is_error() {
        let r = Topology::from_toml_str(
            r#"
[[node]]
name = "rc"
kind = "root"
latency_ns = 10
bandwidth_gbps = 64
stt_ns = 2
[[node]]
name = "p"
kind = "pool"
parent = "nope"
latency_ns = 100
bandwidth_gbps = 32
stt_ns = 20
"#,
        );
        assert!(matches!(r, Err(TopologyError::UnknownParent(_, _))));
    }

    #[test]
    fn bad_kind_is_error() {
        let r = Topology::from_toml_str(
            r#"
[[node]]
name = "rc"
kind = "hub"
latency_ns = 10
bandwidth_gbps = 64
stt_ns = 2
"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn missing_required_key_is_error() {
        let r = Topology::from_toml_str(
            r#"
[[node]]
name = "rc"
kind = "root"
bandwidth_gbps = 64
stt_ns = 2
"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn resolve_builtin() {
        assert!(Topology::resolve("fig2").is_ok());
        assert!(Topology::resolve("nonexistent").is_err());
    }

    #[test]
    fn host_overrides_apply() {
        let t = Topology::from_toml_str(
            r#"
[host]
local_latency_ns = 70
local_bandwidth_gbps = 50
[[node]]
name = "rc"
kind = "root"
latency_ns = 10
bandwidth_gbps = 64
stt_ns = 2
[[node]]
name = "p"
kind = "pool"
parent = "rc"
latency_ns = 100
bandwidth_gbps = 32
stt_ns = 20
"#,
        )
        .unwrap();
        assert_eq!(t.host.local_read_latency_ns, 70.0);
        assert!((t.extra_read_latency(1) - 40.0).abs() < 1e-9);
    }
}

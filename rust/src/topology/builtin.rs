//! Built-in topologies, including the paper's Figure 1 and the Figure 2
//! topology its §4 evaluation simulates.
//!
//! The paper's figures annotate BW / Lat / STT per node but the preprint
//! text does not carry the exact numbers, so the values here follow the
//! public CXL literature the paper cites: ~(1.5–2)× local DRAM latency
//! through one switch level (DirectCXL/Pond measurements), x8 PCIe5-class
//! link bandwidths, and per-64B serialization in the tens of ns through
//! a switch. Every experiment sweeps these parameters anyway; the
//! defaults only anchor the shipped configs.

use super::{HostParams, Node, NodeKind, Topology};

pub const BUILTIN_NAMES: &[&str] = &["fig1", "fig2", "direct", "deep", "wide", "pooled"];

fn root(name: &str) -> Node {
    Node {
        name: name.into(),
        kind: NodeKind::Root,
        parent: None,
        read_latency_ns: 20.0,
        write_latency_ns: 20.0,
        bandwidth: 64.0, // x16 CXL link, GB/s
        stt_ns: 2.0,
        capacity_bytes: 0,
    }
}

fn switch(name: &str, parent: usize, lat: f64, bw: f64, stt: f64) -> Node {
    Node {
        name: name.into(),
        kind: NodeKind::Switch,
        parent: Some(parent),
        read_latency_ns: lat,
        write_latency_ns: lat,
        bandwidth: bw,
        stt_ns: stt,
        capacity_bytes: 0,
    }
}

fn pool(name: &str, parent: usize, rd: f64, wr: f64, bw: f64, stt: f64, gb: u64) -> Node {
    Node {
        name: name.into(),
        kind: NodeKind::Pool,
        parent: Some(parent),
        read_latency_ns: rd,
        write_latency_ns: wr,
        bandwidth: bw,
        stt_ns: stt,
        capacity_bytes: gb << 30,
    }
}

/// Paper Figure 1: RC -> {switch0 -> {pool0, pool1}, switch1 -> pool2}.
/// Two switches, three memory pools.
pub fn fig1() -> Topology {
    Topology::new(
        "fig1",
        HostParams::default(),
        vec![
            root("rc0"),
            switch("sw0", 0, 35.0, 32.0, 25.0),
            switch("sw1", 0, 35.0, 32.0, 25.0),
            pool("pool0", 1, 90.0, 100.0, 30.0, 20.0, 64),
            pool("pool1", 1, 130.0, 140.0, 24.0, 20.0, 128),
            pool("pool2", 2, 110.0, 120.0, 28.0, 20.0, 96),
        ],
    )
    .expect("fig1 is valid")
}

/// Paper Figure 2 / §4: the topology the preliminary evaluation runs —
/// one switch level with two pools plus one directly-attached pool.
pub fn fig2() -> Topology {
    Topology::new(
        "fig2",
        HostParams::default(),
        vec![
            root("rc0"),
            switch("sw0", 0, 35.0, 32.0, 25.0),
            pool("pool0", 1, 90.0, 100.0, 30.0, 20.0, 64),
            pool("pool1", 1, 130.0, 140.0, 24.0, 20.0, 128),
            pool("direct0", 0, 85.0, 95.0, 32.0, 15.0, 64),
        ],
    )
    .expect("fig2 is valid")
}

/// One directly-attached pool (DirectCXL-style, no switch).
pub fn direct() -> Topology {
    Topology::new(
        "direct",
        HostParams::default(),
        vec![root("rc0"), pool("pool0", 0, 85.0, 95.0, 32.0, 15.0, 128)],
    )
    .expect("direct is valid")
}

/// Two cascaded switches before the pool (worst-case hierarchy depth).
pub fn deep() -> Topology {
    Topology::new(
        "deep",
        HostParams::default(),
        vec![
            root("rc0"),
            switch("sw0", 0, 35.0, 32.0, 25.0),
            switch("sw1", 1, 35.0, 28.0, 25.0),
            pool("pool0", 2, 90.0, 100.0, 24.0, 20.0, 256),
        ],
    )
    .expect("deep is valid")
}

/// Four pools fanned out of one switch (stranding-friendly, congestion-prone).
pub fn wide() -> Topology {
    Topology::new(
        "wide",
        HostParams::default(),
        vec![
            root("rc0"),
            switch("sw0", 0, 35.0, 32.0, 25.0),
            pool("pool0", 1, 90.0, 100.0, 30.0, 20.0, 64),
            pool("pool1", 1, 90.0, 100.0, 30.0, 20.0, 64),
            pool("pool2", 1, 90.0, 100.0, 30.0, 20.0, 64),
            pool("pool3", 1, 90.0, 100.0, 30.0, 20.0, 64),
        ],
    )
    .expect("wide is valid")
}

/// Pond-style rack pool: a big shared pool behind two switch levels.
pub fn pooled() -> Topology {
    Topology::new(
        "pooled",
        HostParams::default(),
        vec![
            root("rc0"),
            switch("tor", 0, 45.0, 48.0, 20.0),
            switch("shelf", 1, 35.0, 32.0, 25.0),
            pool("rackpool", 2, 120.0, 130.0, 28.0, 22.0, 1024),
            pool("nearpool", 1, 95.0, 105.0, 30.0, 20.0, 128),
        ],
    )
    .expect("pooled is valid")
}

pub fn by_name(name: &str) -> Option<Topology> {
    match name {
        "fig1" => Some(fig1()),
        "fig2" => Some(fig2()),
        "direct" => Some(direct()),
        "deep" => Some(deep()),
        "wide" => Some(wide()),
        "pooled" => Some(pooled()),
        _ => None,
    }
}

//! CXL.mem topology: the user-provided tree of root complex, switches,
//! and memory pools that CXLMemSim simulates (paper §2, Figure 1).
//!
//! A topology is a rooted tree. The root is the host's CXL Root Complex
//! (RC); interior nodes are CXL switches; leaves are memory pools (or
//! expander devices). Every node carries the three parameters the paper
//! annotates in Figure 1: access latency (ns, per hop), bandwidth
//! (GB/s == bytes/ns), and serial transmission time (STT, ns per
//! cacheline-sized event).
//!
//! Local DRAM is *not* a node: it is pool id 0 by convention, with zero
//! extra latency and no switch membership, so placement policies can
//! target it uniformly (see `alloctrack`).

pub mod builtin;
pub mod parse;
pub mod tensors;

pub use tensors::TopoTensors;

/// Identifies a memory pool from the allocator's point of view.
/// Pool 0 is always local DRAM; CXL pools are 1..=num_cxl_pools.
pub type PoolId = usize;

pub const LOCAL_POOL: PoolId = 0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// The host's CXL root complex (exactly one, the tree root).
    Root,
    /// A CXL switch (interior node).
    Switch,
    /// A memory pool / type-3 device (leaf).
    Pool,
}

/// One node of the topology tree.
#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    pub kind: NodeKind,
    /// Index of the parent node (None only for the root).
    pub parent: Option<usize>,
    /// Added read latency of traversing this hop, ns.
    pub read_latency_ns: f64,
    /// Added write latency of traversing this hop, ns.
    pub write_latency_ns: f64,
    /// Bandwidth of the link into this node, bytes/ns (== GB/s).
    pub bandwidth: f64,
    /// Serial transmission time per 64 B event through this node, ns.
    pub stt_ns: f64,
    /// Pool capacity in bytes (pools only; 0 otherwise).
    pub capacity_bytes: u64,
}

/// Host-side parameters (the machine the program "runs" on).
#[derive(Clone, Debug)]
pub struct HostParams {
    /// Local DRAM load-to-use latency, ns (paper testbed: 88.9).
    pub local_read_latency_ns: f64,
    pub local_write_latency_ns: f64,
    /// Local DRAM bandwidth, bytes/ns.
    pub local_bandwidth: f64,
    /// Local DRAM capacity in bytes (placement policies spill past it).
    pub local_capacity_bytes: u64,
    pub cacheline_bytes: u64,
}

impl Default for HostParams {
    fn default() -> Self {
        // The paper's evaluation platform: i9-12900K, DDR5-4800, 88.9 ns.
        HostParams {
            local_read_latency_ns: 88.9,
            local_write_latency_ns: 88.9,
            local_bandwidth: 38.4, // one DDR5-4800 channel pair, GB/s
            local_capacity_bytes: 96 * (1 << 30),
            cacheline_bytes: 64,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub host: HostParams,
    nodes: Vec<Node>,
    /// Node index of the root complex.
    root: usize,
    /// Node indices of pools, in PoolId-1 order (pool id = position+1).
    pool_nodes: Vec<usize>,
    /// Node indices of non-pool nodes (RC first), in "switch row" order.
    switch_nodes: Vec<usize>,
}

#[derive(Debug)]
pub enum TopologyError {
    RootCount(usize),
    UnknownParent(String, String),
    PoolWithChildren(String),
    NonPositive(String, &'static str, f64),
    DuplicateName(String),
    Cycle(String),
    RootWithParent(String),
    Config(String),
    NoPools,
}

// Hand-written (the `thiserror` derive is unavailable in the offline
// vendored build; messages are unchanged).
impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::RootCount(n) => {
                write!(f, "topology must have exactly one root, found {n}")
            }
            TopologyError::UnknownParent(node, parent) => {
                write!(f, "node `{node}`: unknown parent `{parent}`")
            }
            TopologyError::PoolWithChildren(node) => {
                write!(f, "node `{node}`: pools must be leaves")
            }
            TopologyError::NonPositive(node, field, got) => {
                write!(f, "node `{node}`: {field} must be positive (got {got})")
            }
            TopologyError::DuplicateName(name) => write!(f, "duplicate node name `{name}`"),
            TopologyError::Cycle(node) => {
                write!(f, "topology contains a cycle involving `{node}`")
            }
            TopologyError::RootWithParent(node) => {
                write!(f, "node `{node}` is a root but has a parent")
            }
            TopologyError::Config(msg) => write!(f, "config error: {msg}"),
            TopologyError::NoPools => write!(f, "no memory pools in topology"),
        }
    }
}

impl std::error::Error for TopologyError {}

impl Topology {
    /// Build and validate a topology from a node list. `nodes[i].parent`
    /// refers to indices within `nodes`.
    pub fn new(
        name: &str,
        host: HostParams,
        nodes: Vec<Node>,
    ) -> Result<Topology, TopologyError> {
        // name uniqueness
        let mut seen = std::collections::BTreeSet::new();
        for n in &nodes {
            if !seen.insert(n.name.clone()) {
                return Err(TopologyError::DuplicateName(n.name.clone()));
            }
        }
        // single root
        let roots: Vec<usize> = nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind == NodeKind::Root)
            .map(|(i, _)| i)
            .collect();
        if roots.len() != 1 {
            return Err(TopologyError::RootCount(roots.len()));
        }
        let root = roots[0];
        if nodes[root].parent.is_some() {
            return Err(TopologyError::RootWithParent(nodes[root].name.clone()));
        }
        // parents exist, non-root nodes have parents; check positivity
        for (i, n) in nodes.iter().enumerate() {
            if i != root && n.parent.is_none() {
                return Err(TopologyError::UnknownParent(n.name.clone(), "<none>".into()));
            }
            if let Some(p) = n.parent {
                if p >= nodes.len() {
                    return Err(TopologyError::UnknownParent(
                        n.name.clone(),
                        format!("#{p}"),
                    ));
                }
                if nodes[p].kind == NodeKind::Pool {
                    return Err(TopologyError::PoolWithChildren(nodes[p].name.clone()));
                }
            }
            if n.read_latency_ns < 0.0 {
                return Err(TopologyError::NonPositive(
                    n.name.clone(),
                    "read_latency_ns",
                    n.read_latency_ns,
                ));
            }
            if n.bandwidth <= 0.0 {
                return Err(TopologyError::NonPositive(
                    n.name.clone(),
                    "bandwidth",
                    n.bandwidth,
                ));
            }
            if n.stt_ns < 0.0 {
                return Err(TopologyError::NonPositive(n.name.clone(), "stt_ns", n.stt_ns));
            }
        }
        // acyclicity: walk each node to the root with a step bound
        for (i, n) in nodes.iter().enumerate() {
            let mut cur = i;
            let mut steps = 0;
            while let Some(p) = nodes[cur].parent {
                cur = p;
                steps += 1;
                if steps > nodes.len() {
                    return Err(TopologyError::Cycle(n.name.clone()));
                }
            }
            if cur != root {
                return Err(TopologyError::Cycle(n.name.clone()));
            }
        }
        let pool_nodes: Vec<usize> = nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind == NodeKind::Pool)
            .map(|(i, _)| i)
            .collect();
        if pool_nodes.is_empty() {
            return Err(TopologyError::NoPools);
        }
        let mut switch_nodes: Vec<usize> = vec![root];
        switch_nodes.extend(
            nodes
                .iter()
                .enumerate()
                .filter(|(i, n)| n.kind == NodeKind::Switch && *i != root)
                .map(|(i, _)| i),
        );
        Ok(Topology {
            name: name.to_string(),
            host,
            nodes,
            root,
            pool_nodes,
            switch_nodes,
        })
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn root(&self) -> usize {
        self.root
    }

    /// Number of CXL pools (excluding local DRAM).
    pub fn num_cxl_pools(&self) -> usize {
        self.pool_nodes.len()
    }

    /// Total pools including local DRAM as pool 0.
    pub fn num_pools(&self) -> usize {
        self.pool_nodes.len() + 1
    }

    pub fn num_switches(&self) -> usize {
        self.switch_nodes.len()
    }

    /// Node index for a CXL pool id (>= 1).
    pub fn pool_node(&self, pool: PoolId) -> Option<usize> {
        if pool == LOCAL_POOL {
            None
        } else {
            self.pool_nodes.get(pool - 1).copied()
        }
    }

    pub fn switch_nodes(&self) -> &[usize] {
        &self.switch_nodes
    }

    pub fn pool_name(&self, pool: PoolId) -> &str {
        if pool == LOCAL_POOL {
            "local"
        } else {
            &self.nodes[self.pool_nodes[pool - 1]].name
        }
    }

    /// Pool capacity in bytes (local DRAM for pool 0).
    pub fn pool_capacity(&self, pool: PoolId) -> u64 {
        if pool == LOCAL_POOL {
            self.host.local_capacity_bytes
        } else {
            self.nodes[self.pool_nodes[pool - 1]].capacity_bytes
        }
    }

    /// Node indices on the path from a pool leaf up to and including the
    /// root complex.
    pub fn path_to_root(&self, pool: PoolId) -> Vec<usize> {
        let mut out = Vec::new();
        let Some(mut cur) = self.pool_node(pool) else {
            return out;
        };
        loop {
            out.push(cur);
            match self.nodes[cur].parent {
                Some(p) => cur = p,
                None => break,
            }
        }
        out
    }

    /// Total read path latency for a pool (local DRAM for pool 0), ns.
    pub fn pool_read_latency(&self, pool: PoolId) -> f64 {
        if pool == LOCAL_POOL {
            return self.host.local_read_latency_ns;
        }
        self.path_to_root(pool)
            .iter()
            .map(|&i| self.nodes[i].read_latency_ns)
            .sum()
    }

    pub fn pool_write_latency(&self, pool: PoolId) -> f64 {
        if pool == LOCAL_POOL {
            return self.host.local_write_latency_ns;
        }
        self.path_to_root(pool)
            .iter()
            .map(|&i| self.nodes[i].write_latency_ns)
            .sum()
    }

    /// Extra read latency over local DRAM (the paper's "latency delay"
    /// per event), never negative.
    pub fn extra_read_latency(&self, pool: PoolId) -> f64 {
        (self.pool_read_latency(pool) - self.host.local_read_latency_ns).max(0.0)
    }

    pub fn extra_write_latency(&self, pool: PoolId) -> f64 {
        (self.pool_write_latency(pool) - self.host.local_write_latency_ns).max(0.0)
    }

    /// Minimum bandwidth along the pool's path (the path's bottleneck).
    pub fn pool_path_bandwidth(&self, pool: PoolId) -> f64 {
        if pool == LOCAL_POOL {
            return self.host.local_bandwidth;
        }
        self.path_to_root(pool)
            .iter()
            .map(|&i| self.nodes[i].bandwidth)
            .fold(f64::INFINITY, f64::min)
    }

    /// Whether `switch_node` (a node index) is on pool's path to root.
    pub fn routes_through(&self, pool: PoolId, switch_node: usize) -> bool {
        self.path_to_root(pool).contains(&switch_node)
    }

    /// Human-readable one-line-per-node rendering (used by `topo show`).
    pub fn describe(&self) -> String {
        let mut out = format!(
            "topology `{}`: {} nodes, {} CXL pools, {} switches (incl. RC)\n",
            self.name,
            self.nodes.len(),
            self.num_cxl_pools(),
            self.num_switches()
        );
        out.push_str(&format!(
            "  local DRAM: lat {:.1} ns, bw {:.1} GB/s\n",
            self.host.local_read_latency_ns, self.host.local_bandwidth
        ));
        for pool in 1..self.num_pools() {
            let path: Vec<&str> = self
                .path_to_root(pool)
                .iter()
                .map(|&i| self.nodes[i].name.as_str())
                .collect();
            out.push_str(&format!(
                "  pool {} `{}`: read {:.1} ns (+{:.1}), write {:.1} ns, bw {:.1} GB/s, path {}\n",
                pool,
                self.pool_name(pool),
                self.pool_read_latency(pool),
                self.extra_read_latency(pool),
                self.pool_write_latency(pool),
                self.pool_path_bandwidth(pool),
                path.join(" -> ")
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::builtin;
    use super::*;

    fn mini() -> Topology {
        // rc -> sw -> pool
        Topology::new(
            "mini",
            HostParams::default(),
            vec![
                Node {
                    name: "rc".into(),
                    kind: NodeKind::Root,
                    parent: None,
                    read_latency_ns: 10.0,
                    write_latency_ns: 10.0,
                    bandwidth: 64.0,
                    stt_ns: 2.0,
                    capacity_bytes: 0,
                },
                Node {
                    name: "sw".into(),
                    kind: NodeKind::Switch,
                    parent: Some(0),
                    read_latency_ns: 35.0,
                    write_latency_ns: 35.0,
                    bandwidth: 32.0,
                    stt_ns: 25.0,
                    capacity_bytes: 0,
                },
                Node {
                    name: "pool".into(),
                    kind: NodeKind::Pool,
                    parent: Some(1),
                    read_latency_ns: 150.0,
                    write_latency_ns: 160.0,
                    bandwidth: 30.0,
                    stt_ns: 20.0,
                    capacity_bytes: 64 << 30,
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn path_latency_sums_hops() {
        let t = mini();
        assert!((t.pool_read_latency(1) - 195.0).abs() < 1e-9);
        assert!((t.pool_write_latency(1) - 205.0).abs() < 1e-9);
        assert!((t.extra_read_latency(1) - (195.0 - 88.9)).abs() < 1e-9);
    }

    #[test]
    fn local_pool_is_pool_zero() {
        let t = mini();
        assert_eq!(t.pool_name(0), "local");
        assert!((t.pool_read_latency(0) - 88.9).abs() < 1e-9);
        assert_eq!(t.extra_read_latency(0), 0.0);
    }

    #[test]
    fn bottleneck_bandwidth() {
        let t = mini();
        assert_eq!(t.pool_path_bandwidth(1), 30.0);
    }

    #[test]
    fn routes_through_path_members_only() {
        let t = mini();
        assert!(t.routes_through(1, 0));
        assert!(t.routes_through(1, 1));
        assert!(t.routes_through(1, 2));
    }

    #[test]
    fn rejects_two_roots() {
        let mk = |name: &str| Node {
            name: name.into(),
            kind: NodeKind::Root,
            parent: None,
            read_latency_ns: 1.0,
            write_latency_ns: 1.0,
            bandwidth: 1.0,
            stt_ns: 1.0,
            capacity_bytes: 0,
        };
        let err = Topology::new("x", HostParams::default(), vec![mk("a"), mk("b")]);
        assert!(matches!(err, Err(TopologyError::RootCount(2))));
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut nodes = mini().nodes.clone();
        nodes[2].name = "sw".into();
        assert!(matches!(
            Topology::new("x", HostParams::default(), nodes),
            Err(TopologyError::DuplicateName(_))
        ));
    }

    #[test]
    fn rejects_cycles() {
        let mut nodes = mini().nodes.clone();
        nodes[1].parent = Some(2); // sw's parent is pool, pool's parent sw
        let r = Topology::new("x", HostParams::default(), nodes);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_zero_bandwidth() {
        let mut nodes = mini().nodes.clone();
        nodes[1].bandwidth = 0.0;
        assert!(matches!(
            Topology::new("x", HostParams::default(), nodes),
            Err(TopologyError::NonPositive(_, "bandwidth", _))
        ));
    }

    #[test]
    fn builtin_topologies_validate() {
        for name in builtin::BUILTIN_NAMES {
            let t = builtin::by_name(name).unwrap();
            assert!(t.num_pools() >= 2, "{name} has no CXL pools");
            assert!(!t.describe().is_empty());
        }
    }

    #[test]
    fn fig1_shape_matches_paper() {
        // Figure 1: two switches, three memory pools.
        let t = builtin::fig1();
        assert_eq!(t.num_cxl_pools(), 3);
        // RC + 2 switches
        assert_eq!(t.num_switches(), 3);
    }
}

//! Compiled-model shape constants + `artifacts/manifest.json` reading.
//!
//! Must stay in sync with `python/compile/model.py` (NUM_POOLS /
//! NUM_SWITCHES / NUM_BINS / BATCH); the manifest written by `aot.py`
//! is the source of truth at runtime and is validated against these.

use crate::util::json::Json;

/// Default AOT shapes (mirror model.py).
pub const NUM_POOLS: usize = 8;
pub const NUM_SWITCHES: usize = 8;
pub const NUM_BINS: usize = 256;
/// Default batched-analyzer group size (epochs per `analyze_batch`
/// call). The PJRT artifact is compiled at exactly this E; the native
/// batch analyzer defaults to it but accepts any group via
/// `SimConfig::batch_group` / [`resolve_batch`] — long offline replays
/// profit from much larger groups (the sharded bench measures E = 256).
pub const BATCH: usize = 16;

/// Resolve a `SimConfig::batch_group` knob value to a concrete native
/// group size: `0` means "the default [`BATCH`]", anything else is
/// honored as given.
pub fn resolve_batch(group: usize) -> usize {
    if group == 0 {
        BATCH
    } else {
        group
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub pools: usize,
    pub switches: usize,
    pub nbins: usize,
    pub batch: usize,
    pub single: String,
    pub batch_module: String,
}

impl Manifest {
    pub fn load(artifacts_dir: &str) -> anyhow::Result<Manifest> {
        let path = format!("{artifacts_dir}/manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path}: {e} (run `make artifacts` first)"))?;
        let v = Json::parse(&src).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let get = |k: &str| -> anyhow::Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow::anyhow!("{path}: missing `{k}`"))
        };
        let gets = |k: &str| -> anyhow::Result<String> {
            Ok(v.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow::anyhow!("{path}: missing `{k}`"))?
                .to_string())
        };
        Ok(Manifest {
            pools: get("pools")?,
            switches: get("switches")?,
            nbins: get("nbins")?,
            batch: get("batch")?,
            single: gets("single")?,
            batch_module: gets("batch_module")?,
        })
    }
}

/// Locate the artifacts directory: `CXLMEMSIM_ARTIFACTS` env var, then
/// `./artifacts`, then relative to the crate root (for `cargo test`).
pub fn artifacts_dir() -> String {
    if let Ok(dir) = std::env::var("CXLMEMSIM_ARTIFACTS") {
        return dir;
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        if std::path::Path::new(&format!("{cand}/manifest.json")).exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_loads_and_matches_constants() {
        let dir = artifacts_dir();
        if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&dir).expect("run `make artifacts` before cargo test");
        assert_eq!(m.pools, NUM_POOLS);
        assert_eq!(m.switches, NUM_SWITCHES);
        assert_eq!(m.nbins, NUM_BINS);
        assert_eq!(m.batch, BATCH);
        assert!(std::path::Path::new(&format!("{dir}/{}", m.single)).exists());
        assert!(std::path::Path::new(&format!("{dir}/{}", m.batch_module)).exists());
    }

    #[test]
    fn resolve_batch_defaults_and_passthrough() {
        assert_eq!(resolve_batch(0), BATCH);
        assert_eq!(resolve_batch(1), 1);
        assert_eq!(resolve_batch(256), 256);
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}

//! Pure-rust mirror of the AOT timing analyzer.
//!
//! Implements exactly the math of `python/compile/model.py` (and its
//! oracle `kernels/ref.py`): latency dot products, the descendant-mask
//! matmul, and the two queueing scans — fused here into a single pass
//! per switch row, with all-zero pool columns skipped. f32 arithmetic
//! produces every value with the same operations in the same order as
//! the HLO so results agree to float tolerance — verified against
//! `artifacts/golden.json` in `rust/tests/golden.rs`.
//!
//! This backend is also the performance fast path: for the default
//! (P=8, S=8, B=256) shapes one invocation is a few microseconds, so
//! the epoch loop can run at ~10⁵ epochs/s (see benches/hotpath.rs).

use crate::topology::TopoTensors;

use super::{BatchOutputs, BatchTimingModel, TimingInputs, TimingModel, TimingOutputs};

#[derive(Clone)]
pub struct NativeAnalyzer {
    pools: usize,
    switches: usize,
    nbins: usize,
    extra_rd: Vec<f32>,
    extra_wr: Vec<f32>,
    desc_mask: Vec<f32>,
    stt: Vec<f32>,
    bw: Vec<f32>,
    /// Switch rows with any routed pool (padded rows are provably inert
    /// — zero mask, zero stt/bw — so the scans skip them entirely).
    active_rows: Vec<usize>,
    // scratch buffers reused across epochs (no hot-loop allocation)
    ev: Vec<f32>,
    cong_backlog: Vec<f32>,
    /// Pools whose read+write histograms are all-zero this epoch; the
    /// masked matmul skips their columns (histograms are event counts,
    /// so a zero sum means a zero row and skipping is bit-exact).
    pool_zero: Vec<bool>,
    /// Copy the backlog profile into the outputs. Off by default to
    /// keep the hot path allocation-light; `Coordinator` turns it on
    /// when an epoch policy is installed (policies read the profile).
    pub export_backlog: bool,
}

impl NativeAnalyzer {
    pub fn new(t: &TopoTensors, nbins: usize) -> NativeAnalyzer {
        let active_rows: Vec<usize> = (0..t.switches)
            .filter(|&s| {
                (0..t.pools).any(|p| t.desc_mask[s * t.pools + p] != 0.0)
                    || t.stt[s] != 0.0
                    || t.bw[s] != 0.0
            })
            .collect();
        NativeAnalyzer {
            active_rows,
            pools: t.pools,
            switches: t.switches,
            nbins,
            extra_rd: t.extra_read_lat.clone(),
            extra_wr: t.extra_write_lat.clone(),
            desc_mask: t.desc_mask.clone(),
            stt: t.stt.clone(),
            bw: t.bw.clone(),
            ev: vec![0.0; t.switches * nbins],
            cong_backlog: vec![0.0; t.switches * nbins],
            pool_zero: vec![false; t.pools],
            export_backlog: false,
        }
    }

    /// Borrow the last epoch's backlog profile without copying. Only
    /// maintained while `export_backlog` is on — the common path skips
    /// the per-bin backlog stores entirely.
    pub fn last_backlog(&self) -> &[f32] {
        &self.cong_backlog
    }

    /// The model's three stages for one epoch, writing into caller
    /// slices — shared by the per-epoch [`TimingModel::analyze`] and
    /// the batched kernel so both are bit-identical by construction:
    ///
    /// 1. latency dot products (also yields the sparse-pool mask);
    /// 2. descendant-mask matmul `ev[s,b]`, active rows × live pools;
    /// 3. congestion + bandwidth queueing scans, fused into ONE pass
    ///    over each active switch row (the bandwidth scan needs only
    ///    the current and previous backlog values, which the fused
    ///    loop carries in registers instead of re-reading an [S, B]
    ///    scratch array).
    ///
    /// Every f32 value is produced by the same operations in the same
    /// order as the unfused reference (`kernels/ref.py`), so outputs
    /// stay bit-identical — asserted against `artifacts/golden.json`
    /// in `rust/tests/golden.rs` and across paths in
    /// `tests/pipeline_equivalence.rs`.
    fn analyze_core(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
        lat: &mut [f32],
        cong: &mut [f32],
        bwd: &mut [f32],
        store_backlog: bool,
    ) -> f64 {
        let (p, b) = (self.pools, self.nbins);
        debug_assert_eq!(reads.len(), p * b);
        debug_assert_eq!(writes.len(), p * b);
        debug_assert_eq!(lat.len(), p);

        // 1. latency delay per pool + sparsity mask
        let mut any_traffic = false;
        for pool in 0..p {
            let ro: f32 = reads[pool * b..(pool + 1) * b].iter().sum();
            let wo: f32 = writes[pool * b..(pool + 1) * b].iter().sum();
            lat[pool] = ro * self.extra_rd[pool] + wo * self.extra_wr[pool];
            let zero = ro == 0.0 && wo == 0.0;
            self.pool_zero[pool] = zero;
            any_traffic |= !zero;
        }
        cong.fill(0.0);
        bwd.fill(0.0);
        if !any_traffic {
            // empty epoch: all outputs are exactly zero; skip the
            // matmul and scans entirely (a zeroed input drives every
            // queue term to 0 — see the scan recurrences below)
            if store_backlog {
                self.cong_backlog.fill(0.0);
            }
            return 0.0;
        }

        // 2. ev[s, b] = desc_mask @ (reads + writes), active rows ×
        // pools with traffic only
        self.ev.fill(0.0);
        for &sw in &self.active_rows {
            let row = &self.desc_mask[sw * p..(sw + 1) * p];
            let out = &mut self.ev[sw * b..(sw + 1) * b];
            for pool in 0..p {
                let m = row[pool];
                if m == 0.0 || self.pool_zero[pool] {
                    continue;
                }
                let r = &reads[pool * b..(pool + 1) * b];
                let w = &writes[pool * b..(pool + 1) * b];
                for i in 0..b {
                    out[i] += m * (r[i] + w[i]);
                }
            }
        }

        // 3. fused queueing scans per active row. Congestion: demand =
        // ev*stt against capacity = bin_width; delay = end-of-epoch
        // backlog drain time + transient waiting capped at one epoch
        // (mirrors model.py; DESIGN.md §5). Bandwidth: byte demand of
        // the served (congestion-shifted) stream against bw*bin_width.
        let epoch_len = bin_width * b as f32;
        for &sw in &self.active_rows {
            let stt = self.stt[sw];
            let bw = self.bw[sw];
            let ev = &self.ev[sw * b..(sw + 1) * b];
            let cap = bw * bin_width;
            let mut qc = 0.0f32; // congestion backlog
            let mut qcsum = 0.0f32;
            let mut prev = 0.0f32; // previous bin's backlog
            let mut qb = 0.0f32; // bandwidth backlog (bytes)
            let mut qbsum = 0.0f32;
            if store_backlog {
                let backlog = &mut self.cong_backlog[sw * b..(sw + 1) * b];
                for i in 0..b {
                    let e = ev[i] * stt;
                    qc = (qc + e - bin_width).max(0.0);
                    backlog[i] = qc;
                    qcsum += qc;
                    let served = if stt > 0.0 { (e + prev - qc) / stt } else { ev[i] };
                    let demand = served * bytes_per_ev;
                    prev = qc;
                    qb = (qb + demand - cap).max(0.0);
                    qbsum += qb;
                }
            } else {
                for i in 0..b {
                    let e = ev[i] * stt;
                    qc = (qc + e - bin_width).max(0.0);
                    qcsum += qc;
                    let served = if stt > 0.0 { (e + prev - qc) / stt } else { ev[i] };
                    let demand = served * bytes_per_ev;
                    prev = qc;
                    qb = (qb + demand - cap).max(0.0);
                    qbsum += qb;
                }
            }
            cong[sw] = if stt > 0.0 {
                qc + (qcsum * (bin_width / stt)).min(epoch_len)
            } else {
                0.0
            };
            bwd[sw] = if bw > 0.0 {
                qb / bw + (qbsum * (bin_width / bytes_per_ev)).min(epoch_len)
            } else {
                0.0
            };
        }

        // three partial sums added together, matching the reference's
        // reduction order exactly
        lat.iter().map(|x| *x as f64).sum::<f64>()
            + cong.iter().map(|x| *x as f64).sum::<f64>()
            + bwd.iter().map(|x| *x as f64).sum::<f64>()
    }
}

impl TimingModel for NativeAnalyzer {
    fn pools(&self) -> usize {
        self.pools
    }
    fn switches(&self) -> usize {
        self.switches
    }
    fn nbins(&self) -> usize {
        self.nbins
    }
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn set_export_backlog(&mut self, on: bool) {
        self.export_backlog = on;
    }

    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs> {
        let (p, s, b) = (self.pools, self.switches, self.nbins);
        anyhow::ensure!(inp.reads.len() == p * b, "reads shape");
        anyhow::ensure!(inp.writes.len() == p * b, "writes shape");
        let mut lat = vec![0.0f32; p];
        let mut cong = vec![0.0f32; s];
        let mut bwd = vec![0.0f32; s];
        // backlog is stored and copied out only when a consumer asked
        // for it (epoch policies); the common path skips both the
        // per-bin stores and the 8 KB clone.
        let store = self.export_backlog;
        let total = self.analyze_core(
            inp.reads,
            inp.writes,
            inp.bin_width,
            inp.bytes_per_ev,
            &mut lat,
            &mut cong,
            &mut bwd,
            store,
        );
        let cong_backlog = if store { self.cong_backlog.clone() } else { Vec::new() };
        Ok(TimingOutputs { total, lat, cong, bwd, cong_backlog })
    }
}

/// Batched flavour of the native analyzer: a real batched kernel over
/// E epochs per call — output tensors are allocated once per call at
/// their exact `[E, ·]` sizes and each epoch's stage runs through the
/// shared fused [`NativeAnalyzer::analyze_core`] (no per-epoch
/// `TimingOutputs` allocation, no backlog clone, scratch reused across
/// the E-epoch loop). Exists so the batched replay path
/// ([`crate::coordinator::run_batched`]) has a backend that needs no
/// AOT artifacts and is bit-identical to the per-epoch native analyzer
/// — the PJRT batch module is the dispatch-amortizing counterpart.
///
/// The E epochs of one call are *independent* (no state flows between
/// them — `analyze_core` fully rewrites its scratch per epoch), so the
/// loop shards across worker threads (`with_threads`, below): each
/// worker owns a private [`NativeAnalyzer`]
/// scratch clone (created once at construction, reused for every
/// call) and writes a contiguous, disjoint range of output rows.
/// Results are bit-identical for **any** thread count by construction
/// — the same `analyze_core` invocation produces the same bits into
/// the same row regardless of which worker runs it (asserted in
/// `tests/pipeline_equivalence.rs` and the CI determinism matrix).
pub struct NativeBatchAnalyzer {
    inner: NativeAnalyzer,
    /// Scratch analyzers for workers 1..N (worker 0 reuses `inner`).
    /// Allocated once here so per-call sharding allocates nothing.
    workers: Vec<NativeAnalyzer>,
    batch: usize,
    threads: usize,
}

/// Auto thread resolution (`threads == 0`) refuses to slice the batch
/// thinner than this many epochs per worker — spawning a worker for a
/// couple of microsecond-scale epochs costs more than it saves. An
/// explicit thread count is honored as given (clamped to the batch).
const MIN_AUTO_EPOCHS_PER_WORKER: usize = 4;

impl NativeBatchAnalyzer {
    /// Sequential batched analyzer (one worker, the baseline).
    pub fn new(t: &TopoTensors, nbins: usize, batch: usize) -> NativeBatchAnalyzer {
        NativeBatchAnalyzer::with_threads(t, nbins, batch, 1)
    }

    /// [`NativeBatchAnalyzer::new`] with an explicit shard-worker count
    /// (`0` = one per core, capped so each auto worker gets at least
    /// [`MIN_AUTO_EPOCHS_PER_WORKER`] epochs). Outputs are bit-identical
    /// for every value; only wall-clock changes.
    pub fn with_threads(
        t: &TopoTensors,
        nbins: usize,
        batch: usize,
        threads: usize,
    ) -> NativeBatchAnalyzer {
        let batch = batch.max(1);
        let threads = match threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min((batch / MIN_AUTO_EPOCHS_PER_WORKER).max(1)),
            n => n,
        }
        .clamp(1, batch);
        let inner = NativeAnalyzer::new(t, nbins);
        let workers = (1..threads).map(|_| inner.clone()).collect();
        NativeBatchAnalyzer { inner, workers, batch, threads }
    }
}

/// Run `analyze_core` over a contiguous range of epochs, writing each
/// epoch's outputs into its own row of the (sub)slices. This is the
/// whole per-worker loop: the 1-thread path and every shard run the
/// exact same code, which is what makes sharding bit-exact.
fn analyze_epoch_range(
    an: &mut NativeAnalyzer,
    reads: &[f32],
    writes: &[f32],
    bin_width: f32,
    bytes_per_ev: f32,
    total: &mut [f64],
    lat: &mut [f32],
    cong: &mut [f32],
    bwd: &mut [f32],
) {
    let (p, s, b) = (an.pools, an.switches, an.nbins);
    let n = p * b;
    for i in 0..total.len() {
        total[i] = an.analyze_core(
            &reads[i * n..(i + 1) * n],
            &writes[i * n..(i + 1) * n],
            bin_width,
            bytes_per_ev,
            &mut lat[i * p..(i + 1) * p],
            &mut cong[i * s..(i + 1) * s],
            &mut bwd[i * s..(i + 1) * s],
            false,
        );
    }
}

impl BatchTimingModel for NativeBatchAnalyzer {
    fn pools(&self) -> usize {
        self.inner.pools
    }
    fn switches(&self) -> usize {
        self.inner.switches
    }
    fn nbins(&self) -> usize {
        self.inner.nbins
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn threads(&self) -> usize {
        self.threads
    }
    fn backend_name(&self) -> &'static str {
        "native-batch"
    }

    fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs> {
        let (e, p, s, b) = (self.batch, self.inner.pools, self.inner.switches, self.inner.nbins);
        anyhow::ensure!(reads.len() == e * p * b, "reads shape");
        anyhow::ensure!(writes.len() == e * p * b, "writes shape");
        let mut out = BatchOutputs {
            total: vec![0.0; e],
            lat: vec![0.0; e * p],
            cong: vec![0.0; e * s],
            bwd: vec![0.0; e * s],
        };
        let threads = self.threads.clamp(1, e);
        if threads == 1 {
            analyze_epoch_range(
                &mut self.inner,
                reads,
                writes,
                bin_width,
                bytes_per_ev,
                &mut out.total,
                &mut out.lat,
                &mut out.cong,
                &mut out.bwd,
            );
            return Ok(out);
        }
        // Shard the E independent epochs into contiguous chunks, one
        // per worker. Every worker gets disjoint output row ranges and
        // its own scratch analyzer, so the bits written are identical
        // to the 1-thread loop for any worker count. The calling
        // thread runs the first chunk itself (on `inner`) instead of
        // idling at the scope join — one fewer spawn per call and no
        // oversubscription at `threads == cores`.
        let chunk = e.div_ceil(threads);
        let inner = &mut self.inner;
        let extra = &mut self.workers;
        std::thread::scope(|sc| {
            let mut scratch: Vec<&mut NativeAnalyzer> =
                std::iter::once(inner).chain(extra.iter_mut()).collect();
            let (mut tot, mut lat, mut cong, mut bwd) =
                (&mut out.total[..], &mut out.lat[..], &mut out.cong[..], &mut out.bwd[..]);
            let (mut rd, mut wr) = (reads, writes);
            let mut first = None;
            for (w, an) in scratch.drain(..).enumerate() {
                let take = chunk.min(tot.len());
                if take == 0 {
                    break;
                }
                let (t0, rest) = std::mem::take(&mut tot).split_at_mut(take);
                tot = rest;
                let (l0, rest) = std::mem::take(&mut lat).split_at_mut(take * p);
                lat = rest;
                let (c0, rest) = std::mem::take(&mut cong).split_at_mut(take * s);
                cong = rest;
                let (w0, rest) = std::mem::take(&mut bwd).split_at_mut(take * s);
                bwd = rest;
                let (r0, r1) = rd.split_at(take * p * b);
                rd = r1;
                let (x0, x1) = wr.split_at(take * p * b);
                wr = x1;
                if w == 0 {
                    first = Some((an, r0, x0, t0, l0, c0, w0));
                } else {
                    sc.spawn(move || {
                        analyze_epoch_range(an, r0, x0, bin_width, bytes_per_ev, t0, l0, c0, w0)
                    });
                }
            }
            if let Some((an, r0, x0, t0, l0, c0, w0)) = first {
                analyze_epoch_range(an, r0, x0, bin_width, bytes_per_ev, t0, l0, c0, w0);
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{builtin, TopoTensors};

    fn analyzer(nbins: usize) -> NativeAnalyzer {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        NativeAnalyzer::new(&t, nbins)
    }

    #[test]
    fn zero_traffic_zero_delay() {
        let mut a = analyzer(16);
        let reads = vec![0.0; 8 * 16];
        let writes = vec![0.0; 8 * 16];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total, 0.0);
    }

    #[test]
    fn latency_delay_formula() {
        let mut a = analyzer(4);
        let mut reads = vec![0.0f32; 8 * 4];
        // 10 reads to pool 1 in bin 0
        reads[1 * 4] = 10.0;
        let writes = vec![0.0; 8 * 4];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1e9,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let topo = builtin::fig2();
        let expect = 10.0 * topo.extra_read_latency(1);
        assert!((out.lat[1] as f64 - expect).abs() < 1e-3, "{} vs {expect}", out.lat[1]);
        // huge bin width -> no congestion/bw delay
        assert_eq!(out.cong_total(), 0.0);
        assert_eq!(out.bwd_total(), 0.0);
    }

    #[test]
    fn congestion_grows_with_burst() {
        let mut a = analyzer(8);
        let mk = |n: f32| {
            let mut reads = vec![0.0f32; 8 * 8];
            reads[1 * 8] = n; // burst in bin 0 of pool 1
            reads
        };
        let writes = vec![0.0; 8 * 8];
        let small = a
            .analyze(&TimingInputs {
                reads: &mk(2.0),
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let big = a
            .analyze(&TimingInputs {
                reads: &mk(200.0),
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(big.cong_total() > small.cong_total());
        assert!(big.total > big.lat_total(), "congestion must add delay");
    }

    #[test]
    fn local_pool_free() {
        let mut a = analyzer(8);
        let mut reads = vec![0.0f32; 8 * 8];
        for i in 0..8 {
            reads[i] = 1000.0; // pool 0 = local
        }
        let writes = vec![0.0; 8 * 8];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total, 0.0, "local traffic must cost nothing");
    }

    #[test]
    fn outputs_have_model_shapes() {
        let mut a = analyzer(32);
        let reads = vec![1.0; 8 * 32];
        let writes = vec![1.0; 8 * 32];
        // default: hot path, no backlog export
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 50.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.lat.len(), 8);
        assert_eq!(out.cong.len(), 8);
        assert_eq!(out.bwd.len(), 8);
        assert!(out.cong_backlog.is_empty(), "backlog export must be opt-in");
        // policies opt in and get the full [S, B] profile
        a.set_export_backlog(true);
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 50.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.cong_backlog.len(), 8 * 32);
    }

    #[test]
    fn empty_epoch_resets_exported_backlog() {
        // a zero-traffic epoch must overwrite the previous epoch's
        // backlog profile, not leak it through the early-exit
        let mut a = analyzer(8);
        a.set_export_backlog(true);
        let mut reads = vec![0.0f32; 8 * 8];
        reads[1 * 8] = 500.0;
        let writes = vec![0.0; 8 * 8];
        let busy = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 10.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(busy.cong_backlog.iter().any(|x| *x > 0.0));
        let zeros = vec![0.0f32; 8 * 8];
        let idle = a
            .analyze(&TimingInputs {
                reads: &zeros,
                writes: &zeros,
                bin_width: 10.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(idle.cong_backlog.iter().all(|x| *x == 0.0));
        assert_eq!(idle.total, 0.0);
    }

    #[test]
    fn batch_scratch_does_not_leak_between_epochs() {
        // [dense, all-zero, same-dense]: epoch 1 must be exactly zero
        // (stale ev/backlog scratch would corrupt it) and epoch 2 must
        // equal epoch 0 bit-for-bit
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut batch = NativeBatchAnalyzer::new(&t, 16, 3);
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(41);
        let dense: Vec<f32> = (0..n).map(|_| rng.below(30) as f32).collect();
        let mut reads = vec![0.0f32; 3 * n];
        reads[..n].copy_from_slice(&dense);
        reads[2 * n..].copy_from_slice(&dense);
        let writes = vec![0.0f32; 3 * n];
        let out = batch.analyze_batch(&reads, &writes, 25.0, 64.0).unwrap();
        assert_eq!(out.total[1], 0.0, "empty epoch must cost nothing");
        assert!(out.cong[8..16].iter().all(|x| *x == 0.0));
        assert!(out.bwd[8..16].iter().all(|x| *x == 0.0));
        assert_eq!(out.total[0], out.total[2]);
        assert_eq!(out.epoch(0, 8, 8).lat, out.epoch(2, 8, 8).lat);
        assert_eq!(out.epoch(0, 8, 8).cong, out.epoch(2, 8, 8).cong);
        assert_eq!(out.epoch(0, 8, 8).bwd, out.epoch(2, 8, 8).bwd);
    }

    #[test]
    fn native_batch_matches_single_bit_exactly() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut single = NativeAnalyzer::new(&t, 16);
        let mut batch = NativeBatchAnalyzer::new(&t, 16, 4);
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(3);
        let reads: Vec<f32> = (0..4 * n).map(|_| rng.below(20) as f32).collect();
        let writes: Vec<f32> = (0..4 * n).map(|_| rng.below(9) as f32).collect();
        let out = batch.analyze_batch(&reads, &writes, 100.0, 64.0).unwrap();
        assert_eq!(out.total.len(), 4);
        for i in 0..4 {
            let s = single
                .analyze(&TimingInputs {
                    reads: &reads[i * n..(i + 1) * n],
                    writes: &writes[i * n..(i + 1) * n],
                    bin_width: 100.0,
                    bytes_per_ev: 64.0,
                })
                .unwrap();
            assert_eq!(out.total[i], s.total, "epoch {i}");
            assert_eq!(out.epoch(i, 8, 8).lat, s.lat);
            assert_eq!(out.epoch(i, 8, 8).cong, s.cong);
            assert_eq!(out.epoch(i, 8, 8).bwd, s.bwd);
        }
    }

    #[test]
    fn sharded_batch_matches_single_thread_bit_exactly() {
        // the E epochs are independent and every worker runs the same
        // analyze_core into disjoint rows, so ANY thread count —
        // uneven splits, more workers than epochs — must reproduce
        // the 1-thread outputs bit-for-bit
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let e = 7usize; // prime: never splits evenly
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(77);
        let reads: Vec<f32> = (0..e * n).map(|_| rng.below(30) as f32).collect();
        let writes: Vec<f32> = (0..e * n).map(|_| rng.below(12) as f32).collect();
        let mut base = NativeBatchAnalyzer::new(&t, 16, e);
        let expect = base.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
        for threads in [2usize, 3, 5, 64] {
            let mut sharded = NativeBatchAnalyzer::with_threads(&t, 16, e, threads);
            let got = sharded.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
            assert_eq!(got.total, expect.total, "{threads} threads: totals");
            assert_eq!(got.lat, expect.lat, "{threads} threads: lat");
            assert_eq!(got.cong, expect.cong, "{threads} threads: cong");
            assert_eq!(got.bwd, expect.bwd, "{threads} threads: bwd");
        }
    }

    #[test]
    fn sharded_batch_thread_resolution() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        // explicit counts clamp to the epoch count
        let a = NativeBatchAnalyzer::with_threads(&t, 16, 4, 16);
        assert_eq!(a.threads(), 4);
        // 0 = auto: at least one worker, never thinner than the
        // minimum epochs-per-worker slice
        let b = NativeBatchAnalyzer::with_threads(&t, 16, 8, 0);
        assert!(b.threads() >= 1);
        assert!(b.threads() <= 8 / MIN_AUTO_EPOCHS_PER_WORKER);
        // the sequential constructor stays sequential
        let c = NativeBatchAnalyzer::new(&t, 16, 32);
        assert_eq!(c.threads(), 1);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut a = analyzer(8);
        let reads = vec![0.0; 3];
        let writes = vec![0.0; 8 * 8];
        assert!(a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1.0,
                bytes_per_ev: 64.0,
            })
            .is_err());
    }
}

//! Pure-rust mirror of the AOT timing analyzer.
//!
//! Implements exactly the math of `python/compile/model.py` (and its
//! oracle `kernels/ref.py`): latency dot products, the descendant-mask
//! matmul, and the two queueing scans. f32 arithmetic in the same
//! order as the HLO so results agree to float tolerance — verified
//! against `artifacts/golden.json` in `rust/tests/golden.rs`.
//!
//! This backend is also the performance fast path: for the default
//! (P=8, S=8, B=256) shapes one invocation is a few microseconds, so
//! the epoch loop can run at ~10⁵ epochs/s (see benches/hotpath.rs).

use crate::topology::TopoTensors;

use super::{BatchOutputs, BatchTimingModel, TimingInputs, TimingModel, TimingOutputs};

pub struct NativeAnalyzer {
    pools: usize,
    switches: usize,
    nbins: usize,
    extra_rd: Vec<f32>,
    extra_wr: Vec<f32>,
    desc_mask: Vec<f32>,
    stt: Vec<f32>,
    bw: Vec<f32>,
    /// Switch rows with any routed pool (padded rows are provably inert
    /// — zero mask, zero stt/bw — so the scans skip them entirely).
    active_rows: Vec<usize>,
    // scratch buffers reused across epochs (no hot-loop allocation)
    ev: Vec<f32>,
    cong_backlog: Vec<f32>,
    bw_demand: Vec<f32>,
    /// Copy the backlog profile into the outputs (needed by epoch
    /// policies; off by default to keep the hot path allocation-light).
    pub export_backlog: bool,
}

impl NativeAnalyzer {
    pub fn new(t: &TopoTensors, nbins: usize) -> NativeAnalyzer {
        let active_rows: Vec<usize> = (0..t.switches)
            .filter(|&s| {
                (0..t.pools).any(|p| t.desc_mask[s * t.pools + p] != 0.0)
                    || t.stt[s] != 0.0
                    || t.bw[s] != 0.0
            })
            .collect();
        NativeAnalyzer {
            active_rows,
            pools: t.pools,
            switches: t.switches,
            nbins,
            extra_rd: t.extra_read_lat.clone(),
            extra_wr: t.extra_write_lat.clone(),
            desc_mask: t.desc_mask.clone(),
            stt: t.stt.clone(),
            bw: t.bw.clone(),
            ev: vec![0.0; t.switches * nbins],
            cong_backlog: vec![0.0; t.switches * nbins],
            bw_demand: vec![0.0; t.switches * nbins],
            export_backlog: true,
        }
    }

    /// Borrow the last epoch's backlog profile without copying.
    pub fn last_backlog(&self) -> &[f32] {
        &self.cong_backlog
    }
}

impl TimingModel for NativeAnalyzer {
    fn pools(&self) -> usize {
        self.pools
    }
    fn switches(&self) -> usize {
        self.switches
    }
    fn nbins(&self) -> usize {
        self.nbins
    }
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn set_export_backlog(&mut self, on: bool) {
        self.export_backlog = on;
    }

    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs> {
        let (p, s, b) = (self.pools, self.switches, self.nbins);
        anyhow::ensure!(inp.reads.len() == p * b, "reads shape");
        anyhow::ensure!(inp.writes.len() == p * b, "writes shape");

        // 1. latency delay per pool
        let mut lat = vec![0.0f32; p];
        for pool in 0..p {
            let ro: f32 = inp.reads[pool * b..(pool + 1) * b].iter().sum();
            let wo: f32 = inp.writes[pool * b..(pool + 1) * b].iter().sum();
            lat[pool] = ro * self.extra_rd[pool] + wo * self.extra_wr[pool];
        }

        // 2. ev[s, b] = desc_mask @ (reads + writes), active rows only
        self.ev.iter_mut().for_each(|x| *x = 0.0);
        for &sw in &self.active_rows {
            let row = &self.desc_mask[sw * p..(sw + 1) * p];
            let out = &mut self.ev[sw * b..(sw + 1) * b];
            for pool in 0..p {
                let m = row[pool];
                if m == 0.0 {
                    continue;
                }
                let r = &inp.reads[pool * b..(pool + 1) * b];
                let w = &inp.writes[pool * b..(pool + 1) * b];
                for i in 0..b {
                    out[i] += m * (r[i] + w[i]);
                }
            }
        }

        // 3. congestion scan: demand = ev*stt, capacity = bin_width.
        // delay = end-of-epoch backlog drain time + transient waiting
        // capped at one epoch (mirrors model.py; DESIGN.md §5).
        let epoch_len = inp.bin_width * b as f32;
        let mut cong = vec![0.0f32; s];
        for &sw in &self.active_rows {
            let stt = self.stt[sw];
            let ev = &self.ev[sw * b..(sw + 1) * b];
            let backlog = &mut self.cong_backlog[sw * b..(sw + 1) * b];
            let mut q = 0.0f32;
            let mut qsum = 0.0f32;
            for i in 0..b {
                q = (q + ev[i] * stt - inp.bin_width).max(0.0);
                backlog[i] = q;
                qsum += q;
            }
            cong[sw] = if stt > 0.0 {
                q + (qsum * (inp.bin_width / stt)).min(epoch_len)
            } else {
                0.0
            };
        }

        // 4. bandwidth scan on the served (congestion-shifted) stream
        let mut bwd = vec![0.0f32; s];
        for &sw in &self.active_rows {
            let stt = self.stt[sw];
            let bw = self.bw[sw];
            let ev = &self.ev[sw * b..(sw + 1) * b];
            let backlog = &self.cong_backlog[sw * b..(sw + 1) * b];
            let demand = &mut self.bw_demand[sw * b..(sw + 1) * b];
            let mut prev = 0.0f32;
            for i in 0..b {
                let served_events = if stt > 0.0 {
                    (ev[i] * stt + prev - backlog[i]) / stt
                } else {
                    ev[i]
                };
                demand[i] = served_events * inp.bytes_per_ev;
                prev = backlog[i];
            }
            let cap = bw * inp.bin_width;
            let mut q = 0.0f32;
            let mut qsum = 0.0f32;
            for i in 0..b {
                q = (q + demand[i] - cap).max(0.0);
                qsum += q;
            }
            bwd[sw] = if bw > 0.0 {
                q / bw + (qsum * (inp.bin_width / inp.bytes_per_ev)).min(epoch_len)
            } else {
                0.0
            };
        }

        let total = lat.iter().map(|x| *x as f64).sum::<f64>()
            + cong.iter().map(|x| *x as f64).sum::<f64>()
            + bwd.iter().map(|x| *x as f64).sum::<f64>();
        // backlog is copied out only when a consumer asked for it
        // (epoch policies); the common path skips the 8 KB clone.
        let cong_backlog = if self.export_backlog {
            self.cong_backlog.clone()
        } else {
            Vec::new()
        };
        Ok(TimingOutputs { total, lat, cong, bwd, cong_backlog })
    }
}

/// Batched flavour of the native analyzer: a plain loop over E epochs
/// per call. Exists so the batched replay path ([`crate::coordinator::
/// run_batched`]) has a backend that needs no AOT artifacts and is
/// bit-identical to the per-epoch native analyzer — the PJRT batch
/// module is the dispatch-amortizing counterpart.
pub struct NativeBatchAnalyzer {
    inner: NativeAnalyzer,
    batch: usize,
}

impl NativeBatchAnalyzer {
    pub fn new(t: &TopoTensors, nbins: usize, batch: usize) -> NativeBatchAnalyzer {
        let mut inner = NativeAnalyzer::new(t, nbins);
        inner.export_backlog = false;
        NativeBatchAnalyzer { inner, batch: batch.max(1) }
    }
}

impl BatchTimingModel for NativeBatchAnalyzer {
    fn pools(&self) -> usize {
        self.inner.pools
    }
    fn switches(&self) -> usize {
        self.inner.switches
    }
    fn nbins(&self) -> usize {
        self.inner.nbins
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn backend_name(&self) -> &'static str {
        "native-batch"
    }

    fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs> {
        let (e, p, s, b) = (self.batch, self.inner.pools, self.inner.switches, self.inner.nbins);
        anyhow::ensure!(reads.len() == e * p * b, "reads shape");
        anyhow::ensure!(writes.len() == e * p * b, "writes shape");
        let mut out = BatchOutputs {
            total: Vec::with_capacity(e),
            lat: Vec::with_capacity(e * p),
            cong: Vec::with_capacity(e * s),
            bwd: Vec::with_capacity(e * s),
        };
        for i in 0..e {
            let one = self.inner.analyze(&TimingInputs {
                reads: &reads[i * p * b..(i + 1) * p * b],
                writes: &writes[i * p * b..(i + 1) * p * b],
                bin_width,
                bytes_per_ev,
            })?;
            out.total.push(one.total);
            out.lat.extend_from_slice(&one.lat);
            out.cong.extend_from_slice(&one.cong);
            out.bwd.extend_from_slice(&one.bwd);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{builtin, TopoTensors};

    fn analyzer(nbins: usize) -> NativeAnalyzer {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        NativeAnalyzer::new(&t, nbins)
    }

    #[test]
    fn zero_traffic_zero_delay() {
        let mut a = analyzer(16);
        let reads = vec![0.0; 8 * 16];
        let writes = vec![0.0; 8 * 16];
        let out = a
            .analyze(&TimingInputs { reads: &reads, writes: &writes, bin_width: 100.0, bytes_per_ev: 64.0 })
            .unwrap();
        assert_eq!(out.total, 0.0);
    }

    #[test]
    fn latency_delay_formula() {
        let mut a = analyzer(4);
        let mut reads = vec![0.0f32; 8 * 4];
        // 10 reads to pool 1 in bin 0
        reads[1 * 4] = 10.0;
        let writes = vec![0.0; 8 * 4];
        let out = a
            .analyze(&TimingInputs { reads: &reads, writes: &writes, bin_width: 1e9, bytes_per_ev: 64.0 })
            .unwrap();
        let topo = builtin::fig2();
        let expect = 10.0 * topo.extra_read_latency(1);
        assert!((out.lat[1] as f64 - expect).abs() < 1e-3, "{} vs {expect}", out.lat[1]);
        // huge bin width -> no congestion/bw delay
        assert_eq!(out.cong_total(), 0.0);
        assert_eq!(out.bwd_total(), 0.0);
    }

    #[test]
    fn congestion_grows_with_burst() {
        let mut a = analyzer(8);
        let mk = |n: f32| {
            let mut reads = vec![0.0f32; 8 * 8];
            reads[1 * 8] = n; // burst in bin 0 of pool 1
            reads
        };
        let writes = vec![0.0; 8 * 8];
        let small = a
            .analyze(&TimingInputs { reads: &mk(2.0), writes: &writes, bin_width: 100.0, bytes_per_ev: 64.0 })
            .unwrap();
        let big = a
            .analyze(&TimingInputs { reads: &mk(200.0), writes: &writes, bin_width: 100.0, bytes_per_ev: 64.0 })
            .unwrap();
        assert!(big.cong_total() > small.cong_total());
        assert!(big.total > big.lat_total(), "congestion must add delay");
    }

    #[test]
    fn local_pool_free() {
        let mut a = analyzer(8);
        let mut reads = vec![0.0f32; 8 * 8];
        for i in 0..8 {
            reads[i] = 1000.0; // pool 0 = local
        }
        let writes = vec![0.0; 8 * 8];
        let out = a
            .analyze(&TimingInputs { reads: &reads, writes: &writes, bin_width: 100.0, bytes_per_ev: 64.0 })
            .unwrap();
        assert_eq!(out.total, 0.0, "local traffic must cost nothing");
    }

    #[test]
    fn outputs_have_model_shapes() {
        let mut a = analyzer(32);
        let reads = vec![1.0; 8 * 32];
        let writes = vec![1.0; 8 * 32];
        let out = a
            .analyze(&TimingInputs { reads: &reads, writes: &writes, bin_width: 50.0, bytes_per_ev: 64.0 })
            .unwrap();
        assert_eq!(out.lat.len(), 8);
        assert_eq!(out.cong.len(), 8);
        assert_eq!(out.bwd.len(), 8);
        assert_eq!(out.cong_backlog.len(), 8 * 32);
    }

    #[test]
    fn native_batch_matches_single_bit_exactly() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut single = NativeAnalyzer::new(&t, 16);
        let mut batch = NativeBatchAnalyzer::new(&t, 16, 4);
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(3);
        let reads: Vec<f32> = (0..4 * n).map(|_| rng.below(20) as f32).collect();
        let writes: Vec<f32> = (0..4 * n).map(|_| rng.below(9) as f32).collect();
        let out = batch.analyze_batch(&reads, &writes, 100.0, 64.0).unwrap();
        assert_eq!(out.total.len(), 4);
        for i in 0..4 {
            let s = single
                .analyze(&TimingInputs {
                    reads: &reads[i * n..(i + 1) * n],
                    writes: &writes[i * n..(i + 1) * n],
                    bin_width: 100.0,
                    bytes_per_ev: 64.0,
                })
                .unwrap();
            assert_eq!(out.total[i], s.total, "epoch {i}");
            assert_eq!(out.epoch(i, 8, 8).lat, s.lat);
            assert_eq!(out.epoch(i, 8, 8).cong, s.cong);
            assert_eq!(out.epoch(i, 8, 8).bwd, s.bwd);
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut a = analyzer(8);
        let reads = vec![0.0; 3];
        let writes = vec![0.0; 8 * 8];
        assert!(a
            .analyze(&TimingInputs { reads: &reads, writes: &writes, bin_width: 1.0, bytes_per_ev: 64.0 })
            .is_err());
    }
}

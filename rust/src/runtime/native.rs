//! Pure-rust mirror of the AOT timing analyzer.
//!
//! Implements the math of `python/compile/model.py` (and its oracle
//! `kernels/ref.py`): latency dot products, the descendant-mask
//! matmul, and the two queueing scans. Two scan kernels are available
//! ([`super::ScanKernel`]):
//!
//! * **`exact`** — the scalar reference: scans fused into a single
//!   pass per switch row, every f32 value produced by the same
//!   operations in the same order as the HLO, so results agree with
//!   `artifacts/golden.json` bit-for-bit (`rust/tests/golden.rs`).
//!   This is the golden/bit-identity kernel and the differential
//!   baseline.
//! * **`blocked`** (default) — the same recurrences reformulated as
//!   max-plus prefix scans over fixed-width f32 blocks
//!   ([`SCAN_BLOCK`] lanes): within a block the backlog is computed
//!   branch-free from a log-step prefix sum + prefix min, with one
//!   scalar carry across block boundaries; the descendant-mask matmul
//!   is folded into the same block loop so `ev`, `served`, and byte
//!   demand never round-trip through the `[S, B]` scratch array.
//!   Reassociates float adds, so outputs match `exact` only to ULP /
//!   relative tolerance (property-tested below and in
//!   `tests/pipeline_equivalence.rs`).
//!
//! This backend is also the performance fast path: for the default
//! (P=8, S=8, B=256) shapes one invocation is a few microseconds, so
//! the epoch loop can run at ~10⁵ epochs/s (see benches/hotpath.rs).

use crate::topology::TopoTensors;

use super::{BatchOutputs, BatchTimingModel, ScanKernel, TimingInputs, TimingModel, TimingOutputs};

/// Lane width of the blocked max-plus scan kernel: 16 f32 = one
/// AVX-512 vector (two AVX2 vectors); the log-step prefix networks are
/// 4 shifted-op rounds. Any nbins works — a short tail block runs the
/// same code with inert zero padding.
pub const SCAN_BLOCK: usize = 16;

#[derive(Clone)]
pub struct NativeAnalyzer {
    pools: usize,
    switches: usize,
    nbins: usize,
    kernel: ScanKernel,
    extra_rd: Vec<f32>,
    extra_wr: Vec<f32>,
    desc_mask: Vec<f32>,
    stt: Vec<f32>,
    bw: Vec<f32>,
    // Fault-free base copies of the overlay-mutable tensors. A fault
    // overlay rewrites the active `extra_rd` / `extra_wr` / `bw` from
    // these; `analyze_core` itself never branches on faults, so the
    // fault-free path is untouched (gated in benches/hotpath.rs,
    // `fault_epoch.faultfree_epochs_per_s`).
    base_extra_rd: Vec<f32>,
    base_extra_wr: Vec<f32>,
    base_bw: Vec<f32>,
    /// An overlay is currently applied (so a `None` install must
    /// restore the base tensors).
    overlaid: bool,
    /// Switch rows with any routed pool (padded rows are provably inert
    /// — zero mask, zero stt/bw — so the scans skip them entirely).
    active_rows: Vec<usize>,
    // scratch buffers reused across epochs (no hot-loop allocation)
    ev: Vec<f32>,
    cong_backlog: Vec<f32>,
    /// Pools whose read+write histograms are all-zero this epoch; the
    /// masked matmul skips their columns (histograms are event counts,
    /// so a zero sum means a zero row and skipping is bit-exact).
    pool_zero: Vec<bool>,
    /// Per-row live `(mask, pool)` columns for the blocked kernel
    /// (rebuilt per row; reused so the hot loop allocates nothing).
    live_cols: Vec<(f32, usize)>,
    /// Copy the backlog profile into the outputs. Off by default to
    /// keep the hot path allocation-light; `Coordinator` turns it on
    /// when an epoch policy is installed (policies read the profile).
    pub export_backlog: bool,
}

impl NativeAnalyzer {
    /// Reference analyzer: the `exact` scalar kernel, bit-identical to
    /// the golden vectors. Drivers construct the default `blocked`
    /// performance kernel through [`NativeAnalyzer::with_kernel`].
    pub fn new(t: &TopoTensors, nbins: usize) -> NativeAnalyzer {
        NativeAnalyzer::with_kernel(t, nbins, ScanKernel::Exact)
    }

    pub fn with_kernel(t: &TopoTensors, nbins: usize, kernel: ScanKernel) -> NativeAnalyzer {
        let active_rows: Vec<usize> = (0..t.switches)
            .filter(|&s| {
                (0..t.pools).any(|p| t.desc_mask[s * t.pools + p] != 0.0)
                    || t.stt[s] != 0.0
                    || t.bw[s] != 0.0
            })
            .collect();
        NativeAnalyzer {
            active_rows,
            pools: t.pools,
            switches: t.switches,
            nbins,
            kernel,
            extra_rd: t.extra_read_lat.clone(),
            extra_wr: t.extra_write_lat.clone(),
            desc_mask: t.desc_mask.clone(),
            stt: t.stt.clone(),
            bw: t.bw.clone(),
            base_extra_rd: t.extra_read_lat.clone(),
            base_extra_wr: t.extra_write_lat.clone(),
            base_bw: t.bw.clone(),
            overlaid: false,
            ev: vec![0.0; t.switches * nbins],
            cong_backlog: vec![0.0; t.switches * nbins],
            pool_zero: vec![false; t.pools],
            live_cols: Vec::with_capacity(t.pools),
            export_backlog: false,
        }
    }

    /// The scan kernel this analyzer runs.
    pub fn kernel(&self) -> ScanKernel {
        self.kernel
    }

    /// Borrow the last epoch's backlog profile without copying. Only
    /// maintained while `export_backlog` is on — the common path skips
    /// the per-bin backlog stores entirely.
    pub fn last_backlog(&self) -> &[f32] {
        &self.cong_backlog
    }

    /// (Un)install a fault overlay by rewriting the active tensors
    /// from their fault-free base copies: additive per-pool latency,
    /// multiplicative per-switch-row bandwidth. Overlay vectors may be
    /// shorter than the padded tensor shapes (they are sized by the
    /// real topology); padded tail entries keep their base values.
    pub fn apply_fault_overlay(&mut self, overlay: Option<&crate::fault::FaultOverlay>) {
        match overlay {
            None => {
                if self.overlaid {
                    self.extra_rd.copy_from_slice(&self.base_extra_rd);
                    self.extra_wr.copy_from_slice(&self.base_extra_wr);
                    self.bw.copy_from_slice(&self.base_bw);
                    self.overlaid = false;
                }
            }
            Some(ov) => {
                for p in 0..self.pools {
                    let rd = ov.extra_rd_add.get(p).copied().unwrap_or(0.0);
                    let wr = ov.extra_wr_add.get(p).copied().unwrap_or(0.0);
                    self.extra_rd[p] = self.base_extra_rd[p] + rd;
                    self.extra_wr[p] = self.base_extra_wr[p] + wr;
                }
                for s in 0..self.switches {
                    let sc = ov.bw_scale.get(s).copied().unwrap_or(1.0);
                    self.bw[s] = self.base_bw[s] * sc;
                }
                self.overlaid = true;
            }
        }
    }

    /// The model's three stages for one epoch, writing into caller
    /// slices — shared by the per-epoch [`TimingModel::analyze`] and
    /// the batched kernel so both are bit-identical by construction:
    ///
    /// 1. latency dot products (also yields the sparse-pool mask) —
    ///    kernel-independent, always the reference operation order;
    /// 2. descendant-mask matmul `ev[s,b]`, active rows × live pools;
    /// 3. congestion + bandwidth queueing scans.
    ///
    /// Stages 2 + 3 dispatch on the configured [`ScanKernel`]: the
    /// `exact` kernel fuses both scans into one reference-ordered pass
    /// per active row (every f32 produced by the same operations in
    /// the same order as `kernels/ref.py`, so outputs are bit-identical
    /// to `artifacts/golden.json` — `rust/tests/golden.rs`); the
    /// `blocked` kernel runs the max-plus block formulation
    /// (tolerance-equal, see [`NativeAnalyzer::matmul_and_scan_blocked`]).
    /// For a fixed kernel, per-epoch and batched paths agree
    /// bit-for-bit (`tests/pipeline_equivalence.rs`).
    fn analyze_core(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
        lat: &mut [f32],
        cong: &mut [f32],
        bwd: &mut [f32],
        store_backlog: bool,
    ) -> f64 {
        let (p, b) = (self.pools, self.nbins);
        debug_assert_eq!(reads.len(), p * b);
        debug_assert_eq!(writes.len(), p * b);
        debug_assert_eq!(lat.len(), p);

        // 1. latency delay per pool + sparsity mask
        let mut any_traffic = false;
        for pool in 0..p {
            let ro: f32 = reads[pool * b..(pool + 1) * b].iter().sum();
            let wo: f32 = writes[pool * b..(pool + 1) * b].iter().sum();
            lat[pool] = ro * self.extra_rd[pool] + wo * self.extra_wr[pool];
            let zero = ro == 0.0 && wo == 0.0;
            self.pool_zero[pool] = zero;
            any_traffic |= !zero;
        }
        cong.fill(0.0);
        bwd.fill(0.0);
        if !any_traffic {
            // empty epoch: all outputs are exactly zero; skip the
            // matmul and scans entirely (a zeroed input drives every
            // queue term to 0 — see the scan recurrences below)
            if store_backlog {
                self.cong_backlog.fill(0.0);
            }
            return 0.0;
        }

        match self.kernel {
            ScanKernel::Exact => self.matmul_and_scan_exact(
                reads,
                writes,
                bin_width,
                bytes_per_ev,
                cong,
                bwd,
                store_backlog,
            ),
            ScanKernel::Blocked => self.matmul_and_scan_blocked(
                reads,
                writes,
                bin_width,
                bytes_per_ev,
                cong,
                bwd,
                store_backlog,
            ),
        }

        // three partial sums added together, matching the reference's
        // reduction order exactly
        lat.iter().map(|x| *x as f64).sum::<f64>()
            + cong.iter().map(|x| *x as f64).sum::<f64>()
            + bwd.iter().map(|x| *x as f64).sum::<f64>()
    }

    /// Stages 2 + 3, `exact` kernel: the reference operation order.
    #[allow(clippy::too_many_arguments)]
    fn matmul_and_scan_exact(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
        cong: &mut [f32],
        bwd: &mut [f32],
        store_backlog: bool,
    ) {
        let (p, b) = (self.pools, self.nbins);
        // 2. ev[s, b] = desc_mask @ (reads + writes), active rows ×
        // pools with traffic only
        self.ev.fill(0.0);
        for &sw in &self.active_rows {
            let row = &self.desc_mask[sw * p..(sw + 1) * p];
            let out = &mut self.ev[sw * b..(sw + 1) * b];
            for pool in 0..p {
                let m = row[pool];
                if m == 0.0 || self.pool_zero[pool] {
                    continue;
                }
                let r = &reads[pool * b..(pool + 1) * b];
                let w = &writes[pool * b..(pool + 1) * b];
                for i in 0..b {
                    out[i] += m * (r[i] + w[i]);
                }
            }
        }

        // 3. fused queueing scans per active row. Congestion: demand =
        // ev*stt against capacity = bin_width; delay = end-of-epoch
        // backlog drain time + transient waiting capped at one epoch
        // (mirrors model.py; DESIGN.md §5). Bandwidth: byte demand of
        // the served (congestion-shifted) stream against bw*bin_width.
        let epoch_len = bin_width * b as f32;
        for &sw in &self.active_rows {
            let stt = self.stt[sw];
            let bw = self.bw[sw];
            let ev = &self.ev[sw * b..(sw + 1) * b];
            let cap = bw * bin_width;
            let mut qc = 0.0f32; // congestion backlog
            let mut qcsum = 0.0f32;
            let mut prev = 0.0f32; // previous bin's backlog
            let mut qb = 0.0f32; // bandwidth backlog (bytes)
            let mut qbsum = 0.0f32;
            if store_backlog {
                let backlog = &mut self.cong_backlog[sw * b..(sw + 1) * b];
                for i in 0..b {
                    let e = ev[i] * stt;
                    qc = (qc + e - bin_width).max(0.0);
                    backlog[i] = qc;
                    qcsum += qc;
                    let served = if stt > 0.0 { (e + prev - qc) / stt } else { ev[i] };
                    let demand = served * bytes_per_ev;
                    prev = qc;
                    qb = (qb + demand - cap).max(0.0);
                    qbsum += qb;
                }
            } else {
                for i in 0..b {
                    let e = ev[i] * stt;
                    qc = (qc + e - bin_width).max(0.0);
                    qcsum += qc;
                    let served = if stt > 0.0 { (e + prev - qc) / stt } else { ev[i] };
                    let demand = served * bytes_per_ev;
                    prev = qc;
                    qb = (qb + demand - cap).max(0.0);
                    qbsum += qb;
                }
            }
            cong[sw] = if stt > 0.0 {
                qc + (qcsum * (bin_width / stt)).min(epoch_len)
            } else {
                0.0
            };
            bwd[sw] = if bw > 0.0 {
                qb / bw + (qbsum * (bin_width / bytes_per_ev)).min(epoch_len)
            } else {
                0.0
            };
        }
    }

    /// Stages 2 + 3, `blocked` kernel: per active row, the matmul and
    /// both queueing scans run block-by-block ([`SCAN_BLOCK`] f32
    /// lanes) so `ev`, `served`, and byte demand stay in registers —
    /// the `[S, B]` `ev` scratch array is never touched. The backlog
    /// recurrence `q_i = max(q_{i-1} + d_i, 0)` is evaluated per block
    /// as the max-plus scan identity
    ///
    /// ```text
    /// q_i = max(P_i − min_{t ≤ i} P_t,  carry + P_i)
    /// ```
    ///
    /// with `P` the block's inclusive prefix sum of the deltas
    /// (computed by a log-step network, like the prefix min). The
    /// identity requires `carry ≥ 0`, which holds because backlogs are
    /// clamped at zero; the carry out of a block is its last lane's
    /// backlog — the only value that crosses a block boundary, and the
    /// invariant that makes the blocks independent. Associative in
    /// exact arithmetic; in f32 the reassociated adds make this kernel
    /// tolerance-equal (not bit-equal) to `exact`.
    #[allow(clippy::too_many_arguments)]
    fn matmul_and_scan_blocked(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
        cong: &mut [f32],
        bwd: &mut [f32],
        store_backlog: bool,
    ) {
        let (p, b) = (self.pools, self.nbins);
        let epoch_len = bin_width * b as f32;
        for &sw in &self.active_rows {
            let stt = self.stt[sw];
            let bw = self.bw[sw];
            let cap = bw * bin_width;
            // live (mask, pool) columns for this row — mask zeros and
            // all-zero pools contribute nothing, exactly like `exact`
            self.live_cols.clear();
            for pool in 0..p {
                let m = self.desc_mask[sw * p + pool];
                if m != 0.0 && !self.pool_zero[pool] {
                    self.live_cols.push((m, pool));
                }
            }
            let mut qc_carry = 0.0f32; // congestion backlog across blocks
            let mut qb_carry = 0.0f32; // bandwidth backlog across blocks
            let mut qcsum = 0.0f32;
            let mut qbsum = 0.0f32;
            let mut start = 0usize;
            while start < b {
                let w = SCAN_BLOCK.min(b - start);
                // matmul block: ev over the live columns only
                let mut evb = [0.0f32; SCAN_BLOCK];
                for &(m, pool) in &self.live_cols {
                    let r = &reads[pool * b + start..pool * b + start + w];
                    let wv = &writes[pool * b + start..pool * b + start + w];
                    for i in 0..w {
                        evb[i] += m * (r[i] + wv[i]);
                    }
                }
                // congestion deltas + max-plus block scan
                let mut d = [0.0f32; SCAN_BLOCK];
                for i in 0..w {
                    d[i] = evb[i] * stt - bin_width;
                }
                let mut qc = [0.0f32; SCAN_BLOCK];
                maxplus_block(&d, qc_carry, &mut qc);
                let mut bsum = 0.0f32;
                for i in 0..w {
                    bsum += qc[i];
                }
                qcsum += bsum;
                if store_backlog {
                    self.cong_backlog[sw * b + start..sw * b + start + w]
                        .copy_from_slice(&qc[..w]);
                }
                // served stream + byte-demand deltas (the previous
                // lane's backlog is a shift, not a recurrence)
                let mut d2 = [0.0f32; SCAN_BLOCK];
                if stt > 0.0 {
                    for i in 0..w {
                        let prev = if i == 0 { qc_carry } else { qc[i - 1] };
                        let served = (evb[i] * stt + prev - qc[i]) / stt;
                        d2[i] = served * bytes_per_ev - cap;
                    }
                } else {
                    for i in 0..w {
                        d2[i] = evb[i] * bytes_per_ev - cap;
                    }
                }
                let mut qb = [0.0f32; SCAN_BLOCK];
                maxplus_block(&d2, qb_carry, &mut qb);
                let mut bsum = 0.0f32;
                for i in 0..w {
                    bsum += qb[i];
                }
                qbsum += bsum;
                qc_carry = qc[w - 1];
                qb_carry = qb[w - 1];
                start += w;
            }
            cong[sw] = if stt > 0.0 {
                qc_carry + (qcsum * (bin_width / stt)).min(epoch_len)
            } else {
                0.0
            };
            bwd[sw] = if bw > 0.0 {
                qb_carry / bw + (qbsum * (bin_width / bytes_per_ev)).min(epoch_len)
            } else {
                0.0
            };
        }
    }
}

/// In-place inclusive prefix sum over one scan block, as a log-step
/// (Hillis–Steele) network: each round adds a lane shifted by `off`,
/// doubling `off` — 4 rounds for 16 lanes, each round a contiguous,
/// dependency-free lane range (the downward walk reads only
/// not-yet-updated lanes), which is what lets the compiler keep the
/// whole block in vector registers.
#[inline(always)]
fn prefix_sum_block(v: &mut [f32; SCAN_BLOCK]) {
    let mut off = 1;
    while off < SCAN_BLOCK {
        for i in (off..SCAN_BLOCK).rev() {
            v[i] += v[i - off];
        }
        off <<= 1;
    }
}

/// In-place inclusive prefix **min** over one scan block (same
/// log-step network as [`prefix_sum_block`], with `min` as the
/// combiner).
#[inline(always)]
fn prefix_min_block(v: &mut [f32; SCAN_BLOCK]) {
    let mut off = 1;
    while off < SCAN_BLOCK {
        for i in (off..SCAN_BLOCK).rev() {
            v[i] = v[i].min(v[i - off]);
        }
        off <<= 1;
    }
}

/// One max-plus block step: given per-lane deltas `d` and the carry-in
/// backlog (which must be ≥ 0 — true for zero-clamped queue
/// backlogs), produce per-lane backlogs `q_i = max(q_{i-1} + d_i, 0)`
/// branch-free via `q_i = max(P_i − min_{t≤i} P_t, carry + P_i)`.
/// Unused tail lanes (short final block) compute garbage that callers
/// must ignore; pad `d` with zeros so the values stay finite.
#[inline(always)]
fn maxplus_block(d: &[f32; SCAN_BLOCK], carry: f32, q: &mut [f32; SCAN_BLOCK]) {
    let mut p = *d;
    prefix_sum_block(&mut p);
    let mut m = p;
    prefix_min_block(&mut m);
    for i in 0..SCAN_BLOCK {
        q[i] = (p[i] - m[i]).max(carry + p[i]);
    }
}

impl TimingModel for NativeAnalyzer {
    fn pools(&self) -> usize {
        self.pools
    }
    fn switches(&self) -> usize {
        self.switches
    }
    fn nbins(&self) -> usize {
        self.nbins
    }
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn scan_kernel(&self) -> ScanKernel {
        self.kernel
    }

    fn set_export_backlog(&mut self, on: bool) {
        self.export_backlog = on;
    }

    fn set_fault_overlay(&mut self, overlay: Option<&crate::fault::FaultOverlay>) {
        self.apply_fault_overlay(overlay);
    }

    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs> {
        let (p, s, b) = (self.pools, self.switches, self.nbins);
        anyhow::ensure!(inp.reads.len() == p * b, "reads shape");
        anyhow::ensure!(inp.writes.len() == p * b, "writes shape");
        let mut lat = vec![0.0f32; p];
        let mut cong = vec![0.0f32; s];
        let mut bwd = vec![0.0f32; s];
        // backlog is stored and copied out only when a consumer asked
        // for it (epoch policies); the common path skips both the
        // per-bin stores and the 8 KB clone.
        let store = self.export_backlog;
        let total = self.analyze_core(
            inp.reads,
            inp.writes,
            inp.bin_width,
            inp.bytes_per_ev,
            &mut lat,
            &mut cong,
            &mut bwd,
            store,
        );
        let cong_backlog = if store { self.cong_backlog.clone() } else { Vec::new() };
        Ok(TimingOutputs { total, lat, cong, bwd, cong_backlog })
    }
}

/// Batched flavour of the native analyzer: a real batched kernel over
/// E epochs per call — output tensors are allocated once per call at
/// their exact `[E, ·]` sizes and each epoch's stage runs through the
/// shared fused [`NativeAnalyzer::analyze_core`] (no per-epoch
/// `TimingOutputs` allocation, no backlog clone, scratch reused across
/// the E-epoch loop). Exists so the batched replay path
/// ([`crate::coordinator::run_batched`]) has a backend that needs no
/// AOT artifacts and is bit-identical to the per-epoch native analyzer
/// — the PJRT batch module is the dispatch-amortizing counterpart.
///
/// The E epochs of one call are *independent* (no state flows between
/// them — `analyze_core` fully rewrites its scratch per epoch), so the
/// loop shards across worker threads (`with_threads`, below): each
/// worker owns a private [`NativeAnalyzer`]
/// scratch clone (created once at construction, reused for every
/// call) and writes a contiguous, disjoint range of output rows.
/// Results are bit-identical for **any** thread count by construction
/// — the same `analyze_core` invocation produces the same bits into
/// the same row regardless of which worker runs it (asserted in
/// `tests/pipeline_equivalence.rs` and the CI determinism matrix).
pub struct NativeBatchAnalyzer {
    inner: NativeAnalyzer,
    /// Scratch analyzers for workers 1..N (worker 0 reuses `inner`).
    /// Allocated once here so per-call sharding allocates nothing.
    workers: Vec<NativeAnalyzer>,
    batch: usize,
    threads: usize,
}

/// Auto thread resolution (`threads == 0`) refuses to slice the batch
/// thinner than this many epochs per worker — spawning a worker for a
/// couple of microsecond-scale epochs costs more than it saves. An
/// explicit thread count is honored as given (clamped to the batch).
const MIN_AUTO_EPOCHS_PER_WORKER: usize = 4;

impl NativeBatchAnalyzer {
    /// Sequential batched analyzer (one worker, `exact` kernel — the
    /// bit-identity baseline).
    pub fn new(t: &TopoTensors, nbins: usize, batch: usize) -> NativeBatchAnalyzer {
        NativeBatchAnalyzer::with_threads(t, nbins, batch, 1)
    }

    /// [`NativeBatchAnalyzer::new`] with an explicit shard-worker count
    /// (`0` = one per core, capped so each auto worker gets at least
    /// [`MIN_AUTO_EPOCHS_PER_WORKER`] epochs). Outputs are bit-identical
    /// for every value; only wall-clock changes. `exact` kernel.
    pub fn with_threads(
        t: &TopoTensors,
        nbins: usize,
        batch: usize,
        threads: usize,
    ) -> NativeBatchAnalyzer {
        NativeBatchAnalyzer::with_kernel(t, nbins, batch, threads, ScanKernel::Exact)
    }

    /// Fully parameterized constructor: group size (`batch`), shard
    /// workers, and scan kernel. The bit-identical-across-threads
    /// guarantee holds for *either* kernel (every worker runs the same
    /// kernel into disjoint rows); only `exact` is additionally
    /// bit-identical to the golden reference.
    pub fn with_kernel(
        t: &TopoTensors,
        nbins: usize,
        batch: usize,
        threads: usize,
        kernel: ScanKernel,
    ) -> NativeBatchAnalyzer {
        let batch = batch.max(1);
        let threads = match threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min((batch / MIN_AUTO_EPOCHS_PER_WORKER).max(1)),
            n => n,
        }
        .clamp(1, batch);
        let inner = NativeAnalyzer::with_kernel(t, nbins, kernel);
        let workers = (1..threads).map(|_| inner.clone()).collect();
        NativeBatchAnalyzer { inner, workers, batch, threads }
    }
}

/// Run `analyze_core` over a contiguous range of epochs, writing each
/// epoch's outputs into its own row of the (sub)slices. This is the
/// whole per-worker loop: the 1-thread path and every shard run the
/// exact same code, which is what makes sharding bit-exact.
fn analyze_epoch_range(
    an: &mut NativeAnalyzer,
    reads: &[f32],
    writes: &[f32],
    bin_width: f32,
    bytes_per_ev: f32,
    total: &mut [f64],
    lat: &mut [f32],
    cong: &mut [f32],
    bwd: &mut [f32],
) {
    let (p, s, b) = (an.pools, an.switches, an.nbins);
    let n = p * b;
    for i in 0..total.len() {
        total[i] = an.analyze_core(
            &reads[i * n..(i + 1) * n],
            &writes[i * n..(i + 1) * n],
            bin_width,
            bytes_per_ev,
            &mut lat[i * p..(i + 1) * p],
            &mut cong[i * s..(i + 1) * s],
            &mut bwd[i * s..(i + 1) * s],
            false,
        );
    }
}

impl BatchTimingModel for NativeBatchAnalyzer {
    fn pools(&self) -> usize {
        self.inner.pools
    }
    fn switches(&self) -> usize {
        self.inner.switches
    }
    fn nbins(&self) -> usize {
        self.inner.nbins
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn threads(&self) -> usize {
        self.threads
    }
    fn scan_kernel(&self) -> ScanKernel {
        self.inner.kernel
    }
    fn backend_name(&self) -> &'static str {
        "native-batch"
    }

    /// Propagated to the calling-thread analyzer *and* every shard
    /// worker's scratch clone — each worker must run the whole group
    /// under the same overlay for sharding to stay bit-identical.
    fn set_fault_overlay(&mut self, overlay: Option<&crate::fault::FaultOverlay>) {
        self.inner.apply_fault_overlay(overlay);
        for w in &mut self.workers {
            w.apply_fault_overlay(overlay);
        }
    }

    fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs> {
        let (e, p, s, b) = (self.batch, self.inner.pools, self.inner.switches, self.inner.nbins);
        anyhow::ensure!(reads.len() == e * p * b, "reads shape");
        anyhow::ensure!(writes.len() == e * p * b, "writes shape");
        let mut out = BatchOutputs {
            total: vec![0.0; e],
            lat: vec![0.0; e * p],
            cong: vec![0.0; e * s],
            bwd: vec![0.0; e * s],
        };
        let threads = self.threads.clamp(1, e);
        if threads == 1 {
            analyze_epoch_range(
                &mut self.inner,
                reads,
                writes,
                bin_width,
                bytes_per_ev,
                &mut out.total,
                &mut out.lat,
                &mut out.cong,
                &mut out.bwd,
            );
            return Ok(out);
        }
        // Shard the E independent epochs into contiguous chunks, one
        // per worker. Every worker gets disjoint output row ranges and
        // its own scratch analyzer, so the bits written are identical
        // to the 1-thread loop for any worker count. The calling
        // thread runs the first chunk itself (on `inner`) instead of
        // idling at the scope join — one fewer spawn per call and no
        // oversubscription at `threads == cores`.
        let chunk = e.div_ceil(threads);
        let inner = &mut self.inner;
        let extra = &mut self.workers;
        std::thread::scope(|sc| {
            let mut scratch: Vec<&mut NativeAnalyzer> =
                std::iter::once(inner).chain(extra.iter_mut()).collect();
            let (mut tot, mut lat, mut cong, mut bwd) =
                (&mut out.total[..], &mut out.lat[..], &mut out.cong[..], &mut out.bwd[..]);
            let (mut rd, mut wr) = (reads, writes);
            let mut first = None;
            for (w, an) in scratch.drain(..).enumerate() {
                let take = chunk.min(tot.len());
                if take == 0 {
                    break;
                }
                let (t0, rest) = std::mem::take(&mut tot).split_at_mut(take);
                tot = rest;
                let (l0, rest) = std::mem::take(&mut lat).split_at_mut(take * p);
                lat = rest;
                let (c0, rest) = std::mem::take(&mut cong).split_at_mut(take * s);
                cong = rest;
                let (w0, rest) = std::mem::take(&mut bwd).split_at_mut(take * s);
                bwd = rest;
                let (r0, r1) = rd.split_at(take * p * b);
                rd = r1;
                let (x0, x1) = wr.split_at(take * p * b);
                wr = x1;
                if w == 0 {
                    first = Some((an, r0, x0, t0, l0, c0, w0));
                } else {
                    sc.spawn(move || {
                        analyze_epoch_range(an, r0, x0, bin_width, bytes_per_ev, t0, l0, c0, w0)
                    });
                }
            }
            if let Some((an, r0, x0, t0, l0, c0, w0)) = first {
                analyze_epoch_range(an, r0, x0, bin_width, bytes_per_ev, t0, l0, c0, w0);
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{builtin, TopoTensors};

    fn analyzer(nbins: usize) -> NativeAnalyzer {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        NativeAnalyzer::new(&t, nbins)
    }

    #[test]
    fn zero_traffic_zero_delay() {
        let mut a = analyzer(16);
        let reads = vec![0.0; 8 * 16];
        let writes = vec![0.0; 8 * 16];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total, 0.0);
    }

    #[test]
    fn latency_delay_formula() {
        let mut a = analyzer(4);
        let mut reads = vec![0.0f32; 8 * 4];
        // 10 reads to pool 1 in bin 0
        reads[1 * 4] = 10.0;
        let writes = vec![0.0; 8 * 4];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1e9,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let topo = builtin::fig2();
        let expect = 10.0 * topo.extra_read_latency(1);
        assert!((out.lat[1] as f64 - expect).abs() < 1e-3, "{} vs {expect}", out.lat[1]);
        // huge bin width -> no congestion/bw delay
        assert_eq!(out.cong_total(), 0.0);
        assert_eq!(out.bwd_total(), 0.0);
    }

    #[test]
    fn congestion_grows_with_burst() {
        let mut a = analyzer(8);
        let mk = |n: f32| {
            let mut reads = vec![0.0f32; 8 * 8];
            reads[1 * 8] = n; // burst in bin 0 of pool 1
            reads
        };
        let writes = vec![0.0; 8 * 8];
        let small = a
            .analyze(&TimingInputs {
                reads: &mk(2.0),
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        let big = a
            .analyze(&TimingInputs {
                reads: &mk(200.0),
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(big.cong_total() > small.cong_total());
        assert!(big.total > big.lat_total(), "congestion must add delay");
    }

    #[test]
    fn local_pool_free() {
        let mut a = analyzer(8);
        let mut reads = vec![0.0f32; 8 * 8];
        for i in 0..8 {
            reads[i] = 1000.0; // pool 0 = local
        }
        let writes = vec![0.0; 8 * 8];
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 100.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total, 0.0, "local traffic must cost nothing");
    }

    #[test]
    fn fault_overlay_applies_and_restores_bitexact() {
        use crate::fault::FaultPlan;
        let mut a = analyzer(8);
        let mut reads = vec![0.0f32; 8 * 8];
        reads[8] = 50.0; // 50 reads to pool 1, bin 0
        let writes = vec![0.0; 8 * 8];
        let run = |a: &mut NativeAnalyzer| {
            a.analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1e9,
                bytes_per_ev: 64.0,
            })
            .unwrap()
        };
        let base = run(&mut a);
        let plan = FaultPlan::parse_inline("storm:pool1@0+1:rd=200").unwrap();
        let mut st = plan.resolve(&builtin::fig2()).unwrap();
        st.epoch_begin(0);
        a.set_fault_overlay(st.overlay());
        let stormy = run(&mut a);
        // stage 1 is linear: the storm adds exactly 50 * 200 ns
        let extra = stormy.lat[1] as f64 - base.lat[1] as f64;
        assert!((extra - 50.0 * 200.0).abs() < 1e-2, "extra {extra}");
        // and exactly matches the state's closed-form attribution
        let before = st.retry_delay_ns;
        st.attribute_epoch_delays(|p| if p == 1 { 50.0 } else { 0.0 }, |_| 0.0);
        let attr = st.retry_delay_ns - before;
        assert!((extra - attr).abs() < 1e-2, "{extra} vs {attr}");
        // uninstalling restores the fault-free path bit-for-bit
        a.set_fault_overlay(None);
        let restored = run(&mut a);
        assert_eq!(restored.total, base.total);
        assert_eq!(restored.lat, base.lat);
        assert_eq!(restored.cong, base.cong);
        assert_eq!(restored.bwd, base.bwd);
    }

    #[test]
    fn fault_overlay_batched_matches_per_epoch() {
        use crate::fault::FaultPlan;
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let plan = FaultPlan::parse_inline("storm:pool1@0+1:rd=75,wr=25;retrain:pool0@0+1:frac=0.5")
            .unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(0);
        let e = 3usize;
        let mut reads = vec![0.0f32; e * 8 * 8];
        let mut writes = vec![0.0f32; e * 8 * 8];
        for i in 0..e {
            reads[i * 64 + 8] = 10.0 + i as f32; // pool 1, bin 0
            writes[i * 64 + 16 + 3] = 4.0; // pool 2, bin 3
        }
        // batched, 1 worker vs 3 workers, both overlaid
        let mut b1 = NativeBatchAnalyzer::with_kernel(&t, 8, e, 1, ScanKernel::Blocked);
        let mut b3 = NativeBatchAnalyzer::with_kernel(&t, 8, e, 3, ScanKernel::Blocked);
        BatchTimingModel::set_fault_overlay(&mut b1, st.overlay());
        BatchTimingModel::set_fault_overlay(&mut b3, st.overlay());
        let o1 = b1.analyze_batch(&reads, &writes, 120.0, 64.0).unwrap();
        let o3 = b3.analyze_batch(&reads, &writes, 120.0, 64.0).unwrap();
        assert_eq!(o1.total, o3.total);
        assert_eq!(o1.lat, o3.lat);
        // and both equal the per-epoch analyzer under the same overlay
        let mut a = NativeAnalyzer::with_kernel(&t, 8, ScanKernel::Blocked);
        a.set_fault_overlay(st.overlay());
        for i in 0..e {
            let out = a
                .analyze(&TimingInputs {
                    reads: &reads[i * 64..(i + 1) * 64],
                    writes: &writes[i * 64..(i + 1) * 64],
                    bin_width: 120.0,
                    bytes_per_ev: 64.0,
                })
                .unwrap();
            assert_eq!(out.total, o1.total[i], "epoch {i}");
        }
    }

    #[test]
    fn outputs_have_model_shapes() {
        let mut a = analyzer(32);
        let reads = vec![1.0; 8 * 32];
        let writes = vec![1.0; 8 * 32];
        // default: hot path, no backlog export
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 50.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.lat.len(), 8);
        assert_eq!(out.cong.len(), 8);
        assert_eq!(out.bwd.len(), 8);
        assert!(out.cong_backlog.is_empty(), "backlog export must be opt-in");
        // policies opt in and get the full [S, B] profile
        a.set_export_backlog(true);
        let out = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 50.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.cong_backlog.len(), 8 * 32);
    }

    #[test]
    fn empty_epoch_resets_exported_backlog() {
        // a zero-traffic epoch must overwrite the previous epoch's
        // backlog profile, not leak it through the early-exit
        let mut a = analyzer(8);
        a.set_export_backlog(true);
        let mut reads = vec![0.0f32; 8 * 8];
        reads[1 * 8] = 500.0;
        let writes = vec![0.0; 8 * 8];
        let busy = a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 10.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(busy.cong_backlog.iter().any(|x| *x > 0.0));
        let zeros = vec![0.0f32; 8 * 8];
        let idle = a
            .analyze(&TimingInputs {
                reads: &zeros,
                writes: &zeros,
                bin_width: 10.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert!(idle.cong_backlog.iter().all(|x| *x == 0.0));
        assert_eq!(idle.total, 0.0);
    }

    #[test]
    fn batch_scratch_does_not_leak_between_epochs() {
        // [dense, all-zero, same-dense]: epoch 1 must be exactly zero
        // (stale ev/backlog scratch would corrupt it) and epoch 2 must
        // equal epoch 0 bit-for-bit
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut batch = NativeBatchAnalyzer::new(&t, 16, 3);
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(41);
        let dense: Vec<f32> = (0..n).map(|_| rng.below(30) as f32).collect();
        let mut reads = vec![0.0f32; 3 * n];
        reads[..n].copy_from_slice(&dense);
        reads[2 * n..].copy_from_slice(&dense);
        let writes = vec![0.0f32; 3 * n];
        let out = batch.analyze_batch(&reads, &writes, 25.0, 64.0).unwrap();
        assert_eq!(out.total[1], 0.0, "empty epoch must cost nothing");
        assert!(out.cong[8..16].iter().all(|x| *x == 0.0));
        assert!(out.bwd[8..16].iter().all(|x| *x == 0.0));
        assert_eq!(out.total[0], out.total[2]);
        assert_eq!(out.epoch(0, 8, 8).lat, out.epoch(2, 8, 8).lat);
        assert_eq!(out.epoch(0, 8, 8).cong, out.epoch(2, 8, 8).cong);
        assert_eq!(out.epoch(0, 8, 8).bwd, out.epoch(2, 8, 8).bwd);
    }

    #[test]
    fn native_batch_matches_single_bit_exactly() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut single = NativeAnalyzer::new(&t, 16);
        let mut batch = NativeBatchAnalyzer::new(&t, 16, 4);
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(3);
        let reads: Vec<f32> = (0..4 * n).map(|_| rng.below(20) as f32).collect();
        let writes: Vec<f32> = (0..4 * n).map(|_| rng.below(9) as f32).collect();
        let out = batch.analyze_batch(&reads, &writes, 100.0, 64.0).unwrap();
        assert_eq!(out.total.len(), 4);
        for i in 0..4 {
            let s = single
                .analyze(&TimingInputs {
                    reads: &reads[i * n..(i + 1) * n],
                    writes: &writes[i * n..(i + 1) * n],
                    bin_width: 100.0,
                    bytes_per_ev: 64.0,
                })
                .unwrap();
            assert_eq!(out.total[i], s.total, "epoch {i}");
            assert_eq!(out.epoch(i, 8, 8).lat, s.lat);
            assert_eq!(out.epoch(i, 8, 8).cong, s.cong);
            assert_eq!(out.epoch(i, 8, 8).bwd, s.bwd);
        }
    }

    #[test]
    fn sharded_batch_matches_single_thread_bit_exactly() {
        // the E epochs are independent and every worker runs the same
        // analyze_core into disjoint rows, so ANY thread count —
        // uneven splits, more workers than epochs — must reproduce
        // the 1-thread outputs bit-for-bit
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let e = 7usize; // prime: never splits evenly
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(77);
        let reads: Vec<f32> = (0..e * n).map(|_| rng.below(30) as f32).collect();
        let writes: Vec<f32> = (0..e * n).map(|_| rng.below(12) as f32).collect();
        let mut base = NativeBatchAnalyzer::new(&t, 16, e);
        let expect = base.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
        for threads in [2usize, 3, 5, 64] {
            let mut sharded = NativeBatchAnalyzer::with_threads(&t, 16, e, threads);
            let got = sharded.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
            assert_eq!(got.total, expect.total, "{threads} threads: totals");
            assert_eq!(got.lat, expect.lat, "{threads} threads: lat");
            assert_eq!(got.cong, expect.cong, "{threads} threads: cong");
            assert_eq!(got.bwd, expect.bwd, "{threads} threads: bwd");
        }
    }

    #[test]
    fn sharded_batch_thread_resolution() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        // explicit counts clamp to the epoch count
        let a = NativeBatchAnalyzer::with_threads(&t, 16, 4, 16);
        assert_eq!(a.threads(), 4);
        // 0 = auto: at least one worker, never thinner than the
        // minimum epochs-per-worker slice
        let b = NativeBatchAnalyzer::with_threads(&t, 16, 8, 0);
        assert!(b.threads() >= 1);
        assert!(b.threads() <= 8 / MIN_AUTO_EPOCHS_PER_WORKER);
        // the sequential constructor stays sequential
        let c = NativeBatchAnalyzer::new(&t, 16, 32);
        assert_eq!(c.threads(), 1);
    }

    // ---------------- blocked-kernel differential property tests ----
    //
    // `blocked` reassociates float adds (prefix-sum trees, blockwise
    // partial sums), so it is tolerance-equal to `exact`, not
    // bit-equal: each f32 output must be within 4 ULP of the exact
    // kernel, OR within 1e-5 relative (two correctly-rounded
    // association orders of hundreds of terms can legitimately drift a
    // few more ULP), OR within a scenario-scaled absolute floor: when
    // the exact recurrence drains a backlog to exactly 0.0, the
    // max-plus identity can leave an eps-level residue of the block's
    // *prefix-sum magnitude* (|P| ·  f32::EPSILON), which is neither a
    // small ULP count nor a small relative error against 0. The floor
    // is 1e-4 × an over-approximation of any prefix magnitude the
    // scenario can produce — ~3 orders above the eps residue, far
    // below any real kernel divergence.

    fn ulp_key(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7fff_ffff) as i64)
        } else {
            (b & 0x7fff_ffff) as i64
        }
    }

    fn ulp_diff(a: f32, b: f32) -> u64 {
        (ulp_key(a) - ulp_key(b)).unsigned_abs()
    }

    /// Absolute floor for one scenario: bounds every prefix-sum /
    /// backlog magnitude either scan can reach (events × the largest
    /// per-event cost in ns or bytes, plus a full epoch of drain
    /// capacity on the busiest link), scaled by 1e-4.
    fn kernel_atol(
        t: &TopoTensors,
        reads: &[f32],
        writes: &[f32],
        nbins: usize,
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> f32 {
        let events: f32 = reads.iter().sum::<f32>() + writes.iter().sum::<f32>();
        let stt_max = t.stt.iter().cloned().fold(0.0f32, f32::max);
        let bw_max = t.bw.iter().cloned().fold(0.0f32, f32::max);
        let scale =
            events * (stt_max + bytes_per_ev) + nbins as f32 * bin_width * (1.0 + bw_max);
        1e-4 * scale.max(1.0)
    }

    fn assert_kernels_close(name: &str, got: &[f32], want: &[f32], atol: f32) {
        assert_eq!(got.len(), want.len(), "{name} length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            let ulp = ulp_diff(*a, *b);
            let rel = (a - b).abs() / b.abs().max(f32::MIN_POSITIVE);
            assert!(
                ulp <= 4 || rel <= 1e-5 || (a - b).abs() <= atol,
                "{name}[{i}]: blocked {a} vs exact {b} ({ulp} ULP, rel {rel}, atol {atol})"
            );
        }
    }

    fn assert_outputs_close(
        blocked: &TimingOutputs,
        exact: &TimingOutputs,
        atol: f32,
        ctx: &str,
    ) {
        assert_eq!(blocked.lat, exact.lat, "{ctx}: lat is kernel-independent");
        assert_kernels_close(&format!("{ctx}: cong"), &blocked.cong, &exact.cong, atol);
        assert_kernels_close(&format!("{ctx}: bwd"), &blocked.bwd, &exact.bwd, atol);
        let diff = (blocked.total - exact.total).abs();
        let rel = diff / exact.total.abs().max(1e-30);
        assert!(
            rel <= 1e-5 || diff <= atol as f64,
            "{ctx}: total {} vs {} (rel {rel})",
            blocked.total,
            exact.total
        );
    }

    /// Scalar reference for the max-plus block identity, with exactly
    /// representable integer deltas so tree and sequential sums agree
    /// bit-for-bit.
    #[test]
    fn maxplus_block_matches_scalar_recurrence_on_integers() {
        let mut rng = crate::util::rng::Rng::new(17);
        for round in 0..200 {
            let mut d = [0.0f32; SCAN_BLOCK];
            for x in d.iter_mut() {
                *x = rng.below(41) as f32 - 20.0; // integers in [-20, 20]
            }
            let carry = rng.below(30) as f32;
            let mut q = [0.0f32; SCAN_BLOCK];
            maxplus_block(&d, carry, &mut q);
            let mut scalar = carry;
            for i in 0..SCAN_BLOCK {
                scalar = (scalar + d[i]).max(0.0);
                assert_eq!(q[i], scalar, "round {round} lane {i}");
            }
        }
    }

    /// Property sweep: random epochs — sparse pools (all-zero rows),
    /// saturated backlogs (tiny bin width), varied byte sizes — must
    /// agree between kernels within the ULP/relative tolerance, for
    /// nbins both a multiple of the block width and not.
    #[test]
    fn blocked_matches_exact_property_sweep() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut rng = crate::util::rng::Rng::new(0xb10c);
        for &nbins in &[16usize, 24, 256] {
            let mut exact = NativeAnalyzer::with_kernel(&t, nbins, ScanKernel::Exact);
            let mut blocked = NativeAnalyzer::with_kernel(&t, nbins, ScanKernel::Blocked);
            let n = 8 * nbins;
            for round in 0..40u64 {
                // round style: light, bursty, or saturating traffic
                let cap = match round % 3 {
                    0 => 8,
                    1 => 200,
                    _ => 5000, // saturated: backlog never drains
                };
                let mut reads = vec![0.0f32; n];
                let mut writes = vec![0.0f32; n];
                for pool in 0..8 {
                    if rng.below(4) == 0 {
                        continue; // all-zero pool row
                    }
                    for i in 0..nbins {
                        reads[pool * nbins + i] = rng.below(cap) as f32;
                        writes[pool * nbins + i] = rng.below(cap / 2 + 1) as f32;
                    }
                }
                let bin_width = match round % 4 {
                    0 => 1.0,
                    1 => 100.0,
                    2 => 3906.25,
                    _ => 1e6,
                };
                let inp = TimingInputs {
                    reads: &reads,
                    writes: &writes,
                    bin_width,
                    bytes_per_ev: if round % 2 == 0 { 64.0 } else { 256.0 },
                };
                let atol = kernel_atol(&t, &reads, &writes, nbins, bin_width, inp.bytes_per_ev);
                let e = exact.analyze(&inp).unwrap();
                let b = blocked.analyze(&inp).unwrap();
                let ctx = format!("nbins {nbins} round {round}");
                assert_outputs_close(&b, &e, atol, &ctx);
            }
        }
    }

    /// Degenerate switch parameters: stt == 0 rows (no congestion,
    /// served = raw events) and bw == 0 rows (no bandwidth delay) must
    /// take the same guarded paths in both kernels.
    #[test]
    fn blocked_matches_exact_with_zero_stt_and_zero_bw_rows() {
        // rows: 0 normal, 1 stt == 0, 2 bw == 0, 3 fully inert
        let desc_mask = vec![
            0.0, 1.0, 1.0, 1.0, //
            0.0, 1.0, 0.0, 0.0, //
            0.0, 0.0, 1.0, 0.0, //
            0.0, 0.0, 0.0, 0.0, //
        ];
        let t = TopoTensors {
            pools: 4,
            switches: 4,
            extra_read_lat: vec![0.0, 50.0, 80.0, 120.0],
            extra_write_lat: vec![0.0, 60.0, 90.0, 140.0],
            desc_mask,
            stt: vec![5.0, 0.0, 3.0, 0.0],
            bw: vec![16.0, 8.0, 0.0, 0.0],
        };
        let nbins = 32;
        let n = 4 * nbins;
        let mut exact = NativeAnalyzer::with_kernel(&t, nbins, ScanKernel::Exact);
        let mut blocked = NativeAnalyzer::with_kernel(&t, nbins, ScanKernel::Blocked);
        let mut rng = crate::util::rng::Rng::new(0x57);
        for round in 0..50u64 {
            let reads: Vec<f32> = (0..n).map(|_| rng.below(300) as f32).collect();
            let writes: Vec<f32> = (0..n).map(|_| rng.below(150) as f32).collect();
            let inp = TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 20.0,
                bytes_per_ev: 64.0,
            };
            let atol = kernel_atol(&t, &reads, &writes, nbins, 20.0, 64.0);
            let e = exact.analyze(&inp).unwrap();
            let b = blocked.analyze(&inp).unwrap();
            assert_eq!(e.cong[1], 0.0, "stt == 0 row must have no congestion");
            assert_eq!(b.cong[1], 0.0);
            assert_eq!(e.bwd[2], 0.0, "bw == 0 row must have no bandwidth delay");
            assert_eq!(b.bwd[2], 0.0);
            assert_eq!(b.cong[3], 0.0, "inert row stays zero");
            assert_outputs_close(&b, &e, atol, &format!("degenerate round {round}"));
        }
    }

    /// The exported backlog profile (policy input) must agree between
    /// kernels lane-for-lane within tolerance, and all-local traffic
    /// must still cost exactly zero under `blocked` (the max-plus
    /// identity yields exact zeros for empty rows).
    #[test]
    fn blocked_backlog_export_and_exact_zeros() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let mut exact = NativeAnalyzer::with_kernel(&t, 32, ScanKernel::Exact);
        let mut blocked = NativeAnalyzer::with_kernel(&t, 32, ScanKernel::Blocked);
        exact.set_export_backlog(true);
        blocked.set_export_backlog(true);
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 8 * 32;
        let reads: Vec<f32> = (0..n).map(|_| rng.below(500) as f32).collect();
        let writes: Vec<f32> = (0..n).map(|_| rng.below(200) as f32).collect();
        let inp = TimingInputs {
            reads: &reads,
            writes: &writes,
            bin_width: 10.0,
            bytes_per_ev: 64.0,
        };
        let atol = kernel_atol(&t, &reads, &writes, 32, 10.0, 64.0);
        let e = exact.analyze(&inp).unwrap();
        let b = blocked.analyze(&inp).unwrap();
        assert!(e.cong_backlog.iter().any(|x| *x > 0.0));
        assert_kernels_close("backlog", &b.cong_backlog, &e.cong_backlog, atol);

        // all-local traffic: blocked must produce exact zeros, like
        // the local_pool_free contract for the exact kernel
        let mut local = vec![0.0f32; n];
        for i in 0..32 {
            local[i] = 1000.0; // pool 0 = local
        }
        let zeros = vec![0.0f32; n];
        let out = blocked
            .analyze(&TimingInputs {
                reads: &local,
                writes: &zeros,
                bin_width: 10.0,
                bytes_per_ev: 64.0,
            })
            .unwrap();
        assert_eq!(out.total, 0.0, "local traffic must cost exactly nothing");
        assert!(out.cong_backlog.iter().all(|x| *x == 0.0));
    }

    /// Sharding is kernel-independent: the blocked kernel at any
    /// thread count reproduces the 1-thread blocked outputs
    /// bit-for-bit (every worker runs the same kernel into disjoint
    /// rows).
    #[test]
    fn blocked_sharded_batch_bit_identical_across_threads() {
        let topo = builtin::fig2();
        let t = TopoTensors::build(&topo, 8, 8).unwrap();
        let e = 11usize;
        let n = 8 * 16;
        let mut rng = crate::util::rng::Rng::new(0x5eed);
        let reads: Vec<f32> = (0..e * n).map(|_| rng.below(30) as f32).collect();
        let writes: Vec<f32> = (0..e * n).map(|_| rng.below(12) as f32).collect();
        let mut base = NativeBatchAnalyzer::with_kernel(&t, 16, e, 1, ScanKernel::Blocked);
        let expect = base.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
        for threads in [2usize, 5, 64] {
            let mut sharded =
                NativeBatchAnalyzer::with_kernel(&t, 16, e, threads, ScanKernel::Blocked);
            let got = sharded.analyze_batch(&reads, &writes, 50.0, 64.0).unwrap();
            assert_eq!(got.total, expect.total, "{threads} threads: totals");
            assert_eq!(got.lat, expect.lat, "{threads} threads: lat");
            assert_eq!(got.cong, expect.cong, "{threads} threads: cong");
            assert_eq!(got.bwd, expect.bwd, "{threads} threads: bwd");
        }
        assert_eq!(base.scan_kernel(), ScanKernel::Blocked);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut a = analyzer(8);
        let reads = vec![0.0; 3];
        let writes = vec![0.0; 8 * 8];
        assert!(a
            .analyze(&TimingInputs {
                reads: &reads,
                writes: &writes,
                bin_width: 1.0,
                bytes_per_ev: 64.0,
            })
            .is_err());
    }
}

//! PJRT backend: load the AOT HLO artifact, compile once, execute per
//! epoch. This is the shipped configuration — the timing analyzer the
//! coordinator calls is exactly the module `python/compile/aot.py`
//! lowered, Pallas kernel included (interpret-mode, so it runs on the
//! CPU PJRT plugin).
//!
//! Topology tensors are uploaded once as reusable `Literal`s; only the
//! `[P, B]` read/write histograms cross the FFI boundary per call.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::topology::TopoTensors;

use super::shapes::Manifest;
use super::{BatchOutputs, BatchTimingModel, TimingInputs, TimingModel, TimingOutputs};

thread_local! {
    /// Process-wide (per-thread) executable cache: PJRT client creation
    /// + HLO compilation cost ~40 ms; a sweep constructing hundreds of
    /// Coordinators must pay it once per artifact, not per instance.
    /// Keyed by artifact path; PJRT handles are thread-local (Rc-based),
    /// hence thread_local rather than a global Mutex.
    static EXE_CACHE: RefCell<HashMap<String, Rc<(PjRtClient, PjRtLoadedExecutable)>>> =
        RefCell::new(HashMap::new());
}

fn load_cached(path: &str) -> anyhow::Result<Rc<(PjRtClient, PjRtLoadedExecutable)>> {
    EXE_CACHE.with(|c| {
        if let Some(hit) = c.borrow().get(path) {
            return Ok(hit.clone());
        }
        let client = PjRtClient::cpu()?;
        let proto = HloModuleProto::from_text_file(path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let entry = Rc::new((client, exe));
        c.borrow_mut().insert(path.to_string(), entry.clone());
        Ok(entry)
    })
}

pub struct PjrtAnalyzer {
    pools: usize,
    switches: usize,
    nbins: usize,
    exe: Rc<(PjRtClient, PjRtLoadedExecutable)>,
    // constant inputs, prebuilt
    extra_rd: Literal,
    extra_wr: Literal,
    desc_mask: Literal,
    stt: Literal,
    bw: Literal,
}

fn vec1_f32(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

fn mat_f32(v: &[f32], rows: usize, cols: usize) -> anyhow::Result<Literal> {
    Ok(Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

impl PjrtAnalyzer {
    pub fn new(t: &TopoTensors, nbins: usize, artifacts_dir: &str) -> anyhow::Result<PjrtAnalyzer> {
        let m = Manifest::load(artifacts_dir)?;
        anyhow::ensure!(
            m.pools == t.pools && m.switches == t.switches && m.nbins == nbins,
            "artifact shapes (P={}, S={}, B={}) do not match requested (P={}, S={}, B={nbins}); \
             re-run `make artifacts` with matching sizes",
            m.pools,
            m.switches,
            m.nbins,
            t.pools,
            t.switches,
        );
        let path = format!("{artifacts_dir}/{}", m.single);
        let exe = load_cached(&path)?;
        let mut a = PjrtAnalyzer {
            pools: t.pools,
            switches: t.switches,
            nbins,
            exe,
            extra_rd: vec1_f32(&t.extra_read_lat),
            extra_wr: vec1_f32(&t.extra_write_lat),
            desc_mask: mat_f32(&t.desc_mask, t.switches, t.pools)?,
            stt: vec1_f32(&t.stt),
            bw: vec1_f32(&t.bw),
        };
        // warmup execution: the first PJRT dispatch spins up the CPU
        // client's thread pool (~tens of ms); absorb it at construction
        // so epoch-loop timings measure steady state.
        let zeros = vec![0.0f32; t.pools * nbins];
        let _ = a.analyze(&TimingInputs {
            reads: &zeros,
            writes: &zeros,
            bin_width: 1.0,
            bytes_per_ev: 64.0,
        })?;
        Ok(a)
    }
}

impl TimingModel for PjrtAnalyzer {
    fn pools(&self) -> usize {
        self.pools
    }
    fn switches(&self) -> usize {
        self.switches
    }
    fn nbins(&self) -> usize {
        self.nbins
    }
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs> {
        let (p, b) = (self.pools, self.nbins);
        anyhow::ensure!(inp.reads.len() == p * b, "reads shape");
        anyhow::ensure!(inp.writes.len() == p * b, "writes shape");

        let reads = mat_f32(inp.reads, p, b)?;
        let writes = mat_f32(inp.writes, p, b)?;
        let bin_width = Literal::scalar(inp.bin_width);
        let bytes_per_ev = Literal::scalar(inp.bytes_per_ev);

        let args: [&Literal; 9] = [
            &reads,
            &writes,
            &self.extra_rd,
            &self.extra_wr,
            &self.desc_mask,
            &self.stt,
            &self.bw,
            &bin_width,
            &bytes_per_ev,
        ];
        let result = self.exe.1.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
        let mut it = parts.into_iter();
        let total = it.next().unwrap().get_first_element::<f32>()? as f64;
        let lat = it.next().unwrap().to_vec::<f32>()?;
        let cong = it.next().unwrap().to_vec::<f32>()?;
        let bwd = it.next().unwrap().to_vec::<f32>()?;
        let cong_backlog = it.next().unwrap().to_vec::<f32>()?;
        Ok(TimingOutputs { total, lat, cong, bwd, cong_backlog })
    }
}

/// Batched analyzer over the `timing_batch{E}` artifact: processes E
/// epochs per PJRT call, amortizing dispatch overhead ~E× for offline
/// trace replay (see benches/hotpath.rs for the measured difference).
pub struct PjrtBatchAnalyzer {
    pub pools: usize,
    pub switches: usize,
    pub nbins: usize,
    pub batch: usize,
    exe: Rc<(PjRtClient, PjRtLoadedExecutable)>,
    extra_rd: Literal,
    extra_wr: Literal,
    desc_mask: Literal,
    stt: Literal,
    bw: Literal,
}

impl PjrtBatchAnalyzer {
    pub fn new(
        t: &TopoTensors,
        nbins: usize,
        artifacts_dir: &str,
    ) -> anyhow::Result<PjrtBatchAnalyzer> {
        let m = Manifest::load(artifacts_dir)?;
        anyhow::ensure!(
            m.pools == t.pools && m.switches == t.switches && m.nbins == nbins,
            "artifact shapes do not match; re-run `make artifacts`"
        );
        let path = format!("{artifacts_dir}/{}", m.batch_module);
        let exe = load_cached(&path)?;
        Ok(PjrtBatchAnalyzer {
            pools: t.pools,
            switches: t.switches,
            nbins,
            batch: m.batch,
            exe,
            extra_rd: vec1_f32(&t.extra_read_lat),
            extra_wr: vec1_f32(&t.extra_write_lat),
            desc_mask: mat_f32(&t.desc_mask, t.switches, t.pools)?,
            stt: vec1_f32(&t.stt),
            bw: vec1_f32(&t.bw),
        })
    }

    /// `reads`/`writes` are [E, P, B] flattened; E must equal `batch`
    /// (zero-pad the tail epochs of a shorter run).
    pub fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs> {
        let (e, p, b) = (self.batch, self.pools, self.nbins);
        anyhow::ensure!(reads.len() == e * p * b, "reads shape");
        anyhow::ensure!(writes.len() == e * p * b, "writes shape");
        let reads = Literal::vec1(reads).reshape(&[e as i64, p as i64, b as i64])?;
        let writes = Literal::vec1(writes).reshape(&[e as i64, p as i64, b as i64])?;
        let bin_width = Literal::scalar(bin_width);
        let bytes_per_ev = Literal::scalar(bytes_per_ev);
        let args: [&Literal; 9] = [
            &reads,
            &writes,
            &self.extra_rd,
            &self.extra_wr,
            &self.desc_mask,
            &self.stt,
            &self.bw,
            &bin_width,
            &bytes_per_ev,
        ];
        let result = self.exe.1.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let mut it = parts.into_iter();
        let total = it
            .next()
            .unwrap()
            .to_vec::<f32>()?
            .into_iter()
            .map(|x| x as f64)
            .collect();
        Ok(BatchOutputs {
            total,
            lat: it.next().unwrap().to_vec::<f32>()?,
            cong: it.next().unwrap().to_vec::<f32>()?,
            bwd: it.next().unwrap().to_vec::<f32>()?,
        })
    }
}

impl BatchTimingModel for PjrtBatchAnalyzer {
    fn pools(&self) -> usize {
        self.pools
    }
    fn switches(&self) -> usize {
        self.switches
    }
    fn nbins(&self) -> usize {
        self.nbins
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn backend_name(&self) -> &'static str {
        "pjrt-batch"
    }
    fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs> {
        PjrtBatchAnalyzer::analyze_batch(self, reads, writes, bin_width, bytes_per_ev)
    }
}

//! Timing-analyzer runtime: execute the AOT-compiled model per epoch.
//!
//! Two interchangeable backends implement [`TimingModel`]:
//!
//! * [`pjrt::PjrtAnalyzer`] — loads `artifacts/*.hlo.txt` (HLO text
//!   lowered once by `python/compile/aot.py`), compiles it on the PJRT
//!   CPU client at startup, and executes it per epoch. This is the
//!   shipped configuration; python is never on this path.
//! * [`native::NativeAnalyzer`] — a pure-rust mirror of the same math.
//!   Used for differential testing against the HLO module (both are
//!   checked against `artifacts/golden.json`) and as a zero-dependency
//!   fast path (`--backend native`).
//!
//! Topology tensors are fixed at construction; the per-epoch call only
//! moves the `[P, B]` read/write histograms.

pub mod native;
pub mod pjrt;
pub mod shapes;

use crate::topology::TopoTensors;

/// Per-epoch dynamic inputs (flattened row-major [P, B]).
pub struct TimingInputs<'a> {
    pub reads: &'a [f32],
    pub writes: &'a [f32],
    /// Bin width, ns (epoch length / nbins).
    pub bin_width: f32,
    /// Bytes per sampled event (cacheline).
    pub bytes_per_ev: f32,
}

/// Timing-analyzer outputs for one epoch (ns).
#[derive(Clone, Debug, Default)]
pub struct TimingOutputs {
    pub total: f64,
    pub lat: Vec<f32>,
    pub cong: Vec<f32>,
    pub bwd: Vec<f32>,
    /// Congestion backlog profile [S, B] — input to migration policies.
    pub cong_backlog: Vec<f32>,
}

impl TimingOutputs {
    pub fn lat_total(&self) -> f64 {
        self.lat.iter().map(|x| *x as f64).sum()
    }
    pub fn cong_total(&self) -> f64 {
        self.cong.iter().map(|x| *x as f64).sum()
    }
    pub fn bwd_total(&self) -> f64 {
        self.bwd.iter().map(|x| *x as f64).sum()
    }
}

/// A compiled timing analyzer bound to one topology.
///
/// Not `Send`: the PJRT client handles are thread-local; per-thread
/// analyzers are the supported concurrency model (each thread builds
/// its own, sharing the on-disk artifact).
pub trait TimingModel {
    fn pools(&self) -> usize;
    fn switches(&self) -> usize;
    fn nbins(&self) -> usize;
    fn backend_name(&self) -> &'static str;
    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs>;
    /// Whether `analyze` must copy the congestion-backlog profile into
    /// its outputs (epoch policies need it; skipping it saves an 8 KB
    /// allocation per epoch on the native backend). Default: no-op.
    fn set_export_backlog(&mut self, _on: bool) {}
}

/// Which backend to construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnalyzerBackend {
    /// AOT HLO through PJRT (the shipped path).
    Pjrt,
    /// Pure-rust mirror (differential testing / fast path).
    Native,
}

impl AnalyzerBackend {
    pub fn parse(s: &str) -> Option<AnalyzerBackend> {
        match s {
            "pjrt" => Some(AnalyzerBackend::Pjrt),
            "native" => Some(AnalyzerBackend::Native),
            _ => None,
        }
    }
}

/// Construct a timing model for `tensors` with `nbins` time bins.
/// `artifacts_dir` is only read for the PJRT backend.
pub fn make_analyzer(
    backend: AnalyzerBackend,
    tensors: &TopoTensors,
    nbins: usize,
    artifacts_dir: &str,
) -> anyhow::Result<Box<dyn TimingModel>> {
    match backend {
        AnalyzerBackend::Native => Ok(Box::new(native::NativeAnalyzer::new(tensors, nbins))),
        AnalyzerBackend::Pjrt => Ok(Box::new(pjrt::PjrtAnalyzer::new(tensors, nbins, artifacts_dir)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(AnalyzerBackend::parse("pjrt"), Some(AnalyzerBackend::Pjrt));
        assert_eq!(AnalyzerBackend::parse("native"), Some(AnalyzerBackend::Native));
        assert_eq!(AnalyzerBackend::parse("tpu"), None);
    }
}

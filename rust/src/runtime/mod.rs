//! Timing-analyzer runtime: execute the AOT-compiled model per epoch.
//!
//! Two interchangeable backends implement [`TimingModel`]:
//!
//! * `pjrt::PjrtAnalyzer` — loads `artifacts/*.hlo.txt` (HLO text
//!   lowered once by `python/compile/aot.py`), compiles it on the PJRT
//!   CPU client at startup, and executes it per epoch. Gated behind the
//!   `pjrt` cargo feature (off by default) because it needs the `xla`
//!   crate; with the feature off, requesting the backend is a clean
//!   runtime error and python is never required.
//! * [`native::NativeAnalyzer`] — a pure-rust mirror of the same math.
//!   Used for differential testing against the HLO module (both are
//!   checked against `artifacts/golden.json`) and as a zero-dependency
//!   fast path (`--backend native`).
//!
//! Both backends also come in a *batched* flavour ([`BatchTimingModel`])
//! that analyzes E epochs per call — the PJRT one amortizes FFI
//! dispatch across the `timing_batch{E}` artifact, the native one is a
//! plain loop so batched replay works identically without artifacts.
//!
//! Topology tensors are fixed at construction; the per-epoch call only
//! moves the `[P, B]` read/write histograms.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod shapes;

use crate::topology::TopoTensors;

/// Per-epoch dynamic inputs (flattened row-major [P, B]).
pub struct TimingInputs<'a> {
    pub reads: &'a [f32],
    pub writes: &'a [f32],
    /// Bin width, ns (epoch length / nbins).
    pub bin_width: f32,
    /// Bytes per sampled event (cacheline).
    pub bytes_per_ev: f32,
}

/// Timing-analyzer outputs for one epoch (ns).
#[derive(Clone, Debug, Default)]
pub struct TimingOutputs {
    pub total: f64,
    pub lat: Vec<f32>,
    pub cong: Vec<f32>,
    pub bwd: Vec<f32>,
    /// Congestion backlog profile [S, B] — input to migration policies.
    pub cong_backlog: Vec<f32>,
}

impl TimingOutputs {
    pub fn lat_total(&self) -> f64 {
        self.lat.iter().map(|x| *x as f64).sum()
    }
    pub fn cong_total(&self) -> f64 {
        self.cong.iter().map(|x| *x as f64).sum()
    }
    pub fn bwd_total(&self) -> f64 {
        self.bwd.iter().map(|x| *x as f64).sum()
    }
}

/// A compiled timing analyzer bound to one topology.
///
/// Not `Send` in general: the PJRT client handles are thread-local;
/// per-thread analyzers are the supported concurrency model (each
/// thread builds its own, sharing the on-disk artifact). The native
/// backend is plain data and *is* `Send` — [`make_send_analyzer`] /
/// [`make_send_batch_analyzer`] hand out `Box<dyn … + Send>` models
/// for the pipelined analysis worker (`--pipeline`), and reject PJRT.
pub trait TimingModel {
    fn pools(&self) -> usize;
    fn switches(&self) -> usize;
    fn nbins(&self) -> usize;
    fn backend_name(&self) -> &'static str;
    /// Which queueing-scan kernel this model runs (reported in
    /// `SimReport::scan_kernel`). The default is `Exact` because every
    /// non-native backend (the AOT HLO) *is* the exact computation.
    fn scan_kernel(&self) -> ScanKernel {
        ScanKernel::Exact
    }
    fn analyze(&mut self, inp: &TimingInputs) -> anyhow::Result<TimingOutputs>;
    /// Whether `analyze` must copy the congestion-backlog profile into
    /// its outputs (epoch policies need it; skipping it saves an 8 KB
    /// allocation per epoch on the native backend). Default: no-op.
    fn set_export_backlog(&mut self, _on: bool) {}
    /// Install the fault overlay subsequent `analyze` calls run under
    /// (`None` restores the fault-free base tensors). Default: no-op —
    /// backends without overlay support ignore it, and the drivers
    /// reject fault plans on such backends up front.
    fn set_fault_overlay(&mut self, _overlay: Option<&crate::fault::FaultOverlay>) {}
}

/// Which backend to construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnalyzerBackend {
    /// AOT HLO through PJRT (the shipped path).
    Pjrt,
    /// Pure-rust mirror (differential testing / fast path).
    Native,
}

impl AnalyzerBackend {
    pub fn parse(s: &str) -> Option<AnalyzerBackend> {
        match s {
            "pjrt" => Some(AnalyzerBackend::Pjrt),
            "native" => Some(AnalyzerBackend::Native),
            _ => None,
        }
    }
}

/// Which queueing-scan kernel the native analyzer runs (CLI
/// `--scan-kernel`). The two kernels compute the same recurrences —
/// `Exact` with the reference operation order (bit-identical to
/// `artifacts/golden.json` and the HLO), `Blocked` as max-plus prefix
/// scans over fixed-width f32 blocks (SIMD-friendly, reassociates
/// float adds, so outputs agree to ULP/relative tolerance only — see
/// `NativeAnalyzer::matmul_and_scan_blocked` and the differential
/// property tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScanKernel {
    /// Scalar reference recurrences; the golden/bit-identity kernel.
    Exact,
    /// Blocked max-plus scans; the default performance kernel.
    #[default]
    Blocked,
}

impl ScanKernel {
    pub fn parse(s: &str) -> Option<ScanKernel> {
        match s {
            "exact" => Some(ScanKernel::Exact),
            "blocked" => Some(ScanKernel::Blocked),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScanKernel::Exact => "exact",
            ScanKernel::Blocked => "blocked",
        }
    }
}

/// Outputs of one batched analyzer call over E epochs: `total` is [E];
/// `lat` is [E, P] flattened; `cong`/`bwd` are [E, S] flattened.
#[derive(Clone, Debug)]
pub struct BatchOutputs {
    pub total: Vec<f64>,
    pub lat: Vec<f32>,
    pub cong: Vec<f32>,
    pub bwd: Vec<f32>,
}

impl BatchOutputs {
    /// Slice epoch `i` out of the batch as per-epoch [`TimingOutputs`]
    /// (no backlog in batched modules).
    pub fn epoch(&self, i: usize, pools: usize, switches: usize) -> TimingOutputs {
        TimingOutputs {
            total: self.total[i],
            lat: self.lat[i * pools..(i + 1) * pools].to_vec(),
            cong: self.cong[i * switches..(i + 1) * switches].to_vec(),
            bwd: self.bwd[i * switches..(i + 1) * switches].to_vec(),
            cong_backlog: Vec::new(),
        }
    }
}

/// A timing analyzer that processes E epochs per call (offline replay).
pub trait BatchTimingModel {
    fn pools(&self) -> usize;
    fn switches(&self) -> usize;
    fn nbins(&self) -> usize;
    /// Epochs per call; callers zero-pad the tail of a shorter run.
    fn batch(&self) -> usize;
    /// Shard workers `analyze_batch` fans the E-epoch loop across
    /// (1 = sequential). Outputs are required to be bit-identical for
    /// every value; the count is surfaced in reports
    /// (`SimReport::analyzer_threads_used`) so work conservation is
    /// observable. Default: no sharding.
    fn threads(&self) -> usize {
        1
    }
    /// Which queueing-scan kernel this model runs (see
    /// [`TimingModel::scan_kernel`]).
    fn scan_kernel(&self) -> ScanKernel {
        ScanKernel::Exact
    }
    fn backend_name(&self) -> &'static str;
    /// Install the fault overlay the *whole* next `analyze_batch` call
    /// runs under; the batched driver flushes its pending group on
    /// every overlay change so one group never spans two overlays.
    /// Default: no-op (see [`TimingModel::set_fault_overlay`]).
    fn set_fault_overlay(&mut self, _overlay: Option<&crate::fault::FaultOverlay>) {}
    /// `reads`/`writes` are [E, P, B] flattened with E == `batch()`.
    fn analyze_batch(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        bin_width: f32,
        bytes_per_ev: f32,
    ) -> anyhow::Result<BatchOutputs>;
}

/// Construct a timing model for `tensors` with `nbins` time bins.
/// `artifacts_dir` is only read for the PJRT backend. `kernel` selects
/// the native queueing-scan kernel; the PJRT backend ignores it (the
/// AOT HLO *is* the exact reference computation).
pub fn make_analyzer(
    backend: AnalyzerBackend,
    tensors: &TopoTensors,
    nbins: usize,
    artifacts_dir: &str,
    kernel: ScanKernel,
) -> anyhow::Result<Box<dyn TimingModel>> {
    match backend {
        AnalyzerBackend::Native => {
            let _ = artifacts_dir;
            Ok(Box::new(native::NativeAnalyzer::with_kernel(tensors, nbins, kernel)))
        }
        #[cfg(feature = "pjrt")]
        AnalyzerBackend::Pjrt => {
            Ok(Box::new(pjrt::PjrtAnalyzer::new(tensors, nbins, artifacts_dir)?))
        }
        #[cfg(not(feature = "pjrt"))]
        AnalyzerBackend::Pjrt => Err(anyhow::anyhow!(
            "backend `pjrt` requires building with `--features pjrt` (and the `xla` crate); \
             use `--backend native` or rebuild with the feature"
        )),
    }
}

/// Construct a batched analyzer (E epochs per call) for offline
/// replay. `threads` shards the native backend's E-epoch loop
/// (`0` = one worker per core, `1` = sequential); results are
/// bit-identical for every value. `group` is the native group size E
/// (`0` = [`shapes::BATCH`]); larger groups hand the sharded analyzer
/// more epochs per call, at the cost of policy phase-2 hooks running
/// up to `group − 1` epochs late (see `coordinator::batch`). PJRT
/// manages its own intra-op parallelism, uses its artifact's fixed
/// batch, and runs the exact HLO computation — it ignores `threads`,
/// `group`, and `kernel`.
pub fn make_batch_analyzer(
    backend: AnalyzerBackend,
    tensors: &TopoTensors,
    nbins: usize,
    artifacts_dir: &str,
    threads: usize,
    kernel: ScanKernel,
    group: usize,
) -> anyhow::Result<Box<dyn BatchTimingModel>> {
    match backend {
        AnalyzerBackend::Native => {
            let _ = artifacts_dir;
            Ok(Box::new(native::NativeBatchAnalyzer::with_kernel(
                tensors,
                nbins,
                shapes::resolve_batch(group),
                threads,
                kernel,
            )))
        }
        #[cfg(feature = "pjrt")]
        AnalyzerBackend::Pjrt => {
            Ok(Box::new(pjrt::PjrtBatchAnalyzer::new(tensors, nbins, artifacts_dir)?))
        }
        #[cfg(not(feature = "pjrt"))]
        AnalyzerBackend::Pjrt => Err(anyhow::anyhow!(
            "backend `pjrt` requires building with `--features pjrt` (and the `xla` crate); \
             use `--backend native` or rebuild with the feature"
        )),
    }
}

/// [`make_analyzer`], restricted to backends whose models can move to
/// the pipelined analysis worker thread (`SimConfig::pipeline`). Only
/// the native backend qualifies — its analyzers are plain tensor data.
/// PJRT client handles are thread-local, so requesting it here is a
/// structured error rather than a crash on first use.
pub fn make_send_analyzer(
    backend: AnalyzerBackend,
    tensors: &TopoTensors,
    nbins: usize,
    kernel: ScanKernel,
) -> anyhow::Result<Box<dyn TimingModel + Send>> {
    match backend {
        AnalyzerBackend::Native => {
            Ok(Box::new(native::NativeAnalyzer::with_kernel(tensors, nbins, kernel)))
        }
        AnalyzerBackend::Pjrt => Err(anyhow::anyhow!(
            "--pipeline requires `--backend native`: PJRT client handles are thread-local \
             and cannot move to the pipelined analysis worker"
        )),
    }
}

/// [`make_batch_analyzer`], restricted to backends whose models can
/// move to the pipelined analysis worker thread (see
/// [`make_send_analyzer`]). The worker still shards its E-epoch loop
/// across `threads` scoped workers per call, exactly like the
/// non-pipelined batched analyzer.
pub fn make_send_batch_analyzer(
    backend: AnalyzerBackend,
    tensors: &TopoTensors,
    nbins: usize,
    threads: usize,
    kernel: ScanKernel,
    group: usize,
) -> anyhow::Result<Box<dyn BatchTimingModel + Send>> {
    match backend {
        AnalyzerBackend::Native => Ok(Box::new(native::NativeBatchAnalyzer::with_kernel(
            tensors,
            nbins,
            shapes::resolve_batch(group),
            threads,
            kernel,
        ))),
        AnalyzerBackend::Pjrt => Err(anyhow::anyhow!(
            "--pipeline requires `--backend native`: PJRT client handles are thread-local \
             and cannot move to the pipelined analysis worker"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(AnalyzerBackend::parse("pjrt"), Some(AnalyzerBackend::Pjrt));
        assert_eq!(AnalyzerBackend::parse("native"), Some(AnalyzerBackend::Native));
        assert_eq!(AnalyzerBackend::parse("tpu"), None);
    }

    #[test]
    fn scan_kernel_parse_and_default() {
        assert_eq!(ScanKernel::parse("exact"), Some(ScanKernel::Exact));
        assert_eq!(ScanKernel::parse("blocked"), Some(ScanKernel::Blocked));
        assert_eq!(ScanKernel::parse("simd"), None);
        // the performance kernel is the default; `exact` stays the
        // opt-in golden reference
        assert_eq!(ScanKernel::default(), ScanKernel::Blocked);
        assert_eq!(ScanKernel::Exact.name(), "exact");
        assert_eq!(ScanKernel::Blocked.name(), "blocked");
    }
}

//! Epoch histograms: turn the epoch's sampled miss events into the
//! fixed-shape `[P, B]` read/write tensors the timing model consumes.
//!
//! The paper iterates the raw PEBS event list per epoch; binning to B
//! fixed time bins is what makes the analyzer a dense tensor program
//! (DESIGN.md §5). Bin width = epoch_len / B.

use crate::topology::PoolId;

/// Per-epoch [P, B] read/write histograms, f32 row-major (model input).
#[derive(Clone, Debug)]
pub struct EpochBins {
    pub pools: usize,
    pub nbins: usize,
    pub epoch_ns: f64,
    pub reads: Vec<f32>,
    pub writes: Vec<f32>,
    /// Total events binned (reads + writes), for sanity checks.
    pub total_events: u64,
    /// Events whose timestamp fell outside [0, epoch_ns) — clamped into
    /// the edge bins; should be ~0 in a healthy run.
    pub clamped: u64,
}

impl EpochBins {
    pub fn new(pools: usize, nbins: usize, epoch_ns: f64) -> EpochBins {
        assert!(pools > 0 && nbins > 0 && epoch_ns > 0.0);
        EpochBins {
            pools,
            nbins,
            epoch_ns,
            reads: vec![0.0; pools * nbins],
            writes: vec![0.0; pools * nbins],
            total_events: 0,
            clamped: 0,
        }
    }

    pub fn bin_width_ns(&self) -> f64 {
        self.epoch_ns / self.nbins as f64
    }

    /// Record one sampled miss at epoch-relative time `t_ns` against
    /// pool `pool`, weighted by the PEBS sampling period (a sample with
    /// period k stands for k misses).
    #[inline]
    pub fn record(&mut self, pool: PoolId, is_write: bool, t_ns: f64, weight: f32) {
        debug_assert!(pool < self.pools);
        let mut b = (t_ns / self.bin_width_ns()).floor() as i64;
        if b < 0 {
            b = 0;
            self.clamped += 1;
        } else if b >= self.nbins as i64 {
            b = self.nbins as i64 - 1;
            if t_ns >= self.epoch_ns + 1e-9 {
                self.clamped += 1;
            }
        }
        let idx = pool * self.nbins + b as usize;
        if is_write {
            self.writes[idx] += weight;
        } else {
            self.reads[idx] += weight;
        }
        self.total_events += 1;
    }

    /// Element-wise accumulate another bins' counters (same shape).
    /// Used by multihost to merge per-host epoch bins at the epoch
    /// barrier — always in host order, so the result is deterministic
    /// regardless of how the host phase was threaded.
    pub fn merge_from(&mut self, other: &EpochBins) {
        assert_eq!(self.pools, other.pools);
        assert_eq!(self.nbins, other.nbins);
        for (a, b) in self.reads.iter_mut().zip(&other.reads) {
            *a += *b;
        }
        for (a, b) in self.writes.iter_mut().zip(&other.writes) {
            *a += *b;
        }
        self.total_events += other.total_events;
        self.clamped += other.clamped;
    }

    /// Zero all counters for reuse (avoids reallocating every epoch —
    /// this is on the coordinator's hot path).
    pub fn clear(&mut self) {
        self.reads.iter_mut().for_each(|x| *x = 0.0);
        self.writes.iter_mut().for_each(|x| *x = 0.0);
        self.total_events = 0;
        self.clamped = 0;
    }

    pub fn read_count(&self, pool: PoolId) -> f64 {
        self.reads[pool * self.nbins..(pool + 1) * self.nbins]
            .iter()
            .map(|x| *x as f64)
            .sum()
    }

    pub fn write_count(&self, pool: PoolId) -> f64 {
        self.writes[pool * self.nbins..(pool + 1) * self.nbins]
            .iter()
            .map(|x| *x as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_land_in_right_bin() {
        let mut b = EpochBins::new(2, 10, 1000.0); // bin width 100ns
        b.record(0, false, 0.0, 1.0);
        b.record(0, false, 150.0, 1.0);
        b.record(1, true, 950.0, 1.0);
        assert_eq!(b.reads[0], 1.0);
        assert_eq!(b.reads[1], 1.0);
        assert_eq!(b.writes[1 * 10 + 9], 1.0);
        assert_eq!(b.total_events, 3);
        assert_eq!(b.clamped, 0);
    }

    #[test]
    fn out_of_range_clamps() {
        let mut b = EpochBins::new(1, 4, 400.0);
        b.record(0, false, -5.0, 1.0);
        b.record(0, false, 401.0, 1.0);
        assert_eq!(b.reads[0], 1.0);
        assert_eq!(b.reads[3], 1.0);
        assert_eq!(b.clamped, 2);
    }

    #[test]
    fn boundary_time_goes_to_last_bin_unclamped() {
        let mut b = EpochBins::new(1, 4, 400.0);
        b.record(0, false, 400.0, 1.0); // == epoch_ns: edge, not an error
        assert_eq!(b.reads[3], 1.0);
        assert_eq!(b.clamped, 0);
    }

    #[test]
    fn weights_accumulate() {
        let mut b = EpochBins::new(1, 2, 100.0);
        b.record(0, true, 10.0, 64.0);
        b.record(0, true, 20.0, 64.0);
        assert_eq!(b.write_count(0), 128.0);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = EpochBins::new(2, 8, 800.0);
        b.record(1, false, 10.0, 1.0);
        b.clear();
        assert_eq!(b.total_events, 0);
        assert!(b.reads.iter().all(|x| *x == 0.0));
        assert!(b.writes.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn merge_from_accumulates() {
        let mut a = EpochBins::new(2, 4, 400.0);
        let mut b = EpochBins::new(2, 4, 400.0);
        a.record(0, false, 10.0, 1.0);
        b.record(0, false, 10.0, 2.0);
        b.record(1, true, 350.0, 1.0);
        a.merge_from(&b);
        assert_eq!(a.reads[0], 3.0);
        assert_eq!(a.write_count(1), 1.0);
        assert_eq!(a.total_events, 3);
    }

    #[test]
    fn per_pool_counts() {
        let mut b = EpochBins::new(3, 4, 400.0);
        for i in 0..10 {
            b.record(2, i % 2 == 0, (i * 37) as f64 % 400.0, 1.0);
        }
        assert_eq!(b.read_count(2) + b.write_count(2), 10.0);
        assert_eq!(b.read_count(0), 0.0);
    }
}

//! Epoch histograms: turn the epoch's sampled miss events into the
//! fixed-shape `[P, B]` read/write tensors the timing model consumes.
//!
//! The paper iterates the raw PEBS event list per epoch; binning to B
//! fixed time bins is what makes the analyzer a dense tensor program
//! (DESIGN.md §5). Bin width = epoch_len / B.
//!
//! Two recording paths exist and are bit-identical (differential test
//! in `tests/pipeline_equivalence.rs`):
//!
//! * [`EpochBins::record`] — the scalar baseline: one call per sample,
//!   bin + clamp + accumulate inline;
//! * [`EpochBins::stage`] + [`EpochBins::record_bulk`] — the bulk path
//!   the `EpochDriver` uses: samples are resolved to `(pool, rw, bin,
//!   weight)` deltas up front (clamp branches run once, here) and
//!   scattered into the tensors in one branch-light pass per event
//!   batch. Large batches are first stably partitioned by pool
//!   (counting sort) so the scatter walks contiguous bin runs per
//!   `[P, B]` row instead of bouncing across rows; within one
//!   `(pool, rw, bin)` cell the staging order is preserved, so every
//!   cell accumulates in event order and results stay bit-identical
//!   to the scalar path (and to the unpartitioned
//!   [`EpochBins::record_bulk_seq`] baseline). Both paths bin through
//!   the same precomputed `inv_bin_width` multiply, so grouping never
//!   changes results.

use crate::topology::PoolId;

/// One staged histogram delta: a sample already resolved to its
/// `(pool, rw, bin)` cell, waiting for [`EpochBins::record_bulk`]'s
/// scatter. Small and `Copy` — a batch of these is the staging buffer
/// the epoch driver reuses across batches.
#[derive(Clone, Copy, Debug)]
pub struct BinDelta {
    pub pool: u32,
    pub bin: u32,
    pub is_write: bool,
    pub weight: f32,
}

/// Per-epoch [P, B] read/write histograms, f32 row-major (model input).
#[derive(Clone, Debug)]
pub struct EpochBins {
    pub pools: usize,
    pub nbins: usize,
    pub epoch_ns: f64,
    pub reads: Vec<f32>,
    pub writes: Vec<f32>,
    /// Total events binned (reads + writes), for sanity checks.
    pub total_events: u64,
    /// Events whose timestamp fell outside [0, epoch_ns) — clamped into
    /// the edge bins; should be ~0 in a healthy run.
    pub clamped: u64,
    /// Precomputed `1.0 / bin_width_ns()`: both recording paths multiply
    /// by this instead of dividing per sample.
    inv_bin_width: f64,
    /// Scratch for [`EpochBins::record_bulk`]'s stable counting-sort
    /// partition (reused across scatters; empty until first use).
    scratch: Vec<BinDelta>,
    /// Per-pool cursor/offset table for the partition.
    offsets: Vec<usize>,
}

/// Below this batch size the partition bookkeeping costs more than the
/// cache misses it saves; `record_bulk` falls through to the
/// sequential scatter.
const PARTITION_MIN: usize = 64;

impl EpochBins {
    pub fn new(pools: usize, nbins: usize, epoch_ns: f64) -> EpochBins {
        assert!(pools > 0 && nbins > 0 && epoch_ns > 0.0);
        EpochBins {
            pools,
            nbins,
            epoch_ns,
            reads: vec![0.0; pools * nbins],
            writes: vec![0.0; pools * nbins],
            total_events: 0,
            clamped: 0,
            inv_bin_width: nbins as f64 / epoch_ns,
            scratch: Vec::new(),
            offsets: Vec::new(),
        }
    }

    pub fn bin_width_ns(&self) -> f64 {
        self.epoch_ns / self.nbins as f64
    }

    /// Resolve an epoch-relative time to its (clamped) bin. One shared
    /// helper so `record` and `stage` bin identically.
    #[inline]
    fn bin_of(&self, t_ns: f64) -> (usize, bool) {
        let b = (t_ns * self.inv_bin_width).floor() as i64;
        if b < 0 {
            (0, true)
        } else if b >= self.nbins as i64 {
            (self.nbins - 1, t_ns >= self.epoch_ns + 1e-9)
        } else {
            (b as usize, false)
        }
    }

    /// Record one sampled miss at epoch-relative time `t_ns` against
    /// pool `pool`, weighted by the PEBS sampling period (a sample with
    /// period k stands for k misses). The scalar baseline for
    /// [`EpochBins::record_bulk`] (kept runnable for differential tests
    /// and `benches/hotpath.rs`, like `pool_of_btree`).
    #[inline]
    pub fn record(&mut self, pool: PoolId, is_write: bool, t_ns: f64, weight: f32) {
        debug_assert!(pool < self.pools);
        let (bin, clamped) = self.bin_of(t_ns);
        self.clamped += u64::from(clamped);
        let idx = pool * self.nbins + bin;
        if is_write {
            self.writes[idx] += weight;
        } else {
            self.reads[idx] += weight;
        }
        self.total_events += 1;
    }

    /// Stage one sample for a later bulk scatter: the bin is resolved
    /// (and the clamp branches run) here, once per sample; the deferred
    /// f32 accumulation happens in [`EpochBins::record_bulk`]. Staging
    /// order must equal event order — the scatter preserves it, which
    /// is what makes `stage` + `record_bulk` bit-identical to calling
    /// [`EpochBins::record`] per sample.
    #[inline]
    pub fn stage(
        &mut self,
        pool: PoolId,
        is_write: bool,
        t_ns: f64,
        weight: f32,
        out: &mut Vec<BinDelta>,
    ) {
        debug_assert!(pool < self.pools);
        let (bin, clamped) = self.bin_of(t_ns);
        self.clamped += u64::from(clamped);
        self.total_events += 1;
        out.push(BinDelta { pool: pool as u32, bin: bin as u32, is_write, weight });
    }

    /// Scatter a staged batch into the `[P, B]` tensors. Batches of
    /// `PARTITION_MIN` or more are stably partitioned by pool first
    /// (one counting-sort pass into reused scratch) so the accumulate
    /// loop walks each pool's bin row contiguously instead of bouncing
    /// across `[P, B]` rows with the event stream's pool mixing.
    ///
    /// Bit-exactness: all deltas hitting one `(pool, rw, bin)` cell
    /// share a pool, and the partition is stable, so every cell
    /// accumulates in staging (== event) order — identical results to
    /// the per-sample `record` path and to
    /// [`EpochBins::record_bulk_seq`] (differential tests in
    /// `tests/pipeline_equivalence.rs` and below).
    pub fn record_bulk(&mut self, deltas: &[BinDelta]) {
        if deltas.len() < PARTITION_MIN {
            self.record_bulk_seq(deltas);
            return;
        }
        self.offsets.clear();
        self.offsets.resize(self.pools + 1, 0);
        for d in deltas {
            self.offsets[d.pool as usize + 1] += 1;
        }
        for p in 0..self.pools {
            self.offsets[p + 1] += self.offsets[p];
        }
        // no clear(): the placement loop overwrites every slot (the
        // offsets partition covers 0..len exactly), so stale contents
        // are never read and the resize only default-fills growth
        self.scratch.resize(
            deltas.len(),
            BinDelta { pool: 0, bin: 0, is_write: false, weight: 0.0 },
        );
        for d in deltas {
            let slot = &mut self.offsets[d.pool as usize];
            self.scratch[*slot] = *d;
            *slot += 1;
        }
        for d in &self.scratch {
            let idx = d.pool as usize * self.nbins + d.bin as usize;
            if d.is_write {
                self.writes[idx] += d.weight;
            } else {
                self.reads[idx] += d.weight;
            }
        }
    }

    /// The unpartitioned scatter (accumulation order == staging order,
    /// pools interleaved as the event stream produced them). Kept
    /// runnable as the differential baseline and the
    /// `benches/hotpath.rs` comparison point, like `record` and
    /// `pool_of_btree`.
    pub fn record_bulk_seq(&mut self, deltas: &[BinDelta]) {
        for d in deltas {
            let idx = d.pool as usize * self.nbins + d.bin as usize;
            if d.is_write {
                self.writes[idx] += d.weight;
            } else {
                self.reads[idx] += d.weight;
            }
        }
    }

    /// Element-wise accumulate another bins' counters (same shape).
    /// Used by multihost to merge per-host epoch bins at the epoch
    /// barrier — always in host order, so the result is deterministic
    /// regardless of how the host phase was threaded.
    pub fn merge_from(&mut self, other: &EpochBins) {
        assert_eq!(self.pools, other.pools);
        assert_eq!(self.nbins, other.nbins);
        for (a, b) in self.reads.iter_mut().zip(&other.reads) {
            *a += *b;
        }
        for (a, b) in self.writes.iter_mut().zip(&other.writes) {
            *a += *b;
        }
        self.total_events += other.total_events;
        self.clamped += other.clamped;
    }

    /// Zero all counters for reuse (avoids reallocating every epoch —
    /// this is on the coordinator's hot path).
    pub fn clear(&mut self) {
        self.reads.fill(0.0);
        self.writes.fill(0.0);
        self.total_events = 0;
        self.clamped = 0;
    }

    pub fn read_count(&self, pool: PoolId) -> f64 {
        self.reads[pool * self.nbins..(pool + 1) * self.nbins]
            .iter()
            .map(|x| *x as f64)
            .sum()
    }

    pub fn write_count(&self, pool: PoolId) -> f64 {
        self.writes[pool * self.nbins..(pool + 1) * self.nbins]
            .iter()
            .map(|x| *x as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_land_in_right_bin() {
        let mut b = EpochBins::new(2, 10, 1000.0); // bin width 100ns
        b.record(0, false, 0.0, 1.0);
        b.record(0, false, 150.0, 1.0);
        b.record(1, true, 950.0, 1.0);
        assert_eq!(b.reads[0], 1.0);
        assert_eq!(b.reads[1], 1.0);
        assert_eq!(b.writes[1 * 10 + 9], 1.0);
        assert_eq!(b.total_events, 3);
        assert_eq!(b.clamped, 0);
    }

    #[test]
    fn out_of_range_clamps() {
        let mut b = EpochBins::new(1, 4, 400.0);
        b.record(0, false, -5.0, 1.0);
        b.record(0, false, 401.0, 1.0);
        assert_eq!(b.reads[0], 1.0);
        assert_eq!(b.reads[3], 1.0);
        assert_eq!(b.clamped, 2);
    }

    #[test]
    fn boundary_time_goes_to_last_bin_unclamped() {
        let mut b = EpochBins::new(1, 4, 400.0);
        b.record(0, false, 400.0, 1.0); // == epoch_ns: edge, not an error
        assert_eq!(b.reads[3], 1.0);
        assert_eq!(b.clamped, 0);
    }

    #[test]
    fn weights_accumulate() {
        let mut b = EpochBins::new(1, 2, 100.0);
        b.record(0, true, 10.0, 64.0);
        b.record(0, true, 20.0, 64.0);
        assert_eq!(b.write_count(0), 128.0);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = EpochBins::new(2, 8, 800.0);
        b.record(1, false, 10.0, 1.0);
        b.clear();
        assert_eq!(b.total_events, 0);
        assert!(b.reads.iter().all(|x| *x == 0.0));
        assert!(b.writes.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn merge_from_accumulates() {
        let mut a = EpochBins::new(2, 4, 400.0);
        let mut b = EpochBins::new(2, 4, 400.0);
        a.record(0, false, 10.0, 1.0);
        b.record(0, false, 10.0, 2.0);
        b.record(1, true, 350.0, 1.0);
        a.merge_from(&b);
        assert_eq!(a.reads[0], 3.0);
        assert_eq!(a.write_count(1), 1.0);
        assert_eq!(a.total_events, 3);
    }

    #[test]
    fn bulk_scatter_matches_scalar_record() {
        let mut scalar = EpochBins::new(2, 10, 1000.0);
        let mut bulk = EpochBins::new(2, 10, 1000.0);
        let samples = [
            (0usize, false, 0.0, 1.0f32),
            (0, false, 150.0, 2.0),
            (1, true, 950.0, 64.0),
            (0, false, -5.0, 1.0),   // clamps low
            (1, true, 1001.0, 1.0),  // clamps high
            (1, false, 1000.0, 1.0), // boundary: last bin, unclamped
        ];
        let mut staged = Vec::new();
        for &(p, w, t, wt) in &samples {
            scalar.record(p, w, t, wt);
            bulk.stage(p, w, t, wt, &mut staged);
        }
        bulk.record_bulk(&staged);
        assert_eq!(scalar.reads, bulk.reads);
        assert_eq!(scalar.writes, bulk.writes);
        assert_eq!(scalar.total_events, bulk.total_events);
        assert_eq!(scalar.clamped, bulk.clamped);
    }

    #[test]
    fn stage_counts_clamps_and_events_immediately() {
        let mut b = EpochBins::new(1, 4, 400.0);
        let mut staged = Vec::new();
        b.stage(0, false, -1.0, 1.0, &mut staged);
        b.stage(0, false, 500.0, 1.0, &mut staged);
        // bookkeeping lands at stage time, before the scatter
        assert_eq!(b.total_events, 2);
        assert_eq!(b.clamped, 2);
        assert!(b.reads.iter().all(|x| *x == 0.0), "tensors untouched pre-scatter");
        b.record_bulk(&staged);
        assert_eq!(b.reads[0], 1.0);
        assert_eq!(b.reads[3], 1.0);
    }

    #[test]
    fn partitioned_scatter_matches_seq_and_scalar() {
        // well past PARTITION_MIN, pools interleaved, repeated cells
        // (f32 accumulation-order sensitivity) — all three paths must
        // be bit-identical
        let (pools, nbins, epoch_ns) = (4usize, 8usize, 800.0f64);
        let mut scalar = EpochBins::new(pools, nbins, epoch_ns);
        let mut seq = EpochBins::new(pools, nbins, epoch_ns);
        let mut part = EpochBins::new(pools, nbins, epoch_ns);
        let mut staged = Vec::new();
        for i in 0..500usize {
            let pool = i % pools;
            let is_write = i % 3 == 0;
            let t = ((i * 37) % 800) as f64;
            // varied magnitudes so reordering across cells would show
            let w = 0.1 + (i % 7) as f32 * 1000.5;
            scalar.record(pool, is_write, t, w);
            seq.stage(pool, is_write, t, w, &mut staged);
        }
        // the same staged list drives both scatter flavours (the
        // scatter itself only touches the tensors)
        seq.record_bulk_seq(&staged);
        part.record_bulk(&staged);
        assert_eq!(scalar.reads, seq.reads);
        assert_eq!(scalar.writes, seq.writes);
        assert_eq!(seq.reads, part.reads, "partition must not change sums");
        assert_eq!(seq.writes, part.writes);
    }

    #[test]
    fn empty_bulk_scatter_is_noop() {
        let mut b = EpochBins::new(1, 4, 400.0);
        b.record_bulk(&[]);
        assert_eq!(b.total_events, 0);
        assert!(b.reads.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn per_pool_counts() {
        let mut b = EpochBins::new(3, 4, 400.0);
        for i in 0..10 {
            b.record(2, i % 2 == 0, (i * 37) as f64 % 400.0, 1.0);
        }
        assert_eq!(b.read_count(2) + b.write_count(2), 10.0);
        assert_eq!(b.read_count(0), 0.0);
    }
}

//! Event vocabulary shared by the tracer substrate and the coordinator.
//!
//! In the paper, the *Tracer* produces two streams: allocation events
//! (eBPF on `mmap`/`munmap`/`sbrk`/`brk`) and memory events (PEBS
//! samples of LLC misses). Here the workload engine emits the same two
//! streams; the vocabulary below is deliberately the union of what eBPF
//! + PEBS would deliver so the downstream logic is identical.

pub mod binning;
pub mod io;
pub mod stream;

/// Which allocation interface produced an allocation event — used by
/// size-class placement policies and by the microbenchmarks, which are
/// named after exactly these calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    Mmap,
    Munmap,
    Sbrk,
    Brk,
    Malloc,
    Calloc,
    Free,
}

impl AllocKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AllocKind::Mmap => "mmap",
            AllocKind::Munmap => "munmap",
            AllocKind::Sbrk => "sbrk",
            AllocKind::Brk => "brk",
            AllocKind::Malloc => "malloc",
            AllocKind::Calloc => "calloc",
            AllocKind::Free => "free",
        }
    }

    pub fn parse(s: &str) -> Option<AllocKind> {
        Some(match s {
            "mmap" => AllocKind::Mmap,
            "munmap" => AllocKind::Munmap,
            "sbrk" => AllocKind::Sbrk,
            "brk" => AllocKind::Brk,
            "malloc" => AllocKind::Malloc,
            "calloc" => AllocKind::Calloc,
            "free" => AllocKind::Free,
            _ => return None,
        })
    }

    /// Does this event release memory rather than acquire it?
    pub fn is_release(&self) -> bool {
        matches!(self, AllocKind::Munmap | AllocKind::Free)
    }
}

/// What eBPF would report for one allocation syscall.
#[derive(Clone, Copy, Debug)]
pub struct AllocEvent {
    pub kind: AllocKind,
    /// Virtual base address of the affected range.
    pub addr: u64,
    /// Length in bytes.
    pub len: u64,
    /// Virtual time of the call, ns since workload start.
    pub t_ns: f64,
}

/// One memory access as issued by the program (pre cache filtering).
#[derive(Clone, Copy, Debug)]
pub struct Access {
    pub addr: u64,
    pub is_write: bool,
}

/// What PEBS would report for one sampled LLC-miss event.
#[derive(Clone, Copy, Debug)]
pub struct MissSample {
    pub addr: u64,
    pub is_write: bool,
    /// Virtual time of the miss, ns since epoch start.
    pub t_ns: f64,
}

/// Everything a workload can emit, in program order.
#[derive(Clone, Copy, Debug)]
pub enum WlEvent {
    Alloc(AllocEvent),
    Access(Access),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_kind_roundtrip() {
        for k in [
            AllocKind::Mmap,
            AllocKind::Munmap,
            AllocKind::Sbrk,
            AllocKind::Brk,
            AllocKind::Malloc,
            AllocKind::Calloc,
            AllocKind::Free,
        ] {
            assert_eq!(AllocKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(AllocKind::parse("posix_memalign"), None);
    }

    #[test]
    fn release_classification() {
        assert!(AllocKind::Munmap.is_release());
        assert!(AllocKind::Free.is_release());
        assert!(!AllocKind::Mmap.is_release());
        assert!(!AllocKind::Sbrk.is_release());
    }
}

//! Trace persistence: JSONL (human-greppable) and two binary formats
//! for large traces. Lets users record a workload's event stream once
//! and replay it against many topologies (`cxlmemsim record` /
//! `--trace` on `run`), mirroring how the real tool would archive PEBS
//! + eBPF captures.
//!
//! - **v1** (`CXLTRC\0\x01`): flat count-prefixed record stream. Kept
//!   for compatibility; readable but no longer written by default.
//! - **v2** (`CXLTRC\0\x02`): chunked + run-length encoded, with a
//!   fixed-size chunk directory and a trailing footer. This is what
//!   `record` emits and what `trace::stream::TraceStream` replays with
//!   O(chunk) memory. Layout:
//!
//!   ```text
//!   [8 B magic][chunk payloads, back to back]
//!   [directory: per chunk u64 offset, u64 bytes, u64 events  (24 B)]
//!   [footer: u64 dir_offset, u64 chunk_count, u64 total_events,
//!            u64 total_accesses, 8 B footer magic            (40 B)]
//!   ```
//!
//!   The footer lives at the *end* so the writer never seeks (works on
//!   pipes); readers locate the directory from the last 40 bytes. The
//!   directory is fixed-stride, so seek and sharded fan-out need no
//!   serial parse of payloads.

use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};

use super::{Access, AllocEvent, AllocKind, WlEvent};
use crate::util::json::Json;

/// Magic header for the flat v1 binary format (version byte at the end).
pub const MAGIC_V1: &[u8; 8] = b"CXLTRC\x00\x01";
/// Magic header for the chunked RLE v2 binary format.
pub const MAGIC_V2: &[u8; 8] = b"CXLTRC\x00\x02";
/// Trailing magic closing a finished v2 file; its absence means the
/// recording was interrupted before `V2Writer::finish`.
const FOOTER_MAGIC: &[u8; 8] = b"CXLTRCE\x02";
const FOOTER_LEN: u64 = 40;
const DIR_ENTRY_LEN: u64 = 24;

/// Default events per v2 chunk: big enough that run coalescing and the
/// decode-ahead handoff amortize, small enough that three chunks in
/// flight stay a few MB of decoded events.
pub const V2_DEFAULT_CHUNK_EVENTS: usize = 65_536;
/// Upper bound on events per chunk accepted by writer and reader. The
/// reader sizes decode buffers from directory event counts, so an
/// unbounded (corrupt) count would be an OOM instead of an error.
pub const V2_MAX_CHUNK_EVENTS: usize = 1 << 24;
/// Accesses needed before a run record (21 B) beats singles (9 B each).
const MIN_RUN: usize = 4;

/// Which on-disk trace format a file prefix announces. JSONL has no
/// magic, so anything that is neither v1 nor v2 falls through to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    Jsonl,
    V1,
    V2,
}

pub fn detect_format(head: &[u8]) -> TraceFormat {
    if head.len() >= 8 && &head[..8] == MAGIC_V1 {
        TraceFormat::V1
    } else if head.len() >= 8 && &head[..8] == MAGIC_V2 {
        TraceFormat::V2
    } else {
        TraceFormat::Jsonl
    }
}

// ---------------------------------------------------------------- JSONL

pub fn write_jsonl<W: Write>(w: &mut W, events: &[WlEvent]) -> std::io::Result<()> {
    let mut bw = BufWriter::new(w);
    write_jsonl_events(&mut bw, events)?;
    bw.flush()
}

/// Append events to an already-buffered JSONL writer without flushing —
/// the incremental half of `write_jsonl`, used by the streaming
/// recorder so a multi-GB capture never materializes in memory.
pub fn write_jsonl_events<W: Write>(bw: &mut W, events: &[WlEvent]) -> std::io::Result<()> {
    for ev in events {
        let line = match ev {
            WlEvent::Alloc(a) => format!(
                r#"{{"ev":"alloc","kind":"{}","addr":{},"len":{},"t_ns":{}}}"#,
                a.kind.as_str(),
                a.addr,
                a.len,
                a.t_ns
            ),
            WlEvent::Access(a) => format!(
                r#"{{"ev":"access","addr":{},"w":{}}}"#,
                a.addr,
                if a.is_write { 1 } else { 0 }
            ),
        };
        bw.write_all(line.as_bytes())?;
        bw.write_all(b"\n")?;
    }
    Ok(())
}

/// A required numeric field: missing or mistyped is a line-numbered
/// error, never a silent zero (a corrupt line must not become a
/// plausible-looking access at address 0).
fn req_f64(v: &Json, key: &str, line: usize) -> Result<f64, String> {
    v.get(key)
        .ok_or_else(|| format!("line {line}: missing `{key}`"))?
        .as_f64()
        .ok_or_else(|| format!("line {line}: `{key}` is not a number"))
}

pub fn read_jsonl<R: Read>(r: R) -> Result<Vec<WlEvent>, String> {
    let br = BufReader::new(r);
    let mut out = Vec::new();
    for (i, line) in br.lines().enumerate() {
        let n = i + 1;
        let line = line.map_err(|e| format!("line {n}: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| format!("line {n}: {e}"))?;
        let ev = v
            .get("ev")
            .and_then(|x| x.as_str())
            .ok_or_else(|| format!("line {n}: missing ev"))?;
        match ev {
            "alloc" => {
                let kind = v
                    .get("kind")
                    .and_then(|x| x.as_str())
                    .and_then(AllocKind::parse)
                    .ok_or_else(|| format!("line {n}: bad kind"))?;
                out.push(WlEvent::Alloc(AllocEvent {
                    kind,
                    addr: req_f64(&v, "addr", n)? as u64,
                    len: req_f64(&v, "len", n)? as u64,
                    t_ns: req_f64(&v, "t_ns", n)?,
                }));
            }
            "access" => {
                out.push(WlEvent::Access(Access {
                    addr: req_f64(&v, "addr", n)? as u64,
                    is_write: req_f64(&v, "w", n)? != 0.0,
                }));
            }
            other => return Err(format!("line {n}: unknown ev `{other}`")),
        }
    }
    Ok(out)
}

// --------------------------------------------------------------- binary

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(b: &[u8], off: &mut usize) -> Result<u64, String> {
    let end = *off + 8;
    if end > b.len() {
        return Err("truncated trace".into());
    }
    let v = u64::from_le_bytes(b[*off..end].try_into().unwrap());
    *off = end;
    Ok(v)
}

fn get_u32(b: &[u8], off: &mut usize) -> Result<u32, String> {
    let end = *off + 4;
    if end > b.len() {
        return Err("truncated trace".into());
    }
    let v = u32::from_le_bytes(b[*off..end].try_into().unwrap());
    *off = end;
    Ok(v)
}

fn put_v1_event(buf: &mut Vec<u8>, ev: &WlEvent) {
    match ev {
        WlEvent::Access(a) => {
            buf.push(if a.is_write { 1 } else { 0 });
            put_u64(buf, a.addr);
        }
        WlEvent::Alloc(a) => {
            buf.push(2);
            buf.push(a.kind as u8);
            put_u64(buf, a.addr);
            put_u64(buf, a.len);
            buf.extend_from_slice(&a.t_ns.to_le_bytes());
        }
    }
}

/// v1 binary layout: MAGIC_V1, u64 count, then per event:
///   tag u8 (0=access-read, 1=access-write, 2=alloc)
///   access: u64 addr
///   alloc:  u8 kind, u64 addr, u64 len, f64 t_ns
///
/// Streams through a `BufWriter` in bounded slabs — never buffers the
/// whole serialized trace (it used to build one O(trace) `Vec<u8>`).
pub fn write_binary<W: Write>(w: &mut W, events: &[WlEvent]) -> std::io::Result<()> {
    const SLAB_EVENTS: usize = 4096;
    let mut bw = BufWriter::with_capacity(1 << 16, w);
    bw.write_all(MAGIC_V1)?;
    bw.write_all(&(events.len() as u64).to_le_bytes())?;
    let mut slab = Vec::with_capacity(SLAB_EVENTS * 26);
    for part in events.chunks(SLAB_EVENTS) {
        slab.clear();
        for ev in part {
            put_v1_event(&mut slab, ev);
        }
        bw.write_all(&slab)?;
    }
    bw.flush()
}

fn kind_from_u8(k: u8) -> Result<AllocKind, String> {
    Ok(match k {
        0 => AllocKind::Mmap,
        1 => AllocKind::Munmap,
        2 => AllocKind::Sbrk,
        3 => AllocKind::Brk,
        4 => AllocKind::Malloc,
        5 => AllocKind::Calloc,
        6 => AllocKind::Free,
        _ => return Err(format!("bad alloc kind {k}")),
    })
}

pub fn read_binary(b: &[u8]) -> Result<Vec<WlEvent>, String> {
    if b.len() < 16 || &b[..8] != MAGIC_V1 {
        return Err("not a CXLTRC trace (bad magic)".into());
    }
    let mut off = 8;
    let n = get_u64(b, &mut off)? as usize;
    // the count is untrusted input: never preallocate more than the
    // byte stream could possibly hold (smallest event = 9 bytes) —
    // found by the corrupt-trace fuzz test in rust/tests/failures.rs
    if n > (b.len() - off) / 9 + 1 {
        return Err(format!("event count {n} exceeds trace size {}", b.len()));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // every failure below names the record index and the byte
        // offset the record started at, so a corrupt archive points
        // straight at the damage instead of a bare "truncated trace"
        let start = off;
        let ctx = |err: String| format!("record {i} of {n} at byte {start}: {err}");
        if off >= b.len() {
            return Err(ctx("truncated trace".into()));
        }
        let tag = b[off];
        off += 1;
        match tag {
            0 | 1 => {
                let addr = get_u64(b, &mut off).map_err(&ctx)?;
                out.push(WlEvent::Access(Access { addr, is_write: tag == 1 }));
            }
            2 => {
                if off >= b.len() {
                    return Err(ctx("truncated trace".into()));
                }
                let kind = kind_from_u8(b[off]).map_err(&ctx)?;
                off += 1;
                let addr = get_u64(b, &mut off).map_err(&ctx)?;
                let len = get_u64(b, &mut off).map_err(&ctx)?;
                let end = off + 8;
                if end > b.len() {
                    return Err(ctx("truncated trace".into()));
                }
                let t_ns = f64::from_le_bytes(b[off..end].try_into().unwrap());
                off = end;
                out.push(WlEvent::Alloc(AllocEvent { kind, addr, len, t_ns }));
            }
            t => return Err(ctx(format!("bad tag {t}"))),
        }
    }
    Ok(out)
}

// ------------------------------------------------- binary v2 (chunked)

/// One chunk directory entry: where the chunk's encoded payload lives
/// and how many events it decodes to. Fixed 24-byte wire size, so the
/// directory is random-access — sharded readers can pick chunk ranges
/// without parsing any payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkEntry {
    /// Absolute byte offset of the encoded payload in the file.
    pub offset: u64,
    /// Encoded payload length in bytes.
    pub bytes: u64,
    /// Number of events the payload decodes to.
    pub events: u64,
}

/// Totals reported by `V2Writer::finish`.
#[derive(Clone, Copy, Debug)]
pub struct V2Summary {
    pub events: u64,
    pub accesses: u64,
    pub chunks: u64,
}

/// RLE-encode one chunk of events into `out`. Record vocabulary:
///   tag 0/1: single read/write access — u64 addr                (9 B)
///   tag 2:   alloc — u8 kind, u64 addr, u64 len, f64 t_ns      (26 B)
///   tag 3/4: read/write run — u64 start, u64 stride (wrapping
///            delta, so negative strides are just large u64s),
///            u32 count                                         (21 B)
/// A run needs `MIN_RUN` same-rw constant-stride accesses to pay for
/// itself; shorter candidates emit one single and retry at the next
/// event (so a run starting one event later is still found). Decode
/// recovers addresses via wrapping adds — exact for every u64 pattern,
/// including zero and "negative" strides and wraps past `u64::MAX`.
/// Returns the number of access events (runs expanded) for the footer.
pub fn encode_chunk(events: &[WlEvent], out: &mut Vec<u8>) -> u64 {
    let mut accesses = 0u64;
    let mut i = 0usize;
    while i < events.len() {
        match events[i] {
            WlEvent::Alloc(_) => {
                put_v1_event(out, &events[i]);
                i += 1;
            }
            WlEvent::Access(a) => {
                // longest prefix of same-rw accesses with one wrapping stride
                let mut n = 1usize;
                let mut stride = 0u64;
                let mut prev = a.addr;
                while i + n < events.len() && n < u32::MAX as usize {
                    let WlEvent::Access(b) = events[i + n] else { break };
                    if b.is_write != a.is_write {
                        break;
                    }
                    let d = b.addr.wrapping_sub(prev);
                    if n == 1 {
                        stride = d;
                    } else if d != stride {
                        break;
                    }
                    prev = b.addr;
                    n += 1;
                }
                if n >= MIN_RUN {
                    out.push(if a.is_write { 4 } else { 3 });
                    put_u64(out, a.addr);
                    put_u64(out, stride);
                    out.extend_from_slice(&(n as u32).to_le_bytes());
                    accesses += n as u64;
                    i += n;
                } else {
                    out.push(if a.is_write { 1 } else { 0 });
                    put_u64(out, a.addr);
                    accesses += 1;
                    i += 1;
                }
            }
        }
    }
    accesses
}

/// Decode one chunk payload, appending to `out`. Every failure names
/// the chunk index and the absolute byte offset of the damaged record.
/// The directory's event count is enforced both mid-decode (a corrupt
/// run length cannot balloon the buffer) and at the end.
pub fn decode_chunk(
    payload: &[u8],
    events: u64,
    chunk: usize,
    chunk_offset: u64,
    out: &mut Vec<WlEvent>,
) -> Result<(), String> {
    let base = out.len();
    let mut off = 0usize;
    while off < payload.len() {
        let start = off;
        let ctx =
            |err: String| format!("chunk {chunk} at byte {}: {err}", chunk_offset + start as u64);
        let tag = payload[off];
        off += 1;
        match tag {
            0 | 1 => {
                let addr = get_u64(payload, &mut off).map_err(&ctx)?;
                out.push(WlEvent::Access(Access { addr, is_write: tag == 1 }));
            }
            2 => {
                if off >= payload.len() {
                    return Err(ctx("truncated chunk".into()));
                }
                let kind = kind_from_u8(payload[off]).map_err(&ctx)?;
                off += 1;
                let addr = get_u64(payload, &mut off).map_err(&ctx)?;
                let len = get_u64(payload, &mut off).map_err(&ctx)?;
                let end = off + 8;
                if end > payload.len() {
                    return Err(ctx("truncated chunk".into()));
                }
                let t_ns = f64::from_le_bytes(payload[off..end].try_into().unwrap());
                off = end;
                out.push(WlEvent::Alloc(AllocEvent { kind, addr, len, t_ns }));
            }
            3 | 4 => {
                let first = get_u64(payload, &mut off).map_err(&ctx)?;
                let stride = get_u64(payload, &mut off).map_err(&ctx)?;
                let count = get_u32(payload, &mut off).map_err(&ctx)?;
                if count == 0 {
                    return Err(ctx("zero-length run".into()));
                }
                let decoded = (out.len() - base) as u64;
                if decoded + count as u64 > events {
                    return Err(ctx(format!(
                        "run of {count} overflows chunk event count {events}"
                    )));
                }
                let is_write = tag == 4;
                let mut addr = first;
                for _ in 0..count {
                    out.push(WlEvent::Access(Access { addr, is_write }));
                    addr = addr.wrapping_add(stride);
                }
            }
            t => return Err(ctx(format!("bad tag {t}"))),
        }
        if (out.len() - base) as u64 > events {
            return Err(ctx(format!(
                "payload decodes past directory event count {events}"
            )));
        }
    }
    let decoded = (out.len() - base) as u64;
    if decoded != events {
        return Err(format!(
            "chunk {chunk} at byte {chunk_offset}: decoded {decoded} events, directory says {events}"
        ));
    }
    Ok(())
}

/// Streaming CXLTRC v2 writer: buffers at most `chunk_events` pending
/// events (O(chunk) memory), RLE-encodes each full chunk straight into
/// the underlying writer, and appends the directory + footer on
/// `finish`. Never seeks, so it works on pipes.
pub struct V2Writer<W: Write> {
    w: BufWriter<W>,
    pending: Vec<WlEvent>,
    chunk_events: usize,
    dir: Vec<ChunkEntry>,
    offset: u64,
    total_events: u64,
    total_accesses: u64,
    enc: Vec<u8>,
}

impl<W: Write> V2Writer<W> {
    pub fn new(w: W) -> std::io::Result<V2Writer<W>> {
        V2Writer::with_chunk_events(w, V2_DEFAULT_CHUNK_EVENTS)
    }

    pub fn with_chunk_events(w: W, chunk_events: usize) -> std::io::Result<V2Writer<W>> {
        let chunk_events = chunk_events.clamp(1, V2_MAX_CHUNK_EVENTS);
        let mut bw = BufWriter::with_capacity(1 << 16, w);
        bw.write_all(MAGIC_V2)?;
        Ok(V2Writer {
            w: bw,
            pending: Vec::new(),
            chunk_events,
            dir: Vec::new(),
            offset: 8,
            total_events: 0,
            total_accesses: 0,
            enc: Vec::new(),
        })
    }

    pub fn push(&mut self, ev: WlEvent) -> std::io::Result<()> {
        self.pending.push(ev);
        if self.pending.len() >= self.chunk_events {
            self.flush_chunk()?;
        }
        Ok(())
    }

    pub fn push_slice(&mut self, events: &[WlEvent]) -> std::io::Result<()> {
        for &ev in events {
            self.push(ev)?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> std::io::Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        self.enc.clear();
        let accesses = encode_chunk(&self.pending, &mut self.enc);
        self.w.write_all(&self.enc)?;
        self.dir.push(ChunkEntry {
            offset: self.offset,
            bytes: self.enc.len() as u64,
            events: self.pending.len() as u64,
        });
        self.offset += self.enc.len() as u64;
        self.total_events += self.pending.len() as u64;
        self.total_accesses += accesses;
        self.pending.clear();
        Ok(())
    }

    /// Flush the tail chunk, append directory + footer, return totals.
    /// Dropping a `V2Writer` without `finish` leaves an unreadable
    /// file (no footer) by design — an interrupted recording must not
    /// pass for a complete one.
    pub fn finish(mut self) -> std::io::Result<V2Summary> {
        self.flush_chunk()?;
        let dir_offset = self.offset;
        for c in &self.dir {
            self.w.write_all(&c.offset.to_le_bytes())?;
            self.w.write_all(&c.bytes.to_le_bytes())?;
            self.w.write_all(&c.events.to_le_bytes())?;
        }
        self.w.write_all(&dir_offset.to_le_bytes())?;
        self.w.write_all(&(self.dir.len() as u64).to_le_bytes())?;
        self.w.write_all(&self.total_events.to_le_bytes())?;
        self.w.write_all(&self.total_accesses.to_le_bytes())?;
        self.w.write_all(FOOTER_MAGIC)?;
        self.w.flush()?;
        Ok(V2Summary {
            events: self.total_events,
            accesses: self.total_accesses,
            chunks: self.dir.len() as u64,
        })
    }
}

/// One-shot v2 write of an in-memory event list (tests, small traces).
pub fn write_binary_v2<W: Write>(w: &mut W, events: &[WlEvent]) -> std::io::Result<V2Summary> {
    write_binary_v2_chunked(w, events, V2_DEFAULT_CHUNK_EVENTS)
}

pub fn write_binary_v2_chunked<W: Write>(
    w: &mut W,
    events: &[WlEvent],
    chunk_events: usize,
) -> std::io::Result<V2Summary> {
    let mut v2 = V2Writer::with_chunk_events(w, chunk_events)?;
    v2.push_slice(events)?;
    v2.finish()
}

/// The validated chunk directory of a v2 trace.
#[derive(Clone, Debug)]
pub struct V2Index {
    pub chunks: Vec<ChunkEntry>,
    pub total_events: u64,
    pub total_accesses: u64,
}

impl V2Index {
    pub fn max_chunk_events(&self) -> u64 {
        self.chunks.iter().map(|c| c.events).max().unwrap_or(0)
    }

    /// Parse and validate the directory from any seekable source (a
    /// `File` for streaming, a `Cursor` for in-memory). Validation is
    /// total — magic, footer magic, the exact file-length equation,
    /// contiguous in-bounds chunk extents, plausible per-chunk event
    /// counts, and the event-count sum — so downstream decode can
    /// slice payloads without rechecking bounds.
    pub fn read<R: Read + Seek>(r: &mut R) -> Result<V2Index, String> {
        let io = |e: std::io::Error| format!("reading v2 trace: {e}");
        let file_len = r.seek(SeekFrom::End(0)).map_err(io)?;
        if file_len < 8 + FOOTER_LEN {
            return Err("not a CXLTRC v2 trace (too short)".into());
        }
        let mut magic = [0u8; 8];
        r.seek(SeekFrom::Start(0)).map_err(io)?;
        r.read_exact(&mut magic).map_err(io)?;
        if &magic != MAGIC_V2 {
            return Err("not a CXLTRC v2 trace (bad magic)".into());
        }
        let mut foot = [0u8; FOOTER_LEN as usize];
        r.seek(SeekFrom::Start(file_len - FOOTER_LEN)).map_err(io)?;
        r.read_exact(&mut foot).map_err(io)?;
        if &foot[32..40] != FOOTER_MAGIC {
            return Err("bad v2 footer magic (recording interrupted or file truncated?)".into());
        }
        let word = |i: usize| u64::from_le_bytes(foot[i * 8..i * 8 + 8].try_into().unwrap());
        let (dir_offset, chunk_count, total_events, total_accesses) =
            (word(0), word(1), word(2), word(3));
        let dir_bytes =
            chunk_count.checked_mul(DIR_ENTRY_LEN).ok_or("v2 directory size overflows")?;
        if dir_offset < 8
            || dir_offset.checked_add(dir_bytes).and_then(|v| v.checked_add(FOOTER_LEN))
                != Some(file_len)
        {
            return Err(format!(
                "v2 directory does not fit: {chunk_count} chunks at byte {dir_offset} vs file length {file_len}"
            ));
        }
        let mut raw = vec![0u8; dir_bytes as usize];
        r.seek(SeekFrom::Start(dir_offset)).map_err(io)?;
        r.read_exact(&mut raw).map_err(io)?;
        let mut chunks = Vec::with_capacity(chunk_count as usize);
        let mut expected = 8u64;
        let mut events_sum = 0u64;
        for i in 0..chunk_count as usize {
            let e = &raw[i * DIR_ENTRY_LEN as usize..(i + 1) * DIR_ENTRY_LEN as usize];
            let entry = ChunkEntry {
                offset: u64::from_le_bytes(e[0..8].try_into().unwrap()),
                bytes: u64::from_le_bytes(e[8..16].try_into().unwrap()),
                events: u64::from_le_bytes(e[16..24].try_into().unwrap()),
            };
            let end = entry.offset.checked_add(entry.bytes);
            if entry.offset != expected || end.is_none() || end.unwrap() > dir_offset {
                return Err(format!(
                    "chunk {i} at byte {}: extent of {} bytes out of place (expected offset {expected}, payloads end at {dir_offset})",
                    entry.offset, entry.bytes
                ));
            }
            if entry.events as usize > V2_MAX_CHUNK_EVENTS {
                return Err(format!(
                    "chunk {i} at byte {}: implausible event count {}",
                    entry.offset, entry.events
                ));
            }
            expected = end.unwrap();
            events_sum = events_sum.saturating_add(entry.events);
            chunks.push(entry);
        }
        if expected != dir_offset {
            return Err(format!(
                "chunk payloads end at byte {expected} but directory starts at {dir_offset}"
            ));
        }
        if events_sum != total_events {
            return Err(format!(
                "directory event counts sum to {events_sum} but footer says {total_events}"
            ));
        }
        Ok(V2Index { chunks, total_events, total_accesses })
    }
}

/// In-memory v2 read: validate the directory, then decode every chunk.
/// `trace::stream::TraceStream` is the O(chunk) alternative.
pub fn read_binary_v2(b: &[u8]) -> Result<Vec<WlEvent>, String> {
    let mut cur = std::io::Cursor::new(b);
    let idx = V2Index::read(&mut cur)?;
    let mut out = Vec::with_capacity((idx.total_events as usize).min(V2_MAX_CHUNK_EVENTS));
    for (i, c) in idx.chunks.iter().enumerate() {
        let payload = &b[c.offset as usize..(c.offset + c.bytes) as usize];
        decode_chunk(payload, c.events, i, c.offset, &mut out)?;
    }
    Ok(out)
}

/// Dispatch an in-memory binary trace on its magic (v1 or v2). JSONL
/// has no magic; callers that accept it should sniff for it first
/// (`detect_format`).
pub fn read_binary_any(b: &[u8]) -> Result<Vec<WlEvent>, String> {
    if detect_format(b) == TraceFormat::V2 {
        read_binary_v2(b)
    } else {
        read_binary(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<WlEvent> {
        vec![
            WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Mmap,
                addr: 0x7000_0000,
                len: 4096,
                t_ns: 12.5,
            }),
            WlEvent::Access(Access { addr: 0x7000_0040, is_write: false }),
            WlEvent::Access(Access { addr: 0x7000_0080, is_write: true }),
            WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Free,
                addr: 0x7000_0000,
                len: 4096,
                t_ns: 99.0,
            }),
        ]
    }

    fn assert_equal(a: &[WlEvent], b: &[WlEvent]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            match (x, y) {
                (WlEvent::Access(p), WlEvent::Access(q)) => {
                    assert_eq!(p.addr, q.addr);
                    assert_eq!(p.is_write, q.is_write);
                }
                (WlEvent::Alloc(p), WlEvent::Alloc(q)) => {
                    assert_eq!(p.kind, q.kind);
                    assert_eq!(p.addr, q.addr);
                    assert_eq!(p.len, q.len);
                    assert!((p.t_ns - q.t_ns).abs() < 1e-12);
                }
                _ => panic!("event kind mismatch"),
            }
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_jsonl(&mut buf, &evs).unwrap();
        let back = read_jsonl(&buf[..]).unwrap();
        assert_equal(&evs, &back);
    }

    #[test]
    fn binary_roundtrip() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        let back = read_binary(&buf).unwrap();
        assert_equal(&evs, &back);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        assert!(read_binary(b"NOTATRACE_______").is_err());
        assert!(read_binary(b"short").is_err());
    }

    #[test]
    fn binary_rejects_truncation() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        for cut in [17, buf.len() - 3] {
            assert!(read_binary(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn binary_errors_name_record_and_byte_offset() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        // layout: 16-byte header, alloc (26 B) at 16, reads (9 B) at
        // 42 and 51, alloc at 60 — cutting the tail lands inside
        // record 3, which started at byte 60
        let err = read_binary(&buf[..buf.len() - 3]).unwrap_err();
        assert!(err.contains("record 3 of 4"), "{err}");
        assert!(err.contains("at byte 60"), "{err}");
        // corrupt record 1's tag in place
        let mut bad = buf.clone();
        bad[42] = 9;
        let err = read_binary(&bad).unwrap_err();
        assert!(err.contains("record 1 of 4"), "{err}");
        assert!(err.contains("at byte 42"), "{err}");
        assert!(err.contains("bad tag 9"), "{err}");
    }

    #[test]
    fn binary_bad_alloc_kind_names_record() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        buf[17] = 250; // record 0 is an alloc; its kind byte is 17
        let err = read_binary(&buf).unwrap_err();
        assert!(err.contains("record 0 of 4"), "{err}");
        assert!(err.contains("at byte 16"), "{err}");
        assert!(err.contains("bad alloc kind 250"), "{err}");
    }

    #[test]
    fn jsonl_skips_blank_lines() {
        let src = "\n\n{\"ev\":\"access\",\"addr\":64,\"w\":1}\n\n";
        let evs = read_jsonl(src.as_bytes()).unwrap();
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn jsonl_rejects_unknown_event() {
        let src = "{\"ev\":\"mystery\"}\n";
        assert!(read_jsonl(src.as_bytes()).is_err());
    }

    #[test]
    fn jsonl_missing_and_mistyped_fields_are_line_errors() {
        for (src, needle) in [
            ("{\"ev\":\"access\",\"w\":1}", "addr"),
            ("{\"ev\":\"access\",\"addr\":\"x\",\"w\":1}", "addr"),
            ("{\"ev\":\"access\",\"addr\":64}", "w"),
            ("{\"ev\":\"alloc\",\"kind\":\"mmap\",\"len\":4,\"t_ns\":0}", "addr"),
            ("{\"ev\":\"alloc\",\"kind\":\"mmap\",\"addr\":4,\"t_ns\":0}", "len"),
            ("{\"ev\":\"alloc\",\"kind\":\"mmap\",\"addr\":4,\"len\":4}", "t_ns"),
        ] {
            let err = read_jsonl(src.as_bytes()).unwrap_err();
            assert!(err.contains("line 1"), "{src}: {err}");
            assert!(err.contains(needle), "{src}: {err}");
        }
        // a later line reports its own number
        let src = "{\"ev\":\"access\",\"addr\":64,\"w\":0}\n{\"ev\":\"access\",\"w\":0}\n";
        let err = read_jsonl(src.as_bytes()).unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_traces_roundtrip() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &[]).unwrap();
        assert_eq!(read_binary(&buf).unwrap().len(), 0);
        let mut jbuf = Vec::new();
        write_jsonl(&mut jbuf, &[]).unwrap();
        assert_eq!(read_jsonl(&jbuf[..]).unwrap().len(), 0);
    }

    // ------------------------------------------------------------- v2

    fn roundtrip_v2(evs: &[WlEvent], chunk: usize) -> Vec<WlEvent> {
        let mut buf = Vec::new();
        write_binary_v2_chunked(&mut buf, evs, chunk).unwrap();
        read_binary_v2(&buf).unwrap()
    }

    /// Deterministic LCG event stream mixing runs (forward, backward,
    /// zero-stride), random singles, and allocs.
    fn mixed_stream(seed: u64, n: usize) -> Vec<WlEvent> {
        let mut s = seed | 1;
        let mut step = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            s >> 33
        };
        let mut evs = Vec::new();
        while evs.len() < n {
            match step() % 5 {
                0 => {
                    let start = step();
                    let stride = [64i64, -64, 0, 4096, -1][(step() % 5) as usize] as u64;
                    let count = 1 + (step() % 9) as usize;
                    let w = step() % 2 == 1;
                    let mut a = start;
                    for _ in 0..count {
                        evs.push(WlEvent::Access(Access { addr: a, is_write: w }));
                        a = a.wrapping_add(stride);
                    }
                }
                1 => evs.push(WlEvent::Alloc(AllocEvent {
                    kind: kind_from_u8((step() % 7) as u8).unwrap(),
                    addr: step(),
                    len: step() % (1 << 30),
                    t_ns: (step() % 1000) as f64,
                })),
                _ => evs.push(WlEvent::Access(Access {
                    addr: step(),
                    is_write: step() % 2 == 0,
                })),
            }
        }
        evs.truncate(n);
        evs
    }

    #[test]
    fn v2_roundtrip_small() {
        let evs = sample_events();
        for chunk in [1, 2, 3, 64] {
            assert_equal(&evs, &roundtrip_v2(&evs, chunk));
        }
    }

    #[test]
    fn v2_roundtrip_empty_and_single_event() {
        assert_eq!(roundtrip_v2(&[], 8).len(), 0);
        let one = [WlEvent::Access(Access { addr: 640, is_write: true })];
        assert_equal(&one, &roundtrip_v2(&one, 8));
    }

    #[test]
    fn v2_roundtrip_property_runs_cross_chunk_boundaries() {
        for seed in [3, 7, 11] {
            let evs = mixed_stream(seed, 3000);
            for chunk in [1, 7, 64, 1 << 12] {
                assert_equal(&evs, &roundtrip_v2(&evs, chunk));
            }
        }
    }

    #[test]
    fn v2_long_run_compresses() {
        // one 4096-access stride sweep: RLE makes the file tiny
        let evs: Vec<WlEvent> = (0..4096u64)
            .map(|i| WlEvent::Access(Access { addr: 0x1000 + i * 64, is_write: false }))
            .collect();
        let mut buf = Vec::new();
        let sum = write_binary_v2(&mut buf, &evs).unwrap();
        assert_eq!(sum.events, 4096);
        assert_eq!(sum.accesses, 4096);
        assert_eq!(sum.chunks, 1);
        assert!(buf.len() < 128, "RLE failed: {} bytes", buf.len());
    }

    #[test]
    fn v2_negative_and_zero_strides_roundtrip() {
        let mut evs = Vec::new();
        let mut a = u64::MAX - 100;
        for _ in 0..16 {
            evs.push(WlEvent::Access(Access { addr: a, is_write: true }));
            a = a.wrapping_add(64); // wraps past u64::MAX mid-run
        }
        for _ in 0..16 {
            evs.push(WlEvent::Access(Access { addr: 4096, is_write: false })); // zero stride
        }
        let mut b = 1u64 << 40;
        for _ in 0..16 {
            evs.push(WlEvent::Access(Access { addr: b, is_write: false }));
            b = b.wrapping_sub(4096); // negative stride
        }
        assert_equal(&evs, &roundtrip_v2(&evs, 5));
        assert_equal(&evs, &roundtrip_v2(&evs, 4096));
    }

    #[test]
    fn v2_rejects_truncation_and_bad_magic() {
        let evs = mixed_stream(1, 300);
        let mut buf = Vec::new();
        write_binary_v2_chunked(&mut buf, &evs, 32).unwrap();
        assert!(read_binary_v2(&buf).is_ok());
        for cut in [0, 4, 8, 20, buf.len() - 39, buf.len() - 1] {
            assert!(read_binary_v2(&buf[..cut]).is_err(), "cut {cut}");
        }
        let mut bad = buf.clone();
        bad[7] = 1; // v1 version byte in the magic
        assert!(read_binary_v2(&bad).is_err());
        let n = buf.len();
        let mut bad = buf.clone();
        bad[n - 1] ^= 0xff;
        let err = read_binary_v2(&bad).unwrap_err();
        assert!(err.contains("footer"), "{err}");
    }

    #[test]
    fn v2_corrupt_errors_name_chunk_and_byte() {
        let evs = mixed_stream(2, 200);
        let mut buf = Vec::new();
        write_binary_v2_chunked(&mut buf, &evs, 50).unwrap();
        let idx = V2Index::read(&mut std::io::Cursor::new(&buf[..])).unwrap();
        assert!(idx.chunks.len() >= 3, "want several chunks, got {}", idx.chunks.len());
        // stomp the first record tag of chunk 1
        let off = idx.chunks[1].offset as usize;
        let mut bad = buf.clone();
        bad[off] = 9;
        let err = read_binary_v2(&bad).unwrap_err();
        assert!(err.contains("chunk 1"), "{err}");
        assert!(err.contains(&format!("at byte {off}")), "{err}");
        assert!(err.contains("bad tag 9"), "{err}");
    }

    #[test]
    fn v2_directory_event_mismatch_is_error() {
        let evs = mixed_stream(4, 100);
        let mut buf = Vec::new();
        write_binary_v2_chunked(&mut buf, &evs, 40).unwrap();
        // inflate chunk 0's directory event count and the footer total
        // in lockstep: the payload itself must still be caught lying
        let n = buf.len();
        let dir_offset = u64::from_le_bytes(buf[n - 40..n - 32].try_into().unwrap()) as usize;
        let mut bad = buf.clone();
        let ev_at = dir_offset + 16;
        let cur = u64::from_le_bytes(bad[ev_at..ev_at + 8].try_into().unwrap());
        bad[ev_at..ev_at + 8].copy_from_slice(&(cur + 1).to_le_bytes());
        let tot_at = n - 24;
        let tot = u64::from_le_bytes(bad[tot_at..tot_at + 8].try_into().unwrap());
        bad[tot_at..tot_at + 8].copy_from_slice(&(tot + 1).to_le_bytes());
        let err = read_binary_v2(&bad).unwrap_err();
        assert!(err.contains("chunk 0"), "{err}");
        assert!(err.contains("directory says"), "{err}");
    }

    #[test]
    fn v2_fuzz_bitflips_never_panic() {
        let evs = mixed_stream(9, 400);
        let mut buf = Vec::new();
        write_binary_v2_chunked(&mut buf, &evs, 64).unwrap();
        for i in (0..buf.len()).step_by(7) {
            let mut c = buf.clone();
            c[i] ^= 0xff;
            let _ = read_binary_v2(&c); // must not panic
        }
        for cut in 0..buf.len().min(80) {
            let _ = read_binary_v2(&buf[..cut]);
        }
    }

    #[test]
    fn read_binary_any_dispatches_on_magic() {
        let evs = sample_events();
        let mut v1 = Vec::new();
        write_binary(&mut v1, &evs).unwrap();
        assert_equal(&evs, &read_binary_any(&v1).unwrap());
        let mut v2 = Vec::new();
        write_binary_v2(&mut v2, &evs).unwrap();
        assert_equal(&evs, &read_binary_any(&v2).unwrap());
        assert_eq!(detect_format(&v1), TraceFormat::V1);
        assert_eq!(detect_format(&v2), TraceFormat::V2);
        assert_eq!(detect_format(b"{\"ev\":"), TraceFormat::Jsonl);
    }
}

//! Trace persistence: JSONL (human-greppable) and a compact binary
//! format for large traces. Lets users record a workload's event stream
//! once and replay it against many topologies (`cxlmemsim record` /
//! `--trace` on `run`), mirroring how the real tool would archive PEBS
//! + eBPF captures.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};

use super::{Access, AllocEvent, AllocKind, WlEvent};
use crate::util::json::Json;

/// Magic header for the binary format (version byte at the end).
const MAGIC: &[u8; 8] = b"CXLTRC\x00\x01";

// ---------------------------------------------------------------- JSONL

pub fn write_jsonl<W: Write>(w: &mut W, events: &[WlEvent]) -> std::io::Result<()> {
    let mut bw = BufWriter::new(w);
    for ev in events {
        let line = match ev {
            WlEvent::Alloc(a) => format!(
                r#"{{"ev":"alloc","kind":"{}","addr":{},"len":{},"t_ns":{}}}"#,
                a.kind.as_str(),
                a.addr,
                a.len,
                a.t_ns
            ),
            WlEvent::Access(a) => format!(
                r#"{{"ev":"access","addr":{},"w":{}}}"#,
                a.addr,
                if a.is_write { 1 } else { 0 }
            ),
        };
        bw.write_all(line.as_bytes())?;
        bw.write_all(b"\n")?;
    }
    bw.flush()
}

pub fn read_jsonl<R: Read>(r: R) -> Result<Vec<WlEvent>, String> {
    let br = BufReader::new(r);
    let mut out = Vec::new();
    for (i, line) in br.lines().enumerate() {
        let line = line.map_err(|e| format!("line {}: {e}", i + 1))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| format!("line {}: {e}", i + 1))?;
        let ev = v
            .get("ev")
            .and_then(|x| x.as_str())
            .ok_or_else(|| format!("line {}: missing ev", i + 1))?;
        match ev {
            "alloc" => {
                let kind = v
                    .get("kind")
                    .and_then(|x| x.as_str())
                    .and_then(AllocKind::parse)
                    .ok_or_else(|| format!("line {}: bad kind", i + 1))?;
                out.push(WlEvent::Alloc(AllocEvent {
                    kind,
                    addr: v.get("addr").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                    len: v.get("len").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                    t_ns: v.get("t_ns").and_then(|x| x.as_f64()).unwrap_or(0.0),
                }));
            }
            "access" => {
                out.push(WlEvent::Access(Access {
                    addr: v.get("addr").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                    is_write: v.get("w").and_then(|x| x.as_f64()).unwrap_or(0.0) != 0.0,
                }));
            }
            other => return Err(format!("line {}: unknown ev `{other}`", i + 1)),
        }
    }
    Ok(out)
}

// --------------------------------------------------------------- binary

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(b: &[u8], off: &mut usize) -> Result<u64, String> {
    let end = *off + 8;
    if end > b.len() {
        return Err("truncated trace".into());
    }
    let v = u64::from_le_bytes(b[*off..end].try_into().unwrap());
    *off = end;
    Ok(v)
}

/// Binary layout: MAGIC, u64 count, then per event:
///   tag u8 (0=access-read, 1=access-write, 2=alloc)
///   access: u64 addr
///   alloc:  u8 kind, u64 addr, u64 len, f64 t_ns
pub fn write_binary<W: Write>(w: &mut W, events: &[WlEvent]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(events.len() * 9 + 16);
    buf.extend_from_slice(MAGIC);
    put_u64(&mut buf, events.len() as u64);
    for ev in events {
        match ev {
            WlEvent::Access(a) => {
                buf.push(if a.is_write { 1 } else { 0 });
                put_u64(&mut buf, a.addr);
            }
            WlEvent::Alloc(a) => {
                buf.push(2);
                buf.push(a.kind as u8);
                put_u64(&mut buf, a.addr);
                put_u64(&mut buf, a.len);
                buf.extend_from_slice(&a.t_ns.to_le_bytes());
            }
        }
    }
    w.write_all(&buf)
}

fn kind_from_u8(k: u8) -> Result<AllocKind, String> {
    Ok(match k {
        0 => AllocKind::Mmap,
        1 => AllocKind::Munmap,
        2 => AllocKind::Sbrk,
        3 => AllocKind::Brk,
        4 => AllocKind::Malloc,
        5 => AllocKind::Calloc,
        6 => AllocKind::Free,
        _ => return Err(format!("bad alloc kind {k}")),
    })
}

pub fn read_binary(b: &[u8]) -> Result<Vec<WlEvent>, String> {
    if b.len() < 16 || &b[..8] != MAGIC {
        return Err("not a CXLTRC trace (bad magic)".into());
    }
    let mut off = 8;
    let n = get_u64(b, &mut off)? as usize;
    // the count is untrusted input: never preallocate more than the
    // byte stream could possibly hold (smallest event = 9 bytes) —
    // found by the corrupt-trace fuzz test in rust/tests/failures.rs
    if n > (b.len() - off) / 9 + 1 {
        return Err(format!("event count {n} exceeds trace size {}", b.len()));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // every failure below names the record index and the byte
        // offset the record started at, so a corrupt archive points
        // straight at the damage instead of a bare "truncated trace"
        let start = off;
        let ctx = |err: String| format!("record {i} of {n} at byte {start}: {err}");
        if off >= b.len() {
            return Err(ctx("truncated trace".into()));
        }
        let tag = b[off];
        off += 1;
        match tag {
            0 | 1 => {
                let addr = get_u64(b, &mut off).map_err(&ctx)?;
                out.push(WlEvent::Access(Access { addr, is_write: tag == 1 }));
            }
            2 => {
                if off >= b.len() {
                    return Err(ctx("truncated trace".into()));
                }
                let kind = kind_from_u8(b[off]).map_err(&ctx)?;
                off += 1;
                let addr = get_u64(b, &mut off).map_err(&ctx)?;
                let len = get_u64(b, &mut off).map_err(&ctx)?;
                let end = off + 8;
                if end > b.len() {
                    return Err(ctx("truncated trace".into()));
                }
                let t_ns = f64::from_le_bytes(b[off..end].try_into().unwrap());
                off = end;
                out.push(WlEvent::Alloc(AllocEvent { kind, addr, len, t_ns }));
            }
            t => return Err(ctx(format!("bad tag {t}"))),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<WlEvent> {
        vec![
            WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Mmap,
                addr: 0x7000_0000,
                len: 4096,
                t_ns: 12.5,
            }),
            WlEvent::Access(Access { addr: 0x7000_0040, is_write: false }),
            WlEvent::Access(Access { addr: 0x7000_0080, is_write: true }),
            WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Free,
                addr: 0x7000_0000,
                len: 4096,
                t_ns: 99.0,
            }),
        ]
    }

    fn assert_equal(a: &[WlEvent], b: &[WlEvent]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            match (x, y) {
                (WlEvent::Access(p), WlEvent::Access(q)) => {
                    assert_eq!(p.addr, q.addr);
                    assert_eq!(p.is_write, q.is_write);
                }
                (WlEvent::Alloc(p), WlEvent::Alloc(q)) => {
                    assert_eq!(p.kind, q.kind);
                    assert_eq!(p.addr, q.addr);
                    assert_eq!(p.len, q.len);
                    assert!((p.t_ns - q.t_ns).abs() < 1e-12);
                }
                _ => panic!("event kind mismatch"),
            }
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_jsonl(&mut buf, &evs).unwrap();
        let back = read_jsonl(&buf[..]).unwrap();
        assert_equal(&evs, &back);
    }

    #[test]
    fn binary_roundtrip() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        let back = read_binary(&buf).unwrap();
        assert_equal(&evs, &back);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        assert!(read_binary(b"NOTATRACE_______").is_err());
        assert!(read_binary(b"short").is_err());
    }

    #[test]
    fn binary_rejects_truncation() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        for cut in [17, buf.len() - 3] {
            assert!(read_binary(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn binary_errors_name_record_and_byte_offset() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        // layout: 16-byte header, alloc (26 B) at 16, reads (9 B) at
        // 42 and 51, alloc at 60 — cutting the tail lands inside
        // record 3, which started at byte 60
        let err = read_binary(&buf[..buf.len() - 3]).unwrap_err();
        assert!(err.contains("record 3 of 4"), "{err}");
        assert!(err.contains("at byte 60"), "{err}");
        // corrupt record 1's tag in place
        let mut bad = buf.clone();
        bad[42] = 9;
        let err = read_binary(&bad).unwrap_err();
        assert!(err.contains("record 1 of 4"), "{err}");
        assert!(err.contains("at byte 42"), "{err}");
        assert!(err.contains("bad tag 9"), "{err}");
    }

    #[test]
    fn binary_bad_alloc_kind_names_record() {
        let evs = sample_events();
        let mut buf = Vec::new();
        write_binary(&mut buf, &evs).unwrap();
        buf[17] = 250; // record 0 is an alloc; its kind byte is 17
        let err = read_binary(&buf).unwrap_err();
        assert!(err.contains("record 0 of 4"), "{err}");
        assert!(err.contains("at byte 16"), "{err}");
        assert!(err.contains("bad alloc kind 250"), "{err}");
    }

    #[test]
    fn jsonl_skips_blank_lines() {
        let src = "\n\n{\"ev\":\"access\",\"addr\":64,\"w\":1}\n\n";
        let evs = read_jsonl(src.as_bytes()).unwrap();
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn jsonl_rejects_unknown_event() {
        let src = "{\"ev\":\"mystery\"}\n";
        assert!(read_jsonl(src.as_bytes()).is_err());
    }

    #[test]
    fn empty_traces_roundtrip() {
        let mut buf = Vec::new();
        write_binary(&mut buf, &[]).unwrap();
        assert_eq!(read_binary(&buf).unwrap().len(), 0);
        let mut jbuf = Vec::new();
        write_jsonl(&mut jbuf, &[]).unwrap();
        assert_eq!(read_jsonl(&jbuf[..]).unwrap().len(), 0);
    }
}

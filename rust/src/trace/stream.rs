//! Streaming replay of CXLTRC v2 traces with O(chunk) resident memory.
//!
//! [`TraceStream`] implements [`Workload`] over an on-disk v2 trace:
//! only decoded chunks in flight are resident, never the whole trace,
//! so multi-GB captures replay in a few MB. A decode-ahead thread
//! double-buffers the *next* chunk (seek + read + RLE-decode) while
//! the analyzer consumes the current one, so replay wall-clock
//! approaches max(decode, analyze) instead of their sum.
//!
//! Determinism: the handoff is a rendezvous over a bounded
//! `sync_channel`, not a race — the decoder produces chunks strictly
//! in directory order and the consumer drains them strictly in arrival
//! order, so the event sequence seen by the driver is byte-for-byte
//! the sequence an in-memory `TraceReplay` would emit. Which thread
//! decoded a chunk can never influence a `SimReport`; the determinism
//! matrix (threads × batch-group × scan-kernel) holds unchanged.
//!
//! Memory bound: at most `DECODE_AHEAD_DEPTH + 2` chunks of decoded
//! events exist at once (one being consumed, up to one queued in the
//! channel, one being decoded). The stream counts decoded
//! events-in-flight and records the high-water mark, which tests and
//! the `replay_stream` bench assert against this bound.
//!
//! Sharded replay: the v2 chunk directory makes any chunk an O(1)
//! seek target, so [`TraceStream::open_shard`] replays only chunks
//! `[i·C/N, (i+1)·C/N)` of a C-chunk trace — shard `i` of `N`,
//! 0-based. Shards partition the directory exactly (integer-floor
//! split: every chunk lands in exactly one shard; trailing shards of
//! an N > C split are legitimately empty). Pool/cache state resets
//! per shard, so per-shard miss counts are NOT additive — event and
//! access counts are, which the shard-union tests assert.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::io::{decode_chunk, ChunkEntry, V2Index};
use super::WlEvent;
use crate::workload::Workload;

/// Chunks the decode-ahead thread may queue beyond the one it is
/// decoding: the `sync_channel` bound.
pub const DECODE_AHEAD_DEPTH: usize = 1;

type DecodedChunk = Result<Vec<WlEvent>, String>;

enum Source {
    /// Decode-ahead mode: a named thread owns the file and pushes
    /// decoded chunks through a bounded rendezvous channel.
    Ahead { rx: Option<Receiver<DecodedChunk>>, handle: Option<JoinHandle<()>> },
    /// Inline mode: decode on the consumer thread (bench baseline for
    /// the overlap win, and a fallback if thread spawn ever fails).
    /// `base` is the absolute directory index of `chunks[0]`, so
    /// error messages name the on-disk chunk even under a shard.
    Inline { file: File, chunks: Vec<ChunkEntry>, next: usize, base: usize, buf: Vec<u8> },
}

pub struct TraceStream {
    name: String,
    total_events: u64,
    total_accesses: u64,
    max_chunk_events: u64,
    nchunks: usize,
    /// Absolute chunk range `[chunk_lo, chunk_lo + nchunks)` this
    /// stream serves, and the whole-file totals behind it — equal to
    /// the full directory for an unsharded stream.
    chunk_lo: usize,
    file_chunks: usize,
    event_lo: u64,
    file_events: u64,
    /// Decoded events of the chunk currently being consumed.
    cur: Vec<WlEvent>,
    pos: usize,
    src: Source,
    /// Decoded events alive right now across consumer + channel +
    /// decoder, and the high-water mark — the O(chunk) proof.
    in_flight: Arc<AtomicU64>,
    peak_in_flight: Arc<AtomicU64>,
    error: Option<String>,
    done: bool,
}

fn read_and_decode(
    file: &mut File,
    entry: &ChunkEntry,
    idx: usize,
    buf: &mut Vec<u8>,
) -> DecodedChunk {
    buf.clear();
    buf.resize(entry.bytes as usize, 0);
    file.seek(SeekFrom::Start(entry.offset))
        .map_err(|e| format!("chunk {idx} at byte {}: seek: {e}", entry.offset))?;
    file.read_exact(buf)
        .map_err(|e| format!("chunk {idx} at byte {}: {e}", entry.offset))?;
    let mut out = Vec::with_capacity(entry.events as usize);
    decode_chunk(buf, entry.events, idx, entry.offset, &mut out)?;
    Ok(out)
}

fn note_in_flight(events: usize, in_flight: &AtomicU64, peak: &AtomicU64) {
    let now = in_flight.fetch_add(events as u64, Ordering::SeqCst) + events as u64;
    peak.fetch_max(now, Ordering::SeqCst);
}

impl TraceStream {
    /// Open a v2 trace for streaming replay with decode-ahead.
    pub fn open(path: &str) -> Result<TraceStream, String> {
        TraceStream::open_with(path, true)
    }

    /// `decode_ahead = false` decodes inline on the consumer thread —
    /// same events, no overlap; the bench uses it as the baseline that
    /// quantifies the decode-ahead win.
    pub fn open_with(path: &str, decode_ahead: bool) -> Result<TraceStream, String> {
        TraceStream::open_inner(path, decode_ahead, None)
    }

    /// Open shard `i` of `n` (0-based): chunks `[i·C/N, (i+1)·C/N)` of
    /// the directory, seeked to in O(1). Errors on `n == 0` or
    /// `i >= n`; an empty shard (more shards than chunks) opens fine
    /// and replays zero events.
    pub fn open_shard(path: &str, i: usize, n: usize) -> Result<TraceStream, String> {
        TraceStream::open_inner(path, true, Some((i, n)))
    }

    /// [`open_shard`](TraceStream::open_shard) with an explicit
    /// decode-ahead switch (tests cover both source modes).
    pub fn open_shard_with(
        path: &str,
        decode_ahead: bool,
        i: usize,
        n: usize,
    ) -> Result<TraceStream, String> {
        TraceStream::open_inner(path, decode_ahead, Some((i, n)))
    }

    fn open_inner(
        path: &str,
        decode_ahead: bool,
        shard: Option<(usize, usize)>,
    ) -> Result<TraceStream, String> {
        let mut file = File::open(path).map_err(|e| format!("{path}: {e}"))?;
        let idx = V2Index::read(&mut file).map_err(|e| format!("{path}: {e}"))?;
        let file_chunks = idx.chunks.len();
        let (chunk_lo, chunk_hi, name) = match shard {
            Some((_, 0)) => {
                return Err(format!("{path}: shard count must be >= 1, got N=0"));
            }
            Some((i, n)) if i >= n => {
                return Err(format!(
                    "{path}: shard index {i} out of range for {n} shards (valid: 0..{n})"
                ));
            }
            Some((i, n)) => {
                (i * file_chunks / n, (i + 1) * file_chunks / n, format!("stream:{path}[{i}/{n}]"))
            }
            None => (0, file_chunks, format!("stream:{path}")),
        };
        let shard_chunks: Vec<ChunkEntry> = idx.chunks[chunk_lo..chunk_hi].to_vec();
        let event_lo: u64 = idx.chunks[..chunk_lo].iter().map(|c| c.events).sum();
        let total_events: u64 = shard_chunks.iter().map(|c| c.events).sum();
        // exact for the full file; for a shard the directory doesn't
        // split accesses from allocs, so the hint is the event count
        // (an upper bound — callers only use it for sizing)
        let total_accesses = if shard.is_some() { total_events } else { idx.total_accesses };
        let max_chunk_events = shard_chunks.iter().map(|c| c.events).max().unwrap_or(0);
        let in_flight = Arc::new(AtomicU64::new(0));
        let peak_in_flight = Arc::new(AtomicU64::new(0));
        let nchunks = shard_chunks.len();
        let src = if decode_ahead {
            let (tx, rx) = sync_channel::<DecodedChunk>(DECODE_AHEAD_DEPTH);
            let counters = (in_flight.clone(), peak_in_flight.clone());
            let handle = std::thread::Builder::new()
                .name("cxlms-decode".into())
                .spawn(move || {
                    let mut buf = Vec::new();
                    for (rel, entry) in shard_chunks.iter().enumerate() {
                        // absolute directory index in errors, even
                        // when sharded
                        let decoded = read_and_decode(&mut file, entry, chunk_lo + rel, &mut buf);
                        let failed = decoded.is_err();
                        if let Ok(evs) = &decoded {
                            note_in_flight(evs.len(), &counters.0, &counters.1);
                        }
                        // a send error means the consumer is gone —
                        // stop decoding; a decode error ends the file
                        if tx.send(decoded).is_err() || failed {
                            return;
                        }
                    }
                })
                .map_err(|e| format!("{path}: spawning decode thread: {e}"))?;
            Source::Ahead { rx: Some(rx), handle: Some(handle) }
        } else {
            Source::Inline { file, chunks: shard_chunks, next: 0, base: chunk_lo, buf: Vec::new() }
        };
        Ok(TraceStream {
            name,
            total_events,
            total_accesses,
            max_chunk_events,
            nchunks,
            chunk_lo,
            file_chunks,
            event_lo,
            file_events: idx.total_events,
            cur: Vec::new(),
            pos: 0,
            src,
            in_flight,
            peak_in_flight,
            error: None,
            done: false,
        })
    }

    /// Retire the drained chunk and install the next one. Returns
    /// false at end-of-trace or on a stored decode error.
    fn refill(&mut self) -> bool {
        if !self.cur.is_empty() {
            self.in_flight.fetch_sub(self.cur.len() as u64, Ordering::SeqCst);
            self.cur = Vec::new();
        }
        self.pos = 0;
        if self.done {
            return false;
        }
        loop {
            let next = match &mut self.src {
                Source::Ahead { rx, .. } => match rx.as_ref().expect("receiver alive").recv() {
                    Ok(decoded) => decoded,
                    // decoder exhausted the directory and exited
                    Err(_) => {
                        self.done = true;
                        return false;
                    }
                },
                Source::Inline { file, chunks, next, base, buf } => {
                    if *next >= chunks.len() {
                        self.done = true;
                        return false;
                    }
                    let i = *next;
                    *next += 1;
                    let decoded = read_and_decode(file, &chunks[i], *base + i, buf);
                    if let Ok(evs) = &decoded {
                        note_in_flight(evs.len(), &self.in_flight, &self.peak_in_flight);
                    }
                    decoded
                }
            };
            match next {
                Ok(evs) if evs.is_empty() => continue,
                Ok(evs) => {
                    self.cur = evs;
                    return true;
                }
                Err(e) => {
                    self.error = Some(e);
                    self.done = true;
                    return false;
                }
            }
        }
    }

    /// A decode error surfaced mid-stream. The `Workload` interface
    /// has no error channel, so a damaged chunk ends the stream early
    /// (as exhaustion); callers MUST check this after the run —
    /// `cmd_replay` does — or a truncated replay would pass for a
    /// complete one.
    pub fn take_error(&mut self) -> Option<String> {
        self.error.take()
    }

    pub fn total_events(&self) -> u64 {
        self.total_events
    }

    pub fn chunks(&self) -> usize {
        self.nchunks
    }

    /// Absolute chunk range `[lo, hi)` this stream serves — the whole
    /// directory unless sharded.
    pub fn chunk_range(&self) -> (usize, usize) {
        (self.chunk_lo, self.chunk_lo + self.nchunks)
    }

    /// Absolute event range `[lo, hi)` this stream serves.
    pub fn event_range(&self) -> (u64, u64) {
        (self.event_lo, self.event_lo + self.total_events)
    }

    /// Chunk count of the whole on-disk directory.
    pub fn file_chunks(&self) -> usize {
        self.file_chunks
    }

    /// Event count of the whole on-disk trace.
    pub fn file_events(&self) -> u64 {
        self.file_events
    }

    pub fn max_chunk_events(&self) -> u64 {
        self.max_chunk_events
    }

    /// Decoded events currently resident (all holders).
    pub fn decoded_in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// High-water mark of `decoded_in_flight` — bounded by
    /// `(DECODE_AHEAD_DEPTH + 2) × max_chunk_events`.
    pub fn peak_decoded_in_flight(&self) -> u64 {
        self.peak_in_flight.load(Ordering::SeqCst)
    }
}

impl Workload for TraceStream {
    fn name(&self) -> &str {
        &self.name
    }

    fn next_event(&mut self) -> Option<WlEvent> {
        if self.pos >= self.cur.len() && !self.refill() {
            return None;
        }
        let ev = self.cur[self.pos];
        self.pos += 1;
        Some(ev)
    }

    /// Serves from the resident chunk only — up to
    /// `min(budget, remaining-in-chunk)` events per call. Short pushes
    /// are explicitly allowed by the `Workload` contract; crossing a
    /// chunk boundary waits for the decode-ahead rendezvous on the
    /// next call instead of splicing mid-push.
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        if budget == 0 {
            return self.pos < self.cur.len() || !self.done;
        }
        if self.pos >= self.cur.len() && !self.refill() {
            return false;
        }
        let take = budget.min(self.cur.len() - self.pos);
        sink.extend_from_slice(&self.cur[self.pos..self.pos + take]);
        self.pos += take;
        true
    }

    fn total_accesses_hint(&self) -> u64 {
        self.total_accesses
    }
}

impl Drop for TraceStream {
    fn drop(&mut self) {
        if let Source::Ahead { rx, handle } = &mut self.src {
            // drop the receiver FIRST so a decoder blocked in send()
            // wakes with an error and exits; then the join can't hang
            drop(rx.take());
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::io::{V2Writer, V2_DEFAULT_CHUNK_EVENTS};
    use super::super::{Access, AllocEvent, AllocKind, WlEvent};
    use super::*;
    use crate::workload::TraceReplay;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cxlms-stream-{tag}-{}.bin", std::process::id()))
    }

    /// Write a synthetic trace: one alloc, then `n` strided accesses.
    fn write_trace(path: &std::path::Path, n: u64, chunk_events: usize) -> Vec<WlEvent> {
        let mut events = vec![WlEvent::Alloc(AllocEvent {
            kind: AllocKind::Mmap,
            addr: 0x6000_0000,
            len: n * 64 + 4096,
            t_ns: 0.0,
        })];
        for i in 0..n {
            events.push(WlEvent::Access(Access {
                addr: 0x6000_0000 + i * 64,
                is_write: i % 3 == 0,
            }));
        }
        let f = std::fs::File::create(path).unwrap();
        let mut w = V2Writer::with_chunk_events(f, chunk_events).unwrap();
        w.push_slice(&events).unwrap();
        w.finish().unwrap();
        events
    }

    #[test]
    fn stream_matches_in_memory_event_for_event() {
        for decode_ahead in [false, true] {
            let path = temp_path(&format!("match-{decode_ahead}"));
            let events = write_trace(&path, 5000, 256);
            let mut mem = TraceReplay::new("mem", events);
            let mut s = TraceStream::open_with(path.to_str().unwrap(), decode_ahead).unwrap();
            crate::workload::assert_same_stream(&mut mem, &mut s, 97);
            assert!(s.take_error().is_none());
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn stream_in_flight_is_bounded_by_chunks() {
        let chunk = 128usize;
        let path = temp_path("bound");
        write_trace(&path, 10_000, chunk);
        for decode_ahead in [false, true] {
            let mut s = TraceStream::open_with(path.to_str().unwrap(), decode_ahead).unwrap();
            assert_eq!(s.max_chunk_events(), chunk as u64);
            let mut buf = Vec::new();
            loop {
                buf.clear();
                if !s.next_batch(&mut buf, 100) {
                    break;
                }
            }
            assert!(s.take_error().is_none());
            let peak = s.peak_decoded_in_flight();
            let bound = (DECODE_AHEAD_DEPTH as u64 + 2) * s.max_chunk_events();
            assert!(peak > 0, "counter never moved");
            assert!(peak <= bound, "peak {peak} exceeds O(chunk) bound {bound}");
            assert_eq!(s.decoded_in_flight(), 0, "events leaked after drain");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stream_short_pushes_stay_within_chunks() {
        let path = temp_path("short");
        write_trace(&path, 1000, 64);
        let mut s = TraceStream::open(path.to_str().unwrap()).unwrap();
        let mut total = 0usize;
        let mut buf = Vec::new();
        loop {
            buf.clear();
            let more = s.next_batch(&mut buf, 1000);
            // never more than one chunk per call
            assert!(buf.len() <= 64, "pushed {} > chunk", buf.len());
            total += buf.len();
            if !more {
                break;
            }
        }
        assert_eq!(total as u64, s.total_events());
        assert_eq!(s.total_events(), 1001);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stream_surfaces_decode_errors_after_exhaustion() {
        let path = temp_path("err");
        write_trace(&path, 500, 100);
        // corrupt a payload byte inside a later chunk
        let mut bytes = std::fs::read(&path).unwrap();
        let idx =
            super::super::io::V2Index::read(&mut std::io::Cursor::new(&bytes[..])).unwrap();
        let off = idx.chunks[2].offset as usize;
        bytes[off] = 9; // invalid tag
        std::fs::write(&path, &bytes).unwrap();
        for decode_ahead in [false, true] {
            let mut s = TraceStream::open_with(path.to_str().unwrap(), decode_ahead).unwrap();
            let mut buf = Vec::new();
            loop {
                buf.clear();
                if !s.next_batch(&mut buf, 4096) {
                    break;
                }
            }
            let err = s.take_error().expect("damage must surface");
            assert!(err.contains("chunk 2"), "{err}");
            assert!(err.contains("bad tag 9"), "{err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stream_open_rejects_non_v2() {
        let path = temp_path("notv2");
        std::fs::write(&path, b"CXLTRC\x00\x01_not_a_v2_file____").unwrap();
        let err = TraceStream::open(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("v2"), "{err}");
        std::fs::remove_file(&path).ok();
        assert!(TraceStream::open("/does/not/exist.bin").is_err());
    }

    #[test]
    fn stream_drop_mid_trace_joins_cleanly() {
        // drop while the decoder is likely blocked in send(): Drop
        // must not hang (receiver is dropped before the join)
        let path = temp_path("drop");
        write_trace(&path, 50_000, 64);
        for _ in 0..8 {
            let mut s = TraceStream::open(path.to_str().unwrap()).unwrap();
            let mut buf = Vec::new();
            s.next_batch(&mut buf, 10);
            drop(s);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_union_covers_every_event_exactly_once() {
        // 1001 events over 64-event chunks -> 16 chunks; 5 shards
        // split 16 unevenly (3,3,3,3,4) — the union must still be the
        // whole trace, in order, with no duplicates
        let path = temp_path("shard-union");
        let events = write_trace(&path, 1000, 64);
        for decode_ahead in [false, true] {
            let mut got = Vec::new();
            let mut chunk_cover = 0usize;
            for i in 0..5 {
                let mut s =
                    TraceStream::open_shard_with(path.to_str().unwrap(), decode_ahead, i, 5)
                        .unwrap();
                let (lo, hi) = s.chunk_range();
                assert_eq!(lo, i * s.file_chunks() / 5);
                assert_eq!(hi, (i + 1) * s.file_chunks() / 5);
                chunk_cover += hi - lo;
                let (elo, _) = s.event_range();
                assert_eq!(elo, got.len() as u64, "shards must tile the event index");
                let mut buf = Vec::new();
                while s.next_batch(&mut buf, 4096) {}
                assert!(s.take_error().is_none());
                assert_eq!(buf.len() as u64, s.total_events());
                got.extend(buf);
            }
            let s = TraceStream::open(path.to_str().unwrap()).unwrap();
            assert_eq!(chunk_cover, s.file_chunks());
            assert_eq!(got.len(), events.len());
            assert_eq!(got, events, "decode_ahead={decode_ahead}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_more_shards_than_chunks_gives_empty_shards() {
        let path = temp_path("shard-empty");
        write_trace(&path, 100, 64); // 2 chunks
        let mut seen = 0u64;
        for i in 0..8 {
            let mut s = TraceStream::open_shard(path.to_str().unwrap(), i, 8).unwrap();
            let mut buf = Vec::new();
            while s.next_batch(&mut buf, 4096) {}
            assert!(s.take_error().is_none());
            assert_eq!(buf.len() as u64, s.total_events());
            seen += s.total_events();
        }
        assert_eq!(seen, 101);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_bad_specs_error() {
        let path = temp_path("shard-bad");
        write_trace(&path, 100, 64);
        let p = path.to_str().unwrap();
        let err = TraceStream::open_shard(p, 0, 0).unwrap_err();
        assert!(err.contains("N=0"), "{err}");
        let err = TraceStream::open_shard(p, 4, 4).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        assert!(err.contains("valid: 0..4"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_decode_errors_name_absolute_chunk() {
        let path = temp_path("shard-abs");
        write_trace(&path, 500, 100); // 6 chunks (501 events)
        let mut bytes = std::fs::read(&path).unwrap();
        let idx =
            super::super::io::V2Index::read(&mut std::io::Cursor::new(&bytes[..])).unwrap();
        let off = idx.chunks[4].offset as usize;
        bytes[off] = 9; // invalid tag in chunk 4
        std::fs::write(&path, &bytes).unwrap();
        for decode_ahead in [false, true] {
            // shard 2/3 of 6 chunks = chunks [4, 6): the damage is its
            // first chunk, and the error must say "chunk 4", not 0
            let mut s =
                TraceStream::open_shard_with(path.to_str().unwrap(), decode_ahead, 2, 3).unwrap();
            let mut buf = Vec::new();
            while s.next_batch(&mut buf, 4096) {}
            let err = s.take_error().expect("damage must surface");
            assert!(err.contains("chunk 4"), "{err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn default_chunk_size_is_sane() {
        // three chunks in flight at the default is ~200k decoded
        // events — a few MB resident at ~32 B per `WlEvent`
        assert!((DECODE_AHEAD_DEPTH + 2) * V2_DEFAULT_CHUNK_EVENTS < (1 << 20));
    }
}

//! The composable two-phase policy engine (paper §1: "memory
//! scheduling for complex applications", software vs hardware
//! prefetching/migration, cache-line vs page management).
//!
//! Research policies are stacked in a [`PolicyStack`] and run at every
//! epoch boundary in two phases around the timing analyzer:
//!
//! * **`before_analysis`** — bin shaping: the policy may rewrite the
//!   epoch's `[P, B]` histograms before the analyzer sees them
//!   ([`SoftwarePrefetch`] lives here: it converts a fraction of read
//!   misses into earlier, overlap-friendly traffic);
//! * **`after_analysis`** — placement action: the policy observes the
//!   analyzer's outputs (per-pool latency, per-switch
//!   congestion/bandwidth totals; the `[S, B]` backlog profile too if
//!   the caller opted into its export) and may migrate regions through
//!   the shared [`PolicyCtx`] ([`HotnessMigration`],
//!   [`CongestionRebalance`] live here).
//!
//! Migration is **cost-modeled**, not free: every byte moved through
//! [`PolicyCtx::migrate`] is converted by the stack into read traffic
//! on the source pool and write traffic on the destination pool,
//! injected into the *next* epoch's bins (spread evenly over the
//! epoch's time bins — the migration DMA competes with demand traffic
//! for link bandwidth), plus a configurable per-byte stall charged to
//! the epoch's delay total. The injected copy traffic is input to the
//! timing analyzer only: policies rank pools by *demand* traffic
//! ([`PolicyCtx::injected_events`] is subtracted), so one promotion's
//! copy can't read as demand heat and cascade into the next. Tiering
//! is therefore a genuine tradeoff: a promotion pays for itself only
//! if the saved CXL latency outruns the one-time copy traffic.
//! Conservation (injected bytes + pending bytes == migrated bytes) is
//! asserted in `tests/pipeline_equivalence.rs`.
//!
//! Victim selection uses the allocation tracker's per-region *heat*
//! counters (bumped on the `pool_of` fast path, one increment per
//! lookup — see `alloctrack`): migration policies promote the hottest
//! region on the offending pool, not merely the largest.
//!
//! Stacks are buildable from a CLI spec (`--epoch-policy
//! hotness:3,prefetch:0.5,rebalance`) via [`PolicySpec::parse`] and the
//! [`POLICY_REGISTRY`]. An empty stack is bit-identical to running with
//! no stack installed, on every driver (sequential, batched replay,
//! multihost) — the engine's zero-cost guarantee, asserted in
//! `tests/pipeline_equivalence.rs` and measured in
//! `benches/hotpath.rs` (`policy_epoch`).

use crate::alloctrack::AllocTracker;
use crate::runtime::TimingOutputs;
use crate::topology::{PoolId, LOCAL_POOL};
use crate::trace::binning::EpochBins;

/// One region move performed through [`PolicyCtx::migrate`], recorded
/// so the stack can charge its modeled cost. `bytes` counts only bytes
/// that actually copied (pages already resident on `to` are free), and
/// `from` carries the per-source-pool byte shares — one entry for a
/// `Single` placement, several for an interleaved region whose pages
/// span pools.
#[derive(Clone, Debug)]
pub struct Migration {
    pub start: u64,
    pub bytes: u64,
    pub to: PoolId,
    pub from: Vec<(PoolId, u64)>,
}

/// Shared per-epoch context handed to both policy phases. Owns the
/// migration log for the epoch: policies move regions through
/// [`PolicyCtx::migrate`] (never `AllocTracker::migrate_region`
/// directly) so every move is cost-modeled by the stack.
pub struct PolicyCtx<'a> {
    pub tracker: &'a mut AllocTracker,
    /// Epoch index within the run (0-based).
    pub epoch: u64,
    /// Bytes represented by one binned event (the cacheline size).
    pub bytes_per_ev: f32,
    /// Per-pool event counts (reads + writes) the migration cost model
    /// injected into THIS epoch's bins. Policies ranking pools by bin
    /// traffic must subtract these — the copy traffic is real input to
    /// the timing analyzer, but letting it feed a policy's own
    /// dominance/load signal makes one migration's copy look like
    /// demand heat and cascade into the next (a self-sustaining loop).
    pub injected_events: &'a [f64],
    /// Per-pool offline mask from the fault subsystem (empty when no
    /// pool is offline). [`PolicyCtx::migrate`] refuses offline
    /// destinations, so policies can never repopulate a hot-removed
    /// device.
    pub offline: &'a [bool],
    /// Per-pool degraded mask from the fault subsystem (empty in
    /// fault-free runs): pools currently serving under an active storm,
    /// retrain, or re-online warm-up window. Fault-aware policies
    /// ([`FaultDrain`]) use it to evacuate proactively and to gate
    /// re-admission on recovery.
    pub degraded: &'a [bool],
    migrations: Vec<Migration>,
}

impl PolicyCtx<'_> {
    /// Migrate the region starting at `start` to pool `to`, recording
    /// the move for cost modeling. Returns false (and records nothing)
    /// if the region is unknown, already entirely on `to`, or the move
    /// fails. Copy traffic is charged per *source* pool: an
    /// interleaved region's pages are attributed to the pools they
    /// actually live on, and pages already resident on `to` copy
    /// nothing.
    pub fn migrate(&mut self, start: u64, to: PoolId) -> bool {
        if self.offline.get(to).copied().unwrap_or(false) {
            return false; // destination was hot-removed
        }
        let Some(r) = self.tracker.region_at(start) else {
            return false;
        };
        let mut from: Vec<(PoolId, u64)> = Vec::new();
        // the tracker's span walk is the one source of truth for where
        // the region's bytes live; pages already on `to` copy nothing
        r.for_each_span(|pool, sz| {
            if pool == to || sz == 0 {
                return;
            }
            match from.iter_mut().find(|(p, _)| *p == pool) {
                Some(e) => e.1 += sz,
                None => from.push((pool, sz)),
            }
        });
        if from.is_empty() {
            return false; // nothing would actually move
        }
        if self.tracker.migrate_region(start, to) {
            let bytes = from.iter().map(|(_, b)| *b).sum();
            self.migrations.push(Migration { start, bytes, to, from });
            true
        } else {
            false
        }
    }

    /// Moves recorded so far this epoch (all policies, both phases).
    pub fn migrations(&self) -> &[Migration] {
        &self.migrations
    }
}

/// A composable epoch policy: either hook (or both) may be implemented;
/// the defaults are no-ops so pure bin-shapers and pure migrators stay
/// small. Policies run in stack order within each phase.
pub trait EpochPolicy: Send {
    fn name(&self) -> &'static str;
    /// Phase 1 — bin shaping, before the timing analyzer runs. The
    /// bins may be rewritten in place (traffic must be conserved if the
    /// policy models scheduling rather than elimination).
    fn before_analysis(&mut self, _bins: &mut EpochBins, _ctx: &mut PolicyCtx) {}
    /// Phase 2 — placement action, after the timing analyzer ran.
    /// Migrations go through [`PolicyCtx::migrate`].
    fn after_analysis(&mut self, _bins: &EpochBins, _out: &TimingOutputs, _ctx: &mut PolicyCtx) {}
    /// Total migrations performed (reporting).
    fn migrations(&self) -> u64 {
        0
    }
    /// Total bytes moved (reporting).
    fn moved_bytes(&self) -> u64 {
        0
    }
    /// Bytes moved for availability (drain off degraded pools plus
    /// re-admission after recovery) — a subset of `moved_bytes`; only
    /// fault-aware policies report it.
    fn drained_bytes(&self) -> u64 {
        0
    }
}

/// An ordered stack of [`EpochPolicy`]s plus the migration cost model.
///
/// The epoch drivers call [`PolicyStack::before_analysis`] with the
/// epoch's completed bins (which first injects the previous epoch's
/// migration traffic, then runs each policy's phase-1 hook) and
/// [`PolicyStack::after_analysis`] with the analyzer outputs (phase-2
/// hooks, then converts the epoch's migrations into pending traffic
/// and returns the stall to charge to the epoch's delay).
pub struct PolicyStack {
    policies: Vec<Box<dyn EpochPolicy>>,
    /// Stall charged per migrated byte, ns (models the page-copy
    /// machinery blocking the app: TLB shootdowns + copy bandwidth).
    pub stall_ns_per_byte: f64,
    epoch: u64,
    /// Per-pool migrated bytes awaiting injection as read traffic
    /// (source pools) and write traffic (destination pools).
    pending_reads: Vec<f64>,
    pending_writes: Vec<f64>,
    /// Reused migration-log allocation for [`PolicyCtx`].
    mig_scratch: Vec<Migration>,
    /// Per-pool events (reads + writes) injected into the CURRENT
    /// epoch's bins — exposed to policies via
    /// [`PolicyCtx::injected_events`] so copy traffic never feeds
    /// their own trigger metrics.
    last_injected: Vec<f64>,
    /// Stall accrued since the last `after_analysis` return (phase-1
    /// migrations land here too).
    accrued_stall_ns: f64,
    migrations: u64,
    moved_bytes: u64,
    injected_read_bytes: f64,
    injected_write_bytes: f64,
    stall_ns: f64,
    /// Per-pool offline mask mirrored from the fault subsystem (empty
    /// = nothing offline); exposed to hooks via [`PolicyCtx::offline`].
    offline: Vec<bool>,
    /// Per-pool degraded mask mirrored from the fault subsystem (empty
    /// = nothing degraded); exposed via [`PolicyCtx::degraded`].
    degraded: Vec<bool>,
    /// Per-policy (migrations, moved_bytes, drained_bytes) snapshots
    /// from [`PolicyStack::begin_run`];
    /// [`PolicyStack::per_policy_stats`] reports deltas against them.
    per_policy_base: Vec<(u64, u64, u64)>,
}

impl PolicyStack {
    pub fn new(stall_ns_per_byte: f64) -> PolicyStack {
        PolicyStack {
            policies: Vec::new(),
            stall_ns_per_byte,
            epoch: 0,
            pending_reads: Vec::new(),
            pending_writes: Vec::new(),
            mig_scratch: Vec::new(),
            last_injected: Vec::new(),
            accrued_stall_ns: 0.0,
            migrations: 0,
            moved_bytes: 0,
            injected_read_bytes: 0.0,
            injected_write_bytes: 0.0,
            stall_ns: 0.0,
            offline: Vec::new(),
            degraded: Vec::new(),
            per_policy_base: Vec::new(),
        }
    }

    /// Reset per-run accounting: counters, pending copy traffic, and
    /// the epoch index. The epoch drivers call this at run start so a
    /// stack reused across `Coordinator::run` calls reports THIS run's
    /// numbers — the same persistence split as the alloc tracker,
    /// whose placements survive runs while its counters are reported
    /// as per-run deltas (`TracerRunStats`). Pending (not-yet-
    /// injected) copy traffic from a previous run is dropped: the run
    /// boundary quantizes in-flight DMA away, which keeps the per-run
    /// conservation invariant (injected + pending == migrated) exact.
    /// Policy-internal state (hotness streaks, local-DRAM budgets)
    /// deliberately persists, like the tracker placements it reasons
    /// about.
    pub fn begin_run(&mut self) {
        self.epoch = 0;
        self.pending_reads.fill(0.0);
        self.pending_writes.fill(0.0);
        self.last_injected.fill(0.0);
        self.mig_scratch.clear();
        self.accrued_stall_ns = 0.0;
        self.migrations = 0;
        self.moved_bytes = 0;
        self.injected_read_bytes = 0.0;
        self.injected_write_bytes = 0.0;
        self.stall_ns = 0.0;
        self.offline.clear();
        self.degraded.clear();
        self.per_policy_base = self
            .policies
            .iter()
            .map(|p| (p.migrations(), p.moved_bytes(), p.drained_bytes()))
            .collect();
    }

    /// The per-pool event counts injected into the current epoch's
    /// bins by the last [`PolicyStack::before_analysis`] call (what
    /// [`PolicyCtx::injected_events`] exposes to hooks).
    pub fn injected_events(&self) -> &[f64] {
        &self.last_injected
    }

    /// Override the injected-events vector before running phase-2
    /// hooks for an epoch whose bins were filled earlier. Batched
    /// replay needs this: it runs `before_analysis` per epoch at
    /// boundary time but `after_analysis` at group-flush time, so it
    /// snapshots `injected_events()` per epoch and restores it here —
    /// otherwise every epoch in the group would see the *last*
    /// boundary's vector and the anti-cascade demand subtraction
    /// would silently miss.
    pub fn set_injected_events(&mut self, v: &[f64]) {
        self.last_injected.clear();
        self.last_injected.extend_from_slice(v);
    }

    /// Drain the stall accrued so far (phase-1 migrations). Batched
    /// replay parks this with each epoch at boundary time and
    /// re-credits it via [`PolicyStack::credit_accrued_stall_ns`] just
    /// before that epoch's phase 2 — otherwise several boundaries'
    /// phase-1 stall would all land on the first epoch flushed in the
    /// group (run totals would survive, per-epoch records would not).
    pub fn take_accrued_stall_ns(&mut self) -> f64 {
        std::mem::take(&mut self.accrued_stall_ns)
    }

    /// Re-credit stall previously drained by
    /// [`PolicyStack::take_accrued_stall_ns`].
    pub fn credit_accrued_stall_ns(&mut self, ns: f64) {
        self.accrued_stall_ns += ns;
    }

    /// Per-policy `(name, migrations, moved_bytes)` for this run —
    /// deltas since [`PolicyStack::begin_run`] (policies keep lifetime
    /// counters internally).
    pub fn per_policy_stats(&self) -> Vec<(&'static str, u64, u64)> {
        self.policies
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let (mb, bb, _) = self.per_policy_base.get(i).copied().unwrap_or((0, 0, 0));
                (p.name(), p.migrations() - mb, p.moved_bytes() - bb)
            })
            .collect()
    }

    /// Availability-motivated bytes moved this run (drain off degraded
    /// pools + re-admission), summed over fault-aware policies — deltas
    /// since [`PolicyStack::begin_run`].
    pub fn drained_bytes(&self) -> u64 {
        self.policies
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let base = self.per_policy_base.get(i).map(|b| b.2).unwrap_or(0);
                p.drained_bytes() - base
            })
            .sum()
    }

    /// Builder-style push.
    pub fn with(mut self, p: Box<dyn EpochPolicy>) -> PolicyStack {
        self.policies.push(p);
        self
    }

    pub fn add(&mut self, p: Box<dyn EpochPolicy>) {
        self.policies.push(p);
    }

    pub fn is_empty(&self) -> bool {
        self.policies.is_empty()
    }

    pub fn len(&self) -> usize {
        self.policies.len()
    }

    /// The installed policies, for reporting.
    pub fn policies(&self) -> impl Iterator<Item = &dyn EpochPolicy> {
        self.policies.iter().map(|p| p.as_ref())
    }

    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    pub fn moved_bytes(&self) -> u64 {
        self.moved_bytes
    }

    pub fn injected_read_bytes(&self) -> f64 {
        self.injected_read_bytes
    }

    pub fn injected_write_bytes(&self) -> f64 {
        self.injected_write_bytes
    }

    /// Migrated bytes staged but not yet injected (end-of-run
    /// migrations have no next epoch to land in). Read- and write-side
    /// pending totals are always equal.
    pub fn pending_bytes(&self) -> f64 {
        self.pending_reads.iter().sum()
    }

    pub fn stall_ns(&self) -> f64 {
        self.stall_ns
    }

    fn ensure_pools(&mut self, pools: usize) {
        if self.pending_reads.len() < pools {
            self.pending_reads.resize(pools, 0.0);
            self.pending_writes.resize(pools, 0.0);
        }
        // sized separately: `set_injected_events` may have restored a
        // snapshot of a different length
        if self.last_injected.len() < pools {
            self.last_injected.resize(pools, 0.0);
        }
    }

    /// Spread `events` evenly over one pool row (the migration DMA
    /// streams through the whole epoch, not one instant).
    fn inject_row(row: &mut [f32], events: f64) {
        let per_bin = (events / row.len() as f64) as f32;
        for x in row.iter_mut() {
            *x += per_bin;
        }
    }

    /// Absorb an epoch's migration log into the cost model: pending
    /// traffic for the next epoch plus the per-byte stall. Read
    /// traffic lands on each source pool in proportion to the bytes it
    /// actually held; write traffic lands on the destination.
    fn absorb_migrations(&mut self, mut migs: Vec<Migration>, pools: usize) {
        self.ensure_pools(pools);
        for m in migs.drain(..) {
            self.migrations += 1;
            self.moved_bytes += m.bytes;
            for (pool, bytes) in &m.from {
                self.pending_reads[*pool] += *bytes as f64;
            }
            self.pending_writes[m.to] += m.bytes as f64;
            self.accrued_stall_ns += m.bytes as f64 * self.stall_ns_per_byte;
        }
        self.mig_scratch = migs;
    }

    /// Phase 1: inject the previous epoch's migration traffic into the
    /// bins (reads on source pools, writes on destinations), then run
    /// each policy's `before_analysis` hook in stack order. With an
    /// empty stack and no pending traffic this touches nothing — the
    /// bit-identical-to-no-policy guarantee.
    pub fn before_analysis(
        &mut self,
        bins: &mut EpochBins,
        tracker: &mut AllocTracker,
        bytes_per_ev: f32,
    ) {
        self.ensure_pools(bins.pools);
        let b = bins.nbins;
        for pool in 0..bins.pools {
            self.last_injected[pool] = 0.0;
            let rb = std::mem::take(&mut self.pending_reads[pool]);
            if rb > 0.0 {
                let ev = rb / bytes_per_ev as f64;
                Self::inject_row(&mut bins.reads[pool * b..(pool + 1) * b], ev);
                self.injected_read_bytes += rb;
                self.last_injected[pool] += ev;
            }
            let wb = std::mem::take(&mut self.pending_writes[pool]);
            if wb > 0.0 {
                let ev = wb / bytes_per_ev as f64;
                Self::inject_row(&mut bins.writes[pool * b..(pool + 1) * b], ev);
                self.injected_write_bytes += wb;
                self.last_injected[pool] += ev;
            }
        }
        if self.policies.is_empty() {
            return;
        }
        let mut ctx = PolicyCtx {
            tracker,
            epoch: self.epoch,
            bytes_per_ev,
            injected_events: &self.last_injected,
            offline: &self.offline,
            degraded: &self.degraded,
            migrations: std::mem::take(&mut self.mig_scratch),
        };
        for p in &mut self.policies {
            p.before_analysis(bins, &mut ctx);
        }
        let migs = ctx.migrations;
        self.absorb_migrations(migs, bins.pools);
    }

    /// Mirror the fault subsystem's per-pool offline mask so every
    /// subsequent hook invocation sees it via [`PolicyCtx::offline`].
    /// Drivers call this on overlay-revision edges; an empty mask (the
    /// fault-free default) costs nothing.
    pub fn set_offline_pools(&mut self, mask: &[bool]) {
        self.offline.clear();
        self.offline.extend_from_slice(mask);
    }

    /// Mirror the fault subsystem's per-pool degraded mask (pools
    /// serving under an active storm / retrain / warm-up window) so
    /// hooks see it via [`PolicyCtx::degraded`]. Drivers call this on
    /// overlay-revision edges next to
    /// [`PolicyStack::set_offline_pools`]; an empty mask (the
    /// fault-free default) costs nothing.
    pub fn set_degraded_pools(&mut self, mask: &[bool]) {
        self.degraded.clear();
        self.degraded.extend_from_slice(mask);
    }

    /// Graceful degradation for a hot-removed pool: evacuate every
    /// live region still holding bytes on `from` to `to`, through the
    /// same cost-modeled migration machinery policies use — copy
    /// traffic lands on the source/destination bins of the next
    /// injection and the per-byte stall is accrued, so the
    /// conservation invariant (injected + pending == migrated) holds
    /// for failover exactly as for policy moves. Returns the bytes
    /// evacuated. Interleaved regions are moved whole (every page ends
    /// up on `to`); pages already on `to` copy nothing.
    pub fn failover_pool(
        &mut self,
        tracker: &mut AllocTracker,
        from: PoolId,
        to: PoolId,
        bytes_per_ev: f32,
    ) -> u64 {
        let pools = tracker.stats.pool_bytes.len();
        self.ensure_pools(pools);
        // snapshot the region starts first: migrating mutates the map
        let starts: Vec<u64> = tracker
            .live_regions()
            .filter(|r| {
                let mut hit = false;
                r.for_each_span(|p, sz| hit |= p == from && sz > 0);
                hit
            })
            .map(|r| r.start)
            .collect();
        if starts.is_empty() {
            return 0;
        }
        let mut ctx = PolicyCtx {
            tracker,
            epoch: self.epoch,
            bytes_per_ev,
            injected_events: &self.last_injected,
            offline: &self.offline,
            degraded: &self.degraded,
            migrations: std::mem::take(&mut self.mig_scratch),
        };
        for s in starts {
            ctx.migrate(s, to);
        }
        let migs = ctx.migrations;
        let bytes: u64 = migs.iter().map(|m| m.bytes).sum();
        self.absorb_migrations(migs, pools);
        bytes
    }

    /// Phase 2: run each policy's `after_analysis` hook in stack order,
    /// absorb the epoch's migrations into the cost model, and return
    /// the migration stall (ns) to charge to this epoch's delay.
    pub fn after_analysis(
        &mut self,
        bins: &EpochBins,
        out: &TimingOutputs,
        tracker: &mut AllocTracker,
        bytes_per_ev: f32,
    ) -> f64 {
        if !self.policies.is_empty() {
            self.ensure_pools(bins.pools);
            let mut ctx = PolicyCtx {
                tracker,
                epoch: self.epoch,
                bytes_per_ev,
                injected_events: &self.last_injected,
                offline: &self.offline,
                degraded: &self.degraded,
                migrations: std::mem::take(&mut self.mig_scratch),
            };
            for p in &mut self.policies {
                p.after_analysis(bins, out, &mut ctx);
            }
            let migs = ctx.migrations;
            self.absorb_migrations(migs, bins.pools);
        }
        self.epoch += 1;
        let stall = std::mem::take(&mut self.accrued_stall_ns);
        self.stall_ns += stall;
        stall
    }
}

// ------------------------------------------------------------------
// Spec parsing + registry (CLI: --epoch-policy hotness:3,prefetch:0.5)
// ------------------------------------------------------------------

/// One entry of a parsed `--epoch-policy` spec.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpecEntry {
    Hotness { patience: u32, budget_bytes: u64 },
    Prefetch { coverage: f32 },
    Rebalance { threshold: f64 },
    FaultDrain { budget_bytes: u64 },
}

/// Parse a byte-size spec argument: a plain integer, optionally
/// suffixed with `K`/`M`/`G` (case-insensitive, powers of 1024) —
/// `64M` = 64 MiB. Used by the `hotness:<patience>:<budget>` spec.
pub fn parse_byte_size(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    let (digits, mult) = match t.chars().last() {
        Some('K') | Some('k') => (&t[..t.len() - 1], 1u64 << 10),
        Some('M') | Some('m') => (&t[..t.len() - 1], 1u64 << 20),
        Some('G') | Some('g') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1u64),
    };
    let v: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte size `{s}` (use e.g. 65536, 64K, 64M, 2G)"))?;
    let bytes = v
        .checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte size `{s}` overflows u64"))?;
    anyhow::ensure!(bytes > 0, "byte size `{s}` must be > 0");
    Ok(bytes)
}

/// A parsed, cloneable policy-stack spec. Lives in `SimConfig` so every
/// driver (sequential coordinator, batched replay, multihost) builds
/// its own stack(s) from the same CLI flag.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PolicySpec {
    pub entries: Vec<PolicySpecEntry>,
}

/// Registry row: spec name, optional-argument doc, default argument.
pub struct PolicyInfo {
    pub name: &'static str,
    pub arg: &'static str,
    pub default_arg: f64,
    pub help: &'static str,
}

/// Every spec-constructible policy. `cxlmemsim list` prints this.
pub const POLICY_REGISTRY: &[PolicyInfo] = &[
    PolicyInfo {
        name: "hotness",
        arg: "patience[:budget]",
        default_arg: 3.0,
        help: "promote the hottest region of the dominant CXL pool to local DRAM \
               after <patience> consecutive dominant epochs, moving at most \
               <budget> bytes per run (K/M/G suffixes, e.g. hotness:3:64M; \
               default unlimited)",
    },
    PolicyInfo {
        name: "prefetch",
        arg: "coverage",
        default_arg: 0.5,
        help: "software next-line prefetch: shift <coverage> of each bin's read \
               misses one bin earlier (bin shaping, phase 1)",
    },
    PolicyInfo {
        name: "rebalance",
        arg: "backlog-threshold",
        default_arg: 1e6,
        help: "when the switch backlog integral crosses <threshold>, move the \
               hottest region off the most-loaded pool to the least-loaded one",
    },
    PolicyInfo {
        name: "drain",
        arg: "budget",
        default_arg: 67108864.0,
        help: "fault-aware availability drain: migrate the hottest region off a \
               degraded (storming / retraining / warming-up) pool before the \
               offline sweep, and re-admit drained regions to their origin \
               under demand once it recovers; <budget> caps bytes moved per \
               epoch (K/M/G suffixes, e.g. drain:64M; default 64M)",
    },
];

impl PolicySpec {
    /// Parse a comma-separated stack spec: `name[:arg...],...` in
    /// stack order. `hotness` takes up to two arguments —
    /// `hotness:<patience>[:<budget>]`, the budget a byte size with
    /// optional K/M/G suffix (`hotness:3:64M`). Unknown names list the
    /// registry.
    pub fn parse(s: &str) -> anyhow::Result<PolicySpec> {
        let mut entries = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let mut it = part.split(':');
            let name = it.next().unwrap_or("").trim();
            let args: Vec<&str> = it.map(|a| a.trim()).collect();
            let info = POLICY_REGISTRY
                .iter()
                .find(|i| i.name == name)
                .ok_or_else(|| {
                    let known: Vec<&str> = POLICY_REGISTRY.iter().map(|i| i.name).collect();
                    anyhow::anyhow!(
                        "unknown epoch policy `{name}` (known: {})",
                        known.join(", ")
                    )
                })?;
            let numeric = |a: Option<&&str>| -> anyhow::Result<f64> {
                match a {
                    Some(a) => a
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("bad {} for `{name}`: `{a}`", info.arg)),
                    None => Ok(info.default_arg),
                }
            };
            entries.push(match name {
                "hotness" => {
                    anyhow::ensure!(
                        args.len() <= 2,
                        "`hotness` takes at most patience and budget (hotness:3:64M), \
                         got `{part}`"
                    );
                    let patience = numeric(args.first())?.max(1.0) as u32;
                    let budget_bytes = match args.get(1) {
                        Some(b) => parse_byte_size(b)?,
                        None => u64::MAX,
                    };
                    PolicySpecEntry::Hotness { patience, budget_bytes }
                }
                "drain" => {
                    anyhow::ensure!(
                        args.len() <= 1,
                        "`drain` takes a single {} argument, got `{part}`",
                        info.arg
                    );
                    let budget_bytes = match args.first() {
                        Some(b) => parse_byte_size(b)?,
                        None => info.default_arg as u64,
                    };
                    PolicySpecEntry::FaultDrain { budget_bytes }
                }
                "prefetch" | "rebalance" => {
                    anyhow::ensure!(
                        args.len() <= 1,
                        "`{name}` takes a single {} argument, got `{part}`",
                        info.arg
                    );
                    let val = numeric(args.first())?;
                    if name == "prefetch" {
                        PolicySpecEntry::Prefetch { coverage: val as f32 }
                    } else {
                        PolicySpecEntry::Rebalance { threshold: val }
                    }
                }
                _ => unreachable!("registry and match must stay in sync"),
            });
        }
        if entries.is_empty() {
            anyhow::bail!("empty --epoch-policy spec (see `cxlmemsim list` for policies)");
        }
        Ok(PolicySpec { entries })
    }

    /// Build a runnable stack from the spec, in spec order.
    pub fn build(&self, stall_ns_per_byte: f64) -> PolicyStack {
        let mut stack = PolicyStack::new(stall_ns_per_byte);
        for e in &self.entries {
            stack.add(match e {
                PolicySpecEntry::Hotness { patience, budget_bytes } => {
                    Box::new(HotnessMigration::new(*patience, *budget_bytes))
                }
                PolicySpecEntry::Prefetch { coverage } => {
                    Box::new(SoftwarePrefetch::new(*coverage))
                }
                PolicySpecEntry::Rebalance { threshold } => {
                    Box::new(CongestionRebalance::new(*threshold))
                }
                PolicySpecEntry::FaultDrain { budget_bytes } => {
                    Box::new(FaultDrain::new(*budget_bytes))
                }
            });
        }
        stack
    }
}

// ------------------------------------------------------------------
// Built-in policies
// ------------------------------------------------------------------

/// Hotness-based promotion: if a CXL pool dominates the epoch's miss
/// traffic for `patience` consecutive epochs, migrate that pool's
/// *hottest* region (tracker heat counters; ties broken by size, then
/// lowest start for determinism) to local DRAM — a page-granular
/// what-if of HeMem-style tiering, now paying modeled migration cost.
pub struct HotnessMigration {
    pub patience: u32,
    pub local_budget_bytes: u64,
    streak: Vec<u32>,
    moved_bytes: u64,
    migrations: u64,
}

impl HotnessMigration {
    pub fn new(patience: u32, local_budget_bytes: u64) -> HotnessMigration {
        HotnessMigration {
            patience,
            local_budget_bytes,
            streak: Vec::new(),
            moved_bytes: 0,
            migrations: 0,
        }
    }

    /// Dominant CXL pool by *demand* traffic: the stack's injected
    /// migration copy traffic is subtracted so one promotion's copy
    /// can't read as demand heat and cascade into the next.
    fn hottest_pool(bins: &EpochBins, injected: &[f64]) -> Option<(PoolId, f64)> {
        (1..bins.pools)
            .map(|p| (p, demand_count(bins, injected, p)))
            // half an event: below that is f32 rounding residue from
            // the injection spread, not demand
            .filter(|(_, c)| *c > 0.5)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }
}

/// Pool traffic minus the cost model's injected copy events (clamped
/// at zero: the spread-over-bins injection is f32-rounded).
fn demand_count(bins: &EpochBins, injected: &[f64], pool: PoolId) -> f64 {
    let inj = injected.get(pool).copied().unwrap_or(0.0);
    (bins.read_count(pool) + bins.write_count(pool) - inj).max(0.0)
}

/// Hottest live region on `pool`: max heat, then max size, then lowest
/// start (deterministic). Callers must `sync_heat` first.
fn hottest_region_on(tracker: &AllocTracker, pool: PoolId) -> Option<(u64, u64)> {
    tracker
        .live_regions()
        .filter(|r| r.pool_of(r.start) == pool)
        .map(|r| (r.start, r.len, r.heat))
        .max_by_key(|&(start, len, heat)| (heat, len, std::cmp::Reverse(start)))
        .map(|(start, len, _)| (start, len))
}

impl EpochPolicy for HotnessMigration {
    fn name(&self) -> &'static str {
        "hotness-migration"
    }

    fn after_analysis(&mut self, bins: &EpochBins, _out: &TimingOutputs, ctx: &mut PolicyCtx) {
        if self.streak.len() < bins.pools {
            self.streak.resize(bins.pools, 0);
        }
        let Some((hot, _count)) = Self::hottest_pool(bins, ctx.injected_events) else {
            self.streak.iter_mut().for_each(|s| *s = 0);
            return;
        };
        for p in 0..bins.pools {
            if p == hot {
                self.streak[p] += 1;
            } else {
                self.streak[p] = 0;
            }
        }
        if self.streak[hot] < self.patience || self.moved_bytes >= self.local_budget_bytes {
            return;
        }
        ctx.tracker.sync_heat();
        if let Some((start, len)) = hottest_region_on(ctx.tracker, hot) {
            if self.moved_bytes + len <= self.local_budget_bytes
                && ctx.migrate(start, LOCAL_POOL)
            {
                // count the bytes that actually copied (pages already
                // local are free) so per-policy rows match the stack's
                // totals; the budget pre-check above uses `len` as a
                // conservative upper bound
                let copied = ctx.migrations().last().map(|m| m.bytes).unwrap_or(len);
                self.moved_bytes += copied;
                self.migrations += 1;
                self.streak[hot] = 0;
            }
        }
    }

    fn migrations(&self) -> u64 {
        self.migrations
    }

    fn moved_bytes(&self) -> u64 {
        self.moved_bytes
    }
}

/// Congestion-aware rebalancing: when a switch's backlog integral
/// crosses a threshold, move the *hottest* region (tracker heat) off
/// the most-loaded pool to the least-loaded pool (or local DRAM). Uses
/// the analyzer's congestion outputs — available because the timing
/// model exports them (DESIGN.md §3 L2 outputs).
pub struct CongestionRebalance {
    /// Backlog-integral threshold (ns-work · bins) per epoch.
    pub threshold: f64,
    migrations: u64,
    moved_bytes: u64,
}

impl CongestionRebalance {
    pub fn new(threshold: f64) -> CongestionRebalance {
        CongestionRebalance { threshold, migrations: 0, moved_bytes: 0 }
    }
}

impl EpochPolicy for CongestionRebalance {
    fn name(&self) -> &'static str {
        "congestion-rebalance"
    }

    fn after_analysis(&mut self, bins: &EpochBins, out: &TimingOutputs, ctx: &mut PolicyCtx) {
        // total backlog integral over all switches this epoch
        let backlog: f64 = out.cong.iter().map(|x| *x as f64).sum();
        if backlog < self.threshold {
            return;
        }
        // most-loaded CXL pool by *demand* traffic (the cost model's
        // injected copy events are excluded, like HotnessMigration).
        // The >0.5-event demand gate also guards the trigger: the
        // backlog integral necessarily includes congestion caused by
        // our own injected copy traffic, so without demand on any CXL
        // pool a migration could only be chasing its own copies —
        // ping-ponging regions and charging stall forever.
        let Some((hot, _)) = (1..bins.pools)
            .map(|p| (p, demand_count(bins, ctx.injected_events, p)))
            .filter(|(_, c)| *c > 0.5)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        else {
            return;
        };
        // least-loaded destination (local counts as a destination)
        let dest = (0..bins.pools)
            .filter(|p| *p != hot)
            .min_by(|&a, &b| {
                let ca = demand_count(bins, ctx.injected_events, a);
                let cb = demand_count(bins, ctx.injected_events, b);
                ca.partial_cmp(&cb).unwrap()
            })
            .unwrap_or(LOCAL_POOL);
        ctx.tracker.sync_heat();
        if let Some((start, len)) = hottest_region_on(ctx.tracker, hot) {
            if ctx.migrate(start, dest) {
                self.migrations += 1;
                // actually-copied bytes, so per-policy rows match the
                // stack totals (resident pages on `dest` are free)
                self.moved_bytes +=
                    ctx.migrations().last().map(|m| m.bytes).unwrap_or(len);
            }
        }
    }

    fn migrations(&self) -> u64 {
        self.migrations
    }

    fn moved_bytes(&self) -> u64 {
        self.moved_bytes
    }
}

/// Software next-line prefetching modelled as traffic shaping: a
/// fraction of read misses is converted into earlier, overlap-friendly
/// accesses. In epoch terms: read counts are moved one bin earlier and
/// de-rated by `coverage` (prefetched lines don't stall the core). A
/// phase-1 (bin shaping) stack member: it rewrites the bins before the
/// analyzer runs — traffic is conserved (prefetched lines still
/// transit the link), only its timing moves.
pub struct SoftwarePrefetch {
    /// Fraction of sequential read misses covered by prefetch [0, 1].
    pub coverage: f32,
}

impl SoftwarePrefetch {
    pub fn new(coverage: f32) -> SoftwarePrefetch {
        SoftwarePrefetch { coverage: coverage.clamp(0.0, 1.0) }
    }

    /// Shift `coverage` of each bin's reads one bin earlier, in place.
    pub fn apply(&self, bins: &mut EpochBins) {
        let (p, b) = (bins.pools, bins.nbins);
        for pool in 0..p {
            for bin in 1..b {
                let idx = pool * b + bin;
                let moved = bins.reads[idx] * self.coverage;
                bins.reads[idx] -= moved;
                // prefetched lines still transit the link (bandwidth!)
                // but one bin earlier and without stalling: keep them as
                // reads in the earlier bin.
                bins.reads[idx - 1] += moved;
            }
        }
    }
}

impl EpochPolicy for SoftwarePrefetch {
    fn name(&self) -> &'static str {
        "software-prefetch"
    }

    fn before_analysis(&mut self, bins: &mut EpochBins, _ctx: &mut PolicyCtx) {
        self.apply(bins);
    }
}

/// Fault-aware availability drain (CLI `drain[:budget]`): while a pool
/// is *degraded* — serving under an active retry storm, link retrain,
/// or re-online warm-up window ([`PolicyCtx::degraded`]) — migrate its
/// hottest region to a healthy pool *before* any offline sweep, so a
/// storm that escalates to hot-remove finds the hot data already gone.
/// Every drained region is remembered with its origin pool; once the
/// origin is healthy again (not degraded, not offline) the region is
/// re-admitted under demand — the symmetric recovery path that lets the
/// re-onlined pool re-balance without a dedicated rebalancer.
///
/// Moves go through [`PolicyCtx::migrate`] like any policy move, so
/// drain and re-admit traffic is cost-modeled (copy traffic + per-byte
/// stall) and counted in the conservation invariant. Both directions
/// are demand-gated like [`HotnessMigration`] (the >0.5-event threshold
/// on *demand* traffic, injected copy events excluded) so the policy
/// cannot cascade off its own copies, and both share one per-epoch byte
/// budget, at most one drain plus one re-admit per epoch.
pub struct FaultDrain {
    /// Byte budget per epoch, shared by drain and re-admit moves.
    pub budget_bytes: u64,
    /// FIFO of (region start, origin pool) drained and not yet
    /// re-admitted. Records for regions that were freed, or that some
    /// other policy already moved home, are dropped when encountered.
    drained: Vec<(u64, PoolId)>,
    migrations: u64,
    moved_bytes: u64,
}

impl FaultDrain {
    pub fn new(budget_bytes: u64) -> FaultDrain {
        FaultDrain { budget_bytes, drained: Vec::new(), migrations: 0, moved_bytes: 0 }
    }
}

impl EpochPolicy for FaultDrain {
    fn name(&self) -> &'static str {
        "fault-drain"
    }

    fn after_analysis(&mut self, bins: &EpochBins, _out: &TimingOutputs, ctx: &mut PolicyCtx) {
        if ctx.degraded.is_empty() && self.drained.is_empty() {
            return; // fault-free fast path
        }
        let (deg, off) = (ctx.degraded, ctx.offline);
        let is_deg = |p: PoolId| deg.get(p).copied().unwrap_or(false);
        let is_off = |p: PoolId| off.get(p).copied().unwrap_or(false);
        let mut budget = self.budget_bytes;
        // drain: the degraded pool with the most demand, if any
        let src = (0..bins.pools)
            .filter(|&p| is_deg(p) && !is_off(p))
            .map(|p| (p, demand_count(bins, ctx.injected_events, p)))
            .filter(|(_, c)| *c > 0.5)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if let Some((src, _)) = src {
            // lowest-numbered healthy destination (local DRAM first)
            let dest = (0..bins.pools).find(|&p| p != src && !is_deg(p) && !is_off(p));
            if let Some(dest) = dest {
                ctx.tracker.sync_heat();
                if let Some((start, len)) = hottest_region_on(ctx.tracker, src) {
                    if len <= budget && ctx.migrate(start, dest) {
                        let copied =
                            ctx.migrations().last().map(|m| m.bytes).unwrap_or(len);
                        self.drained.push((start, src));
                        self.migrations += 1;
                        self.moved_bytes += copied;
                        budget = budget.saturating_sub(copied);
                    }
                }
            }
        }
        // re-admit: oldest parked record whose origin recovered, under
        // demand on the region's current pool; at most one per epoch
        let mut idx = 0;
        while idx < self.drained.len() {
            let (start, origin) = self.drained[idx];
            let info = ctx.tracker.region_at(start).map(|r| (r.pool_of(r.start), r.len));
            let Some((cur, len)) = info else {
                self.drained.remove(idx); // freed while parked
                continue;
            };
            if cur == origin {
                self.drained.remove(idx); // already home again
                continue;
            }
            if is_deg(origin) || is_off(origin) {
                idx += 1; // origin not healthy yet — stay parked
                continue;
            }
            if demand_count(bins, ctx.injected_events, cur) > 0.5
                && len <= budget
                && ctx.migrate(start, origin)
            {
                let copied = ctx.migrations().last().map(|m| m.bytes).unwrap_or(len);
                self.migrations += 1;
                self.moved_bytes += copied;
                self.drained.remove(idx);
            }
            break;
        }
    }

    fn migrations(&self) -> u64 {
        self.migrations
    }

    fn moved_bytes(&self) -> u64 {
        self.moved_bytes
    }

    fn drained_bytes(&self) -> u64 {
        self.moved_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloctrack::PolicyKind;
    use crate::topology::builtin;
    use crate::trace::{AllocEvent, AllocKind};

    fn tracker_with_region(pool_policy: PolicyKind) -> AllocTracker {
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(&topo, pool_policy.build(&topo));
        t.on_alloc_event(&AllocEvent {
            kind: AllocKind::Mmap,
            addr: 0x1000,
            len: 1 << 20,
            t_ns: 0.0,
        });
        t
    }

    fn bins_hot_on(pool: usize) -> EpochBins {
        let mut b = EpochBins::new(8, 16, 1600.0);
        for bin in 0..16 {
            b.record(pool, false, bin as f64 * 100.0, 50.0);
        }
        b
    }

    fn outputs() -> TimingOutputs {
        TimingOutputs {
            total: 1e6,
            lat: vec![0.0; 8],
            cong: vec![1e9; 8],
            bwd: vec![0.0; 8],
            cong_backlog: vec![0.0; 8 * 16],
        }
    }

    fn ctx<'a>(t: &'a mut AllocTracker) -> PolicyCtx<'a> {
        PolicyCtx {
            tracker: t,
            epoch: 0,
            bytes_per_ev: 64.0,
            injected_events: &[],
            offline: &[],
            degraded: &[],
            migrations: Vec::new(),
        }
    }

    fn ctx_masks<'a>(
        t: &'a mut AllocTracker,
        offline: &'a [bool],
        degraded: &'a [bool],
    ) -> PolicyCtx<'a> {
        PolicyCtx {
            tracker: t,
            epoch: 0,
            bytes_per_ev: 64.0,
            injected_events: &[],
            offline,
            degraded,
            migrations: Vec::new(),
        }
    }

    #[test]
    fn hotness_migration_waits_for_patience() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = HotnessMigration::new(3, u64::MAX);
        {
            let mut c = ctx(&mut t);
            pol.after_analysis(&bins, &outputs(), &mut c);
            pol.after_analysis(&bins, &outputs(), &mut c);
            assert_eq!(pol.migrations(), 0, "must wait for patience");
            pol.after_analysis(&bins, &outputs(), &mut c);
            assert_eq!(pol.migrations(), 1);
            assert_eq!(c.migrations().len(), 1, "move must be cost-recorded");
        }
        assert_eq!(t.pool_of(0x1000), LOCAL_POOL);
    }

    #[test]
    fn hotness_migration_respects_budget() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = HotnessMigration::new(1, 100); // budget < region size
        let mut c = ctx(&mut t);
        for _ in 0..5 {
            pol.after_analysis(&bins, &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 0);
    }

    #[test]
    fn hotness_migration_picks_hottest_not_largest() {
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
        let (big, small) = (0x10_0000u64, 0x80_0000u64);
        t.on_alloc_event(&AllocEvent { kind: AllocKind::Mmap, addr: big, len: 1 << 20, t_ns: 0.0 });
        t.on_alloc_event(&AllocEvent {
            kind: AllocKind::Mmap,
            addr: small,
            len: 1 << 16,
            t_ns: 0.0,
        });
        // force both regions onto the same pool
        assert!(t.migrate_region(big, 2));
        assert!(t.migrate_region(small, 2));
        // the small region is the hot one
        for i in 0..200u64 {
            t.pool_of(small + (i % 1024) * 64);
        }
        let bins = bins_hot_on(2);
        let mut pol = HotnessMigration::new(1, u64::MAX);
        let mut c = ctx(&mut t);
        pol.after_analysis(&bins, &outputs(), &mut c);
        assert_eq!(pol.migrations(), 1);
        drop(c);
        assert_eq!(t.pool_of(small), LOCAL_POOL, "hotter region must move first");
        assert_eq!(t.pool_of(big), 2, "colder (bigger) region must stay");
    }

    #[test]
    fn congestion_rebalance_triggers_on_backlog() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = CongestionRebalance::new(1.0);
        {
            let mut c = ctx(&mut t);
            pol.after_analysis(&bins, &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 1);
        assert_ne!(t.pool_of(0x1000), hot);
    }

    #[test]
    fn congestion_rebalance_idle_below_threshold() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let bins = bins_hot_on(1);
        let mut pol = CongestionRebalance::new(f64::INFINITY);
        let mut c = ctx(&mut t);
        pol.after_analysis(&bins, &outputs(), &mut c);
        assert_eq!(pol.migrations(), 0);
    }

    #[test]
    fn prefetch_conserves_traffic() {
        let mut bins = bins_hot_on(2);
        let before: f32 = bins.reads.iter().sum();
        SoftwarePrefetch::new(0.5).apply(&mut bins);
        let after: f32 = bins.reads.iter().sum();
        assert!((before - after).abs() < 1e-3, "prefetch must not destroy traffic");
    }

    #[test]
    fn prefetch_shifts_earlier() {
        let mut bins = EpochBins::new(2, 4, 400.0);
        bins.record(1, false, 350.0, 100.0); // all in last bin
        SoftwarePrefetch::new(1.0).apply(&mut bins);
        assert_eq!(bins.reads[1 * 4 + 3], 0.0);
        assert_eq!(bins.reads[1 * 4 + 2], 100.0);
    }

    #[test]
    fn prefetch_runs_as_phase_one_stack_member() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let mut bins = EpochBins::new(8, 4, 400.0);
        bins.record(1, false, 350.0, 100.0);
        let mut stack = PolicyStack::new(0.0).with(Box::new(SoftwarePrefetch::new(1.0)));
        stack.before_analysis(&mut bins, &mut t, 64.0);
        assert_eq!(bins.reads[1 * 4 + 3], 0.0, "stack must apply bin shaping");
        assert_eq!(bins.reads[1 * 4 + 2], 100.0);
    }

    #[test]
    fn empty_stack_is_a_noop() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let mut bins = bins_hot_on(2);
        let snapshot = bins.clone();
        let mut stack = PolicyStack::new(0.5);
        stack.before_analysis(&mut bins, &mut t, 64.0);
        let stall = stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stall, 0.0);
        assert_eq!(bins.reads, snapshot.reads, "empty stack must not touch bins");
        assert_eq!(bins.writes, snapshot.writes);
        assert_eq!(stack.migrations(), 0);
    }

    #[test]
    fn stack_models_migration_cost() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let region_bytes = 1u64 << 20;
        let mut stack =
            PolicyStack::new(0.25).with(Box::new(HotnessMigration::new(1, u64::MAX)));
        let mut bins = bins_hot_on(hot);
        stack.before_analysis(&mut bins, &mut t, 64.0);
        let stall = stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1);
        assert_eq!(stack.moved_bytes(), region_bytes);
        // stall charged in the migrating epoch
        assert_eq!(stall, region_bytes as f64 * 0.25);
        // traffic pending until the next epoch's bins exist
        assert_eq!(stack.pending_bytes(), region_bytes as f64);
        assert_eq!(stack.injected_read_bytes(), 0.0);

        // next epoch: the copy traffic lands — reads on the source
        // pool, writes on the destination (LOCAL) — spread over bins
        let mut next = EpochBins::new(8, 16, 1600.0);
        stack.before_analysis(&mut next, &mut t, 64.0);
        assert_eq!(stack.pending_bytes(), 0.0);
        assert_eq!(stack.injected_read_bytes(), region_bytes as f64);
        assert_eq!(stack.injected_write_bytes(), region_bytes as f64);
        let events = region_bytes as f64 / 64.0;
        let rd: f64 = next.read_count(hot);
        let wr: f64 = next.write_count(LOCAL_POOL);
        assert!((rd - events).abs() / events < 1e-3, "read traffic on source: {rd} vs {events}");
        assert!((wr - events).abs() / events < 1e-3, "write traffic on dest: {wr} vs {events}");
    }

    #[test]
    fn injected_copy_traffic_does_not_retrigger_migration() {
        // one promotion's copy traffic must not read as demand heat on
        // the source pool and cascade into migrating the next region
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
        for (addr, len) in [(0x10_0000u64, 1u64 << 20), (0x80_0000, 1 << 20)] {
            t.on_alloc_event(&AllocEvent { kind: AllocKind::Mmap, addr, len, t_ns: 0.0 });
            assert!(t.migrate_region(addr, 2)); // both on pool 2
        }
        let mut stack =
            PolicyStack::new(0.0).with(Box::new(HotnessMigration::new(1, u64::MAX)));
        let mut bins = bins_hot_on(2);
        stack.before_analysis(&mut bins, &mut t, 64.0);
        stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1, "demand heat must trigger the first move");
        // epoch 2: NO demand traffic — only the injected copy lands
        let mut bins2 = EpochBins::new(8, 16, 1600.0);
        stack.before_analysis(&mut bins2, &mut t, 64.0);
        assert!(bins2.read_count(2) > 0.0, "copy traffic must reach the analyzer input");
        stack.after_analysis(&bins2, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1, "copy traffic alone must not cascade");
    }

    #[test]
    fn interleaved_migration_charges_each_source_pool() {
        let topo = builtin::fig2(); // 3 CXL pools
        let mk = || {
            let mut t = AllocTracker::new(
                &topo,
                PolicyKind::Interleave { page_bytes: 4096 }.build(&topo),
            );
            t.on_alloc_event(&AllocEvent {
                kind: AllocKind::Mmap,
                addr: 0x0,
                len: 4096 * 6,
                t_ns: 0.0,
            });
            t
        };
        // to LOCAL: every page copies; reads split across the 3 pools
        let mut t = mk();
        {
            let mut c = ctx(&mut t);
            assert!(c.migrate(0x0, LOCAL_POOL));
            let m = &c.migrations()[0];
            assert_eq!(m.bytes, 4096 * 6);
            assert_eq!(m.from.len(), 3, "each striped pool held pages");
            assert!(m.from.iter().all(|(_, b)| *b == 4096 * 2));
        }
        // to a pool already holding part of the stripe: those pages
        // are free, only the other pools' pages copy
        let mut t = mk();
        let dest = t.pool_of(64);
        let mut c = ctx(&mut t);
        assert!(c.migrate(0x0, dest));
        let m = &c.migrations()[0];
        assert_eq!(m.bytes, 4096 * 4, "resident pages must not be charged");
        assert!(m.from.iter().all(|(p, _)| *p != dest));
    }

    #[test]
    fn rebalance_is_demand_gated_against_its_own_copy_traffic() {
        // backlog above threshold but ALL pool traffic is our own
        // injected copy: rebalance must not ping-pong
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
        for (addr, len) in [(0x10_0000u64, 1u64 << 20), (0x80_0000, 1 << 20)] {
            t.on_alloc_event(&AllocEvent { kind: AllocKind::Mmap, addr, len, t_ns: 0.0 });
            assert!(t.migrate_region(addr, 2));
        }
        let mut stack =
            PolicyStack::new(0.0).with(Box::new(CongestionRebalance::new(1.0)));
        let mut bins = bins_hot_on(2);
        stack.before_analysis(&mut bins, &mut t, 64.0);
        stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1, "demand + backlog must trigger the move");
        // next epoch: zero demand, only the injected copy traffic
        let mut bins2 = EpochBins::new(8, 16, 1600.0);
        stack.before_analysis(&mut bins2, &mut t, 64.0);
        stack.after_analysis(&bins2, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1, "copy traffic alone must not rebalance");
    }

    #[test]
    fn begin_run_resets_accounting_but_keeps_policy_state() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let mut stack =
            PolicyStack::new(0.25).with(Box::new(HotnessMigration::new(1, u64::MAX)));
        let mut bins = bins_hot_on(hot);
        stack.before_analysis(&mut bins, &mut t, 64.0);
        stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1);
        assert!(stack.pending_bytes() > 0.0);

        stack.begin_run();
        assert_eq!(stack.migrations(), 0, "per-run counters must reset");
        assert_eq!(stack.moved_bytes(), 0);
        assert_eq!(stack.pending_bytes(), 0.0, "pending copy traffic must drop");
        assert_eq!(stack.injected_read_bytes(), 0.0);
        assert_eq!(stack.stall_ns(), 0.0);
        // the dropped pending must NOT inject into the next run
        let mut next = EpochBins::new(8, 16, 1600.0);
        stack.before_analysis(&mut next, &mut t, 64.0);
        assert!(
            next.reads.iter().all(|x| *x == 0.0),
            "run-1 pending must not leak into run 2"
        );
        // per-policy rows are per-run deltas over persisting lifetime
        // counters
        let stats = stack.per_policy_stats();
        assert_eq!(stats[0], ("hotness-migration", 0, 0));
    }

    #[test]
    fn spec_parses_stack_in_order() {
        let spec = PolicySpec::parse("hotness:2,prefetch:0.25,rebalance").unwrap();
        assert_eq!(
            spec.entries,
            vec![
                PolicySpecEntry::Hotness { patience: 2, budget_bytes: u64::MAX },
                PolicySpecEntry::Prefetch { coverage: 0.25 },
                PolicySpecEntry::Rebalance { threshold: 1e6 },
            ]
        );
        let stack = spec.build(0.0625);
        assert_eq!(stack.len(), 3);
        let names: Vec<&str> = stack.policies().map(|p| p.name()).collect();
        assert_eq!(
            names,
            ["hotness-migration", "software-prefetch", "congestion-rebalance"]
        );
    }

    #[test]
    fn spec_defaults_and_errors() {
        let spec = PolicySpec::parse("hotness").unwrap();
        assert_eq!(
            spec.entries,
            vec![PolicySpecEntry::Hotness { patience: 3, budget_bytes: u64::MAX }]
        );
        assert!(PolicySpec::parse("").is_err(), "empty spec must error");
        assert!(PolicySpec::parse("oracle").is_err(), "unknown name must error");
        assert!(PolicySpec::parse("hotness:fast").is_err(), "bad arg must error");
    }

    #[test]
    fn spec_hotness_budget_round_trips() {
        // the per-run byte budget rides as a third `:` field with
        // K/M/G units (powers of 1024)
        let spec = PolicySpec::parse("hotness:3:64M").unwrap();
        assert_eq!(
            spec.entries,
            vec![PolicySpecEntry::Hotness { patience: 3, budget_bytes: 64 << 20 }]
        );
        let spec = PolicySpec::parse("hotness:1:2G,prefetch:0.5").unwrap();
        assert_eq!(
            spec.entries[0],
            PolicySpecEntry::Hotness { patience: 1, budget_bytes: 2 << 30 }
        );
        let spec = PolicySpec::parse("hotness:5:128k").unwrap();
        assert_eq!(
            spec.entries,
            vec![PolicySpecEntry::Hotness { patience: 5, budget_bytes: 128 << 10 }]
        );
        // plain byte counts work too
        let spec = PolicySpec::parse("hotness:2:4096").unwrap();
        assert_eq!(
            spec.entries,
            vec![PolicySpecEntry::Hotness { patience: 2, budget_bytes: 4096 }]
        );
        // errors: bad unit, zero budget, too many fields, non-hotness
        // policies reject extra fields
        assert!(PolicySpec::parse("hotness:3:64Q").is_err());
        assert!(PolicySpec::parse("hotness:3:0").is_err());
        assert!(PolicySpec::parse("hotness:3:64M:9").is_err());
        assert!(PolicySpec::parse("prefetch:0.5:64M").is_err());
        assert!(PolicySpec::parse("rebalance:1e6:2").is_err());
    }

    #[test]
    fn parse_byte_size_units() {
        assert_eq!(parse_byte_size("4096").unwrap(), 4096);
        assert_eq!(parse_byte_size("64K").unwrap(), 64 << 10);
        assert_eq!(parse_byte_size("64M").unwrap(), 64 << 20);
        assert_eq!(parse_byte_size("2g").unwrap(), 2 << 30);
        assert!(parse_byte_size("").is_err());
        assert!(parse_byte_size("M").is_err());
        assert!(parse_byte_size("-1K").is_err());
        assert!(parse_byte_size("999999999999G").is_err(), "overflow must error");
    }

    #[test]
    fn spec_budget_limits_migrated_bytes() {
        // behavioral round-trip: a parsed 4K budget must stop the
        // built stack from moving a 1 MB region
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut stack = PolicySpec::parse("hotness:1:4K").unwrap().build(0.0);
        for _ in 0..5 {
            stack.before_analysis(&mut bins.clone(), &mut t, 64.0);
            stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        }
        assert_eq!(stack.migrations(), 0, "4K budget must block a 1MB move");
        // and an ample parsed budget allows it
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut stack = PolicySpec::parse("hotness:1:64M").unwrap().build(0.0);
        stack.before_analysis(&mut bins.clone(), &mut t, 64.0);
        stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert_eq!(stack.migrations(), 1);
    }

    #[test]
    fn heat_decay_retires_formerly_hot_victims() {
        // two regions on the same pool: OLD was hammered long ago,
        // RECENT is modestly hot right now. With lifetime-cumulative
        // heat (decay 1.0) the stale counter wins victimhood; with
        // per-epoch decay the faded region must lose it.
        let topo = builtin::fig2();
        let (old_r, recent) = (0x10_0000u64, 0x80_0000u64);
        let setup = |decay: f64| {
            let mut t = AllocTracker::new(&topo, PolicyKind::CxlOnly.build(&topo));
            t.set_heat_decay(decay);
            for addr in [old_r, recent] {
                t.on_alloc_event(&AllocEvent {
                    kind: AllocKind::Mmap,
                    addr,
                    len: 1 << 20,
                    t_ns: 0.0,
                });
                assert!(t.migrate_region(addr, 2)); // same pool
            }
            // epoch history: OLD is hammered, then many idle epochs
            for i in 0..400u64 {
                t.pool_of(old_r + (i % 512) * 64);
            }
            for _ in 0..12 {
                t.decay_heat(); // idle epoch boundaries
            }
            // now RECENT warms up
            for i in 0..30u64 {
                t.pool_of(recent + (i % 512) * 64);
            }
            t
        };
        let run_policy = |t: &mut AllocTracker| {
            let bins = bins_hot_on(2);
            let mut pol = HotnessMigration::new(1, u64::MAX);
            let mut c = ctx(t);
            pol.after_analysis(&bins, &outputs(), &mut c);
            assert_eq!(pol.migrations(), 1);
        };
        // lifetime-cumulative: the stale 400-lookup counter wins
        let mut t = setup(1.0);
        run_policy(&mut t);
        assert_eq!(t.pool_of(old_r), LOCAL_POOL, "without decay old heat wins");
        assert_eq!(t.pool_of(recent), 2);
        // decayed: 400 * 0.5^12 rounds to 0, the warm region wins
        let mut t = setup(0.5);
        run_policy(&mut t);
        assert_eq!(t.pool_of(recent), LOCAL_POOL, "decay must retire stale heat");
        assert_eq!(t.pool_of(old_r), 2, "formerly-hot region must stay put");
    }

    #[test]
    fn migrate_refuses_offline_destination() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let from = t.pool_of(0x1000);
        let offline = {
            let mut m = vec![false; 8];
            m[LOCAL_POOL] = true;
            m
        };
        let mut c = PolicyCtx {
            tracker: &mut t,
            epoch: 0,
            bytes_per_ev: 64.0,
            injected_events: &[],
            offline: &offline,
            degraded: &[],
            migrations: Vec::new(),
        };
        assert!(!c.migrate(0x1000, LOCAL_POOL), "offline destination must be refused");
        assert!(c.migrations().is_empty());
        assert_eq!(t.pool_of(0x1000), from, "region must not have moved");
    }

    #[test]
    fn failover_evacuates_offline_pool_with_cost_accounting() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let from = t.pool_of(0x1000);
        assert_ne!(from, LOCAL_POOL);
        let to = if from == 1 { 2 } else { 1 };
        let mut stack = PolicyStack::new(0.0625);
        stack.begin_run();
        let mut mask = vec![false; 8];
        mask[from] = true;
        stack.set_offline_pools(&mask);
        let moved = stack.failover_pool(&mut t, from, to, 64.0);
        assert_eq!(moved, 1 << 20, "whole region evacuated");
        assert_eq!(t.pool_of(0x1000), to);
        assert_eq!(t.stats.pool_bytes[from], 0);
        // cost-modeled like any policy migration: counted, pending for
        // the next injection, and stalled per byte
        assert_eq!(stack.migrations(), 1);
        assert_eq!(stack.moved_bytes(), 1 << 20);
        assert_eq!(stack.pending_bytes(), (1u64 << 20) as f64);
        // draining the epoch charges the stall
        let bins = bins_hot_on(to);
        let stall = stack.after_analysis(&bins, &outputs(), &mut t, 64.0);
        assert!((stall - (1u64 << 20) as f64 * 0.0625).abs() < 1e-6);
        // nothing left on the offline pool: a second sweep is a no-op
        assert_eq!(stack.failover_pool(&mut t, from, to, 64.0), 0);
    }

    #[test]
    fn drain_evacuates_degraded_pool_then_readmits_on_recovery() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let src = t.pool_of(0x1000);
        assert_ne!(src, LOCAL_POOL);
        let mut deg = vec![false; 8];
        deg[src] = true;
        let mut pol = FaultDrain::new(u64::MAX);
        // epoch 1: pool degraded + demand on it → drain to local DRAM
        {
            let mut c = ctx_masks(&mut t, &[], &deg);
            pol.after_analysis(&bins_hot_on(src), &outputs(), &mut c);
            assert_eq!(c.migrations().len(), 1, "drain must be cost-recorded");
        }
        assert_eq!(pol.migrations(), 1);
        assert_eq!(pol.drained_bytes(), 1 << 20);
        assert_eq!(t.pool_of(0x1000), LOCAL_POOL);
        // epoch 2: origin still degraded → record stays parked even
        // though the region's current pool sees demand
        {
            let mut c = ctx_masks(&mut t, &[], &deg);
            pol.after_analysis(&bins_hot_on(LOCAL_POOL), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 1, "no re-admit while the origin is degraded");
        assert_eq!(t.pool_of(0x1000), LOCAL_POOL);
        // epoch 3: origin recovered but zero demand → still parked
        {
            let mut c = ctx_masks(&mut t, &[], &[]);
            pol.after_analysis(&EpochBins::new(8, 16, 1600.0), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 1, "re-admit must be demand-gated");
        // epoch 4: origin recovered + demand → re-admitted home
        {
            let mut c = ctx_masks(&mut t, &[], &[]);
            pol.after_analysis(&bins_hot_on(LOCAL_POOL), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 2);
        assert_eq!(t.pool_of(0x1000), src, "region must return to its origin pool");
        assert_eq!(pol.drained_bytes(), 2 << 20, "both directions count as drain traffic");
        // epoch 5: nothing parked, nothing degraded → pure no-op
        {
            let mut c = ctx_masks(&mut t, &[], &[]);
            pol.after_analysis(&bins_hot_on(src), &outputs(), &mut c);
            assert!(c.migrations().is_empty());
        }
        assert_eq!(pol.migrations(), 2);
    }

    #[test]
    fn drain_respects_budget_and_demand_gate() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let src = t.pool_of(0x1000);
        let mut deg = vec![false; 8];
        deg[src] = true;
        // budget below the region size: nothing may move
        let mut pol = FaultDrain::new(4096);
        {
            let mut c = ctx_masks(&mut t, &[], &deg);
            pol.after_analysis(&bins_hot_on(src), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 0, "per-epoch budget must block the move");
        // ample budget but zero demand on the degraded pool: no move
        let mut pol = FaultDrain::new(u64::MAX);
        {
            let mut c = ctx_masks(&mut t, &[], &deg);
            pol.after_analysis(&EpochBins::new(8, 16, 1600.0), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 0, "drain must be demand-gated");
        assert_eq!(t.pool_of(0x1000), src);
    }

    #[test]
    fn drain_avoids_degraded_and_offline_destinations() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let src = t.pool_of(0x1000);
        // local DRAM offline, every other pool except 3 degraded:
        // the drain must land on pool 3
        let mut deg = vec![true; 8];
        deg[3] = false;
        let mut off = vec![false; 8];
        off[LOCAL_POOL] = true;
        deg[LOCAL_POOL] = false;
        let mut pol = FaultDrain::new(u64::MAX);
        {
            let mut c = ctx_masks(&mut t, &off, &deg);
            pol.after_analysis(&bins_hot_on(src), &outputs(), &mut c);
        }
        assert_eq!(pol.migrations(), 1);
        assert_eq!(t.pool_of(0x1000), 3, "only healthy pool must receive the drain");
    }

    #[test]
    fn spec_parses_drain_with_budget() {
        let spec = PolicySpec::parse("drain").unwrap();
        assert_eq!(spec.entries, vec![PolicySpecEntry::FaultDrain { budget_bytes: 64 << 20 }]);
        let spec = PolicySpec::parse("drain:1M").unwrap();
        assert_eq!(spec.entries, vec![PolicySpecEntry::FaultDrain { budget_bytes: 1 << 20 }]);
        let stack = spec.build(0.0);
        assert_eq!(stack.policies().map(|p| p.name()).collect::<Vec<_>>(), ["fault-drain"]);
        assert!(PolicySpec::parse("drain:1M:2").is_err());
        assert!(PolicySpec::parse("drain:huge").is_err());
    }
}

//! Research-enablement policies (paper §1: "memory scheduling for
//! complex applications", software vs hardware prefetching/migration,
//! cache-line vs page management).
//!
//! An [`EpochPolicy`] observes each epoch's binned traffic and the
//! timing analyzer's outputs (including the per-switch congestion
//! backlog profile) and may act on the allocation tracker — e.g.
//! migrate hot regions toward local DRAM or rebalance away from
//! congested switches.

use crate::alloctrack::AllocTracker;
use crate::runtime::TimingOutputs;
use crate::topology::{PoolId, LOCAL_POOL};
use crate::trace::binning::EpochBins;

/// Called once per epoch, after the timing analyzer has run.
pub trait EpochPolicy: Send {
    fn name(&self) -> &'static str;
    fn on_epoch(&mut self, tracker: &mut AllocTracker, bins: &EpochBins, out: &TimingOutputs);
    /// Total migrations performed (reporting).
    fn migrations(&self) -> u64;
}

/// Hotness-based promotion: if a CXL pool dominates the epoch's miss
/// traffic for `patience` consecutive epochs, migrate that pool's
/// hottest region to local DRAM (a page-granular what-if of HeMem-style
/// tiering).
pub struct HotnessMigration {
    pub patience: u32,
    pub local_budget_bytes: u64,
    streak: Vec<u32>,
    moved_bytes: u64,
    migrations: u64,
}

impl HotnessMigration {
    pub fn new(patience: u32, local_budget_bytes: u64) -> HotnessMigration {
        HotnessMigration {
            patience,
            local_budget_bytes,
            streak: Vec::new(),
            moved_bytes: 0,
            migrations: 0,
        }
    }

    fn hottest_pool(bins: &EpochBins) -> Option<(PoolId, f64)> {
        (1..bins.pools)
            .map(|p| (p, bins.read_count(p) + bins.write_count(p)))
            .filter(|(_, c)| *c > 0.0)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }
}

impl EpochPolicy for HotnessMigration {
    fn name(&self) -> &'static str {
        "hotness-migration"
    }

    fn on_epoch(&mut self, tracker: &mut AllocTracker, bins: &EpochBins, _out: &TimingOutputs) {
        if self.streak.len() < bins.pools {
            self.streak.resize(bins.pools, 0);
        }
        let Some((hot, _count)) = Self::hottest_pool(bins) else {
            self.streak.iter_mut().for_each(|s| *s = 0);
            return;
        };
        for p in 0..bins.pools {
            if p == hot {
                self.streak[p] += 1;
            } else {
                self.streak[p] = 0;
            }
        }
        if self.streak[hot] < self.patience || self.moved_bytes >= self.local_budget_bytes {
            return;
        }
        // migrate the largest region currently on the hot pool
        let candidate = tracker
            .live_regions()
            .filter(|r| r.pool_of(r.start) == hot)
            .map(|r| (r.start, r.len))
            .max_by_key(|(_, len)| *len);
        if let Some((start, len)) = candidate {
            if self.moved_bytes + len <= self.local_budget_bytes
                && tracker.migrate_region(start, LOCAL_POOL)
            {
                self.moved_bytes += len;
                self.migrations += 1;
                self.streak[hot] = 0;
            }
        }
    }

    fn migrations(&self) -> u64 {
        self.migrations
    }
}

/// Congestion-aware rebalancing: when a switch's backlog integral
/// crosses a threshold, move one region off its most-loaded descendant
/// pool to the least-loaded pool (or local DRAM). Uses the analyzer's
/// `cong_backlog` output — only available because the timing model
/// exports it (DESIGN.md §3 L2 outputs).
pub struct CongestionRebalance {
    /// Backlog-integral threshold (ns-work · bins) per epoch.
    pub threshold: f64,
    migrations: u64,
}

impl CongestionRebalance {
    pub fn new(threshold: f64) -> CongestionRebalance {
        CongestionRebalance { threshold, migrations: 0 }
    }
}

impl EpochPolicy for CongestionRebalance {
    fn name(&self) -> &'static str {
        "congestion-rebalance"
    }

    fn on_epoch(&mut self, tracker: &mut AllocTracker, bins: &EpochBins, out: &TimingOutputs) {
        // total backlog integral over all switches this epoch
        let backlog: f64 = out.cong.iter().map(|x| *x as f64).sum();
        if backlog < self.threshold {
            return;
        }
        // most-loaded CXL pool by epoch traffic
        let Some((hot, _)) = (1..bins.pools)
            .map(|p| (p, bins.read_count(p) + bins.write_count(p)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        else {
            return;
        };
        // least-loaded destination (local counts as a destination)
        let dest = (0..bins.pools)
            .filter(|p| *p != hot)
            .min_by(|&a, &b| {
                let ca = bins.read_count(a) + bins.write_count(a);
                let cb = bins.read_count(b) + bins.write_count(b);
                ca.partial_cmp(&cb).unwrap()
            })
            .unwrap_or(LOCAL_POOL);
        let candidate = tracker
            .live_regions()
            .filter(|r| r.pool_of(r.start) == hot)
            .map(|r| (r.start, r.len))
            .max_by_key(|(_, len)| *len);
        if let Some((start, _)) = candidate {
            if tracker.migrate_region(start, dest) {
                self.migrations += 1;
            }
        }
    }

    fn migrations(&self) -> u64 {
        self.migrations
    }
}

/// Software next-line prefetching modelled as traffic shaping: a
/// fraction of read misses is converted into earlier, overlap-friendly
/// accesses. In epoch terms: read counts are moved one bin earlier and
/// de-rated by `coverage` (prefetched lines don't stall the core). This
/// is a *model-side* policy: it rewrites the bins before analysis.
pub struct SoftwarePrefetch {
    /// Fraction of sequential read misses covered by prefetch [0, 1].
    pub coverage: f32,
}

impl SoftwarePrefetch {
    pub fn new(coverage: f32) -> SoftwarePrefetch {
        SoftwarePrefetch { coverage: coverage.clamp(0.0, 1.0) }
    }

    /// Apply to an epoch's bins in place (called by experiments before
    /// the analyzer; not an EpochPolicy since it edits inputs).
    pub fn apply(&self, bins: &mut EpochBins) {
        let (p, b) = (bins.pools, bins.nbins);
        for pool in 0..p {
            for bin in 1..b {
                let idx = pool * b + bin;
                let moved = bins.reads[idx] * self.coverage;
                bins.reads[idx] -= moved;
                // prefetched lines still transit the link (bandwidth!)
                // but one bin earlier and without stalling: keep them as
                // reads in the earlier bin.
                bins.reads[idx - 1] += moved;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloctrack::PolicyKind;
    use crate::topology::builtin;
    use crate::trace::{AllocEvent, AllocKind};

    fn tracker_with_region(pool_policy: PolicyKind) -> AllocTracker {
        let topo = builtin::fig2();
        let mut t = AllocTracker::new(&topo, pool_policy.build(&topo));
        t.on_alloc_event(&AllocEvent {
            kind: AllocKind::Mmap,
            addr: 0x1000,
            len: 1 << 20,
            t_ns: 0.0,
        });
        t
    }

    fn bins_hot_on(pool: usize) -> EpochBins {
        let mut b = EpochBins::new(8, 16, 1600.0);
        for bin in 0..16 {
            b.record(pool, false, bin as f64 * 100.0, 50.0);
        }
        b
    }

    fn outputs() -> TimingOutputs {
        TimingOutputs {
            total: 1e6,
            lat: vec![0.0; 8],
            cong: vec![1e9; 8],
            bwd: vec![0.0; 8],
            cong_backlog: vec![0.0; 8 * 16],
        }
    }

    #[test]
    fn hotness_migration_waits_for_patience() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = HotnessMigration::new(3, u64::MAX);
        pol.on_epoch(&mut t, &bins, &outputs());
        pol.on_epoch(&mut t, &bins, &outputs());
        assert_eq!(pol.migrations(), 0, "must wait for patience");
        pol.on_epoch(&mut t, &bins, &outputs());
        assert_eq!(pol.migrations(), 1);
        assert_eq!(t.pool_of(0x1000), LOCAL_POOL);
    }

    #[test]
    fn hotness_migration_respects_budget() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = HotnessMigration::new(1, 100); // budget < region size
        for _ in 0..5 {
            pol.on_epoch(&mut t, &bins, &outputs());
        }
        assert_eq!(pol.migrations(), 0);
    }

    #[test]
    fn congestion_rebalance_triggers_on_backlog() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let hot = t.pool_of(0x1000);
        let bins = bins_hot_on(hot);
        let mut pol = CongestionRebalance::new(1.0);
        pol.on_epoch(&mut t, &bins, &outputs());
        assert_eq!(pol.migrations(), 1);
        assert_ne!(t.pool_of(0x1000), hot);
    }

    #[test]
    fn congestion_rebalance_idle_below_threshold() {
        let mut t = tracker_with_region(PolicyKind::CxlOnly);
        let bins = bins_hot_on(1);
        let mut pol = CongestionRebalance::new(f64::INFINITY);
        pol.on_epoch(&mut t, &bins, &outputs());
        assert_eq!(pol.migrations(), 0);
    }

    #[test]
    fn prefetch_conserves_traffic() {
        let mut bins = bins_hot_on(2);
        let before: f32 = bins.reads.iter().sum();
        SoftwarePrefetch::new(0.5).apply(&mut bins);
        let after: f32 = bins.reads.iter().sum();
        assert!((before - after).abs() < 1e-3, "prefetch must not destroy traffic");
    }

    #[test]
    fn prefetch_shifts_earlier() {
        let mut bins = EpochBins::new(2, 4, 400.0);
        bins.record(1, false, 350.0, 100.0); // all in last bin
        SoftwarePrefetch::new(1.0).apply(&mut bins);
        assert_eq!(bins.reads[1 * 4 + 3], 0.0);
        assert_eq!(bins.reads[1 * 4 + 2], 100.0);
    }
}

//! CXLMemSim CLI — the leader entrypoint.
//!
//! Subcommands:
//!   run        simulate one workload on a topology
//!   table1     reproduce the paper's Table 1 (native / detailed / CXLMemSim)
//!   sweep      scenario sweep engine: `sweep spec.toml` expands a TOML
//!              grid into cells, runs them on a worker pool, and writes
//!              one JSON comparison artifact with baseline deltas and
//!              accuracy-harness ordering checks (docs/REPRODUCING.md);
//!              without a spec, the legacy inline topo × workload table
//!   multihost  N hosts sharing pools (congestion/coherency study)
//!   record     capture a workload's event trace to a file
//!   replay     simulate a recorded trace
//!   topo       show / dump a topology
//!   list       list workloads, topologies, policies, backends
//!
//! Run `cxlmemsim <cmd> --help-args` for flags; all flags have defaults.

use cxlmemsim::alloctrack::PolicyKind;
use cxlmemsim::coordinator::{run_batched, Coordinator, SimConfig};
use cxlmemsim::gem5like::DetailedSim;
use cxlmemsim::multihost;
use cxlmemsim::policy::{PolicySpec, POLICY_REGISTRY};
use cxlmemsim::runtime::{AnalyzerBackend, ScanKernel};
use cxlmemsim::topology::{builtin, Topology};
use cxlmemsim::trace::io as trace_io;
use cxlmemsim::util::benchutil::{markdown_table, time_once};
use cxlmemsim::util::cli::Args;
use cxlmemsim::workload::{self, TraceWorkload, ALL_WORKLOADS, TABLE1_WORKLOADS};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let result = match cmd.as_str() {
        "run" => cmd_run(&args),
        "table1" => cmd_table1(&args),
        "sweep" => cmd_sweep(&args),
        "multihost" => cmd_multihost(&args),
        "record" => cmd_record(&args),
        "replay" => cmd_replay(&args),
        "topo" => cmd_topo(&args),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "cxlmemsim — a pure-software simulated CXL.mem\n\
         usage: cxlmemsim <run|table1|sweep|multihost|record|replay|topo|list> [--flags]\n\
         sweep: cxlmemsim sweep <spec.toml> [--out FILE] [--sweep-workers N]\n\
                (grid spec -> one JSON comparison artifact; see\n\
                 examples/specs/ and docs/REPRODUCING.md)\n\
         common flags: --workload W --topo T --policy P --backend pjrt|native\n\
                       --epoch-ms F --scale F --seed N --sample-period N\n\
                       --cache-scale N --max-epochs N --event-batch N --json\n\
                       --epoch-policy hotness:3,prefetch:0.5,rebalance (policy stack)\n\
                       --mig-stall-ns-per-byte F (modeled migration cost)\n\
                       --batched (run/replay: grouped-analyzer replay driver)\n\
                       --pipeline (run/replay: analyze epoch N on a worker\n\
                         thread while the pump fills N+1; reports bit-identical\n\
                         to serial; native backend only)\n\
                       --trace FILE (run/replay: simulate a recorded trace;\n\
                         v1/v2/JSONL auto-detected, v2 streams with O(chunk)\n\
                         memory + decode-ahead)\n\
                       --shard i/N (replay: only chunks [i*C/N,(i+1)*C/N) of a\n\
                         v2 trace, 0-based; per-shard report, O(1) seek)\n\
                       --format v2|v1|jsonl (record: output format, default v2\n\
                         chunked+RLE; .jsonl extension implies jsonl)\n\
                       --chunk-events N (record: events per v2 chunk)\n\
                       --analyzer-threads N (batched: shard the E-epoch analyzer\n\
                         loop; 0 = one per core, results identical for any N)\n\
                       --batch-group N (batched: epochs per analyzer call;\n\
                         0 = default 16; policy phase-2 runs up to N-1 epochs late)\n\
                       --scan-kernel blocked|exact (native queueing scans:\n\
                         blocked = max-plus SIMD blocks, exact = golden reference)\n\
                       --heat-decay F (per-epoch region-heat decay in [0,1];\n\
                         1.0 = lifetime-cumulative)\n\
                       --threads N (multihost: work-stealing host-phase workers)\n\
                       --faults FILE (deterministic RAS fault plan, TOML)\n\
                       --fault SPEC (inline plan, e.g.\n\
                         \"storm:pool1@5+10:rd=200,wr=300;offline:pool0@20\";\n\
                         kinds: storm (retry latency), retrain (bw fraction),\n\
                         offline (hot-remove + failover), online (re-join with\n\
                         decaying warm-up); native backend only)\n\
                       --fault-soak SPEC (seeded MTBF chaos plan, e.g.\n\
                         \"mtbf=200,kinds=storm|retrain|offline+online,seed=7\";\n\
                         exponential inter-arrivals, reproducible bit-for-bit)"
    );
}

fn config_from(args: &Args) -> anyhow::Result<SimConfig> {
    let mut cfg = SimConfig::default();
    cfg.epoch_ms = args.f64("epoch-ms", cfg.epoch_ms);
    cfg.scale = args.f64("scale", cfg.scale);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.sample_period = args.u64("sample-period", cfg.sample_period as u64) as u32;
    cfg.cache_scale = args.u64("cache-scale", cfg.cache_scale);
    cfg.cpi_ns = args.f64("cpi-ns", cfg.cpi_ns);
    cfg.mlp = args.f64("mlp", cfg.mlp);
    if let Some(n) = args.opt_str("max-epochs") {
        cfg.max_epochs = n.parse().ok();
    }
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = AnalyzerBackend::parse(&b)
            .ok_or_else(|| anyhow::anyhow!("bad --backend `{b}` (pjrt|native)"))?;
    }
    if let Some(p) = args.opt_str("policy") {
        cfg.policy = PolicyKind::parse(&p)
            .ok_or_else(|| anyhow::anyhow!("bad --policy `{p}` (see `cxlmemsim list`)"))?;
    }
    if let Some(dir) = args.opt_str("artifacts") {
        cfg.artifacts_dir = dir;
    }
    cfg.prefetcher = args.opt_str("prefetch");
    cfg.keep_epoch_records = args.bool("epoch-records");
    cfg.event_batch = args.usize("event-batch", cfg.event_batch).max(1);
    cfg.analyzer_threads = args.usize("analyzer-threads", cfg.analyzer_threads);
    cfg.batch_group = args.usize("batch-group", cfg.batch_group);
    if let Some(k) = args.opt_str("scan-kernel") {
        cfg.scan_kernel = ScanKernel::parse(&k)
            .ok_or_else(|| anyhow::anyhow!("bad --scan-kernel `{k}` (blocked|exact)"))?;
    }
    cfg.pipeline = args.bool("pipeline");
    cfg.heat_decay = args.f64("heat-decay", cfg.heat_decay);
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.heat_decay),
        "--heat-decay must be in [0, 1], got {}",
        cfg.heat_decay
    );
    if let Some(spec) = args.opt_str("epoch-policy") {
        cfg.epoch_policy = Some(PolicySpec::parse(&spec)?);
    }
    cfg.mig_stall_ns_per_byte =
        args.f64("mig-stall-ns-per-byte", cfg.mig_stall_ns_per_byte);
    // deterministic RAS fault schedule: --faults file.toml, --fault
    // inline-spec, or --fault-soak mtbf-spec (mutually exclusive; see
    // `cxlmemsim::fault`). The soak plan is generated from `--seed`
    // unless the spec carries its own `seed=` key.
    let fault_sources = (
        args.opt_str("faults"),
        args.opt_str("fault"),
        args.opt_str("fault-soak"),
    );
    match fault_sources {
        (Some(_), Some(_), _) | (Some(_), _, Some(_)) | (_, Some(_), Some(_)) => {
            anyhow::bail!(
                "--faults <file>, --fault <spec>, and --fault-soak <spec> are mutually exclusive"
            )
        }
        (Some(path), None, None) => {
            let src = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("--faults {path}: {e}"))?;
            cfg.faults = Some(cxlmemsim::fault::FaultPlan::parse_toml(&src)?);
        }
        (None, Some(spec), None) => {
            cfg.faults = Some(cxlmemsim::fault::FaultPlan::parse_inline(&spec)?);
        }
        (None, None, Some(spec)) => {
            cfg.faults = Some(cxlmemsim::fault::FaultPlan::generate(cfg.seed, &spec)?);
        }
        (None, None, None) => {}
    }
    Ok(cfg)
}

fn topo_from(args: &Args) -> anyhow::Result<Topology> {
    let spec = args.str("topo", "fig2");
    Ok(Topology::resolve(&spec)?)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let topo = topo_from(args)?;
    let cfg = config_from(args)?;
    // --trace FILE: simulate a recorded trace instead of a synthetic
    // workload (same drivers, same flags as `replay`)
    if let Some(path) = args.opt_str("trace") {
        return replay_trace(args, topo, cfg, &path);
    }
    let wl = args.str("workload", "mmap_read");
    // --batched: the grouped-analyzer replay driver (policy stacks run
    // with phase-2 applied at group-flush time)
    let rep = if args.bool("batched") {
        let mut workload = cxlmemsim::workload::by_name(&wl, cfg.scale, cfg.seed)
            .ok_or_else(|| anyhow::anyhow!("unknown workload `{wl}`"))?;
        run_batched(&topo, &cfg, workload.as_mut())?
    } else {
        let mut sim = Coordinator::new(topo, cfg)?;
        sim.run_workload(&wl)?
    };
    if args.bool("json") {
        println!("{}", rep.to_json().to_string());
    } else {
        print!("{}", rep.summary());
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> anyhow::Result<()> {
    let mut cfg = config_from(args)?;
    if args.opt_str("scale").is_none() {
        cfg.scale = 0.02; // keep the default run minutes-scale
    }
    let topo = topo_from(args)?;
    let detailed = !args.bool("skip-detailed");
    println!(
        "Table 1 reproduction: topology `{}`, scale {}, backend {:?}",
        topo.name, cfg.scale, cfg.backend
    );
    let mut rows = Vec::new();
    for wl_name in TABLE1_WORKLOADS {
        // native: the workload alone (what the program costs us to run)
        let mut wl = workload::by_name(wl_name, cfg.scale, cfg.seed).unwrap();
        let (accesses, native_wall) = time_once(|| {
            let mut n = 0u64;
            while wl.next_event().is_some() {
                n += 1;
            }
            n
        });

        // detailed (gem5-like) baseline
        let det_wall = if detailed {
            let mut det = DetailedSim::new(topo.clone(), cfg.cache_scale, cfg.policy.clone());
            let mut wl = workload::by_name(wl_name, cfg.scale, cfg.seed).unwrap();
            let rep = det.run(wl.as_mut());
            Some(rep.wall_s)
        } else {
            None
        };

        // CXLMemSim
        let mut sim = Coordinator::new(topo.clone(), cfg.clone())?;
        let rep = sim.run_workload(wl_name)?;

        rows.push(vec![
            wl_name.to_string(),
            format!("{:.4}", native_wall),
            det_wall.map(|w| format!("{w:.4}")).unwrap_or_else(|| "-".into()),
            format!("{:.4}", rep.wall_s),
            det_wall
                .map(|w| format!("{:.1}x", w / native_wall))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}x", rep.wall_s / native_wall),
            format!("{:.3}x", rep.sim_slowdown()),
            format!("{}", accesses),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "Benchmark",
                "Native (s)",
                "Detailed (s)",
                "CXLMemSim (s)",
                "Detailed/Native",
                "CXLMemSim/Native",
                "SimSlowdown",
                "Events"
            ],
            &rows
        )
    );
    Ok(())
}

/// `sweep <spec.toml>`: the scenario sweep engine (`cxlmemsim::sweep`)
/// — expand the spec's grid, run every cell across a work-stealing
/// worker pool, write ONE JSON comparison artifact, and exit non-zero
/// if any cell failed or any accuracy-harness invariant was violated.
/// Without a positional spec the legacy inline topo × workload
/// markdown table is kept (`--workloads` / `--topos`).
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    if let Some(spec_path) = args.positional.first() {
        return cmd_sweep_spec(args, spec_path);
    }
    let cfg = config_from(args)?;
    let wls: Vec<String> = args
        .str("workloads", "mmap_read,mcf_like,wrf_like")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let topos: Vec<String> = args
        .str("topos", "direct,fig2,deep,wide,pooled")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let mut rows = Vec::new();
    for topo_name in &topos {
        let topo = Topology::resolve(topo_name)?;
        for wl in &wls {
            let mut sim = Coordinator::new(topo.clone(), cfg.clone())?;
            let rep = sim.run_workload(wl)?;
            rows.push(vec![
                topo_name.clone(),
                wl.clone(),
                format!("{:.3}", rep.native_ns / 1e6),
                format!("{:.3}", rep.simulated_ns / 1e6),
                format!("{:.3}x", rep.sim_slowdown()),
                format!("{:.3}", rep.lat_delay_ns / 1e6),
                format!("{:.3}", rep.cong_delay_ns / 1e6),
                format!("{:.3}", rep.bwd_delay_ns / 1e6),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                "Topology",
                "Workload",
                "Native(ms)",
                "Sim(ms)",
                "Slowdown",
                "Lat(ms)",
                "Cong(ms)",
                "BW(ms)"
            ],
            &rows
        )
    );
    Ok(())
}

fn cmd_sweep_spec(args: &Args, spec_path: &str) -> anyhow::Result<()> {
    use cxlmemsim::sweep::{self, SweepOptions, SweepSpec};
    let spec = SweepSpec::from_file(spec_path)?;
    let opts = SweepOptions {
        // --sweep-workers N overrides the spec's `workers` (0 = one
        // per core); the artifact is byte-identical for any value
        workers: args.usize("sweep-workers", 0),
        // shard fan-out re-launches this binary as `replay --shard`
        shard_exe: std::env::current_exe().ok(),
    };
    let outcome = sweep::run_spec(&spec, &opts);
    let out = args.str("out", &format!("SWEEP_{}.json", spec.name));
    std::fs::write(&out, outcome.artifact.to_string() + "\n")
        .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    println!(
        "sweep `{}`: {} cells ({} failed), {} invariants ({} violated)",
        spec.name,
        outcome.cells,
        outcome.cell_failures,
        spec.invariants.len(),
        outcome.invariant_failures
    );
    if let Some(invs) = outcome.artifact.get("invariants").and_then(|v| v.as_arr()) {
        for inv in invs {
            let metric = inv.get("metric").and_then(|v| v.as_str()).unwrap_or("?");
            let axis = inv.get("axis").and_then(|v| v.as_str()).unwrap_or("?");
            let holds = inv.get("holds") == Some(&cxlmemsim::util::json::Json::Bool(true));
            let checked = inv.get("checked").and_then(|v| v.as_f64()).unwrap_or(0.0);
            println!(
                "  invariant {metric} along {axis}: {} ({checked:.0} orderings checked)",
                if holds { "holds" } else { "VIOLATED" }
            );
        }
    }
    println!("artifact: {out}");
    // the accuracy harness is a regression suite: violations (and
    // failed cells) fail the run *after* the artifact is on disk
    anyhow::ensure!(
        outcome.cell_failures == 0 && outcome.invariant_failures == 0,
        "sweep `{}`: {} cell failures, {} invariant violations (see {out})",
        spec.name,
        outcome.cell_failures,
        outcome.invariant_failures
    );
    Ok(())
}

fn cmd_multihost(args: &Args) -> anyhow::Result<()> {
    let topo = topo_from(args)?;
    let cfg = config_from(args)?;
    let n = args.usize("hosts", 4);
    let wl_name = args.str("workload", "stream");
    let workloads: Vec<_> = (0..n)
        .map(|i| workload::by_name(&wl_name, cfg.scale, cfg.seed + i as u64).unwrap())
        .collect();
    // --threads N pins the host-phase thread count (0 = one per core);
    // the result is identical either way, only wall-clock changes
    let rep = match args.usize("threads", 0) {
        0 => multihost::run_shared(&topo, &cfg, workloads)?,
        t => multihost::run_shared_threads(&topo, &cfg, workloads, t)?,
    };
    println!(
        "multihost: {} x {} on `{}`: {} epochs, mean slowdown {:.3}x",
        n,
        wl_name,
        topo.name,
        rep.epochs,
        rep.mean_slowdown()
    );
    println!(
        "  shared delay: total {:.3} ms (congestion {:.3} ms, bandwidth {:.3} ms)",
        rep.total_delay_ns / 1e6,
        rep.cong_delay_ns / 1e6,
        rep.bwd_delay_ns / 1e6
    );
    if rep.invalidations > 0 {
        println!(
            "  coherency: {} back-invalidations, {} messages (use --workload shared)",
            rep.invalidations, rep.coherence_msgs
        );
    }
    if rep.migrations > 0 {
        println!(
            "  policy engine: {} migrations, {:.1} KB moved, {:.3} ms modeled stall",
            rep.migrations,
            rep.migrated_bytes as f64 / 1024.0,
            rep.mig_stall_ns / 1e6
        );
    }
    if rep.faults_injected > 0 {
        println!(
            "  faults: {} injected, {:.3} ms retry delay, {} throttled epochs, \
             {} pools offline, {:.1} KB failover-migrated",
            rep.faults_injected,
            rep.retry_delay_ns / 1e6,
            rep.throttled_epochs,
            rep.pools_offline,
            rep.failover_migrated_bytes as f64 / 1024.0
        );
        if rep.pools_reonlined > 0 || rep.drain_migrated_bytes > 0 {
            println!(
                "  recovery: {} pools re-onlined, {:.3} ms warm-up delay, \
                 {:.1} KB drain-migrated",
                rep.pools_reonlined,
                rep.warmup_delay_ns / 1e6,
                rep.drain_migrated_bytes as f64 / 1024.0
            );
        }
    }
    if rep.host_workers > 1 {
        let busy: Vec<String> = rep
            .worker_busy_fracs
            .iter()
            .map(|f| format!("{:.0}%", f * 100.0))
            .collect();
        println!(
            "  work conservation: {} workers, {} steals over {} rebalanced epochs, \
             busy [{}]",
            rep.host_workers,
            rep.steals,
            rep.shard_rebalances,
            busy.join(" ")
        );
    }
    for (i, h) in rep.hosts.iter().enumerate() {
        println!(
            "  host{i}: native {:.3} ms -> sim {:.3} ms ({} misses, {} migrations)",
            h.native_ns / 1e6,
            h.simulated_ns / 1e6,
            h.misses,
            h.migrations
        );
    }
    Ok(())
}

fn cmd_record(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let wl_name = args.str("workload", "mmap_read");
    let out = args.str("out", "trace.bin");
    let format = args
        .opt_str("format")
        .unwrap_or_else(|| if out.ends_with(".jsonl") { "jsonl".into() } else { "v2".into() });
    let mut wl = workload::by_name(&wl_name, cfg.scale, cfg.seed)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{wl_name}`"))?;
    let f = std::fs::File::create(&out)?;
    let batch = cfg.event_batch.max(1);
    let mut buf = Vec::with_capacity(batch);
    match format.as_str() {
        // default: chunked RLE v2, pulled through `next_batch` and
        // pushed straight into the streaming writer — the capture
        // never materializes in memory
        "v2" => {
            let chunk_events = args.usize("chunk-events", trace_io::V2_DEFAULT_CHUNK_EVENTS);
            let mut w = trace_io::V2Writer::with_chunk_events(f, chunk_events)?;
            loop {
                buf.clear();
                let more = wl.next_batch(&mut buf, batch);
                w.push_slice(&buf)?;
                if !more {
                    break;
                }
            }
            let sum = w.finish()?;
            println!(
                "recorded {} events from {wl_name} to {out} (CXLTRC v2, {} chunks)",
                sum.events, sum.chunks
            );
        }
        // streamed line by line; kept for greppability
        "jsonl" => {
            use std::io::Write;
            let mut bw = std::io::BufWriter::new(f);
            let mut n = 0u64;
            loop {
                buf.clear();
                let more = wl.next_batch(&mut buf, batch);
                trace_io::write_jsonl_events(&mut bw, &buf)?;
                n += buf.len() as u64;
                if !more {
                    break;
                }
            }
            bw.flush()?;
            println!("recorded {n} events from {wl_name} to {out} (JSONL)");
        }
        // the legacy flat format carries its event count up front, so
        // it alone still collects the trace in memory
        "v1" => {
            let mut events = Vec::new();
            while let Some(ev) = wl.next_event() {
                events.push(ev);
            }
            let mut f = f;
            trace_io::write_binary(&mut f, &events)?;
            println!("recorded {} events from {wl_name} to {out} (CXLTRC v1)", events.len());
        }
        other => anyhow::bail!("bad --format `{other}` (v2|v1|jsonl)"),
    }
    Ok(())
}

fn cmd_replay(args: &Args) -> anyhow::Result<()> {
    let topo = topo_from(args)?;
    let cfg = config_from(args)?;
    let path = args
        .opt_str("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace <file> required"))?;
    replay_trace(args, topo, cfg, &path)
}

/// Shared by `replay` and `run --trace`: open with format
/// auto-detection (v2 streams with O(chunk) memory + decode-ahead;
/// v1/JSONL load fully), drive the requested driver, then surface any
/// mid-stream decode error — the `Workload` interface reports damage
/// as early exhaustion, so skipping the check would let a truncated
/// replay pass for a complete one.
fn replay_trace(args: &Args, topo: Topology, cfg: SimConfig, path: &str) -> anyhow::Result<()> {
    // --shard i/N: replay only this shard's chunk range of a v2 trace
    // (the chunk directory makes the first chunk an O(1) seek). The
    // report is per-shard; pool/cache state resets per shard, so miss
    // counts are not additive across shards — event counts are.
    let mut replay = match args.opt_str("shard") {
        Some(spec) => {
            let (i, n) = parse_shard(&spec)?;
            let replay = TraceWorkload::open_shard(path, i, n)?;
            if let Some(s) = replay.stream() {
                let (clo, chi) = s.chunk_range();
                let (elo, ehi) = s.event_range();
                eprintln!(
                    "shard {i}/{n}: chunks [{clo}, {chi}) of {}, events [{elo}, {ehi}) of {}",
                    s.file_chunks(),
                    s.file_events()
                );
            }
            replay
        }
        None => TraceWorkload::open(path)?,
    };
    // --batched: offline replay through the grouped analyzer, with the
    // E-epoch loop sharded across --analyzer-threads workers — the
    // work-conserving path for long recorded traces (output is
    // bit-identical to the sequential coordinator on the native
    // backend)
    let rep = if args.bool("batched") {
        run_batched(&topo, &cfg, &mut replay)?
    } else {
        let mut sim = Coordinator::new(topo, cfg)?;
        sim.run(&mut replay)?
    };
    if let Some(e) = replay.take_error() {
        anyhow::bail!("replay of {path}: {e}");
    }
    if args.bool("json") {
        println!("{}", rep.to_json().to_string());
    } else {
        print!("{}", rep.summary());
        if let Some(s) = replay.stream() {
            println!(
                "streaming replay: {} chunks, peak decoded events in flight {}",
                s.chunks(),
                s.peak_decoded_in_flight()
            );
        }
    }
    Ok(())
}

/// Parse `--shard i/N` (0-based shard index over N shards).
fn parse_shard(spec: &str) -> anyhow::Result<(usize, usize)> {
    let (istr, nstr) = spec
        .split_once('/')
        .ok_or_else(|| anyhow::anyhow!("bad --shard `{spec}`: expected i/N, e.g. 0/4"))?;
    let i: usize = istr.trim().parse().map_err(|_| {
        anyhow::anyhow!("bad --shard `{spec}`: shard index `{istr}` is not a number")
    })?;
    let n: usize = nstr.trim().parse().map_err(|_| {
        anyhow::anyhow!("bad --shard `{spec}`: shard count `{nstr}` is not a number")
    })?;
    anyhow::ensure!(n >= 1, "bad --shard `{spec}`: shard count must be >= 1");
    anyhow::ensure!(
        i < n,
        "bad --shard `{spec}`: shard index {i} out of range for {n} shards (valid: 0..{n})"
    );
    Ok((i, n))
}

fn cmd_topo(args: &Args) -> anyhow::Result<()> {
    let topo = topo_from(args)?;
    if args.bool("dump-toml") {
        print!("{}", topo.to_toml());
    } else {
        print!("{}", topo.describe());
    }
    Ok(())
}

fn cmd_list() -> anyhow::Result<()> {
    println!("workloads:  {}", ALL_WORKLOADS.join(", "));
    println!("topologies: {} (or a path to a .toml)", builtin::BUILTIN_NAMES.join(", "));
    println!("policies:   local, cxl, localfirst, interleave, sizeclass, leastloaded");
    println!("backends:   pjrt (AOT HLO via PJRT), native (pure-rust mirror)");
    println!(
        "scan-kernel: blocked (max-plus SIMD blocks, default), exact (golden \
         reference, bit-identical)"
    );
    println!("prefetch:   nextline, stride (hardware prefetcher models, --prefetch)");
    println!(
        "sweep axes: {} (grid/config keys in a sweep spec; see docs/REPRODUCING.md)",
        cxlmemsim::sweep::KNOWN_SETTINGS.join(", ")
    );
    println!("epoch-policy stack (--epoch-policy name[:arg],... — two-phase engine):");
    for p in POLICY_REGISTRY {
        println!(
            "  {:10} [{}, default {}]  {}",
            p.name, p.arg, p.default_arg, p.help
        );
    }
    Ok(())
}

//! # CXLMemSim — a pure-software simulated CXL.mem
//!
//! Reproduction of *"CXLMemSim: A pure software simulated CXL.mem for
//! performance characterization"* (Yang et al., 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: topology management,
//!   the tracer substrate (workload engine + cache hierarchy + alloc
//!   tracker), the epoch loop, delay injection, the detailed `gem5like`
//!   baseline, and the CLI.
//! * **Layer 2** — the timing analyzer as a JAX graph
//!   (`python/compile/model.py`), AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Layer 1** — the per-switch queueing scan as a Pallas kernel
//!   (`python/compile/kernels/queue_scan.py`).
//!
//! Python never runs at simulation time: with the `pjrt` cargo feature,
//! `runtime` loads the HLO artifacts through PJRT (`xla` crate) and
//! executes them per epoch; the default build uses the pure-rust
//! `native` mirror of the same math and needs no artifacts at all.
//!
//! ## The batched event pipeline
//!
//! The tracer substrate is the product: the paper's claim is epoch
//! sampling running orders of magnitude faster than cycle-accurate
//! simulation, so per-event overhead is the whole game. The hot path is
//! organized around three ideas (see `coordinator::driver`):
//!
//! * **Batched event flow** — `Workload::next_batch` emits runs of
//!   events through one virtual call (all built-in workloads implement
//!   native run-length emission), and the `EpochDriver` pump iterates a
//!   plain `Vec<WlEvent>`: a monomorphic inner loop instead of one dyn
//!   dispatch per event. `SimConfig::event_batch = 1` recovers the
//!   legacy per-event loop as a measurable baseline, with bit-identical
//!   simulation output (`tests/pipeline_equivalence.rs`).
//! * **Bulk miss accounting** — sampled misses, write-backs, and
//!   prefetch fills are staged as pre-binned `(pool, rw, bin, weight)`
//!   deltas (`EpochBins::stage`: one reciprocal multiply, clamp
//!   branches run once at stage time) and scattered into the `[P, B]`
//!   histograms once per event batch (`EpochBins::record_bulk`); the
//!   scalar `EpochBins::record` stays as the differential baseline.
//! * **Tracer fast paths** — `AllocTracker::pool_of` (one call per LLC
//!   miss) answers through a one-entry MRU region cache backed by a
//!   lazily rebuilt flat interval index (binary search), instead of a
//!   `BTreeMap::range` walk per miss; misses have strong spatial
//!   locality so the MRU entry absorbs the vast majority of lookups.
//! * **One epoch driver for the epoch-sampling modes** — the
//!   sequential coordinator and the grouped-analyzer replay
//!   (`coordinator::run_batched`) share one `EpochDriver`, differing
//!   only in their `EpochFlush` strategy, so accounting semantics
//!   (prefetcher traffic, sampling, write-backs, epoch policies) land
//!   once for both. The `gem5like` detailed baseline keeps its own
//!   event-accounting loop by design (it models a different machine)
//!   but adopts the same batched pump.
//! * **Vectorized analysis** — `NativeAnalyzer` runs its queueing
//!   scans through one of two kernels (`SimConfig::scan_kernel`): the
//!   default `blocked` max-plus block scans (SIMD-friendly, see "Hot
//!   path anatomy") or the `exact` scalar reference; both skip
//!   all-zero pool columns and only store/export the backlog profile
//!   when an epoch policy asked for it. `NativeBatchAnalyzer` drives
//!   the same core over E epochs (`SimConfig::batch_group`; default
//!   16, 256 profitable for long replays) into pre-sized `[E, ·]`
//!   tensors (no per-epoch allocation).
//! * **Work-conserving multihost workers** — the multihost runner
//!   keeps a persistent worker pool alive across epochs behind a
//!   `std::sync::Barrier`; each epoch the workers drain a shared
//!   atomic host-index queue (work stealing), so early finishers help
//!   with the remaining hosts instead of idling; per-host bins still
//!   merge deterministically, in host order, at the epoch barrier.
//!
//! ## Threading model
//!
//! Every parallel loop in the simulator is over *independent* work,
//! and every reduction of that work happens on one thread in a fixed
//! order — which is why reports are bit-identical for any thread
//! count (asserted in `tests/pipeline_equivalence.rs` and re-run by
//! CI's determinism matrix at 1/2/8 workers, serial and pipelined):
//!
//! * **Sharded batched analyzer** (`runtime::native::
//!   NativeBatchAnalyzer`, used by `coordinator::run_batched` and
//!   `replay --batched`): the E epochs of one `analyze_batch` call
//!   share no state, so the loop splits into contiguous chunks, one
//!   per worker (`SimConfig::analyzer_threads` /
//!   `--analyzer-threads`; 0 = one per core). Each worker owns a
//!   private scratch analyzer and writes a disjoint `[E, ·]` output
//!   row range; the same `analyze_core` call produces the same bits
//!   into the same row no matter which worker runs it. The worker
//!   count used is reported as `SimReport::analyzer_threads_used`.
//! * **Work-stealing multihost host phase** (`multihost`): within an
//!   epoch each host advances independently (coherence delivery is
//!   deferred to the barrier), so workers claim host indices from a
//!   shared atomic queue until it drains — a giant host pins one
//!   worker while the rest absorb the remaining hosts
//!   (`MultiHostReport::{steals, shard_rebalances,
//!   worker_busy_fracs}` make the work conservation observable). The
//!   epoch barrier then merges bins, delivers coherence, analyzes,
//!   and runs policy phases on the coordinator thread in host order,
//!   which pins the result for any worker count.
//! * **Pipelined epoch execution** (`SimConfig::pipeline` /
//!   `--pipeline`, `coordinator::pipeline`): the epoch *boundary* is
//!   split across two threads. The pump thread fills epoch N+1's
//!   `EpochBins` while a dedicated analysis worker runs the timing
//!   model over epoch N's frozen bins; the handoff is a depth-1
//!   rendezvous over a bounded `sync_channel`, and drained bins are
//!   recycled back to the pump, so exactly two bin buffers exist
//!   (double buffering, not a queue). Determinism comes from the
//!   handoff contract, not from luck: bins freeze before send, the
//!   worker computes a pure function of them, and results merge into
//!   the report on the pump thread in epoch order. When a non-empty
//!   `PolicyStack` is installed the pipeline runs **lock-step** (send
//!   then immediately drain, reported `pipeline_depth = 0`) because
//!   phase-2 policy hooks mutate the tracker that the *next* epoch's
//!   pump reads — overlap there would change which epoch a migration
//!   lands in. Fault runs drain early at every overlay-revision edge
//!   so one in-flight analysis never spans two overlays. The worker
//!   owns the analyzer for its lifetime, which is why `--pipeline`
//!   requires the (Send) `native` backend — PJRT client handles are
//!   thread-local. Reports stay bit-identical to serial for every
//!   `--analyzer-threads` / `--batch-group` / `--scan-kernel` knob,
//!   and grow `pipeline_depth`, `pump_busy_ns`, `analyze_busy_ns`,
//!   and `overlap_frac` so the hiding is observable.
//! * **Everything else is single-threaded by design** — the epoch
//!   driver's event pump is a sequential accounting loop (virtual
//!   time is inherently serial: event K+1's cache walk depends on
//!   event K's), and policy stacks always run on the pump thread,
//!   between epochs, in stack order.
//!
//! ## The two-phase policy engine
//!
//! Research policies (`policy` module) compose in a `PolicyStack`
//! installed on any driver — sequential coordinator, batched replay,
//! multihost (one stack per host) — or built from the CLI
//! (`--epoch-policy hotness:3,prefetch:0.5,rebalance`). Each epoch
//! boundary runs two phases around the timing analyzer:
//! `before_analysis` reshapes the epoch's `[P, B]` histograms
//! (software prefetch lives here), `after_analysis` acts on the
//! analyzer's outputs (hotness migration, congestion rebalance —
//! picking victims by the alloc tracker's per-region heat counters,
//! bumped on the `pool_of` fast path). Migration is cost-modeled:
//! moved bytes become read traffic on the source pool and write
//! traffic on the destination pool injected into the next epoch's
//! bins, plus a configurable per-byte stall in the delay total — so
//! tiering experiments pay for their copies. An empty stack is
//! bit-identical to no stack on every driver
//! (`tests/pipeline_equivalence.rs`), and its per-epoch overhead is
//! measured at ~0 in `benches/hotpath.rs` (`policy_epoch`).
//!
//! ## Fault model & degraded modes
//!
//! The `fault` module injects deterministic CXL RAS events
//! (`--faults plan.toml` / `--fault "storm:pool1@5+10:rd=200"`):
//! **retry storms** (per-pool read/write latency inflated for a window
//! of epochs), **link retraining** (every switch row on the pool's
//! path to the root throttled to a fraction of nominal bandwidth),
//! **pool offline** (device hot-remove), and **pool online** (hot-add
//! ending a prior offline window — lifecycle-checked at parse time:
//! an `online` without a matching `offline`, or overlapping offline
//! windows on one pool, are structured [`fault::FaultError`]s, never
//! silent no-ops). A `FaultPlan` holds pool *names* and binds them to
//! a concrete topology at run start (`FaultPlan::resolve`); seeded
//! start jitter keeps chaos runs reproducible. Plans can also be
//! *generated*: `FaultPlan::generate(seed, "mtbf=200,kinds=storm|
//! retrain|offline+online")` (CLI `--fault-soak`) draws exponential
//! inter-arrival times from the repo's own deterministic
//! `util::rng::Rng`, so an MTBF soak is an ordinary plan — same spec +
//! same seed is bit-identical on every machine, and a plan whose first
//! event lies past the horizon leaves the report byte-identical to a
//! fault-free run. All drivers advance the schedule identically at the
//! epoch barrier (`FaultState::epoch_begin`, plan order; the multihost
//! coordinator resolves `host = "hN"`-scoped events per host, in host
//! order, so faulting one host leaves the others' `HostReport`s
//! untouched), then hand the analyzer a [`fault::FaultOverlay`] —
//! additive per-pool latency, multiplicative per-switch bandwidth —
//! applied over copies of its base tensors, so the fault-free path is
//! untouched (pinned at ~0 overhead by
//! `fault_epoch.faultfree_epochs_per_s` and the armed-but-idle
//! `fault_soak.armed_epochs_per_s` in `benches/hotpath.rs`). The
//! batched driver flushes its pending group on every overlay-revision
//! edge, so one `analyze_batch` call never spans two overlays and
//! `--batch-group 1` vs `256` stay bit-identical under faults, as do
//! all analyzer / worker thread counts (CI's determinism matrix gains
//! fault and soak axes).
//!
//! Degradation — and recovery — is graceful, never a panic: when a
//! pool goes offline, its live regions fail over to the fallback pool
//! through the policy stack's cost-modeled migration machinery (copy
//! traffic + per-byte stall charged like any policy move; drivers
//! auto-install an empty stack when faults are configured), policies
//! see the reduced pool set (`PolicyCtx::migrate` refuses offline
//! destinations), and a run with no reachable pool fails with the
//! structured [`fault::FaultError::NoReachablePool`]. An `online`
//! event reverses the sweep: the pool rejoins placement, pays a
//! per-byte re-population stall for whatever returns, and serves its
//! first `warmup_epochs` under a transient latency adder that decays
//! linearly to zero — warm-up epochs are overlay-revision edges, so
//! batched/pipelined grouping stays exact, and the warm-up share of
//! latency is recovered in closed form (`warmup_delay_ns`) exactly
//! like the storm share. The optional `drain` policy
//! ([`policy::FaultDrain`]) makes the stack fault-*aware*: it reads
//! fault state through `PolicyCtx` and proactively evacuates the
//! hottest region off a degraded (storming / retraining, not yet
//! offline) pool — demand-gated above 0.5 so an idle pool is never
//! churned, byte-budgeted per epoch, at most one move per epoch to
//! avoid migration cascades — and symmetrically re-admits the oldest
//! drained region once its origin pool is healthy again. Reports carry
//! the full lifecycle (`faults_injected`, `retry_delay_ns`,
//! `throttled_epochs`, `pools_offline`, `pools_reonlined`,
//! `warmup_delay_ns`, `failover_migrated_bytes`,
//! `drain_migrated_bytes`), and migration conservation is exact across
//! a round trip: `migrated_bytes == failover_migrated_bytes +
//! drain_migrated_bytes` (`tests/pipeline_equivalence.rs`).
//!
//! ## Trace formats & streaming replay
//!
//! `cxlmemsim record` captures a workload's event stream; `replay` /
//! `run --trace` simulate it against any topology. Three formats,
//! auto-detected by magic (`trace::io::detect_format`):
//!
//! * **JSONL** — one event per line, greppable. Strict: a missing or
//!   mistyped field is a line-numbered error, never a silent zero.
//! * **CXLTRC v1** (`CXLTRC\0\x01`) — flat count-prefixed records.
//!   Still read and writable (`record --format v1`), no longer the
//!   default.
//! * **CXLTRC v2** (`CXLTRC\0\x02`, the default) — chunked + RLE:
//!   payloads of ≤ `--chunk-events` events, a fixed-stride chunk
//!   directory (byte offset + event count per chunk, so seek and
//!   sharded fan-out need no serial parse), and a trailing footer
//!   (directory offset + totals) so the writer never seeks. Inside a
//!   chunk, ≥4 same-rw constant-stride accesses collapse into one
//!   21-byte run record (start, wrapping stride, count) — workloads
//!   emit runs natively, so recording is nearly free and decode is
//!   exact for any u64 address pattern, negative/zero strides
//!   included.
//!
//! Replay of a v2 trace streams ([`trace::stream::TraceStream`]):
//! only decoded chunks in flight are resident — O(chunk), not
//! O(trace) — and a decode-ahead thread seeks/reads/decodes chunk
//! N+1 while the driver consumes chunk N, so replay wall-clock
//! approaches max(decode, analyze) instead of their sum (measured in
//! `benches/hotpath.rs` `replay_stream`, with the peak
//! decoded-events-in-flight counter proving the memory bound).
//! Determinism is preserved because the handoff is a rendezvous over
//! a bounded channel, not a race: chunks arrive strictly in directory
//! order, so the driver sees byte-for-byte the sequence an in-memory
//! `TraceReplay` would emit, and reports stay bit-identical across
//! `--analyzer-threads`, `--batch-group`, and `--scan-kernel`
//! (asserted in `tests/pipeline_equivalence.rs`, re-run by the CI
//! determinism matrix). A damaged chunk surfaces as a chunk-indexed
//! error after the run (`workload::TraceWorkload::take_error`), never
//! as a silently truncated report.
//!
//! The chunk directory also enables **sharded replay**
//! (`replay --shard i/N`): shard i opens the file, seeks straight to
//! its contiguous chunk range `[i·C/N, (i+1)·C/N)` — O(1), no serial
//! parse of earlier shards — and replays only those events, emitting
//! its own `SimReport`. Shards partition the directory exactly, so
//! per-shard `accesses` / `alloc_events` sum to the full-replay
//! totals (asserted in `tests/pipeline_equivalence.rs` and a CI
//! smoke); cache and tracker state reset per shard, so miss counts
//! are legitimately not additive. Sharding needs the v2 directory: a
//! v1 or JSONL trace gets a structured "re-record as v2" error, and
//! an out-of-range `i/N` is rejected up front.
//!
//! ## Hot path anatomy
//!
//! One `Access` event costs, in order: the cache walk
//! (`cache::CacheHierarchy::access`), on a miss a `pool_of` lookup
//! (MRU hit in the common case) plus a staged bin delta, and the
//! epoch-boundary check. Everything else — the bulk scatter, the
//! analyzer call, policy hooks — is amortized per batch or per epoch.
//!
//! The per-*epoch* cost splits into pump work (event accounting into
//! `EpochBins`) and analysis work (the queueing scans over the frozen
//! `[P, B]` histograms). Serially those alternate on one thread;
//! `--pipeline` overlaps them, so epoch wall-clock approaches
//! max(pump, analyze) instead of pump + analyze — the same shape as
//! the streaming decode-ahead, one layer up, and the two compose: a
//! pipelined streaming replay runs decode → pump → analyze three
//! threads deep. `benches/hotpath.rs` `pipeline_overlap` measures
//! both regimes (pump-heavy: long epochs, analysis is the small
//! fraction; analyze-heavy: short epochs, analysis dominates) and
//! reports the hidden fraction via `overlap_frac`.
//!
//! Inside the analyzer, the last serial structure was the two queueing
//! recurrences `q_i = max(q_{i-1} + d_i, 0)` — a loop-carried max per
//! time bin that defeats autovectorization. The default `blocked`
//! kernel (`runtime::native`, `SimConfig::scan_kernel`) removes it:
//! per [`runtime::native::SCAN_BLOCK`]-lane block the backlog is
//! computed branch-free as `q_i = max(P_i − min_{t≤i} P_t, carry +
//! P_i)` from a log-step prefix sum `P` and prefix min — valid
//! because the carry (the previous block's last backlog) is always
//! ≥ 0, which is the **block-boundary invariant**: one scalar f32 is
//! the only state crossing blocks, so the 4-round shifted-op networks
//! inside a block vectorize freely. The descendant-mask matmul is
//! folded into the same block loop, so `ev`, the served stream, and
//! byte demand stay in registers instead of round-tripping an `[S, B]`
//! scratch array. The reformulation is associative in exact
//! arithmetic but *reassociates f32 adds*, so the scalar `exact`
//! kernel remains in the tree as the reference: it reproduces
//! `artifacts/golden.json` (and the HLO) bit-for-bit, anchors the CI
//! determinism matrix, and bounds `blocked` through ULP/relative
//! differential property tests (`runtime::native` tests,
//! `tests/pipeline_equivalence.rs`).
//!
//! `benches/hotpath.rs` measures each stage against its kept-runnable
//! baseline (per-event pump vs batched, `pool_of_btree` vs fast path,
//! `record` vs `record_bulk`, scalar vs fused batch analyze, `exact`
//! vs `blocked` scan kernels, group-16 vs group-256 batched replay,
//! 1-thread vs pooled multihost, serial vs pipelined epoch
//! execution) and writes `BENCH_hotpath.json` so
//! the perf trajectory is tracked across PRs (CI uploads it per run,
//! in `HOTPATH_SMOKE` mode, and `tools/bench_gate.py` fails >25%
//! regressions against `rust/BENCH_baseline.json`).
//!
//! ## Scenario sweeps & paper-figure reproduction
//!
//! `cxlmemsim sweep examples/specs/table1.toml` expands a TOML
//! (topology × policy × workload × knob) grid into cells (`sweep`
//! module), executes them across a work-stealing cell pool — the
//! multihost queue pattern, one level up — and writes ONE JSON
//! comparison artifact: per-cell reports (stripped of wall-clock /
//! scheduling keys, so artifacts are byte-identical for any worker
//! count), deltas vs a named `[baseline]` cell, and `[[invariant]]`
//! verdicts. The invariants are the coarse accuracy harness: they pin
//! relative delay *orderings* across topologies (direct ≤ fig2 ≤
//! deep, …) rather than absolute nanoseconds, and a violated ordering
//! fails the sweep — a regression suite for the simulation model.
//! The same engine drives the multi-process `replay --shard i/N`
//! fan-out (`shards = N` cells launch N child processes and merge
//! their reports through `coordinator::report::merge_shard_json`) and
//! multihost cells. Committed specs under `examples/specs/` map the
//! paper's figures to one command each (`docs/REPRODUCING.md`).
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use cxlmemsim::prelude::*;
//!
//! let topo = cxlmemsim::topology::builtin::fig2();
//! let mut cfg = SimConfig::default();
//! cfg.scale = 0.01;
//! let mut sim = Coordinator::new(topo, cfg).unwrap();
//! let report = sim.run_workload("mmap_read").unwrap();
//! println!("{}", report.summary());
//! ```

pub mod alloctrack;
pub mod cache;
pub mod coordinator;
pub mod fault;
pub mod gem5like;
pub mod metrics;
pub mod multihost;
pub mod policy;
pub mod runtime;
pub mod sweep;
pub mod topology;
pub mod trace;
pub mod util;
pub mod workload;

/// Most-used types, one import away.
pub mod prelude {
    pub use crate::alloctrack::{AllocTracker, PolicyKind};
    pub use crate::coordinator::{Coordinator, SimConfig, SimReport};
    pub use crate::fault::{FaultError, FaultOverlay, FaultPlan, FaultState};
    pub use crate::policy::{EpochPolicy, PolicySpec, PolicyStack};
    pub use crate::runtime::{AnalyzerBackend, ScanKernel, TimingInputs, TimingOutputs};
    pub use crate::sweep::{SweepError, SweepOptions, SweepSpec};
    pub use crate::topology::{builtin, Topology, TopoTensors};
    pub use crate::trace::stream::TraceStream;
    pub use crate::workload::{
        by_name as workload_by_name, TraceWorkload, Workload, TABLE1_WORKLOADS,
    };
}

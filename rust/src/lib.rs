//! # CXLMemSim — a pure-software simulated CXL.mem
//!
//! Reproduction of *"CXLMemSim: A pure software simulated CXL.mem for
//! performance characterization"* (Yang et al., 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: topology management,
//!   the tracer substrate (workload engine + cache hierarchy + alloc
//!   tracker), the epoch loop, delay injection, the detailed `gem5like`
//!   baseline, and the CLI.
//! * **Layer 2** — the timing analyzer as a JAX graph
//!   (`python/compile/model.py`), AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Layer 1** — the per-switch queueing scan as a Pallas kernel
//!   (`python/compile/kernels/queue_scan.py`).
//!
//! Python never runs at simulation time: with the `pjrt` cargo feature,
//! `runtime` loads the HLO artifacts through PJRT (`xla` crate) and
//! executes them per epoch; the default build uses the pure-rust
//! `native` mirror of the same math and needs no artifacts at all.
//!
//! ## The batched event pipeline
//!
//! The tracer substrate is the product: the paper's claim is epoch
//! sampling running orders of magnitude faster than cycle-accurate
//! simulation, so per-event overhead is the whole game. The hot path is
//! organized around three ideas (see `coordinator::driver`):
//!
//! * **Batched event flow** — `Workload::next_batch` emits runs of
//!   events through one virtual call (all built-in workloads implement
//!   native run-length emission), and the `EpochDriver` pump iterates a
//!   plain `Vec<WlEvent>`: a monomorphic inner loop instead of one dyn
//!   dispatch per event. `SimConfig::event_batch = 1` recovers the
//!   legacy per-event loop as a measurable baseline, with bit-identical
//!   simulation output (`tests/pipeline_equivalence.rs`).
//! * **Tracer fast paths** — `AllocTracker::pool_of` (one call per LLC
//!   miss) answers through a one-entry MRU region cache backed by a
//!   lazily rebuilt flat interval index (binary search), instead of a
//!   `BTreeMap::range` walk per miss; misses have strong spatial
//!   locality so the MRU entry absorbs the vast majority of lookups.
//! * **One epoch driver for the epoch-sampling modes** — the
//!   sequential coordinator and the grouped-analyzer replay
//!   (`coordinator::run_batched`) share one `EpochDriver`, differing
//!   only in their `EpochFlush` strategy, so accounting semantics
//!   (prefetcher traffic, sampling, write-backs, epoch policies) land
//!   once for both. The `gem5like` detailed baseline keeps its own
//!   event-accounting loop by design (it models a different machine)
//!   but adopts the same batched pump. The multihost runner shards its
//!   per-epoch host phase across OS threads and merges per-host bins
//!   deterministically at the epoch barrier.
//!
//! `benches/hotpath.rs` measures all three against their baselines
//! (per-event pump, `pool_of_btree`) and writes the numbers to
//! `BENCH_hotpath.json` so the perf trajectory is tracked across PRs.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use cxlmemsim::prelude::*;
//!
//! let topo = cxlmemsim::topology::builtin::fig2();
//! let mut cfg = SimConfig::default();
//! cfg.scale = 0.01;
//! let mut sim = Coordinator::new(topo, cfg).unwrap();
//! let report = sim.run_workload("mmap_read").unwrap();
//! println!("{}", report.summary());
//! ```

pub mod alloctrack;
pub mod cache;
pub mod coordinator;
pub mod gem5like;
pub mod metrics;
pub mod multihost;
pub mod policy;
pub mod runtime;
pub mod topology;
pub mod trace;
pub mod util;
pub mod workload;

/// Most-used types, one import away.
pub mod prelude {
    pub use crate::alloctrack::{AllocTracker, PolicyKind};
    pub use crate::coordinator::{Coordinator, SimConfig, SimReport};
    pub use crate::runtime::{AnalyzerBackend, TimingInputs, TimingOutputs};
    pub use crate::topology::{builtin, Topology, TopoTensors};
    pub use crate::workload::{by_name as workload_by_name, Workload, TABLE1_WORKLOADS};
}

//! # CXLMemSim — a pure-software simulated CXL.mem
//!
//! Reproduction of *"CXLMemSim: A pure software simulated CXL.mem for
//! performance characterization"* (Yang et al., 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: topology management,
//!   the tracer substrate (workload engine + cache hierarchy + alloc
//!   tracker), the epoch loop, delay injection, the detailed `gem5like`
//!   baseline, and the CLI.
//! * **Layer 2** — the timing analyzer as a JAX graph
//!   (`python/compile/model.py`), AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Layer 1** — the per-switch queueing scan as a Pallas kernel
//!   (`python/compile/kernels/queue_scan.py`).
//!
//! Python never runs at simulation time: `runtime` loads the HLO
//! artifacts through PJRT (`xla` crate) and executes them per epoch.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use cxlmemsim::prelude::*;
//!
//! let topo = cxlmemsim::topology::builtin::fig2();
//! let mut cfg = SimConfig::default();
//! cfg.scale = 0.01;
//! let mut sim = Coordinator::new(topo, cfg).unwrap();
//! let report = sim.run_workload("mmap_read").unwrap();
//! println!("{}", report.summary());
//! ```

pub mod alloctrack;
pub mod cache;
pub mod coordinator;
pub mod gem5like;
pub mod metrics;
pub mod multihost;
pub mod policy;
pub mod runtime;
pub mod topology;
pub mod trace;
pub mod util;
pub mod workload;

/// Most-used types, one import away.
pub mod prelude {
    pub use crate::alloctrack::{AllocTracker, PolicyKind};
    pub use crate::coordinator::{Coordinator, SimConfig, SimReport};
    pub use crate::runtime::{AnalyzerBackend, TimingInputs, TimingOutputs};
    pub use crate::topology::{builtin, Topology, TopoTensors};
    pub use crate::workload::{by_name as workload_by_name, Workload, TABLE1_WORKLOADS};
}

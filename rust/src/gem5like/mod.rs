//! `gem5like` — the detailed event-driven baseline (Table 1's "Gem5"
//! column substitute).
//!
//! The paper compares CXLMemSim against a gem5 syscall-emulation CXL
//! model [3]; gem5 is unavailable here, so this module implements an
//! honest detailed simulator with the fidelity/cost profile of one:
//!
//!   * every access walks the full cache hierarchy (same `cache`
//!     substrate as the coordinator);
//!   * every LLC miss becomes a *packet* that traverses its pool's
//!     switch path hop by hop, **flit by flit** (64 B line = 8 flits of
//!     8 B, like PCIe/CXL serialization) through a global event queue
//!     (`BinaryHeap`) with exact per-hop busy-until bookkeeping and a
//!     bounded MSHR window limiting memory-level parallelism;
//!   * writebacks are full packets too.
//!
//! The per-event heap traffic is what makes detailed simulators slow —
//! and why the paper's epoch-sampling design wins (Table 1: gem5 is
//! ~100-3000× native; CXLMemSim ~4-40×). This module reproduces that
//! shape, and doubles as an *accuracy* reference for the epoch model
//! (bench `fig_accuracy`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::alloctrack::AllocTracker;
use crate::cache::{AccessOutcome, CacheHierarchy};
use crate::topology::Topology;
use crate::trace::WlEvent;
use crate::workload::Workload;

/// Flit size through CXL links, bytes.
const FLIT_BYTES: u64 = 8;
/// Maximum outstanding misses (MSHR entries).
const MSHRS: usize = 16;
/// Reorder-buffer capacity (Golden Cove: 512).
const ROB_ENTRIES: usize = 512;
/// Non-memory instructions modelled between consecutive accesses
/// (gem5 SE simulates every instruction; this is the detailed model's
/// per-instruction pipeline bookkeeping).
const INSTS_PER_ACCESS: usize = 3;

/// Minimal out-of-order core model: a reorder buffer of completion
/// times with in-order retirement. Every instruction (memory or ALU)
/// allocates an entry; a full ROB stalls dispatch until the head
/// retires — the same structural bookkeeping a gem5 O3 CPU performs
/// per instruction, and a large part of why detailed simulation is
/// orders of magnitude slower than epoch sampling.
struct Rob {
    /// completion times, ring buffer in program order
    slots: Vec<f64>,
    head: usize,
    len: usize,
}

impl Rob {
    fn new() -> Rob {
        Rob { slots: vec![0.0; ROB_ENTRIES], head: 0, len: 0 }
    }

    /// Dispatch one instruction completing at `done`; returns the time
    /// dispatch could proceed (>= now if the ROB head stalled us).
    #[inline]
    fn dispatch(&mut self, now: f64, done: f64) -> f64 {
        let mut t = now;
        if self.len == ROB_ENTRIES {
            // stall until the oldest instruction retires
            let oldest = self.slots[self.head];
            if oldest > t {
                t = oldest;
            }
            self.head = (self.head + 1) % ROB_ENTRIES;
            self.len -= 1;
        }
        let tail = (self.head + self.len) % ROB_ENTRIES;
        self.slots[tail] = done;
        self.len += 1;
        t
    }

    /// Retire every instruction complete at `now` (head-first, in order).
    #[inline]
    fn retire(&mut self, now: f64) {
        while self.len > 0 && self.slots[self.head] <= now {
            self.head = (self.head + 1) % ROB_ENTRIES;
            self.len -= 1;
        }
    }

    fn drain(&mut self, now: f64) -> f64 {
        let mut t = now;
        while self.len > 0 {
            let c = self.slots[self.head];
            if c > t {
                t = c;
            }
            self.head = (self.head + 1) % ROB_ENTRIES;
            self.len -= 1;
        }
        t
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
struct Ev {
    /// Completion time, ns.
    t: f64,
    /// Packet id (for MSHR retirement ordering).
    id: u64,
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on time
        other.t.partial_cmp(&self.t).unwrap_or(Ordering::Equal)
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Clone, Debug, Default)]
pub struct DetailedReport {
    pub workload: String,
    pub topology: String,
    /// Simulated execution time, ns.
    pub simulated_ns: f64,
    pub wall_s: f64,
    pub accesses: u64,
    pub instructions: u64,
    pub misses: u64,
    pub packets: u64,
    pub flit_events: u64,
    /// Time packets spent queued behind busy hops, ns (congestion).
    pub queue_wait_ns: f64,
}

pub struct DetailedSim {
    topo: Topology,
    cache: CacheHierarchy,
    tracker: AllocTracker,
    /// busy-until per topology node, ns.
    busy_until: Vec<f64>,
    /// per-node service time per flit, ns (stt scaled to flit).
    flit_service: Vec<f64>,
    cpi_ns: f64,
    /// monotone event-id source for the event queue.
    evseq: u64,
}

impl DetailedSim {
    pub fn new(
        topo: Topology,
        cache_scale: u64,
        policy: crate::alloctrack::PolicyKind,
    ) -> DetailedSim {
        let tracker = AllocTracker::new(&topo, policy.build(&topo));
        let n = topo.nodes().len();
        let line = topo.host.cacheline_bytes;
        let flits = (line / FLIT_BYTES).max(1) as f64;
        // per-flit serialization: node STT is per full event (line)
        let flit_service: Vec<f64> =
            topo.nodes().iter().map(|nd| nd.stt_ns / flits).collect();
        DetailedSim {
            topo,
            cache: CacheHierarchy::scaled(cache_scale),
            tracker,
            busy_until: vec![0.0; n],
            flit_service,
            cpi_ns: 0.3,
            evseq: 0,
        }
    }

    /// Serialize one packet (a full cacheline) through the pool's path,
    /// flit by flit, starting no earlier than `start`; returns (finish
    /// time, queue wait, flit events).
    ///
    /// Every flit-hop transfer is a *discrete event* scheduled through
    /// the simulator's event queue (`evq`) — exactly the bookkeeping a
    /// gem5-style simulator performs, and the reason detailed models
    /// are orders of magnitude slower than epoch sampling: a single
    /// LLC miss through a 3-hop path costs 24 schedule/dispatch pairs.
    fn send_packet(
        &mut self,
        evq: &mut BinaryHeap<Ev>,
        pool: usize,
        start: f64,
        is_write: bool,
    ) -> (f64, f64, u64) {
        let path = self.topo.path_to_root(pool);
        if path.is_empty() {
            // local DRAM: flat latency, no queueing
            let lat = if is_write {
                self.topo.host.local_write_latency_ns
            } else {
                self.topo.host.local_read_latency_ns
            };
            return (start + lat, 0.0, 0);
        }
        let flits = (self.topo.host.cacheline_bytes / FLIT_BYTES).max(1);
        let mut t = start;
        let mut wait = 0.0;
        let mut events = 0u64;
        // propagation latency of the whole path (one-way request +
        // response folded into per-hop read/write latencies)
        let prop: f64 = path
            .iter()
            .map(|&n| {
                if is_write {
                    self.topo.nodes()[n].write_latency_ns
                } else {
                    self.topo.nodes()[n].read_latency_ns
                }
            })
            .sum();
        // serialization: each hop transmits `flits` flits; each flit
        // occupies the hop for flit_service ns; hops pipeline per flit.
        // The transfer cascade runs through the event queue: schedule
        // the flit-hop completion, then dispatch it (pop) to drive the
        // next leg — the event-driven structure gem5 uses.
        for f in 0..flits {
            let _ = f;
            for &node in path.iter().rev() {
                let free = self.busy_until[node];
                let begin = if free > t {
                    wait += free - t;
                    free
                } else {
                    t
                };
                let svc = self.flit_service[node].max(1e-3);
                self.busy_until[node] = begin + svc;
                self.evseq += 1;
                evq.push(Ev { t: begin + svc, id: self.evseq });
                // dispatch the earliest pending event (this flit unless
                // an older in-flight completion precedes it)
                if let Some(done) = evq.pop() {
                    t = t.max(done.t).max(begin + svc);
                } else {
                    t = begin + svc;
                }
                events += 1;
            }
        }
        (t + prop, wait, events)
    }

    /// Run a workload to completion through the detailed model.
    pub fn run(&mut self, wl: &mut dyn Workload) -> DetailedReport {
        let wall_start = std::time::Instant::now();
        let mut rep = DetailedReport {
            workload: wl.name().to_string(),
            topology: self.topo.name.clone(),
            ..Default::default()
        };
        // outstanding-miss window: completion times of in-flight packets
        let mut mshr: BinaryHeap<Ev> = BinaryHeap::new();
        // global flit event queue (schedule/dispatch per flit-hop)
        let mut evq: BinaryHeap<Ev> = BinaryHeap::new();
        // per-instruction pipeline model
        let mut rob = Rob::new();
        let mut now = 0.0f64;
        let mut pkt_id = 0u64;

        // batched event pump: pull events through the workload's native
        // batched emission so the (already expensive) detailed model
        // does not also pay a virtual call per event
        let mut buf: Vec<WlEvent> =
            Vec::with_capacity(crate::coordinator::DEFAULT_EVENT_BATCH);
        let mut more = true;
        while more {
            buf.clear();
            more = wl.next_batch(&mut buf, crate::coordinator::DEFAULT_EVENT_BATCH);
            for i in 0..buf.len() {
            let ev = buf[i];
            match ev {
                WlEvent::Alloc(mut a) => {
                    a.t_ns = now;
                    self.tracker.on_alloc_event(&a);
                    now += 1_000.0;
                }
                WlEvent::Access(a) => {
                    rep.accesses += 1;
                    // the ALU instructions between accesses go through
                    // the pipeline one by one (gem5 SE fidelity)
                    for i in 0..INSTS_PER_ACCESS {
                        rep.instructions += 1;
                        let done = now + self.cpi_ns * (1.0 + (i as f64) * 0.1);
                        now = rob.dispatch(now, done);
                        rob.retire(now);
                    }
                    let outcome = self.cache.access(a.addr, a.is_write);
                    rep.instructions += 1;
                    let mem_done = now + self.cache.hit_latency_ns(outcome);
                    now = rob.dispatch(now, mem_done);
                    rob.retire(now);
                    now += self.cpi_ns + self.cache.hit_latency_ns(outcome);
                    if let AccessOutcome::Miss { writeback } = outcome {
                        rep.misses += 1;
                        // MSHR full: stall until the oldest retires
                        while mshr.len() >= MSHRS {
                            let done = mshr.pop().unwrap();
                            if done.t > now {
                                now = done.t;
                            }
                        }
                        let pool = self.tracker.pool_of(a.addr);
                        if pool == crate::topology::LOCAL_POOL {
                            // local DRAM miss: flat latency, no CXL packet
                            now += if a.is_write {
                                self.topo.host.local_write_latency_ns
                            } else {
                                self.topo.host.local_read_latency_ns
                            };
                        } else {
                            let (finish, wait, flits) =
                                self.send_packet(&mut evq, pool, now, a.is_write);
                            rep.packets += 1;
                            rep.flit_events += flits;
                            rep.queue_wait_ns += wait;
                            pkt_id += 1;
                            mshr.push(Ev { t: finish, id: pkt_id });
                            // a dependent load: the core stalls for the data
                            if !a.is_write {
                                now = finish.max(now);
                            }
                        }
                        if let Some(wb) = writeback {
                            let wb_pool = self.tracker.pool_of(wb);
                            if wb_pool == crate::topology::LOCAL_POOL {
                                // local write-back: absorbed by the
                                // memory controller, no CXL traffic
                            } else {
                                let (f2, w2, fl2) =
                                    self.send_packet(&mut evq, wb_pool, now, true);
                                rep.packets += 1;
                                rep.flit_events += fl2;
                                rep.queue_wait_ns += w2;
                                pkt_id += 1;
                                mshr.push(Ev { t: f2, id: pkt_id });
                            }
                        }
                    }
                }
            }
            }
        }
        // drain
        while let Some(done) = mshr.pop() {
            if done.t > now {
                now = done.t;
            }
        }
        now = rob.drain(now);
        rep.simulated_ns = now;
        rep.wall_s = wall_start.elapsed().as_secs_f64();
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloctrack::PolicyKind;
    use crate::topology::builtin;
    use crate::workload;

    fn run(topo: Topology, wl_name: &str) -> DetailedReport {
        let mut sim = DetailedSim::new(topo, 64, PolicyKind::CxlOnly);
        let mut wl = workload::by_name(wl_name, 0.002, 3).unwrap();
        sim.run(wl.as_mut())
    }

    #[test]
    fn runs_and_counts() {
        let rep = run(builtin::fig2(), "mmap_write");
        assert!(rep.accesses > 0);
        assert!(rep.misses > 0);
        assert!(rep.packets >= rep.misses);
        assert!(rep.flit_events > rep.packets, "flit-level serialization expected");
        assert!(rep.simulated_ns > 0.0);
    }

    #[test]
    fn deep_topology_slower_than_direct() {
        let d = run(builtin::direct(), "mmap_write");
        let deep = run(builtin::deep(), "mmap_write");
        assert!(
            deep.simulated_ns > d.simulated_ns,
            "deep {} <= direct {}",
            deep.simulated_ns,
            d.simulated_ns
        );
    }

    #[test]
    fn local_policy_has_no_queue_wait() {
        let mut sim = DetailedSim::new(builtin::fig2(), 64, PolicyKind::LocalOnly);
        let mut wl = workload::by_name("stream", 0.002, 3).unwrap();
        let rep = sim.run(wl.as_mut());
        assert_eq!(rep.packets, 0, "local misses don't create CXL packets");
        assert_eq!(rep.queue_wait_ns, 0.0);
    }

    #[test]
    fn congestion_appears_under_bursts() {
        let rep = run(builtin::fig2(), "stream");
        assert!(rep.queue_wait_ns > 0.0, "streaming misses must queue at the switch");
    }

    #[test]
    fn detailed_is_slower_than_it_looks() {
        // sanity: flit events dominate -> detailed work per miss is
        // (hops * flits) heap-adjacent operations, >= 8 per miss here.
        let rep = run(builtin::deep(), "uniform");
        assert!(rep.flit_events as f64 / rep.packets.max(1) as f64 >= 8.0);
    }
}

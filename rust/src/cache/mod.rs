//! Cache hierarchy substrate — the PEBS substitute.
//!
//! Intel PEBS delivers (address, rw, timestamp) tuples for sampled
//! LLC-miss events. Without PEBS, CXLMemSim derives the same stream by
//! running the workload's virtual address trace through a simulated
//! inclusive L1/L2/LLC hierarchy (set-associative, LRU, write-allocate,
//! write-back). Dirty evictions emit a write event against the evicted
//! line's pool, matching how a real CXL device observes write-backs.
//!
//! Geometry defaults to the paper's i9-12900K testbed (30 MB LLC); the
//! `scaled` constructor shrinks everything for fast tests/benches.

pub mod prefetch;
pub mod set_assoc;

pub use prefetch::{Prefetcher, PrefetchStats};
pub use set_assoc::SetAssocCache;

/// Outcome of one access against the full hierarchy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AccessOutcome {
    L1Hit,
    L2Hit,
    LlcHit,
    /// LLC miss: goes to memory. `writeback` carries the dirty victim
    /// line's address if the LLC eviction was dirty.
    Miss { writeback: Option<u64> },
}

/// Latency (ns) the core observes for each hit level; the *memory*
/// latency is supplied by the topology, not here.
#[derive(Clone, Copy, Debug)]
pub struct HitLatencies {
    pub l1_ns: f64,
    pub l2_ns: f64,
    pub llc_ns: f64,
}

impl Default for HitLatencies {
    fn default() -> Self {
        // Golden Cove-ish: 5 cyc L1 / 15 cyc L2 / ~60 cyc LLC @5GHz.
        HitLatencies { l1_ns: 1.0, l2_ns: 3.0, llc_ns: 12.0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub llc_hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Three-level inclusive hierarchy.
#[derive(Clone, Debug)]
pub struct CacheHierarchy {
    pub l1: SetAssocCache,
    pub l2: SetAssocCache,
    pub llc: SetAssocCache,
    pub lat: HitLatencies,
    pub stats: CacheStats,
    line_bytes: u64,
}

impl CacheHierarchy {
    /// The paper's testbed: 48 KB/12-way L1D, 1.25 MB/10-way L2,
    /// 30 MB/12-way shared LLC, 64 B lines.
    pub fn i9_12900k() -> CacheHierarchy {
        CacheHierarchy::new(
            SetAssocCache::new(48 << 10, 12, 64),
            SetAssocCache::new(1_310_720, 10, 64),
            SetAssocCache::new(30 << 20, 12, 64),
            HitLatencies::default(),
        )
    }

    /// Geometry scaled down by `factor` (same associativity); used by
    /// tests and fast benches so working sets overflow quickly.
    pub fn scaled(factor: u64) -> CacheHierarchy {
        let f = factor.max(1);
        CacheHierarchy::new(
            SetAssocCache::new((48 << 10) / f, 12, 64),
            SetAssocCache::new(1_310_720 / f, 10, 64),
            SetAssocCache::new((30 << 20) / f, 12, 64),
            HitLatencies::default(),
        )
    }

    pub fn new(
        l1: SetAssocCache,
        l2: SetAssocCache,
        llc: SetAssocCache,
        lat: HitLatencies,
    ) -> CacheHierarchy {
        let line = llc.line_bytes();
        assert_eq!(l1.line_bytes(), line);
        assert_eq!(l2.line_bytes(), line);
        CacheHierarchy { l1, l2, llc, lat, stats: CacheStats::default(), line_bytes: line }
    }

    pub fn line_bytes(&self) -> u64 {
        self.line_bytes
    }

    /// Run one access through the hierarchy. Returns the outcome; the
    /// caller converts `Miss` into a PEBS-style sample.
    #[inline]
    pub fn access(&mut self, addr: u64, is_write: bool) -> AccessOutcome {
        self.stats.accesses += 1;
        let line = addr / self.line_bytes;

        if self.l1.probe(line, is_write) {
            self.stats.l1_hits += 1;
            return AccessOutcome::L1Hit;
        }
        if self.l2.probe(line, is_write) {
            // fill upward; L1 victim may be dirty but stays inside the
            // hierarchy (absorbed by L2 inclusivity), no memory traffic.
            self.l1.fill(line, is_write);
            self.stats.l2_hits += 1;
            return AccessOutcome::L2Hit;
        }
        if self.llc.probe(line, is_write) {
            self.l2.fill(line, is_write);
            self.l1.fill(line, is_write);
            self.stats.llc_hits += 1;
            return AccessOutcome::LlcHit;
        }

        // LLC miss: fill all levels; LLC eviction may write back and, by
        // inclusion, invalidates the line in L1/L2 (dirty state there is
        // folded into the write-back decision).
        self.stats.misses += 1;
        let victim = self.llc.fill(line, is_write);
        let mut writeback = None;
        if let Some(v) = victim {
            let inner_dirty = self.l1.invalidate(v.line) | self.l2.invalidate(v.line);
            if v.dirty || inner_dirty {
                self.stats.writebacks += 1;
                writeback = Some(v.line * self.line_bytes);
            }
        }
        self.l2.fill(line, is_write);
        self.l1.fill(line, is_write);
        AccessOutcome::Miss { writeback }
    }

    /// Hit latency for an outcome (misses get topology latency added by
    /// the caller).
    pub fn hit_latency_ns(&self, outcome: AccessOutcome) -> f64 {
        match outcome {
            AccessOutcome::L1Hit => self.lat.l1_ns,
            AccessOutcome::L2Hit => self.lat.l2_ns,
            AccessOutcome::LlcHit => self.lat.llc_ns,
            AccessOutcome::Miss { .. } => self.lat.llc_ns, // + memory latency by caller
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Coherence back-invalidation: drop `addr`'s line from every level
    /// (a peer host wrote the shared line). Returns whether any copy
    /// was present — i.e. whether an invalidation message was needed.
    pub fn coherence_invalidate(&mut self, addr: u64) -> bool {
        let line = addr / self.line_bytes;
        let present =
            self.l1.contains(line) || self.l2.contains(line) || self.llc.contains(line);
        if present {
            self.l1.invalidate(line);
            self.l2.invalidate(line);
            self.llc.invalidate(line);
        }
        present
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CacheHierarchy {
        // 4-set/2-way L1 (512B), 16-set/2-way L2 (2KB), 64-set/4-way LLC (16KB)
        CacheHierarchy::new(
            SetAssocCache::new(512, 2, 64),
            SetAssocCache::new(2048, 2, 64),
            SetAssocCache::new(16384, 4, 64),
            HitLatencies::default(),
        )
    }

    #[test]
    fn first_touch_misses_then_hits() {
        let mut h = tiny();
        assert!(matches!(h.access(0x1000, false), AccessOutcome::Miss { .. }));
        assert_eq!(h.access(0x1000, false), AccessOutcome::L1Hit);
        assert_eq!(h.access(0x1008, false), AccessOutcome::L1Hit); // same line
        assert_eq!(h.stats.misses, 1);
        assert_eq!(h.stats.l1_hits, 2);
    }

    #[test]
    fn llc_overflow_generates_misses() {
        let mut h = tiny();
        // touch 16x the LLC capacity sequentially, twice
        let lines = 16384 / 64 * 16;
        for round in 0..2 {
            for i in 0..lines {
                h.access(i * 64, false);
            }
            let _ = round;
        }
        // streaming working set >> LLC: second round must still miss
        assert!(h.stats.misses as u64 > lines, "misses={}", h.stats.misses);
    }

    #[test]
    fn small_working_set_fits_after_warmup() {
        let mut h = tiny();
        // 8 lines fit in L1 (512B = 8 lines)
        for _ in 0..10 {
            for i in 0..8 {
                h.access(i * 64, false);
            }
        }
        assert_eq!(h.stats.misses, 8); // only compulsory misses
    }

    #[test]
    fn dirty_eviction_emits_writeback() {
        let mut h = tiny();
        // write a line, then stream reads over it to force eviction
        h.access(0x0, true);
        let mut saw_wb = false;
        for i in 1..4096u64 {
            if let AccessOutcome::Miss { writeback: Some(wb) } = h.access(i * 64, false) {
                if wb == 0 {
                    saw_wb = true;
                }
            }
        }
        assert!(saw_wb, "dirty line 0 never written back");
        assert!(h.stats.writebacks > 0);
    }

    #[test]
    fn clean_stream_never_writes_back() {
        let mut h = tiny();
        for i in 0..8192u64 {
            h.access(i * 64, false);
        }
        assert_eq!(h.stats.writebacks, 0);
    }

    #[test]
    fn latencies_are_ordered() {
        let h = tiny();
        assert!(h.hit_latency_ns(AccessOutcome::L1Hit) < h.hit_latency_ns(AccessOutcome::L2Hit));
        assert!(h.hit_latency_ns(AccessOutcome::L2Hit) < h.hit_latency_ns(AccessOutcome::LlcHit));
    }

    #[test]
    fn i9_geometry_sizes() {
        // sets round down to a power of two, so the realized LLC is in
        // (15, 30] MB — 24 MB for the 30 MB/12-way nominal geometry.
        let h = CacheHierarchy::i9_12900k();
        assert!(h.llc.capacity_bytes() <= 30 << 20);
        assert!(h.llc.capacity_bytes() > 15 << 20);
        assert_eq!(h.line_bytes(), 64);
    }

    #[test]
    fn miss_rate_sane() {
        let mut h = tiny();
        for i in 0..1000u64 {
            h.access((i % 4) * 64, false);
        }
        assert!(h.stats.miss_rate() < 0.01);
    }
}

//! Hardware prefetcher models (paper §1: "the system will enable a
//! comparison of software and hardware memory prefetching").
//!
//! Two classic L2-adjacent prefetchers:
//!
//! * [`NextLinePrefetcher`] — on a miss, fetch the next N lines;
//! * [`StridePrefetcher`] — a PC-less stride table keyed by line
//!   region, detecting constant-stride streams (what Intel's "AMP"
//!   does for streaming code).
//!
//! Prefetches are issued into the hierarchy as non-demand fills: they
//! do not stall the core, but they *do* transit the CXL link — the
//! coordinator bins them as prefetch traffic, so a prefetcher can
//! trade latency delay for bandwidth delay exactly as the paper's
//! research agenda anticipates.

use super::CacheHierarchy;

/// A prefetch decision: lines to fetch after the current access.
pub trait Prefetcher: Send {
    fn name(&self) -> &'static str;
    /// Observe a demand access (post-cache); return line addresses to
    /// prefetch (byte addresses, line-aligned).
    fn observe(&mut self, addr: u64, was_miss: bool) -> Vec<u64>;
    fn stats(&self) -> PrefetchStats;
}

#[derive(Clone, Copy, Debug, Default)]
pub struct PrefetchStats {
    pub issued: u64,
    pub useful_hint: u64,
}

/// Fetch the next `degree` sequential lines on every demand miss.
pub struct NextLinePrefetcher {
    degree: usize,
    line_bytes: u64,
    stats: PrefetchStats,
}

impl NextLinePrefetcher {
    pub fn new(degree: usize, line_bytes: u64) -> Self {
        NextLinePrefetcher { degree: degree.max(1), line_bytes, stats: PrefetchStats::default() }
    }
}

impl Prefetcher for NextLinePrefetcher {
    fn name(&self) -> &'static str {
        "nextline"
    }

    fn observe(&mut self, addr: u64, was_miss: bool) -> Vec<u64> {
        if !was_miss {
            return Vec::new();
        }
        let line = addr / self.line_bytes;
        self.stats.issued += self.degree as u64;
        (1..=self.degree as u64)
            .map(|i| (line + i) * self.line_bytes)
            .collect()
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// Region-based stride detector: tracks the last address and stride per
/// 4 KB region in a small direct-mapped table; two confirmations arm it.
pub struct StridePrefetcher {
    line_bytes: u64,
    degree: usize,
    /// (region_tag, last_line, stride, confidence)
    table: Vec<(u64, u64, i64, u8)>,
    stats: PrefetchStats,
}

const STRIDE_TABLE: usize = 256;

impl StridePrefetcher {
    pub fn new(degree: usize, line_bytes: u64) -> Self {
        StridePrefetcher {
            line_bytes,
            degree: degree.max(1),
            table: vec![(u64::MAX, 0, 0, 0); STRIDE_TABLE],
            stats: PrefetchStats::default(),
        }
    }
}

impl Prefetcher for StridePrefetcher {
    fn name(&self) -> &'static str {
        "stride"
    }

    fn observe(&mut self, addr: u64, _was_miss: bool) -> Vec<u64> {
        let line = addr / self.line_bytes;
        let region = addr >> 12;
        let slot = (region as usize) & (STRIDE_TABLE - 1);
        let (tag, last, stride, conf) = self.table[slot];
        let mut out = Vec::new();
        if tag == region {
            let new_stride = line as i64 - last as i64;
            if new_stride == stride && new_stride != 0 {
                let conf = conf.saturating_add(1);
                self.table[slot] = (region, line, stride, conf);
                if conf >= 2 {
                    // armed: prefetch degree lines ahead along the stride
                    for i in 1..=self.degree as i64 {
                        let target = line as i64 + new_stride * i;
                        if target > 0 {
                            out.push(target as u64 * self.line_bytes);
                        }
                    }
                    self.stats.issued += out.len() as u64;
                    self.stats.useful_hint += 1;
                }
            } else {
                self.table[slot] = (region, line, new_stride, 1);
            }
        } else {
            self.table[slot] = (region, line, 0, 0);
        }
        out
    }

    fn stats(&self) -> PrefetchStats {
        self.stats
    }
}

/// Issue prefetches into the hierarchy as non-demand fills; returns how
/// many actually missed (i.e. generated memory/CXL traffic).
pub fn issue_prefetches(cache: &mut CacheHierarchy, targets: &[u64]) -> Vec<u64> {
    let mut fetched = Vec::new();
    for &t in targets {
        let line = t / cache.line_bytes();
        // only fetch if not already cached anywhere
        if !cache.llc.contains(line) && !cache.l2.contains(line) && !cache.l1.contains(line) {
            cache.llc.fill(line, false);
            cache.l2.fill(line, false);
            fetched.push(t);
        }
    }
    fetched
}

/// Named constructors for CLI / experiments.
pub fn by_name(name: &str, line_bytes: u64) -> Option<Box<dyn Prefetcher>> {
    match name {
        "nextline" => Some(Box::new(NextLinePrefetcher::new(2, line_bytes))),
        "stride" => Some(Box::new(StridePrefetcher::new(4, line_bytes))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheHierarchy;

    #[test]
    fn nextline_fires_on_miss_only() {
        let mut p = NextLinePrefetcher::new(2, 64);
        assert!(p.observe(0x1000, false).is_empty());
        let t = p.observe(0x1000, true);
        assert_eq!(t, vec![0x1040, 0x1080]);
    }

    #[test]
    fn stride_detects_constant_stride() {
        let mut p = StridePrefetcher::new(2, 64);
        // stride of 2 lines within one region
        assert!(p.observe(0x0, true).is_empty()); // allocate entry
        assert!(p.observe(0x80, true).is_empty()); // stride=2 recorded
        let t = p.observe(0x100, true); // second confirmation arms it
        assert!(!t.is_empty(), "stride must arm after two confirmations");
        assert_eq!(t[0], 0x100 + 0x80);
        let t = p.observe(0x180, true); // stays armed
        assert_eq!(t[0], 0x180 + 0x80);
    }

    #[test]
    fn stride_ignores_random_pattern() {
        let mut p = StridePrefetcher::new(2, 64);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut issued = 0;
        for _ in 0..1000 {
            issued += p.observe(rng.below(1 << 28) & !63, true).len();
        }
        assert!(issued < 50, "random traffic should rarely arm the stride table");
    }

    #[test]
    fn issue_prefetches_fills_and_dedups() {
        let mut h = CacheHierarchy::scaled(64);
        let t = issue_prefetches(&mut h, &[0x1000, 0x1040]);
        assert_eq!(t.len(), 2);
        // second issue: already resident, no traffic
        let t = issue_prefetches(&mut h, &[0x1000, 0x1040]);
        assert!(t.is_empty());
        // demand access now hits below L1 (filled to L2/LLC)
        use crate::cache::AccessOutcome;
        assert!(matches!(h.access(0x1000, false), AccessOutcome::L2Hit));
    }

    #[test]
    fn by_name_registry() {
        assert!(by_name("nextline", 64).is_some());
        assert!(by_name("stride", 64).is_some());
        assert!(by_name("oracle", 64).is_none());
    }
}

//! One set-associative cache level with true-LRU replacement.
//!
//! Tags are stored in a flat `Vec<u64>` (0 = invalid; tags are stored
//! +1 so line 0 is representable), LRU as a per-way u64 stamp from a
//! global monotone counter. Associativity is small (<= 16) so the
//! per-set scans are cheap and branch-predictable; this level is on the
//! per-access hot path of both the coordinator and the gem5like
//! baseline, so no per-access allocation happens here.

/// Victim returned by `fill` when a valid line is evicted.
#[derive(Clone, Copy, Debug)]
pub struct Victim {
    pub line: u64,
    pub dirty: bool,
}

#[derive(Clone, Debug)]
pub struct SetAssocCache {
    sets: usize,
    ways: usize,
    line_bytes: u64,
    /// Interleaved [tag0, meta0, tag1, meta1, ...] per set, where
    /// tag = line+1 (0 = invalid) and meta = stamp << 1 | dirty.
    /// One sequential scan touches ~3 cache lines per 12-way set versus
    /// 5-6 with parallel tag/stamp/dirty arrays (§Perf iteration 3).
    slots: Vec<u64>,
    tick: u64,
}

impl SetAssocCache {
    /// `capacity_bytes` is rounded down to a whole number of sets; sets
    /// are forced to a power of two for cheap indexing.
    pub fn new(capacity_bytes: u64, ways: usize, line_bytes: u64) -> SetAssocCache {
        assert!(ways >= 1 && line_bytes.is_power_of_two());
        let raw_sets = (capacity_bytes / line_bytes / ways as u64).max(1);
        let sets = (raw_sets.next_power_of_two() >> if raw_sets.is_power_of_two() { 0 } else { 1 })
            .max(1) as usize;
        SetAssocCache {
            sets,
            ways,
            line_bytes,
            slots: vec![0; sets * ways * 2],
            tick: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn line_bytes(&self) -> u64 {
        self.line_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.sets as u64 * self.ways as u64 * self.line_bytes
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Look up a line; on hit, refresh LRU and (for writes) set dirty.
    #[inline]
    pub fn probe(&mut self, line: u64, is_write: bool) -> bool {
        let base = self.set_of(line) * self.ways * 2;
        let tag = line + 1;
        let slots = &mut self.slots[base..base + self.ways * 2];
        for w in 0..self.ways {
            if slots[w * 2] == tag {
                self.tick += 1;
                let dirty = (slots[w * 2 + 1] & 1) | (is_write as u64);
                slots[w * 2 + 1] = self.tick << 1 | dirty;
                return true;
            }
        }
        false
    }

    /// Insert a line (after a miss), evicting LRU if needed. Returns the
    /// victim if a valid line was displaced. If the line is already
    /// present this refreshes it instead (idempotent fill).
    #[inline]
    pub fn fill(&mut self, line: u64, is_write: bool) -> Option<Victim> {
        let base = self.set_of(line) * self.ways * 2;
        let tag = line + 1;
        self.tick += 1;
        let tick = self.tick;
        let slots = &mut self.slots[base..base + self.ways * 2];
        // single pass: find the line, a free way, and the LRU way
        let mut free: Option<usize> = None;
        let mut lru = 0usize;
        let mut lru_stamp = u64::MAX;
        for w in 0..self.ways {
            let t = slots[w * 2];
            if t == tag {
                let dirty = (slots[w * 2 + 1] & 1) | (is_write as u64);
                slots[w * 2 + 1] = tick << 1 | dirty;
                return None;
            }
            if t == 0 {
                if free.is_none() {
                    free = Some(w);
                }
            } else {
                let stamp = slots[w * 2 + 1] >> 1;
                if stamp < lru_stamp {
                    lru_stamp = stamp;
                    lru = w;
                }
            }
        }
        if let Some(w) = free {
            slots[w * 2] = tag;
            slots[w * 2 + 1] = tick << 1 | is_write as u64;
            return None;
        }
        let victim = Victim {
            line: slots[lru * 2] - 1,
            dirty: slots[lru * 2 + 1] & 1 != 0,
        };
        slots[lru * 2] = tag;
        slots[lru * 2 + 1] = tick << 1 | is_write as u64;
        Some(victim)
    }

    /// Remove a line if present (inclusion enforcement). Returns whether
    /// the invalidated copy was dirty.
    #[inline]
    pub fn invalidate(&mut self, line: u64) -> bool {
        let base = self.set_of(line) * self.ways * 2;
        let tag = line + 1;
        let slots = &mut self.slots[base..base + self.ways * 2];
        for w in 0..self.ways {
            if slots[w * 2] == tag {
                let was_dirty = slots[w * 2 + 1] & 1 != 0;
                slots[w * 2] = 0;
                slots[w * 2 + 1] = 0;
                return was_dirty;
            }
        }
        false
    }

    /// Non-mutating presence check (coherence probes).
    #[inline]
    pub fn contains(&self, line: u64) -> bool {
        let base = self.set_of(line) * self.ways * 2;
        let tag = line + 1;
        (0..self.ways).any(|w| self.slots[base + w * 2] == tag)
    }

    /// Number of valid lines (tests only; O(size)).
    pub fn occupancy(&self) -> usize {
        self.slots.chunks_exact(2).filter(|s| s[0] != 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_power_of_two_sets() {
        let c = SetAssocCache::new(48 << 10, 12, 64);
        assert!(c.sets().is_power_of_two());
        assert!(c.capacity_bytes() <= 48 << 10);
    }

    #[test]
    fn probe_miss_then_fill_then_hit() {
        let mut c = SetAssocCache::new(1024, 2, 64);
        assert!(!c.probe(7, false));
        assert!(c.fill(7, false).is_none());
        assert!(c.probe(7, false));
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = SetAssocCache::new(2 * 64, 2, 64); // 1 set, 2 ways
        assert_eq!(c.sets(), 1);
        c.fill(1, false);
        c.fill(2, false);
        c.probe(1, false); // 1 is now MRU
        let v = c.fill(3, false).expect("must evict");
        assert_eq!(v.line, 2);
        assert!(c.probe(1, false));
        assert!(c.probe(3, false));
        assert!(!c.probe(2, false));
    }

    #[test]
    fn dirty_bit_tracks_writes() {
        let mut c = SetAssocCache::new(2 * 64, 2, 64);
        c.fill(1, true);
        c.fill(2, false);
        let v = c.fill(3, false).unwrap(); // evicts 1 (LRU)
        assert_eq!(v.line, 1);
        assert!(v.dirty);
    }

    #[test]
    fn write_probe_dirties_line() {
        let mut c = SetAssocCache::new(2 * 64, 2, 64);
        c.fill(1, false);
        c.probe(1, true);
        c.fill(2, false);
        let v = c.fill(3, false).unwrap();
        assert!(v.dirty, "write-probe must dirty the line");
    }

    #[test]
    fn invalidate_removes_and_reports_dirty() {
        let mut c = SetAssocCache::new(1024, 2, 64);
        c.fill(9, true);
        assert!(c.invalidate(9));
        assert!(!c.probe(9, false));
        assert!(!c.invalidate(9)); // second time: not present
    }

    #[test]
    fn fill_is_idempotent() {
        let mut c = SetAssocCache::new(1024, 2, 64);
        c.fill(5, false);
        assert!(c.fill(5, true).is_none()); // refresh, no eviction
        assert_eq!(c.occupancy(), 1);
    }

    #[test]
    fn line_zero_is_representable() {
        let mut c = SetAssocCache::new(1024, 2, 64);
        c.fill(0, true);
        assert!(c.probe(0, false));
        assert!(c.invalidate(0));
    }

    #[test]
    fn sets_map_distinct_lines() {
        let mut c = SetAssocCache::new(4 * 64, 1, 64); // 4 sets, direct-mapped
        for line in 0..4 {
            c.fill(line, false);
        }
        assert_eq!(c.occupancy(), 4);
        for line in 0..4 {
            assert!(c.probe(line, false));
        }
    }
}
